"""L2 model tests: shapes, flat-theta layout, training dynamics, custom VJP."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.ModelConfig(
    vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=16, batch=4
)


def synthetic_tokens(cfg, rng, n_batches=1):
    """Repeating-pattern corpus: learnable next-token structure."""
    period = 7
    base = rng.integers(0, cfg.vocab, period)
    out = []
    for _ in range(n_batches):
        start = rng.integers(0, period, cfg.batch)
        rows = [
            [int(base[(s + t) % period]) for t in range(cfg.seq_len)]
            for s in start
        ]
        out.append(np.array(rows, np.int32))
    return out


def test_param_layout_consistent():
    names = [n for n, _ in model.param_layout(CFG)]
    assert len(names) == len(set(names))
    theta = jnp.arange(model.param_count(CFG), dtype=jnp.float32)
    parts = model.unpack(CFG, theta)
    total = sum(int(np.prod(v.shape)) for v in parts.values())
    assert total == model.param_count(CFG)
    # Slices tile theta exactly, in order, with no gaps.
    offset = 0
    for name, shape in model.param_layout(CFG):
        n = int(np.prod(shape))
        np.testing.assert_array_equal(
            np.asarray(parts[name]).ravel(),
            np.arange(offset, offset + n, dtype=np.float32),
        )
        offset += n


def test_init_params_deterministic_and_layout_aware():
    init = model.make_init_params(CFG)
    t1 = np.asarray(init(jnp.uint32(7)))
    t2 = np.asarray(init(jnp.uint32(7)))
    t3 = np.asarray(init(jnp.uint32(8)))
    np.testing.assert_array_equal(t1, t2)
    assert not np.array_equal(t1, t3)
    parts = model.unpack(CFG, jnp.asarray(t1))
    np.testing.assert_array_equal(np.asarray(parts["l0.ln1_scale"]), 1.0)
    np.testing.assert_array_equal(np.asarray(parts["l0.b1"]), 0.0)
    assert np.abs(np.asarray(parts["embed"])).max() < 0.2


def test_forward_shape_and_finiteness():
    init = model.make_init_params(CFG)
    theta = init(jnp.uint32(0))
    rng = np.random.default_rng(0)
    (tokens,) = synthetic_tokens(CFG, rng)
    logits = model.forward(CFG, theta, jnp.asarray(tokens))
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality():
    """Changing a future token must not change past logits."""
    init = model.make_init_params(CFG)
    theta = init(jnp.uint32(3))
    rng = np.random.default_rng(1)
    (tokens,) = synthetic_tokens(CFG, rng)
    tokens2 = tokens.copy()
    tokens2[:, -1] = (tokens2[:, -1] + 1) % CFG.vocab
    l1 = model.forward(CFG, theta, jnp.asarray(tokens))
    l2 = model.forward(CFG, theta, jnp.asarray(tokens2))
    np.testing.assert_allclose(
        np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=1e-5
    )


def test_train_step_decreases_loss():
    init = model.make_init_params(CFG)
    step = jax.jit(model.make_train_step(CFG))
    theta = init(jnp.uint32(0))
    rng = np.random.default_rng(42)
    batches = synthetic_tokens(CFG, rng, n_batches=30)
    losses = []
    for tokens in batches:
        theta, loss = step(theta, jnp.asarray(tokens), jnp.float32(0.1))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.8, losses


def test_custom_vjp_matches_plain_jnp_grads():
    """Grads through the Pallas matmul == grads through jnp.matmul."""

    def loss_pallas(theta, tokens):
        return model.loss_fn(CFG, theta, tokens)

    # Re-create the model computation with jnp matmul instead of pmatmul.
    def loss_plain(theta, tokens):
        orig = model.pmatmul
        # monkeypatch-free: call the internals with a swapped _dense
        saved = model._dense
        model._dense = lambda x2d, w: jnp.matmul(x2d, w)
        try:
            return model.loss_fn(CFG, theta, tokens)
        finally:
            model._dense = saved

    init = model.make_init_params(CFG)
    theta = init(jnp.uint32(5))
    rng = np.random.default_rng(5)
    (tokens,) = synthetic_tokens(CFG, rng)
    g1 = jax.grad(loss_pallas)(theta, jnp.asarray(tokens))
    g2 = jax.grad(loss_plain)(theta, jnp.asarray(tokens))
    np.testing.assert_allclose(
        np.asarray(g1), np.asarray(g2), rtol=1e-3, atol=1e-5
    )


def test_eval_loss_matches_train_step_loss():
    init = model.make_init_params(CFG)
    ev = jax.jit(model.make_eval_loss(CFG))
    step = jax.jit(model.make_train_step(CFG))
    theta = init(jnp.uint32(9))
    rng = np.random.default_rng(9)
    (tokens,) = synthetic_tokens(CFG, rng)
    _, l_step = step(theta, jnp.asarray(tokens), jnp.float32(0.0))
    l_eval = ev(theta, jnp.asarray(tokens))
    assert float(l_step) == pytest.approx(float(l_eval), rel=1e-5)
