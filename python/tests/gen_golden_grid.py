"""Seed generator for ``golden_waste_grid.json`` — the f64 golden grid the
kernel cross-check (``test_golden_grid.py``) compares against.

The *authoritative* producer of this file is the Rust CLI::

    cargo run --release -- export-grid --out python/tests/golden_waste_grid.json

which emits the batched model's f64 clipped surfaces (bit-identical to the
scalar ``model::waste::waste_clipped``).  This script is the documented
fallback for environments without a Rust toolchain: it mirrors the Rust
expressions term-for-term in pure-python IEEE-754 doubles — the same
operation trees in the same association order — so its output matches the
Rust export to the last ulp (and the committed file can be refreshed from
either side).  CI always regenerates from Rust before running the test.

Usage: ``python tests/gen_golden_grid.py [out.json]``
"""

import sys

# Paper constants (rust/src/util.rs::paper).
SECONDS_PER_YEAR = 365.0 * 24.0 * 3600.0
MU_IND_YEARS = 125.0
C = 600.0
R = 600.0
D = 60.0

ABS_TOL = 2e-4  # waste_grid::CROSSCHECK_ABS_TOL
REL_TOL = 1e-4  # waste_grid::CROSSCHECK_REL_TOL


def scenario(procs, cp_ratio, recall, precision, window):
    """Mirror of Platform::paper / PredictorSpec::paper_{a,b} (f64)."""
    mu = MU_IND_YEARS * SECONDS_PER_YEAR / float(procs)
    return {
        "mu": mu,
        "c": C,
        "cp": cp_ratio * C,
        "d": D,
        "r": R,
        "p": precision,
        "rec": recall,
        "i": window,
        "e": window / 2.0,  # PredModel::Paper: E_I^f = I/2
    }


def battery():
    """The export-grid scenario battery, in its exact loop order."""
    out = []
    for procs in (1 << 16, 1 << 18):
        for cp_ratio in (1.0, 0.1):
            for window in (300.0, 1200.0):
                for recall, precision in ((0.85, 0.82), (0.7, 0.4)):
                    out.append(scenario(procs, cp_ratio, recall, precision, window))
    return out


# -- closed forms, mirroring rust/src/model/waste.rs expression-for-expression


def tp_extr(s):
    """model::optimal::tp_extr — clamp(sqrt(((1-p)I + pE) Cp / p), Cp, max(I, Cp))."""
    p, i, e, cp = s["p"], s["i"], s["e"], s["cp"]
    raw = (((1.0 - p) * i + p * e) * cp / p) ** 0.5
    return min(max(raw, cp), max(i, cp))


def q0(s, tr):
    return 1.0 - (1.0 - s["c"] / tr) * (1.0 - (tr / 2.0 + s["d"] + s["r"]) / s["mu"])


def instant(s, tr):
    p, r = s["p"], s["rec"]
    inner = (
        p * (s["d"] + s["r"]) + r * s["cp"] + (1.0 - r) * p * tr / 2.0 + p * r * s["e"]
    ) / (p * s["mu"])
    return 1.0 - (1.0 - s["c"] / tr) * (1.0 - inner)


def nockpt(s, tr):
    p, r, i, e = s["p"], s["rec"], s["i"], s["e"]
    head = (r / (p * s["mu"])) * (1.0 - p) * i
    inner = (
        p * (s["d"] + s["r"]) + r * s["cp"] + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e)
    ) / (p * s["mu"])
    return 1.0 - head - (1.0 - s["c"] / tr) * (1.0 - inner)


def withckpt(s, tr, tp):
    p, r, i, e = s["p"], s["rec"], s["i"], s["e"]
    head = (r / (p * s["mu"])) * (1.0 - s["cp"] / tp) * ((1.0 - p) * i + p * (e - tp))
    inner = (
        p * (s["d"] + s["r"]) + r * s["cp"] + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e)
    ) / (p * s["mu"])
    return 1.0 - head - (1.0 - s["c"] / tr) * (1.0 - inner)


def clipped_surface(s, grid):
    """model::waste::waste_clipped over the grid, all four strategies."""
    tp = tp_extr(s)
    rows = [[], [], [], []]
    for tr in grid:
        if tr <= s["c"]:
            for row in rows:
                row.append(1.0)
            continue
        for row, raw in zip(
            rows, (q0(s, tr), instant(s, tr), nockpt(s, tr), withckpt(s, tr, tp))
        ):
            row.append(min(max(raw, 0.0), 1.0))
    return rows


# -- serialization matching rust/src/jsonio.rs (sorted keys, compact,
#    integral floats written without a decimal point)


def jnum(x):
    if float(x) == int(x) and abs(x) < 1e15:
        return str(int(x))
    return repr(float(x))


def jval(v):
    if isinstance(v, str):
        return '"' + v + '"'
    if isinstance(v, (int, float)):
        return jnum(v)
    if isinstance(v, list):
        return "[" + ",".join(jval(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(
            jval(k) + ":" + jval(v[k]) for k in sorted(v)
        ) + "}"
    raise TypeError(type(v))


def main(out_path):
    grid = [650.0 + 900.0 * k for k in range(48)]
    scs = battery()
    doc = {
        "schema": "ckptwin-golden-grid/1",
        "strategies": ["q0", "instant", "nockpt", "withckpt"],
        "tolerance": {"abs": ABS_TOL, "rel": REL_TOL},
        "tr": grid,
        "params": [
            [s["mu"], s["c"], s["cp"], s["d"], s["r"], s["p"], s["rec"],
             s["i"], s["e"], 0.0]
            for s in scs
        ],
        "surfaces": [clipped_surface(s, grid) for s in scs],
    }
    text = jval(doc)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"wrote {out_path} — {len(scs)} scenarios × 4 × {len(grid)} "
          f"({len(text)} bytes)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "tests/golden_waste_grid.json")
