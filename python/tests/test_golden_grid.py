"""Kernel cross-check against the Rust-exported golden waste grid.

``golden_waste_grid.json`` holds the Rust batched model's f64 clipped
surfaces (``ckptwin export-grid``; bit-identical to the scalar
``model::waste::waste_clipped``).  Both python implementations — the
pure-jnp oracle and the Pallas kernel — must reproduce every cell within
the priced f32 tolerance ``abs + rel·|w|`` carried inside the file
(mirrors ``runtime::waste_grid::CROSSCHECK_{ABS,REL}_TOL``): this is the
other direction of the Rust-side ``crosscheck_waste_grid`` gate, closing
the loop between the two backends without a PJRT artifact build.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.waste_grid import waste_grid

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_waste_grid.json")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        doc = json.load(f)
    assert doc["schema"] == "ckptwin-golden-grid/1"
    return doc


def _check(got, doc):
    """Element-wise |kernel − golden| ≤ abs + rel·|golden|."""
    want = np.asarray(doc["surfaces"], np.float64)  # [B, 4, G]
    got = np.asarray(got, np.float64)
    assert got.shape == want.shape
    tol = doc["tolerance"]["abs"] + doc["tolerance"]["rel"] * np.abs(want)
    err = np.abs(got - want)
    worst = np.unravel_index(np.argmax(err - tol), err.shape)
    assert (err <= tol).all(), (
        f"worst cell {worst}: got {got[worst]}, golden {want[worst]}, "
        f"|err| {err[worst]:.3e} > tol {tol[worst]:.3e}"
    )


def test_golden_grid_shape(golden):
    b, g = len(golden["params"]), len(golden["tr"])
    assert golden["strategies"] == ["q0", "instant", "nockpt", "withckpt"]
    assert len(golden["surfaces"]) == b
    assert all(
        len(s) == 4 and all(len(row) == g for row in s)
        for s in golden["surfaces"]
    )
    # Golden wastes are clipped: all in [0, 1].
    surf = np.asarray(golden["surfaces"])
    assert (surf >= 0.0).all() and (surf <= 1.0).all()


def test_ref_matches_golden(golden):
    params = np.asarray(golden["params"], np.float32)
    tr = np.asarray(golden["tr"], np.float32)
    got = ref.waste_grid_ref(params, tr)
    _check(got, golden)


def test_pallas_kernel_matches_golden(golden):
    params = np.asarray(golden["params"], np.float32)
    tr = np.asarray(golden["tr"], np.float32)
    got = waste_grid(jnp.asarray(params), jnp.asarray(tr), block_g=8)
    _check(got, golden)
