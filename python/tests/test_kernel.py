"""Kernel-vs-ref correctness: the CORE signal of the compile path.

Hypothesis sweeps the waste-grid Pallas kernel's shapes and parameter ranges
against the pure-jnp oracle in ``kernels/ref.py``, plus fixed-value checks
against hand-computed paper quantities.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.waste_grid import waste_grid

# Paper constants (Section 4.1).
C = 600.0
R = 600.0
D = 60.0
MU_IND_YEARS = 125.0
SECONDS_PER_YEAR = 365.0 * 24.0 * 3600.0


def paper_mu(n_procs: int) -> float:
    return MU_IND_YEARS * SECONDS_PER_YEAR / n_procs


def make_params(mu, c, cp, d, rr, p, r, i, e=None):
    e = i / 2.0 if e is None else e
    return np.array([[mu, c, cp, d, rr, p, r, i, e, 0.0]], np.float32)


scenario_st = st.tuples(
    st.floats(2e3, 5e6),      # mu
    st.floats(30.0, 1200.0),  # C
    st.floats(3.0, 2400.0),   # Cp
    st.floats(0.0, 600.0),    # D
    st.floats(0.0, 1200.0),   # R
    st.floats(0.05, 1.0),     # p
    st.floats(0.05, 1.0),     # r
    st.floats(10.0, 7200.0),  # I
)


@settings(max_examples=25, deadline=None)
@given(
    scenarios=st.lists(scenario_st, min_size=1, max_size=5),
    block_g=st.sampled_from([8, 32, 128]),
    n_blocks=st.integers(1, 4),
)
def test_waste_grid_matches_ref(scenarios, block_g, n_blocks):
    params = np.array(
        [[mu, c, cp, d, rr, p, r, i, i / 2.0, 0.0]
         for (mu, c, cp, d, rr, p, r, i) in scenarios],
        np.float32,
    )
    g = block_g * n_blocks
    tr = np.linspace(100.0, 50_000.0, g).astype(np.float32)
    got = waste_grid(jnp.asarray(params), jnp.asarray(tr), block_g=block_g)
    want = ref.waste_grid_ref(params, tr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_waste_values_paper_scenario():
    """Hand-check Eq. 3 at the paper's 2^16-processor scenario."""
    mu = paper_mu(2**16)  # ~60k s
    params = make_params(mu, C, C, D, R, 0.82, 0.85, 300.0)
    # RFO optimum Tr = sqrt(2 C (mu - (D + R)))
    tr_opt = math.sqrt(2.0 * C * (mu - (D + R)))
    tr = np.full(8, tr_opt, np.float32)
    out = np.asarray(waste_grid(jnp.asarray(params), jnp.asarray(tr), block_g=8))
    expected = 1.0 - (1.0 - C / tr_opt) * (1.0 - (tr_opt / 2 + D + R) / mu)
    np.testing.assert_allclose(out[0, 0], expected, rtol=1e-5)
    # Prediction-aware wastes must beat q=0 at this small window size.
    assert out[0, 1, 0] < out[0, 0, 0]
    assert out[0, 2, 0] < out[0, 0, 0]


def test_waste_grid_invalid_period_is_one():
    params = make_params(paper_mu(2**16), C, C, D, R, 0.82, 0.85, 600.0)
    tr = np.array([100.0, 300.0, C, C + 1.0, 2000.0, 3000.0, 4000.0, 5000.0],
                  np.float32)
    out = np.asarray(waste_grid(jnp.asarray(params), jnp.asarray(tr), block_g=8))
    assert (out[:, :, :3] == 1.0).all()   # T_R <= C
    assert (out[:, :, 3:] < 1.0).all()


def test_tp_extr_matches_simplified_formula():
    """With E = I/2, T_P^extr = sqrt((2-p) I Cp / (2p)).

    Note: the paper's §3.2 "simplified" display writes sqrt((2-p)I Cp / p),
    but substituting E = I/2 into its own general formula
    sqrt(((1-p)I + pE) Cp / p) gives (1-p)I + pI/2 = (2-p)I/2 — the display
    drops the factor 2.  We follow the general formula (Eq. before §3.3).
    """
    p, i, cp = 0.82, 3000.0, 60.0
    got = float(ref.tp_extr(jnp.float32(cp), jnp.float32(p),
                            jnp.float32(i), jnp.float32(i / 2)))
    want = math.sqrt((2.0 - p) * i * cp / (2.0 * p))
    assert got == pytest.approx(want, rel=1e-6)


def test_tp_extr_clamped_to_window():
    # Huge Cp: the raw extremum exceeds I and must clamp at max(Cp, I).
    got = float(ref.tp_extr(jnp.float32(1200.0), jnp.float32(0.4),
                            jnp.float32(300.0), jnp.float32(150.0)))
    assert got == pytest.approx(1200.0)
    # Tiny Cp with tiny window: lower clamp at Cp.
    got = float(ref.tp_extr(jnp.float32(10.0), jnp.float32(0.99),
                            jnp.float32(1.0), jnp.float32(0.5)))
    assert got == pytest.approx(10.0)
