"""AOT lowering sanity: every artifact parses and carries expected shapes."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_waste_grid_lowering_text():
    lowered = aot.lower_waste_grid()
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert f"f32[{aot.WASTE_B},4,{aot.WASTE_G}]" in text.replace(" ", "")


def test_init_params_lowering_text():
    cfg = model.ModelConfig(
        vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64,
        seq_len=16, batch=4,
    )
    lowered = aot.lower_init_params(cfg)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert f"f32[{model.param_count(cfg)}]" in text


def test_train_step_lowering_roundtrip_numerics():
    """Executing the lowered train step == executing the jitted function."""
    cfg = model.ModelConfig(
        vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64,
        seq_len=16, batch=4,
    )
    step = model.make_train_step(cfg)
    theta = model.make_init_params(cfg)(jnp.uint32(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab,
                                          (cfg.batch, cfg.seq_len), np.int32)
    )
    lr = jnp.float32(0.05)
    direct_theta, direct_loss = jax.jit(step)(theta, tokens, lr)
    compiled = jax.jit(step).lower(theta, tokens, lr).compile()
    aot_theta, aot_loss = compiled(theta, tokens, lr)
    np.testing.assert_allclose(np.asarray(direct_theta),
                               np.asarray(aot_theta), rtol=1e-6)
    np.testing.assert_allclose(float(direct_loss), float(aot_loss), rtol=1e-6)
