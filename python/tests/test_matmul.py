"""Blocked-matmul Pallas kernel vs jnp reference."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul


@settings(max_examples=20, deadline=None)
@given(
    m_blocks=st.integers(1, 3),
    n_blocks=st.integers(1, 3),
    k=st.sampled_from([1, 3, 8, 64, 129]),
    block_m=st.sampled_from([8, 16, 32]),
    block_n=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m_blocks, n_blocks, k, block_m, block_n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m_blocks * block_m, k), np.float32)
    y = rng.standard_normal((k, n_blocks * block_n), np.float32)
    got = matmul(jnp.asarray(x), jnp.asarray(y),
                 block_m=block_m, block_n=block_n)
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_matmul_mxu_shape():
    """The production 128x128 blocking on model-sized operands."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 128), np.float32)
    y = rng.standard_normal((128, 512), np.float32)
    got = matmul(jnp.asarray(x), jnp.asarray(y))
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_matmul_small_dims_clamp_block():
    """Blocks clamp down to the operand size when dims < 128."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, 16), np.float32)
    y = rng.standard_normal((16, 8), np.float32)
    got = matmul(jnp.asarray(x), jnp.asarray(y))
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
