"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the Rust
runtime (`rust/src/runtime/`) loads the text with
``HloModuleProto::from_text_file`` and executes via the PJRT CPU client.

HLO **text** — not ``lowered.compile().serialize()`` and not a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``).  The text
parser reassigns ids, so text round-trips cleanly.

Artifacts written to ``--out-dir`` (default ``../artifacts``):

    waste_grid.hlo.txt   (params f32[B,10], tr f32[G]) -> (waste f32[B,4,G],)
    init_params.hlo.txt  (seed u32[])                  -> (theta f32[P],)
    train_step.hlo.txt   (theta, tokens i32[B,S], lr)  -> (theta', loss)
    eval_loss.hlo.txt    (theta, tokens)               -> (loss,)
    manifest.json        shapes + model config, consumed by the Rust runtime
"""

import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed artifact shapes for the waste-grid offload.  The Rust side pads its
# scenario batch and period grid up to these (padded rows use valid dummy
# parameters; padded periods land at > C and are simply ignored).
WASTE_B = 64
WASTE_G = 512


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_waste_grid():
    spec_p = jax.ShapeDtypeStruct((WASTE_B, 10), jnp.float32)
    spec_t = jax.ShapeDtypeStruct((WASTE_G,), jnp.float32)

    def fn(params, tr):
        return (model.waste_surfaces(params, tr),)

    return jax.jit(fn).lower(spec_p, spec_t)


def lower_init_params(cfg):
    init = model.make_init_params(cfg)

    def fn(seed):
        return (init(seed),)

    return jax.jit(fn).lower(jax.ShapeDtypeStruct((), jnp.uint32))


def lower_train_step(cfg):
    step = model.make_train_step(cfg)
    p = model.param_count(cfg)
    spec_theta = jax.ShapeDtypeStruct((p,), jnp.float32)
    spec_tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    spec_lr = jax.ShapeDtypeStruct((), jnp.float32)
    # Donate theta: the update happens in place on the device buffer.
    return jax.jit(step, donate_argnums=(0,)).lower(
        spec_theta, spec_tok, spec_lr
    )


def lower_eval_loss(cfg):
    ev = model.make_eval_loss(cfg)
    p = model.param_count(cfg)
    spec_theta = jax.ShapeDtypeStruct((p,), jnp.float32)
    spec_tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    def fn(theta, tokens):
        return (ev(theta, tokens),)

    return jax.jit(fn).lower(spec_theta, spec_tok)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help="sentinel artifact path")
    parser.add_argument("--d-model", type=int, default=128)
    parser.add_argument("--n-layers", type=int, default=2)
    parser.add_argument("--d-ff", type=int, default=512)
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    cfg = model.ModelConfig(
        d_model=args.d_model, n_layers=args.n_layers, d_ff=args.d_ff
    )

    artifacts = {
        "waste_grid": lower_waste_grid(),
        "init_params": lower_init_params(cfg),
        "train_step": lower_train_step(cfg),
        "eval_loss": lower_eval_loss(cfg),
    }
    for name, lowered in artifacts.items():
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "format": "hlo-text",
        "waste_grid": {"batch": WASTE_B, "grid": WASTE_G, "n_params": 10,
                       "n_strategies": 4},
        "model": dataclasses.asdict(cfg),
        "param_count": model.param_count(cfg),
        "entries": {
            "waste_grid": "waste_grid.hlo.txt",
            "init_params": "init_params.hlo.txt",
            "train_step": "train_step.hlo.txt",
            "eval_loss": "eval_loss.hlo.txt",
        },
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")
    # `make` freshness sentinel: the Makefile tracks model.hlo.txt.
    (out_dir / "model.hlo.txt").write_text(
        "# sentinel; see manifest.json for the real artifact list\n"
    )


if __name__ == "__main__":
    main()
