"""Pallas kernel: blocked matmul for the transformer's dense layers.

MXU-shaped blocking: the Pallas grid tiles (M, N) into (block_m, block_n)
output tiles; each program keeps an x-panel (block_m, K) and a y-panel
(K, block_n) resident in VMEM and accumulates in f32.  For the model sizes
used here (K <= 1024) the panels fit comfortably in VMEM
(128*1024*4 B = 512 KiB per panel), so no K-loop carry is needed; on a real
TPU this is the classic "K-resident" schedule that keeps the MXU busy with
one 128x128xK contraction per program.

Lowered with ``interpret=True``: the emitted HLO is plain dot/reshape ops that
the CPU PJRT client executes at native XLA speed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul(x, y, *, block_m=128, block_n=128):
    """x: f32[M, K] @ y: f32[K, N] -> f32[M, N].

    M must be a multiple of ``block_m`` and N of ``block_n`` (the model picks
    dimensions accordingly; tests sweep other block sizes).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)

    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda im, jn: (im, 0)),
            pl.BlockSpec((k, block_n), lambda im, jn: (0, jn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda im, jn: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
