"""Pallas kernel: batched waste-surface evaluation.

Evaluates the paper's four closed-form wastes (RFO Eq. 3, Instant Eq. 14,
NoCkptI Eq. 10, WithCkptI Eq. 4) for a batch of scenarios over a shared grid
of candidate regular periods ``T_R``.  This is the compute hot-spot of the
BestPeriod analytic search: one kernel launch scores B x G x 4 candidates.

TPU shaping (see DESIGN.md §Hardware-Adaptation): the Pallas grid iterates
over (scenario, period-tile); each program holds one scenario's parameter row
(10 f32) plus one period tile (``block_g`` f32) in VMEM and emits a
(1, 4, block_g) output tile.  Everything is elementwise (VPU work); the kernel
is memory-streaming over scenario rows.  Lowered with ``interpret=True`` so
the resulting HLO runs on the CPU PJRT client (Mosaic custom-calls cannot).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _waste_grid_kernel(params_ref, tr_ref, out_ref):
    """One (scenario, period-tile) program.

    params_ref: f32[1, 10]       — scenario row (see ref.py for layout)
    tr_ref:     f32[block_g]     — candidate T_R tile
    out_ref:    f32[1, 4, block_g]
    """
    row = params_ref[0, :]
    mu, c, cp, d = row[0], row[1], row[2], row[3]
    rr, p, r, i, e = row[4], row[5], row[6], row[7], row[8]

    # Optimal proactive period for WithCkptI, clamped to [Cp, max(Cp, I)].
    tp = jnp.clip(
        jnp.sqrt(((1.0 - p) * i + p * e) * cp / p), cp, jnp.maximum(cp, i)
    )

    t = tr_ref[...]

    # Eq. (3): q = 0 (RFO / prediction-ignoring periodic checkpointing).
    w0 = 1.0 - (1.0 - c / t) * (1.0 - (t / 2.0 + d + rr) / mu)

    # The three q = 1 strategies share the trailing factor of Eqs. 14/10/4.
    inner_instant = (
        p * (d + rr) + r * cp + (1.0 - r) * p * t / 2.0 + p * r * e
    ) / (p * mu)
    w1 = 1.0 - (1.0 - c / t) * (1.0 - inner_instant)

    inner_win = (
        p * (d + rr)
        + r * cp
        + (1.0 - r) * p * t / 2.0
        + r * ((1.0 - p) * i + p * e)
    ) / (p * mu)
    head_nockpt = (r / (p * mu)) * (1.0 - p) * i
    w2 = 1.0 - head_nockpt - (1.0 - c / t) * (1.0 - inner_win)

    head_with = (
        (r / (p * mu)) * (1.0 - cp / tp) * ((1.0 - p) * i + p * (e - tp))
    )
    w3 = 1.0 - head_with - (1.0 - c / t) * (1.0 - inner_win)

    out = jnp.stack([w0, w1, w2, w3], axis=0)  # [4, block_g]
    out = jnp.clip(out, 0.0, 1.0)
    out = jnp.where((t <= c)[None, :], 1.0, out)
    out_ref[0, :, :] = out


@functools.partial(jax.jit, static_argnames=("block_g",))
def waste_grid(params, tr, *, block_g=512):
    """Evaluate waste surfaces for all scenarios x periods x strategies.

    params: f32[B, 10]; tr: f32[G] with G a multiple of ``block_g``
    (pad with any value > C; padded wastes are still well-defined).
    Returns f32[B, 4, G].
    """
    b, n_params = params.shape
    (g,) = tr.shape
    assert n_params == ref.N_PARAMS, params.shape
    assert g % block_g == 0, (g, block_g)

    return pl.pallas_call(
        _waste_grid_kernel,
        grid=(b, g // block_g),
        in_specs=[
            pl.BlockSpec((1, ref.N_PARAMS), lambda ib, ig: (ib, 0)),
            pl.BlockSpec((block_g,), lambda ib, ig: (ig,)),
        ],
        out_specs=pl.BlockSpec(
            (1, ref.N_STRATEGIES, block_g), lambda ib, ig: (ib, 0, ig)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, ref.N_STRATEGIES, g), jnp.float32
        ),
        interpret=True,
    )(params.astype(jnp.float32), tr.astype(jnp.float32))
