"""Pure-jnp correctness oracles for the Pallas kernels.

These implement the paper's closed-form waste expressions (Aupy, Robert,
Vivien, Zaidouni — "Checkpointing strategies with prediction windows", 2013)
directly with jax.numpy, with no Pallas involved.  pytest compares the Pallas
kernels against these, and the Rust closed-form model is validated against the
HLO artifact produced from the kernels, so the three implementations
(jnp ref, Pallas kernel, Rust `model::waste`) must all agree.

Parameter-vector layout (one scenario row, f32[10]):

    idx  name  meaning
    0    mu    platform MTBF (seconds)
    1    C     regular checkpoint duration
    2    Cp    proactive checkpoint duration
    3    D     downtime
    4    R     recovery duration
    5    p     predictor precision
    6    r     predictor recall
    7    I     prediction-window length
    8    E     E_I^f, expected fault position inside the window (usually I/2)
    9    pad   reserved (ignored)

Strategy ordering of the output rows (waste[b, s, g]):

    s=0  RFO / q=0          (Eq. 3)
    s=1  Instant, q=1       (Eq. 14)
    s=2  NoCkptI, q=1       (Eq. 10)
    s=3  WithCkptI, q=1     (Eq. 4, with T_P = clamp(T_P^extr, Cp, max(Cp, I)))

Waste values are clipped to [0, 1]; grid points with T_R <= C are reported as
waste = 1 (an invalid period wastes everything).
"""

import jax.numpy as jnp

# Number of strategies evaluated per scenario (output axis 1).
N_STRATEGIES = 4
# Parameter-vector width (input axis 1).
N_PARAMS = 10


def tp_extr(cp, p, i, e):
    """Optimal proactive period T_P^extr = sqrt(((1-p)I + pE) * Cp / p).

    Clamped to [Cp, max(Cp, I)] as required by the paper (at least one
    proactive checkpoint must fit into the window).
    """
    raw = jnp.sqrt(((1.0 - p) * i + p * e) * cp / p)
    return jnp.clip(raw, cp, jnp.maximum(cp, i))


def waste_q0(tr, mu, c, d, r_rec):
    """Eq. (3): waste of periodic checkpointing ignoring predictions."""
    return 1.0 - (1.0 - c / tr) * (1.0 - (tr / 2.0 + d + r_rec) / mu)


def waste_instant(tr, mu, c, cp, d, rr, p, r, e):
    """Eq. (14): waste of Instant with q=1."""
    inner = (p * (d + rr) + r * cp + (1.0 - r) * p * tr / 2.0 + p * r * e) / (
        p * mu
    )
    return 1.0 - (1.0 - c / tr) * (1.0 - inner)


def waste_nockpt(tr, mu, c, cp, d, rr, p, r, i, e):
    """Eq. (10): waste of NoCkptI with q=1."""
    head = (r / (p * mu)) * (1.0 - p) * i
    inner = (
        p * (d + rr)
        + r * cp
        + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e)
    ) / (p * mu)
    return 1.0 - head - (1.0 - c / tr) * (1.0 - inner)


def waste_withckpt(tr, tp, mu, c, cp, d, rr, p, r, i, e):
    """Eq. (4): waste of WithCkptI with q=1, for a given proactive period tp."""
    head = (r / (p * mu)) * (1.0 - cp / tp) * ((1.0 - p) * i + p * (e - tp))
    inner = (
        p * (d + rr)
        + r * cp
        + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e)
    ) / (p * mu)
    return 1.0 - head - (1.0 - c / tr) * (1.0 - inner)


def waste_grid_ref(params, tr):
    """Reference for the `waste_grid` kernel.

    params: f32[B, 10] scenario rows (layout above).
    tr:     f32[G] candidate regular periods, shared across scenarios.
    returns f32[B, 4, G] clipped wastes.
    """
    params = jnp.asarray(params, jnp.float32)
    tr = jnp.asarray(tr, jnp.float32)
    mu = params[:, 0:1]
    c = params[:, 1:2]
    cp = params[:, 2:3]
    d = params[:, 3:4]
    rr = params[:, 4:5]
    p = params[:, 5:6]
    r = params[:, 6:7]
    i = params[:, 7:8]
    e = params[:, 8:9]
    tp = tp_extr(cp, p, i, e)

    t = tr[None, :]
    w0 = waste_q0(t, mu, c, d, rr)
    w1 = waste_instant(t, mu, c, cp, d, rr, p, r, e)
    w2 = waste_nockpt(t, mu, c, cp, d, rr, p, r, i, e)
    w3 = waste_withckpt(t, tp, mu, c, cp, d, rr, p, r, i, e)

    out = jnp.stack([w0, w1, w2, w3], axis=1)  # [B, 4, G]
    out = jnp.clip(out, 0.0, 1.0)
    invalid = (t <= c)[:, None, :]  # periods not longer than C are invalid
    return jnp.where(invalid, 1.0, out)


def matmul_ref(x, y):
    """Reference for the blocked matmul kernel: plain f32 matmul."""
    return jnp.matmul(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
