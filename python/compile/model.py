"""L2: JAX compute graphs lowered to the AOT artifacts.

Two graph families live here:

1. ``waste_surfaces`` — the analytic waste-surface evaluation used by the
   Rust BestPeriod searcher; thin wrapper around the ``waste_grid`` Pallas
   kernel (L1).

2. A small causal-transformer language model used as the *real workload* of
   the end-to-end checkpointing driver: ``init_params`` / ``train_step`` /
   ``eval_loss``.  All parameters live in ONE flat f32 vector ``theta`` so
   that the Rust coordinator can checkpoint/restore the model state as a
   single blob — exactly what a checkpointing runtime does.  The dense
   layers (attention projections, MLP, output head) run through the Pallas
   blocked-matmul kernel, wired with a custom VJP so the same kernel serves
   the backward pass.

Python only runs at build time: ``aot.py`` lowers these functions to HLO
text once; the Rust runtime loads and executes the artifacts via PJRT.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import matmul as matmul_kernel
from .kernels import waste_grid as waste_grid_kernel


# ---------------------------------------------------------------------------
# Waste surfaces (analytic model offload)
# ---------------------------------------------------------------------------

def waste_surfaces(params, tr):
    """f32[B,10] scenarios x f32[G] periods -> f32[B,4,G] wastes."""
    return waste_grid_kernel.waste_grid(params, tr)


# ---------------------------------------------------------------------------
# Pallas matmul with custom VJP (so fwd AND bwd use the L1 kernel)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def pmatmul(x, y):
    return matmul_kernel.matmul(x, y)


def _pmatmul_fwd(x, y):
    return matmul_kernel.matmul(x, y), (x, y)


def _pmatmul_bwd(res, g):
    x, y = res
    # dx = g @ y^T ; dy = x^T @ g — both through the Pallas kernel.
    dx = matmul_kernel.matmul(g, y.T)
    dy = matmul_kernel.matmul(x.T, g)
    return dx, dy


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


# ---------------------------------------------------------------------------
# Model configuration and flat parameter layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer-LM hyperparameters.

    Dimensions are kept multiples of 128 where they feed the Pallas matmul
    (d_model, d_ff, vocab) and batch*seq is a multiple of 128 as well.
    """

    vocab: int = 256        # byte-level
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 128
    batch: int = 8

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


def param_layout(cfg: ModelConfig):
    """Ordered (name, shape) list defining the flat theta layout."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    layout = [("embed", (v, d)), ("pos", (s, d))]
    for layer in range(cfg.n_layers):
        prefix = f"l{layer}."
        layout += [
            (prefix + "ln1_scale", (d,)),
            (prefix + "ln1_bias", (d,)),
            (prefix + "wq", (d, d)),
            (prefix + "wk", (d, d)),
            (prefix + "wv", (d, d)),
            (prefix + "wo", (d, d)),
            (prefix + "ln2_scale", (d,)),
            (prefix + "ln2_bias", (d,)),
            (prefix + "w1", (d, f)),
            (prefix + "b1", (f,)),
            (prefix + "w2", (f, d)),
            (prefix + "b2", (d,)),
        ]
    layout += [
        ("lnf_scale", (d,)),
        ("lnf_bias", (d,)),
        ("wout", (d, v)),
    ]
    return layout


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_layout(cfg):
        n = 1
        for dim in shape:
            n *= dim
        total += n
    return total


def unpack(cfg: ModelConfig, theta):
    """Slice the flat vector into a {name: array} dict (static offsets)."""
    params = {}
    offset = 0
    for name, shape in param_layout(cfg):
        n = 1
        for dim in shape:
            n *= dim
        params[name] = theta[offset : offset + n].reshape(shape)
        offset += n
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def _dense(x2d, w):
    """(B*S, K) @ (K, N) through the Pallas kernel."""
    return pmatmul(x2d, w)


def _attention(cfg: ModelConfig, x, p, prefix):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x2 = x.reshape(b * s, d)
    q = _dense(x2, p[prefix + "wq"]).reshape(b, s, h, hd)
    k = _dense(x2, p[prefix + "wk"]).reshape(b, s, h, hd)
    v = _dense(x2, p[prefix + "wv"]).reshape(b, s, h, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, d)
    return _dense(ctx, p[prefix + "wo"]).reshape(b, s, d)


def forward(cfg: ModelConfig, theta, tokens):
    """tokens: i32[B, S] -> logits f32[B, S, V]."""
    p = unpack(cfg, theta)
    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos"][None, :s]
    for layer in range(cfg.n_layers):
        prefix = f"l{layer}."
        h = _layer_norm(x, p[prefix + "ln1_scale"], p[prefix + "ln1_bias"])
        x = x + _attention(cfg, h, p, prefix)
        h = _layer_norm(x, p[prefix + "ln2_scale"], p[prefix + "ln2_bias"])
        h2 = h.reshape(b * s, cfg.d_model)
        h2 = jax.nn.gelu(_dense(h2, p[prefix + "w1"]) + p[prefix + "b1"])
        h2 = _dense(h2, p[prefix + "w2"]) + p[prefix + "b2"]
        x = x + h2.reshape(b, s, cfg.d_model)
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = _dense(x.reshape(b * s, cfg.d_model), p["wout"])
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(cfg: ModelConfig, theta, tokens):
    """Next-token cross-entropy over positions 0..S-2."""
    logits = forward(cfg, theta, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Exported entry points (lowered by aot.py)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig):
    """(theta f32[P], tokens i32[B,S], lr f32[]) -> (theta' f32[P], loss f32[])."""

    def train_step(theta, tokens, lr):
        loss, grad = jax.value_and_grad(
            functools.partial(loss_fn, cfg)
        )(theta, tokens)
        return theta - lr * grad, loss

    return train_step


def make_eval_loss(cfg: ModelConfig):
    """(theta f32[P], tokens i32[B,S]) -> loss f32[]."""

    def eval_loss(theta, tokens):
        return loss_fn(cfg, theta, tokens)

    return eval_loss


def make_init_params(cfg: ModelConfig):
    """(seed u32[]) -> theta f32[P]; seeded, so runs reproduce bit-exactly."""

    def init_params(seed):
        key = jax.random.PRNGKey(seed)
        pieces = []
        for name, shape in param_layout(cfg):
            key, sub = jax.random.split(key)
            n = 1
            for dim in shape:
                n *= dim
            if name.endswith("_scale"):
                piece = jnp.ones((n,), jnp.float32)
            elif name.endswith("_bias") or name.endswith("b1") or name.endswith("b2"):
                piece = jnp.zeros((n,), jnp.float32)
            else:
                piece = 0.02 * jax.random.normal(sub, (n,), jnp.float32)
            pieces.append(piece)
        return jnp.concatenate(pieces)

    return init_params
