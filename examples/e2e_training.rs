//! End-to-end driver: train the AOT-compiled transformer LM under fault
//! injection, with the paper's WithCkptI proactive checkpointing, and
//! compare against prediction-ignoring RFO on the *same* fault trace.
//!
//! This exercises the full three-layer stack:
//!   L1 Pallas matmul kernel -> L2 JAX train step -> HLO artifact ->
//!   L3 Rust coordinator (PJRT execution, durable checkpoints, recovery).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_training -- --steps 300
//! ```

use ckptwin::cli::Args;
use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::coordinator::{self, workload::PjrtWorkload, CoordinatorConfig};
use ckptwin::model::optimal;
use ckptwin::runtime::Runtime;
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::{Policy, PolicyKind};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps: u64 = args.get_or("steps", 300);
    let mtbf: f64 = args.get_or("mtbf", 3000.0);
    let seed: u64 = args.get_or("seed", 42);

    let rt = Runtime::discover()?;
    println!(
        "PJRT platform: {} | model: {} params ({} layers of d={} via manifest)",
        rt.platform_name(),
        rt.manifest.param_count,
        "n/a",
        "n/a"
    );

    // Scaled exascale scenario: 1 step = 30 simulated seconds of work.
    let scenario = Scenario {
        platform: Platform { mu: mtbf, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
        predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
        fault_law: Law::Exponential,
        false_pred_law: Law::Exponential,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 0.0,
    };

    let runs: [(&str, PolicyKind, f64); 2] = [
        ("RFO (ignore predictions)", PolicyKind::IgnorePredictions,
            optimal::rfo_period(&scenario.platform)),
        ("WithCkptI (trust predictor)", PolicyKind::WithCkpt,
            optimal::tr_extr_window(&scenario)),
    ];
    let tp = optimal::tp_extr(&scenario).max(scenario.platform.cp * 1.1);

    let mut final_summaries = Vec::new();
    for (name, kind, tr) in runs {
        println!("\n=== {name}: T_R={tr:.0}s T_P={tp:.0}s, MTBF={mtbf}s ===");
        let cfg = CoordinatorConfig {
            scenario,
            policy: Policy { kind, tr, tp },
            seconds_per_step: 30.0,
            total_steps: steps,
            ckpt_dir: format!("results/e2e-{}", name.split(' ').next().unwrap())
                .into(),
            seed,
            log_every: 10,
        };
        let mut workload = PjrtWorkload::new(&rt, seed, 0.1)?;
        let rep = coordinator::run(&cfg, &mut workload)?;

        println!("loss curve (every 50 validated steps):");
        for (step, loss) in &rep.losses {
            if step % 50 == 0 || *step == steps {
                println!("  step {step:>5}  loss {loss:.4}");
            }
        }
        println!(
            "sim makespan {:.0}s | waste {:.4} (model predicts {:.4})",
            rep.sim_makespan, rep.sim_waste, rep.predicted_waste
        );
        println!(
            "faults {} | recoveries {} | reg ckpts {} | pro ckpts {} | steps executed {} (lost {})",
            rep.n_faults, rep.n_recoveries, rep.n_reg_ckpts, rep.n_pro_ckpts,
            rep.steps_executed, rep.steps_lost
        );
        println!(
            "wall {:.1}s -> {:.1} steps/s",
            rep.wall_seconds,
            rep.steps_executed as f64 / rep.wall_seconds
        );
        let first = rep.losses.first().map(|(_, l)| *l).unwrap_or(f32::NAN);
        let last = rep.losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN);
        final_summaries.push((name, rep.sim_waste, first, last));
    }

    println!("\n=== summary (same fault trace) ===");
    for (name, waste, first, last) in &final_summaries {
        println!(
            "{name:<28} waste {waste:.4} | loss {first:.3} -> {last:.3}"
        );
    }
    if final_summaries.len() == 2 {
        let (rfo, aware) = (final_summaries[0].1, final_summaries[1].1);
        println!(
            "prediction-aware scheduling changed waste by {:+.1}% vs RFO",
            (aware / rfo - 1.0) * 100.0
        );
    }
    Ok(())
}
