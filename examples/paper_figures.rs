//! Regenerate the paper's full evaluation: Figures 2–21 and Tables 4–5.
//!
//! ```bash
//! # Smoke pass (few instances):
//! CKPTWIN_INSTANCES=10 cargo run --release --example paper_figures
//! # Paper-accurate (100 instances; slower):
//! cargo run --release --example paper_figures
//! # Subset:
//! cargo run --release --example paper_figures -- --figures 2,14,18 --tables 4
//! ```
//!
//! CSVs land in `results/`; a summary is printed per experiment.

use ckptwin::cli::Args;
use ckptwin::harness::{default_instances, figures, tables};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let instances = default_instances();
    let bp_seeds: usize = args.get_or("best-period-seeds", 10);
    let parse_list = |key: &str| -> Option<Vec<u8>> {
        args.get_str(key).map(|s| {
            s.split(',').filter_map(|t| t.trim().parse().ok()).collect()
        })
    };
    let figure_ids = parse_list("figures").unwrap_or((2..=21).collect());
    let table_ids = parse_list("tables").unwrap_or(vec![4, 5]);

    println!(
        "regenerating {} figures + {} tables at {instances} instances/point\n",
        figure_ids.len(),
        table_ids.len()
    );

    for spec in figures::waste_vs_n_specs() {
        if !figure_ids.contains(&spec.id) {
            continue;
        }
        let t = std::time::Instant::now();
        let rows = figures::run_waste_vs_n(&spec, instances, bp_seeds)
            .expect("figure run");
        println!(
            "figure {:>2} (waste vs N, predictor {}, Cp={}C, {} FPs): {} rows in {:.1}s",
            spec.id,
            if spec.predictor_a { "A" } else { "B" },
            spec.cp_ratio,
            if spec.uniform_false_preds { "uniform" } else { "failure-law" },
            rows.len(),
            t.elapsed().as_secs_f64()
        );
    }

    for spec in figures::waste_vs_tr_specs() {
        if !figure_ids.contains(&spec.id) {
            continue;
        }
        let t = std::time::Instant::now();
        let rows = figures::run_waste_vs_tr(&spec, instances, 24)
            .expect("figure run");
        println!(
            "figure {:>2} (waste vs T_R, predictor {}, N=2^{}): {} rows in {:.1}s",
            spec.id,
            if spec.predictor_a { "A" } else { "B" },
            spec.procs.trailing_zeros(),
            rows.len(),
            t.elapsed().as_secs_f64()
        );
    }

    for spec in figures::waste_vs_i_specs() {
        if !figure_ids.contains(&spec.id) {
            continue;
        }
        let t = std::time::Instant::now();
        let rows = figures::run_waste_vs_i(&spec, instances, bp_seeds)
            .expect("figure run");
        println!(
            "figure {:>2} (waste vs I, predictor {}, N=2^{}): {} rows in {:.1}s",
            spec.id,
            if spec.predictor_a { "A" } else { "B" },
            spec.procs.trailing_zeros(),
            rows.len(),
            t.elapsed().as_secs_f64()
        );
    }

    for &id in &table_ids {
        let shape = if id == 4 { 0.7 } else { 0.5 };
        let t = std::time::Instant::now();
        let table = tables::run_table(id, shape, instances).expect("table run");
        println!("\n{}", tables::render(&table));
        println!("table {id} in {:.1}s", t.elapsed().as_secs_f64());
    }

    println!("\nall outputs under results/");
}
