//! Predictor trade-off study: when is a fault predictor worth trusting?
//!
//! Sweeps (i) the literature predictors surveyed in the paper's Table 6,
//! (ii) a synthetic recall × precision × window grid, and (iii) every
//! window-placement model in the predictor registry, reporting for each
//! the best prediction-aware heuristic vs RFO — reproducing the paper's
//! §4.2 conclusion that below a platform-MTBF threshold (or past a window
//! size) predictions become useless or harmful, and showing how the
//! placement model itself moves the verdict (a late-biased window helps —
//! more of the window's work precedes the fault; jittered placement hurts —
//! effective recall drops).
//!
//! ```bash
//! cargo run --release --example predictor_sweep -- --procs 262144
//! ```

use ckptwin::cli::Args;
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::harness::evaluate_heuristics;
use ckptwin::predictor::{registry as predictors, table6_presets};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::trace::{Event, TraceStream};

fn best_aware(results: &[ckptwin::harness::HeuristicResult]) -> (String, f64) {
    results
        .iter()
        .filter(|r| {
            matches!(r.name.as_str(), "Instant" | "NoCkptI" | "WithCkptI")
        })
        .map(|r| (r.name.clone(), r.waste))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap()
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let procs: u64 = args.get_or("procs", 1 << 18);
    let instances: usize = args.get_or("instances", 30);
    let law = Law::Weibull { shape: 0.7 };

    println!("platform: N = 2^{} procs, Weibull(0.7) failures\n", procs.trailing_zeros());

    // --- Part 1: Table-6 literature predictors --------------------------
    println!("literature predictors (paper Table 6):");
    println!(
        "{:<18} {:>5} {:>5} {:>7} | {:>8} {:>8} {:>18} {:>8}",
        "predictor", "p", "r", "I(s)", "RFO", "best", "heuristic", "verdict"
    );
    for (name, spec) in table6_presets() {
        let sc = Scenario::paper(procs, 1.0, spec, law, law);
        let res = evaluate_heuristics(&sc, instances, 0);
        let rfo = res.iter().find(|r| r.name == "RFO").unwrap().waste;
        let (bname, bwaste) = best_aware(&res);
        println!(
            "{:<18} {:>5.2} {:>5.2} {:>7.0} | {:>8.4} {:>8.4} {:>18} {:>8}",
            name,
            spec.precision,
            spec.recall,
            spec.window,
            rfo,
            bwaste,
            bname,
            if bwaste < rfo { "trust" } else { "ignore" }
        );
    }

    // --- Part 2: synthetic (recall, precision) grid ----------------------
    println!("\nsynthetic predictor grid (I = 600 s): waste gain vs RFO (%)");
    let recalls = [0.3, 0.5, 0.7, 0.9];
    let precisions = [0.2, 0.4, 0.6, 0.8, 0.95];
    print!("{:>8}", "r \\ p");
    for p in precisions {
        print!(" {p:>7.2}");
    }
    println!();
    for r in recalls {
        print!("{r:>8.2}");
        for p in precisions {
            let spec = PredictorSpec::paper(r, p, 600.0);
            let sc = Scenario::paper(procs, 1.0, spec, law, law);
            let res = evaluate_heuristics(&sc, instances, 0);
            let rfo = res.iter().find(|x| x.name == "RFO").unwrap().waste;
            let (_, bwaste) = best_aware(&res);
            print!(" {:>7.1}", (1.0 - bwaste / rfo) * 100.0);
        }
        println!();
    }

    // --- Part 3: registry window-placement models ------------------------
    // Every registered predictor model end-to-end: measured effective
    // (r, p) from a generated trace, plus the RFO-vs-aware verdict.
    println!("\nregistry predictor models (I = 600 s):");
    println!(
        "{:<44} {:>7} {:>7} {:>8} {:>8} {:>8}",
        "predictor", "r_eff", "p_eff", "RFO", "best", "verdict"
    );
    for pid in predictors::all_defaults() {
        let spec = pid.spec(600.0);
        let sc = Scenario::paper(procs, 1.0, spec, law, law);
        // Effective quality, measured on one trace: jitter loses windows,
        // the others keep their nominal r/p.
        let horizon = 400.0 * sc.platform.mu;
        let evs = TraceStream::new(&sc, 1).take_until(horizon);
        let faults: Vec<f64> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Fault { t, .. } => Some(*t),
                _ => None,
            })
            .collect();
        let announced: Vec<_> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Prediction(p) => Some(*p),
                _ => None,
            })
            .collect();
        let (r_eff, p_eff) = ckptwin::predictor::score(&faults, &announced);
        let res = evaluate_heuristics(&sc, instances, 0);
        let rfo = res.iter().find(|r| r.name == "RFO").unwrap().waste;
        let (_, bwaste) = best_aware(&res);
        println!(
            "{:<44} {:>7.3} {:>7.3} {:>8.4} {:>8.4} {:>8}",
            pid.to_string(),
            r_eff,
            p_eff,
            rfo,
            bwaste,
            if bwaste < rfo { "trust" } else { "ignore" }
        );
    }

    // --- Part 4: window-size threshold ----------------------------------
    println!("\nwindow-size threshold (predictor A): waste vs I");
    println!("{:>8} {:>10} {:>10} {:>10}", "I(s)", "RFO", "best-aware", "verdict");
    for window in [150.0, 300.0, 600.0, 1200.0, 2400.0, 3000.0, 4800.0] {
        let sc = Scenario::paper(
            procs, 1.0, PredictorSpec::paper_a(window), law, law,
        );
        let res = evaluate_heuristics(&sc, instances, 0);
        let rfo = res.iter().find(|x| x.name == "RFO").unwrap().waste;
        let (_, bwaste) = best_aware(&res);
        println!(
            "{:>8.0} {:>10.4} {:>10.4} {:>10}",
            window,
            rfo,
            bwaste,
            if bwaste < rfo { "trust" } else { "ignore" }
        );
    }
}
