//! The open strategy axis: run the registry's prediction-handling
//! extensions through a campaign grid — ExactPred vs Instant (paired
//! traces), and a QTrust sweep over the trust probability q.
//!
//! ```bash
//! cargo run --release --example new_strategies
//! ```
//!
//! The same campaigns run from the CLI with registry names only (note the
//! quotes: parentheses are shell metacharacters):
//!
//! ```bash
//! ckptwin campaign run --out results/exactpred.jsonl --scale 0.1 \
//!   --procs 65536,262144 --cp-ratios 1.0 --laws exponential,weibull0.7 \
//!   --predictors a --windows 300,900 \
//!   --strategies "instant,exactpred,windowendckpt,nockpt"
//! ckptwin campaign run --out results/qtrust.jsonl --scale 0.1 \
//!   --procs 262144 --laws weibull0.7 --windows 600 \
//!   --strategies "rfo,qtrust(q=0.25),qtrust(q=0.5),qtrust(q=0.75),nockpt"
//! ckptwin campaign report --out results/qtrust.jsonl
//! ```

use ckptwin::campaign::{evaluate_grid, CampaignOptions, Grid};
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::registry::parse_strategy_list;

fn main() {
    let opt = CampaignOptions { instances: 20, block: 0, threads: 0 };

    // --- ExactPred vs Instant (and friends), paired traces ---------------
    // Cells at one scenario point share fault traces (the seed derives
    // from the fault environment), so the deltas below are paired — the
    // paper's comparison methodology, now covering registry extensions.
    let grid = Grid {
        procs: vec![1 << 16, 1 << 18],
        cp_ratios: vec![1.0],
        fault_laws: vec![Law::Exponential, Law::Weibull { shape: 0.7 }],
        uniform_false_preds: false,
        predictors: vec![ckptwin::predictor::registry::get("a").unwrap()],
        windows: vec![300.0, 900.0],
        strategies: parse_strategy_list(
            "instant,exactpred,windowendckpt,nockpt",
        )
        .expect("registered strategies"),
        scale: 0.1,
    };
    println!("ExactPred vs Instant ({} cells):", grid.len());
    println!(
        "{:<14} {:>8} {:>6} {:<16} {:>10} {:>10}",
        "law", "procs", "I", "strategy", "waste", "±ci95"
    );
    for o in evaluate_grid(&grid, &opt) {
        let name = o.cell.strategy.to_string();
        println!(
            "{:<14} {:>8} {:>6} {name:<16} {:>10.4} {:>10.4}",
            o.cell.fault_law.label(),
            o.cell.procs,
            o.cell.predictor.window,
            o.waste.mean(),
            o.waste.ci95(),
        );
    }

    // --- QTrust sweep: the paper's claim that q is extremal --------------
    // Interior trust probabilities should never beat both extremes
    // (q = 0 is RFO's mode, q = 1 is NoCkptI).
    let sweep = Grid {
        procs: vec![1 << 18],
        cp_ratios: vec![1.0],
        fault_laws: vec![Law::Weibull { shape: 0.7 }],
        uniform_false_preds: false,
        predictors: vec![ckptwin::predictor::registry::get("a").unwrap()],
        windows: vec![600.0],
        strategies: parse_strategy_list(
            "rfo,qtrust(q=0.25),qtrust(q=0.5),qtrust(q=0.75),nockpt",
        )
        .expect("registered strategies"),
        scale: 0.1,
    };
    println!("\nQTrust sweep (q = 0 is RFO's mode, q = 1 is NoCkptI):");
    for o in evaluate_grid(&sweep, &opt) {
        let name = o.cell.strategy.to_string();
        println!("  {name:<16} waste {:.4} ±{:.4}", o.waste.mean(), o.waste.ci95());
    }
}
