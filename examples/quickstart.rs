//! Quickstart: evaluate the paper's checkpointing strategies on one
//! scenario, comparing simulated waste against the analytic model.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::harness::evaluate_heuristics;
use ckptwin::model::optimal;
use ckptwin::sim::distribution::Law;
use ckptwin::util::SECONDS_PER_DAY;

fn main() {
    // The paper's 2^16-processor platform with predictor A (p=0.82,
    // r=0.85) announcing 10-minute prediction windows.
    let scenario = Scenario::paper(
        1 << 16,                          // N processors => mu = mu_ind / N
        1.0,                              // C_p = C
        PredictorSpec::paper_a(600.0),    // I = 600 s
        Law::Weibull { shape: 0.7 },      // real-platform-like failures
        Law::Weibull { shape: 0.7 },      // false predictions, same law
    );

    println!(
        "platform: mu = {:.0} s, C = R = 600 s, D = 60 s; job = {:.1} days",
        scenario.platform.mu,
        scenario.job_size / SECONDS_PER_DAY
    );
    println!(
        "predictor: precision {:.2}, recall {:.2}, window {} s",
        scenario.predictor.precision,
        scenario.predictor.recall,
        scenario.predictor.window
    );
    println!(
        "closed-form optima: RFO T = {:.0} s, window-aware T_R = {:.0} s, T_P = {:.0} s\n",
        optimal::rfo_period(&scenario.platform),
        optimal::tr_extr_window(&scenario),
        optimal::tp_extr(&scenario)
    );

    // 40 instances keeps the example snappy; the paper uses 100.
    let results = evaluate_heuristics(&scenario, 40, 8);
    println!(
        "{:<22} {:>9} {:>9} {:>10} {:>13}",
        "heuristic", "waste", "±95%", "analytic", "makespan (d)"
    );
    for r in &results {
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>10.4} {:>13.2}",
            r.name,
            r.waste,
            r.waste_ci,
            r.analytic_waste,
            r.makespan / SECONDS_PER_DAY
        );
    }

    let daly = results.iter().find(|r| r.name == "Daly").unwrap().makespan;
    let best = results
        .iter()
        .min_by(|a, b| a.makespan.total_cmp(&b.makespan))
        .unwrap();
    println!(
        "\nbest heuristic: {} — {:.1}% faster than Daly",
        best.name,
        (1.0 - best.makespan / daly) * 100.0
    );
}
