//! Bench: closed-form waste evaluation (the analytic hot path inside every
//! period search) and the optimal-period formulas.

use ckptwin::bench_support::{bench_val, report_throughput};
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::model::{optimal, waste};
use ckptwin::sim::distribution::Law;

fn main() {
    let sc = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(1200.0),
        Law::Exponential,
        Law::Exponential,
    );

    let grid: Vec<f64> = (0..512).map(|k| 700.0 + k as f64 * 40.0).collect();

    let r = bench_val("waste_model/q0_grid512", 30.0, || {
        grid.iter().map(|&t| waste::q0(&sc, t)).sum::<f64>()
    });
    report_throughput(&r, 512.0, "eval");

    let r = bench_val("waste_model/withckpt_grid512", 30.0, || {
        let tp = optimal::tp_extr(&sc);
        grid.iter().map(|&t| waste::withckpt(&sc, t, tp)).sum::<f64>()
    });
    report_throughput(&r, 512.0, "eval");

    let r = bench_val("waste_model/all4_clipped_grid512", 30.0, || {
        use ckptwin::model::waste::GridStrategy::*;
        let mut acc = 0.0;
        for &t in &grid {
            for s in [Q0, Instant, NoCkpt, WithCkpt] {
                acc += waste::waste_clipped(&sc, s, t);
            }
        }
        acc
    });
    report_throughput(&r, 4.0 * 512.0, "eval");

    bench_val("waste_model/optimal_periods", 10.0, || {
        (
            optimal::rfo_period(&sc.platform),
            optimal::tr_extr_window(&sc),
            optimal::tr_extr_instant(&sc),
            optimal::tp_extr(&sc),
        )
    });
}
