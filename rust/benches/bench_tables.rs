//! Bench: regenerate Table 4 and Table 5 cells (reduced instance count) —
//! the end-to-end cost of the paper's headline comparison.
//!
//! Set `CKPTWIN_INSTANCES` to control the per-cell instance count
//! (default here: 5 — the paper's tables use 100).

use ckptwin::bench_support::bench_val;
use ckptwin::harness::tables;

fn main() {
    let instances: usize = std::env::var("CKPTWIN_INSTANCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);

    for (id, shape) in [(4u8, 0.7), (5u8, 0.5)] {
        let r = bench_val(
            &format!("tables/table{id}_weibull{shape}_{instances}inst"),
            1000.0,
            || tables::run_table(id, shape, instances).unwrap().cells.len(),
        );
        println!(
            "  table {id}: {:.2} s/run at {instances} instances (paper: 100)",
            r.median()
        );
    }
}
