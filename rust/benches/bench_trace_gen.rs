//! Bench: RNG, distribution sampling, and merged trace generation — the
//! substrate under every simulation instance.

use ckptwin::bench_support::{bench_val, report_throughput};
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::sim::distribution::{Distribution, Law};
use ckptwin::sim::rng::Rng;
use ckptwin::sim::trace::TraceStream;

fn main() {
    let mut rng = Rng::new(1);
    let r = bench_val("trace/rng_u64_x1000", 20.0, || {
        let mut acc = 0u64;
        for _ in 0..1000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });
    report_throughput(&r, 1000.0, "draw");

    for law in [Law::Exponential, Law::Weibull { shape: 0.7 }, Law::Uniform] {
        let d = Distribution::new(law, 1000.0);
        let mut rng = Rng::new(2);
        let r = bench_val(
            &format!("trace/sample_{}_x1000", law.label()),
            20.0,
            || {
                let mut acc = 0.0;
                for _ in 0..1000 {
                    acc += d.sample(&mut rng);
                }
                acc
            },
        );
        report_throughput(&r, 1000.0, "draw");
    }

    let sc = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(1200.0),
        Law::Weibull { shape: 0.7 },
        Law::Weibull { shape: 0.7 },
    );
    let mut seed = 0u64;
    let r = bench_val("trace/stream_1000_events", 60.0, || {
        seed += 1;
        let mut ts = TraceStream::new(&sc, seed);
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += ts.next_event().time();
        }
        acc
    });
    report_throughput(&r, 1000.0, "event");
}
