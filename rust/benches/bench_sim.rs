//! Bench: the PR 2 simulation fast path, measured against the seed code
//! path **in the same run** — both numbers land in `BENCH_PR2.json`.
//!
//! * simulate-throughput (events/s): one campaign cell's worth of work —
//!   the paper-set strategy variants over shared fault environments —
//!   through the seed path (fresh heap `TraceStream` per simulation, as
//!   `campaign::run_cells` did pre-change) vs the fast path (per-worker
//!   `TracePool` replaying one flat-generated trace per seed).
//! * single-simulation events/s: heap stream vs flat stream, no caching.
//! * BestPeriod wall-clock: the pre-change exhaustive sweep over
//!   heap-backed trace memos vs the adaptive racing search over
//!   flat-backed memos.

use ckptwin::bench_support::{bench_val, report_throughput, update_bench_json};
use ckptwin::campaign::TracePool;
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::jsonio::Value;
use ckptwin::model::batch::{BatchEvaluator, STRATEGIES};
use ckptwin::model::optimal;
use ckptwin::model::waste::waste_checked;
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::{simulate, simulate_from_capped};
use ckptwin::predictor::registry as registry_predictors;
use ckptwin::sim::trace::{EventSource, FlatTrace, TraceCache, TraceStream};
use ckptwin::strategy::best_period::{search_with, SearchConfig};
use ckptwin::strategy::{registry, Policy, PolicyKind};

fn main() {
    let mut json: Vec<(String, Value)> = Vec::new();

    // ---- simulate-throughput: a campaign cell's strategy variants ------
    // Weibull 0.7 per-processor traces at 2^18 procs: the paper's default
    // regime, where trace generation is a large share of each simulation.
    let sc = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(1200.0),
        Law::Weibull { shape: 0.7 },
        Law::Weibull { shape: 0.7 },
    );
    let pols: Vec<Policy> =
        registry::paper_set().iter().map(|s| s.policy(&sc)).collect();
    let seeds: [u64; 4] = [1, 2, 3, 4];
    // Events consumed per full pass (identical on both paths).
    let total_events: f64 = seeds
        .iter()
        .flat_map(|&seed| pols.iter().map(move |pol| (seed, pol)))
        .map(|(seed, pol)| simulate(&sc, pol, seed).events as f64)
        .sum();

    let r_seedpath = bench_val("sim/cell_variants_seedpath", 300.0, || {
        let mut acc = 0.0;
        for &seed in &seeds {
            for pol in &pols {
                acc += simulate_from_capped(
                    &sc,
                    pol,
                    1.0,
                    seed,
                    TraceStream::new(&sc, seed),
                    f64::INFINITY,
                )
                .makespan;
            }
        }
        acc
    });
    report_throughput(&r_seedpath, total_events, "event");

    let r_fastpath = bench_val("sim/cell_variants_fastpath", 300.0, || {
        let mut pool = TracePool::new();
        let mut acc = 0.0;
        for &seed in &seeds {
            for pol in &pols {
                acc += simulate_from_capped(
                    &sc,
                    pol,
                    1.0,
                    seed,
                    pool.replay(0, &sc, seed),
                    f64::INFINITY,
                )
                .makespan;
            }
        }
        acc
    });
    report_throughput(&r_fastpath, total_events, "event");
    let sim_speedup = r_seedpath.median() / r_fastpath.median();
    println!("sim/cell_variants speedup: {sim_speedup:.2}x");
    json.push((
        "sim_events_per_s_seedpath".into(),
        Value::Num(total_events / r_seedpath.median()),
    ));
    json.push((
        "sim_events_per_s_fastpath".into(),
        Value::Num(total_events / r_fastpath.median()),
    ));
    json.push(("sim_throughput_speedup".into(), Value::Num(sim_speedup)));

    // ---- single simulation: heap vs flat stream, no caching ------------
    // One fixed seed for both paths: bench_val calibrates its own
    // iteration counts, so a rolling seed would time the two paths over
    // different instance populations.
    let pol = registry::get("WithCkptI").unwrap().policy(&sc);
    let single_seed = 100u64;
    let single_events = simulate(&sc, &pol, single_seed).events as f64;
    let r_heap = bench_val("sim/single_heap_stream", 120.0, || {
        simulate_from_capped(
            &sc,
            &pol,
            1.0,
            single_seed,
            TraceStream::new(&sc, single_seed),
            f64::INFINITY,
        )
        .makespan
    });
    report_throughput(&r_heap, single_events, "event");
    let r_flat = bench_val("sim/single_flat_stream", 120.0, || {
        simulate_from_capped(
            &sc,
            &pol,
            1.0,
            single_seed,
            FlatTrace::new(&sc, single_seed),
            f64::INFINITY,
        )
        .makespan
    });
    report_throughput(&r_flat, single_events, "event");
    json.push((
        "single_sim_heap_vs_flat_speedup".into(),
        Value::Num(r_heap.median() / r_flat.median()),
    ));

    // ---- BestPeriod search: exhaustive seed path vs adaptive race ------
    let sc_bp = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(1200.0),
        Law::Exponential,
        Law::Exponential,
    );
    let tp = optimal::tp_extr(&sc_bp).max(sc_bp.platform.cp * 1.1);
    let bp_seeds: Vec<u64> = (0..16).collect();

    let r_exh = bench_val("best_period/exhaustive_seedpath_16seeds", 800.0, || {
        // Pre-change behavior: fresh heap-backed memos per search call,
        // every candidate scored on every seed.
        let mut caches: Vec<TraceCache> = bp_seeds
            .iter()
            .map(|&s| TraceCache::reference(&sc_bp, s))
            .collect();
        search_with(
            &sc_bp,
            PolicyKind::WithCkpt,
            tp,
            &bp_seeds,
            &SearchConfig::exhaustive(24, 8),
            &mut caches,
        )
        .tr
    });
    let r_race = bench_val("best_period/adaptive_fastpath_16seeds", 800.0, || {
        let mut caches: Vec<TraceCache> =
            bp_seeds.iter().map(|&s| TraceCache::new(&sc_bp, s)).collect();
        search_with(
            &sc_bp,
            PolicyKind::WithCkpt,
            tp,
            &bp_seeds,
            &SearchConfig::adaptive(24, 8),
            &mut caches,
        )
        .tr
    });
    let bp_speedup = r_exh.median() / r_race.median();
    println!("best_period speedup: {bp_speedup:.2}x");
    json.push((
        "bestperiod_search_secs_seedpath".into(),
        Value::Num(r_exh.median()),
    ));
    json.push((
        "bestperiod_search_secs_fastpath".into(),
        Value::Num(r_race.median()),
    ));
    json.push(("bestperiod_speedup".into(), Value::Num(bp_speedup)));

    // ---- trace generation: paper predictor vs mixedwin model -----------
    // The PR 5 predictor-model refactor routes every window draw through
    // the PredictorModel trait object; this tracks its cost on the fixed-
    // window paper path (target: no regression) and prices the
    // heterogeneous-window model's extra per-announcement draw.
    let gen_events = |sc: &Scenario| {
        let mut ts = FlatTrace::new(sc, 7);
        let mut acc = 0.0;
        for _ in 0..20_000 {
            acc += ts.next_event().time();
        }
        acc
    };
    let sc_paper = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(600.0),
        Law::Exponential,
        Law::Exponential,
    );
    let mut sc_mixed = sc_paper;
    sc_mixed.predictor = registry_predictors::get("mixedwin")
        .expect("registered")
        .spec(600.0);
    let r_gen_paper =
        bench_val("trace_gen/paper_fixed_window", 120.0, || gen_events(&sc_paper));
    report_throughput(&r_gen_paper, 20_000.0, "event");
    let r_gen_mixed =
        bench_val("trace_gen/mixedwin", 120.0, || gen_events(&sc_mixed));
    report_throughput(&r_gen_mixed, 20_000.0, "event");
    json.push((
        "trace_gen_events_per_s_paper".into(),
        Value::Num(20_000.0 / r_gen_paper.median()),
    ));
    json.push((
        "trace_gen_events_per_s_mixedwin".into(),
        Value::Num(20_000.0 / r_gen_mixed.median()),
    ));
    json.push((
        "trace_gen_mixedwin_overhead".into(),
        Value::Num(r_gen_mixed.median() / r_gen_paper.median()),
    ));

    // ---- per-processor trace generation: N-sweep to 10^6 (PR 8) --------
    // The timer-wheel source behind FlatTrace, pulling raw events through
    // the full merge, at 10^4..10^6 fresh-Weibull processors; plus the
    // wheel-vs-heap ratio at 10^6 (same scenario, same seed, heap
    // reference TraceStream) — the headline number of the scale-out work.
    let sweep_sc = |n: u64| {
        Scenario::paper(
            n,
            1.0,
            PredictorSpec::paper_a(600.0),
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.7 },
        )
    };
    const SWEEP_EVENTS: usize = 20_000;
    let mut wheel_medians: Vec<f64> = Vec::new();
    for (tag, n) in [("n1e4", 10_000u64), ("n1e5", 100_000), ("n1e6", 1_000_000)] {
        let sc_n = sweep_sc(n);
        let r = bench_val(&format!("trace_gen/perproc_wheel_{tag}"), 150.0, || {
            let mut ts = FlatTrace::new(&sc_n, 7);
            let mut acc = 0.0;
            for _ in 0..SWEEP_EVENTS {
                acc += ts.next_event().time();
            }
            acc
        });
        report_throughput(&r, SWEEP_EVENTS as f64, "event");
        wheel_medians.push(r.median());
        json.push((
            format!("perproc_events_per_s_{tag}"),
            Value::Num(SWEEP_EVENTS as f64 / r.median()),
        ));
    }
    let sc_1e6 = sweep_sc(1_000_000);
    let r_heap_1e6 = bench_val("trace_gen/perproc_heap_n1e6", 150.0, || {
        let mut ts = TraceStream::new(&sc_1e6, 7);
        let mut acc = 0.0;
        for _ in 0..SWEEP_EVENTS {
            acc += ts.next_event().time();
        }
        acc
    });
    report_throughput(&r_heap_1e6, SWEEP_EVENTS as f64, "event");
    let wheel_speedup = r_heap_1e6.median() / wheel_medians[2];
    println!("trace_gen/perproc wheel-vs-heap speedup at 1e6: {wheel_speedup:.2}x");
    json.push(("wheel_vs_heap_speedup".into(), Value::Num(wheel_speedup)));

    // ---- batched waste-model evaluator (PR 10) -------------------------
    // Full checked surfaces (4 strategies × G periods) for a block of
    // scenarios: the scalar per-cell waste_checked loop (what figures and
    // validate ran pre-change) vs model::batch's coefficient-hoisted rows.
    // Both sides single-threaded so the ratio prices the evaluator, not
    // the scheduler.
    let batch_items: Vec<(Scenario, f64)> = [1u64 << 16, 1 << 18, 1 << 19]
        .iter()
        .flat_map(|&n| {
            [PredictorSpec::paper_a(1200.0), PredictorSpec::paper_b(300.0)]
                .into_iter()
                .map(move |pred| {
                    let s = Scenario::paper(
                        n,
                        1.0,
                        pred,
                        Law::Exponential,
                        Law::Exponential,
                    );
                    let tp = optimal::tp_extr(&s).max(s.platform.cp * 1.1);
                    (s, tp)
                })
        })
        .collect();
    let surf_grid: Vec<f64> =
        (0..512).map(|k| 650.0 + 90.0 * k as f64).collect();
    let n_cells =
        (batch_items.len() * STRATEGIES.len() * surf_grid.len()) as f64;
    let r_scalar_model = bench_val("waste_model/scalar_surfaces", 200.0, || {
        let mut acc = 0.0;
        for (s, tp) in &batch_items {
            for strat in STRATEGIES {
                for &tr in &surf_grid {
                    if let Some(w) = waste_checked(s, strat, tr, *tp).value() {
                        acc += w;
                    }
                }
            }
        }
        acc
    });
    report_throughput(&r_scalar_model, n_cells, "cell");
    let r_batch_model = bench_val("waste_model/batched_surfaces", 200.0, || {
        let mut ev = BatchEvaluator::new();
        let mut acc = 0.0;
        for (s, tp) in &batch_items {
            let surf = ev.surface(s, *tp, &surf_grid);
            for strat in STRATEGIES {
                for cell in surf.row(strat) {
                    if let Some(w) = cell.value() {
                        acc += w;
                    }
                }
            }
        }
        acc
    });
    report_throughput(&r_batch_model, n_cells, "cell");
    let batch_speedup = r_scalar_model.median() / r_batch_model.median();
    println!("waste_model batched-vs-scalar speedup: {batch_speedup:.2}x");
    json.push((
        "batch_waste_cells_per_s".into(),
        Value::Num(n_cells / r_batch_model.median()),
    ));
    json.push(("batch_vs_scalar_speedup".into(), Value::Num(batch_speedup)));

    // ---- BestPeriod racing: batched model seeding vs no model ----------
    // Same adaptive race; the batched side prunes the candidate grid with
    // model::batch before simulating (strategy::best_period::model_seed).
    use ckptwin::strategy::best_period::ModelSide;
    let r_bp_off = bench_val("best_period/adaptive_no_model", 800.0, || {
        let mut caches: Vec<TraceCache> =
            bp_seeds.iter().map(|&s| TraceCache::new(&sc_bp, s)).collect();
        search_with(
            &sc_bp,
            PolicyKind::WithCkpt,
            tp,
            &bp_seeds,
            &SearchConfig::adaptive(24, 8).with_model(ModelSide::Off),
            &mut caches,
        )
        .tr
    });
    let r_bp_batch = bench_val("best_period/adaptive_batch_model", 800.0, || {
        let mut caches: Vec<TraceCache> =
            bp_seeds.iter().map(|&s| TraceCache::new(&sc_bp, s)).collect();
        search_with(
            &sc_bp,
            PolicyKind::WithCkpt,
            tp,
            &bp_seeds,
            &SearchConfig::adaptive(24, 8).with_model(ModelSide::Batched),
            &mut caches,
        )
        .tr
    });
    let bp_batch_speedup = r_bp_off.median() / r_bp_batch.median();
    println!("best_period batch-seeded speedup: {bp_batch_speedup:.2}x");
    json.push((
        "bestperiod_batch_speedup".into(),
        Value::Num(bp_batch_speedup),
    ));

    update_bench_json("bench_sim", &json);
}
