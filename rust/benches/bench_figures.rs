//! Bench: one representative point per figure family (waste-vs-N,
//! waste-vs-T_R, waste-vs-I), at reduced instance counts — measures the
//! cost structure of regenerating the paper's evaluation.

use ckptwin::bench_support::bench_val;
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::harness::{evaluate_heuristics, run_instances};
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::registry;

fn main() {
    let instances: usize = std::env::var("CKPTWIN_INSTANCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    // Figures 2-13 family: one (N, I, law) point, all 5 named heuristics.
    let sc = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(600.0),
        Law::Weibull { shape: 0.7 },
        Law::Weibull { shape: 0.7 },
    );
    bench_val(
        &format!("figures/waste_vs_n_point_{instances}inst"),
        500.0,
        || evaluate_heuristics(&sc, instances, 0).len(),
    );

    // Figures 14-17 family: one T_R sweep column (4 heuristics x 1 period).
    let pol = registry::get("WithCkptI").unwrap().policy(&sc);
    bench_val(
        &format!("figures/waste_vs_tr_point_{instances}inst"),
        300.0,
        || run_instances(&sc, &pol, instances).0.mean(),
    );

    // Figures 18-21 family: one window size, all heuristics.
    let sc_i = Scenario::paper(
        1 << 16,
        1.0,
        PredictorSpec::paper_b(3000.0),
        Law::Exponential,
        Law::Exponential,
    );
    bench_val(
        &format!("figures/waste_vs_i_point_{instances}inst"),
        500.0,
        || evaluate_heuristics(&sc_i, instances, 0).len(),
    );
}
