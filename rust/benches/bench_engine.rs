//! Bench: the discrete-event engine — events/second and full-instance
//! latency at the paper's scenario scale.  This is the L3 hot path: every
//! figure point costs (heuristics × instances) of these.

use ckptwin::bench_support::{bench_val, report_throughput};
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::simulate;
use ckptwin::strategy::registry;

fn main() {
    for (tag, procs) in [("2^16", 1u64 << 16), ("2^19", 1u64 << 19)] {
        let sc = Scenario::paper(
            procs,
            1.0,
            PredictorSpec::paper_a(600.0),
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.7 },
        );
        for name in ["RFO", "WithCkptI"] {
            let strat = registry::get(name).unwrap();
            let pol = strat.policy(&sc);
            let mut seed = 0u64;
            // Events per instance, probed once, for the throughput line.
            let probe = simulate(&sc, &pol, 0);
            let events = probe.events.max(1) as f64
                + probe.n_reg_ckpts as f64
                + probe.n_pro_ckpts as f64;
            let r = bench_val(
                &format!("engine/instance_{tag}_{name}"),
                80.0,
                || {
                    seed += 1;
                    simulate(&sc, &pol, seed).makespan
                },
            );
            report_throughput(&r, events, "event");
        }
    }
}
