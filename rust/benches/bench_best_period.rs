//! Bench: BestPeriod search — brute-force simulation search vs the
//! closed-form formulas vs the PJRT waste-grid artifact (the L1 offload).
//!
//! The PJRT path amortizes: one execute scores 64 scenarios × 512 periods
//! × 4 strategies — the ablation the paper's Maple plots correspond to.

use ckptwin::bench_support::{bench_val, report_throughput};
use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::model::optimal;
use ckptwin::runtime::Runtime;
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::{best_period, PolicyKind};

fn main() {
    let sc = Scenario::paper(
        1 << 18,
        1.0,
        PredictorSpec::paper_a(1200.0),
        Law::Exponential,
        Law::Exponential,
    );
    let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.1);

    bench_val("best_period/closed_form", 5.0, || {
        optimal::tr_extr_window(&sc)
    });

    let seeds: Vec<u64> = (0..4).collect();
    let r = bench_val("best_period/brute_force_sim_24x8_4seeds", 300.0, || {
        best_period::search(&sc, PolicyKind::WithCkpt, tp, &seeds, 24, 8)
            .tr
    });
    report_throughput(&r, ((24 + 1 + 8) * 4) as f64, "sim");

    // CPU closed-form grid (same work the PJRT artifact does).
    let grid: Vec<f64> = (0..512)
        .map(|k| 660.0 * (200.0f64).powf(k as f64 / 511.0))
        .collect();
    let scenarios: Vec<Scenario> = (0..64)
        .map(|i| {
            Scenario::paper(
                1 << (16 + (i % 4)),
                [1.0, 0.1, 2.0][i % 3],
                PredictorSpec::paper_a([300.0, 600.0, 900.0, 1200.0, 3000.0][i % 5]),
                Law::Exponential,
                Law::Exponential,
            )
        })
        .collect();
    let r = bench_val("best_period/cpu_grid_64x512x4", 100.0, || {
        use ckptwin::model::waste::{waste_clipped, GridStrategy::*};
        let mut acc = 0.0;
        for s in &scenarios {
            for &t in &grid {
                for g in [Q0, Instant, NoCkpt, WithCkpt] {
                    acc += waste_clipped(s, g, t);
                }
            }
        }
        acc
    });
    report_throughput(&r, (64 * 512 * 4) as f64, "eval");

    match Runtime::discover() {
        Ok(rt) => {
            // Warm the compile cache outside the timed region.
            rt.waste_surfaces(&scenarios, &grid).expect("warmup");
            let r = bench_val("best_period/pjrt_grid_64x512x4", 200.0, || {
                rt.waste_surfaces(&scenarios, &grid).unwrap().len()
            });
            report_throughput(&r, (64 * 512 * 4) as f64, "eval");
        }
        Err(e) => println!("best_period/pjrt_grid: skipped ({e})"),
    }
}
