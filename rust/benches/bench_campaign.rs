//! Bench: the campaign engine — work-stealing grid execution vs
//! single-thread, and the fixed per-cell costs (expansion, hashing,
//! store append).

use ckptwin::bench_support::{bench_val, report_throughput, update_bench_json};
use ckptwin::campaign::{self, CampaignOptions, CellOutcome, Grid, Store};
use ckptwin::jsonio::Value;

fn main() {
    let mut json: Vec<(String, Value)> = Vec::new();
    let instances: usize = std::env::var("CKPTWIN_INSTANCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    let grid = Grid::smoke();
    let n_cells = grid.len();

    let r = bench_val("campaign/expand_smoke_grid", 10.0, || grid.expand().len());
    report_throughput(&r, n_cells as f64, "cell");

    let paper = Grid::paper();
    let r = bench_val("campaign/expand_paper_1200_cells", 20.0, || {
        paper.expand().len()
    });
    report_throughput(&r, paper.len() as f64, "cell");

    for (tag, threads) in [("1thread", 1usize), ("all_threads", 0)] {
        let r = bench_val(
            &format!("campaign/smoke_grid_{n_cells}cells_{instances}inst_{tag}"),
            2000.0,
            || {
                let opt = CampaignOptions { instances, block: 0, threads };
                campaign::evaluate_grid(&grid, &opt).len()
            },
        );
        report_throughput(&r, n_cells as f64, "cell");
        json.push((
            format!("cells_per_s_{tag}"),
            Value::Num(n_cells as f64 / r.median()),
        ));
    }

    // Store append path (JSON encode + flush per record).  One store is
    // reused across iterations: re-creating it per iteration would measure
    // file creation, not append throughput.
    let opt = CampaignOptions { instances, block: 0, threads: 0 };
    let outcomes: Vec<CellOutcome> = campaign::evaluate_grid(&grid, &opt);
    let path = std::env::temp_dir().join(format!(
        "ckptwin-bench-store-{}.jsonl",
        std::process::id()
    ));
    // `create` now refuses non-empty leftovers from an earlier run.
    let _ = std::fs::remove_file(&path);
    let mut store = Store::create(&path).expect("store");
    let r = bench_val("campaign/store_append_per_cell", 50.0, || {
        for o in &outcomes {
            store.append(&o.record()).expect("append");
        }
        store.len()
    });
    report_throughput(&r, outcomes.len() as f64, "append");
    json.push((
        "store_appends_per_s".into(),
        Value::Num(outcomes.len() as f64 / r.median()),
    ));
    drop(store);
    let _ = std::fs::remove_file(&path);

    update_bench_json("bench_campaign", &json);
}
