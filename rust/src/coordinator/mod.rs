//! The checkpointing coordinator: the paper's scheduling algorithm run as a
//! *real system* rather than a simulation.
//!
//! The coordinator drives an actual workload (by default the AOT-compiled
//! transformer training step, see [`workload::PjrtWorkload`]) in **scaled
//! simulation time**: each unit of work represents `seconds_per_step`
//! seconds of an exascale job, and the fault process, prediction feed,
//! checkpoint costs (C, C_p) and downtime/recovery (D, R) all live on that
//! clock.  Model state is snapshotted to a durable, checksummed
//! [`checkpoint::CheckpointStore`]; an injected fault really destroys the
//! in-memory state and recovery really reloads the last checkpoint — so a
//! scheduling bug (checkpointing too rarely, trusting a bad predictor)
//! shows up as lost training steps and a worse loss curve, exactly the
//! waste the paper analyzes.
//!
//! Concurrency: the leader loop executes work and *defers checkpoint I/O*
//! to a writer thread (snapshots are cheap copies; serialization + fsync
//! happen off the hot path) — the standard "asynchronous checkpointing"
//! optimization.  The write is still charged C (or C_p) on the simulation
//! clock, faithful to the paper's cost model.

pub mod checkpoint;
pub mod workload;

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Scenario;
use crate::model::waste::waste_clipped;
use crate::obs::{Hist, SpanTimer, Stopwatch};
use crate::sim::trace::{Event, TraceStream};
use crate::strategy::{Policy, PolicyKind};
use checkpoint::CheckpointStore;
use workload::Workload;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Fault/predictor/cost parameters, on the simulation clock.
    pub scenario: Scenario,
    /// Checkpointing policy to run.
    pub policy: Policy,
    /// Simulated seconds of useful work represented by one workload step.
    pub seconds_per_step: f64,
    /// Job size in steps (overrides `scenario.job_size`).
    pub total_steps: u64,
    /// Checkpoint directory.
    pub ckpt_dir: PathBuf,
    /// Trace seed.
    pub seed: u64,
    /// Record the loss every this many validated steps (0 = every step).
    pub log_every: u64,
}

/// Outcome of a coordinator run.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// (validated step index, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Simulated makespan (s).
    pub sim_makespan: f64,
    /// Measured waste on the simulation clock.
    pub sim_waste: f64,
    /// The analytic model's prediction of the waste (Eqs. 3/14/10/4).
    pub predicted_waste: f64,
    pub n_faults: u64,
    pub n_recoveries: u64,
    pub n_reg_ckpts: u64,
    pub n_pro_ckpts: u64,
    pub n_preds_trusted: u64,
    /// Steps actually executed, including destroyed + recomputed ones.
    pub steps_executed: u64,
    /// Steps whose work was destroyed by faults.
    pub steps_lost: u64,
    /// Wall-clock seconds of the run.
    pub wall_seconds: f64,
    /// Wall-clock latency (ns) of each leader-loop pass: one scheduling
    /// decision plus the action it dispatched (step, checkpoint queue,
    /// recovery).  log2-bucketed; the tail exposes slow recoveries and
    /// checkpoint stalls.
    pub decision_ns: Hist,
}

enum WriterMsg {
    Save { step: u64, theta: Vec<f32> },
    /// Barrier: ack once all previously queued saves are durable.  Sent
    /// before every recovery so "what is on disk" is deterministic.
    Sync(mpsc::Sender<()>),
    Stop,
}

/// Run the coordinator to completion.
pub fn run(config: &CoordinatorConfig, workload: &mut dyn Workload) -> Result<Report> {
    let sc = &config.scenario;
    let pol = &config.policy;
    pol.validate(sc);
    let sps = config.seconds_per_step;
    assert!(sps > 0.0);
    let job_steps = config.total_steps;

    // Regular-mode period in steps (the work part of T_R).
    let steps_per_period =
        (((pol.tr - sc.platform.c) / sps).round() as u64).max(1);
    // WithCkpt proactive period in steps.
    let steps_per_pro_period =
        (((pol.tp - sc.platform.cp) / sps).round() as u64).max(1);

    let store = CheckpointStore::new(&config.ckpt_dir, 4)?;
    let (tx, rx) = mpsc::channel::<WriterMsg>();
    let writer_dir = config.ckpt_dir.clone();
    let writer = std::thread::spawn(move || -> Result<u64> {
        let store = CheckpointStore::new(&writer_dir, 4)?;
        let mut written = 0;
        while let Ok(msg) = rx.recv() {
            match msg {
                WriterMsg::Save { step, theta } => {
                    store.save(step, &theta)?;
                    written += 1;
                }
                WriterMsg::Sync(ack) => {
                    let _ = ack.send(());
                }
                WriterMsg::Stop => break,
            }
        }
        Ok(written)
    });

    let mut stream = TraceStream::new(sc, config.seed);
    let mut next_ev = stream.next_event();

    let wall_start = Instant::now();
    let mut rep = Report::default();
    let mut sim_t = 0.0f64;
    // Validated = secured by the last completed checkpoint; `since` = steps
    // done since then (lost on fault).
    let mut validated: u64 = 0;
    let mut since: u64 = 0;
    let mut period_done: u64 = 0; // steps completed in the current period

    // Take checkpoint step-0 so recovery always has something to load.
    store.save(0, &workload.snapshot())?;

    // --- helpers -----------------------------------------------------------
    macro_rules! pop_event {
        () => {{
            next_ev = stream.next_event();
        }};
    }

    // Process a fault at `tf`: destroy unvalidated work, restore, serve D+R.
    macro_rules! serve_fault {
        ($tf:expr) => {{
            rep.n_faults += 1;
            period_done = 0;
            sim_t = $tf + sc.platform.d + sc.platform.r;
            // Drain the async writer before reading "latest": recovery
            // must see a deterministic durable state.
            let (ack_tx, ack_rx) = mpsc::channel();
            tx.send(WriterMsg::Sync(ack_tx))
                .map_err(|_| anyhow!("checkpoint writer died"))?;
            ack_rx
                .recv()
                .map_err(|_| anyhow!("checkpoint writer died"))?;
            let (step, theta) = store
                .load_latest()?
                .ok_or_else(|| anyhow!("no checkpoint to recover from"))?;
            debug_assert!(step <= validated);
            workload.restore(theta)?;
            // Everything past the last *durable* checkpoint is destroyed:
            // the unvalidated steps, plus any validated-on-the-sim-clock
            // steps whose async write had not landed yet.  All of them are
            // honestly re-executed by the main loop.
            rep.steps_lost += since + (validated - step);
            since = 0;
            validated = step;
            rep.n_recoveries += 1;
        }};
    }

    // Commit a checkpoint at the current sim time (charged `cost` sim s).
    macro_rules! commit_ckpt {
        ($cost:expr, $proactive:expr) => {{
            sim_t += $cost;
            validated += since;
            since = 0;
            tx.send(WriterMsg::Save {
                step: validated,
                theta: workload.snapshot(),
            })
            .map_err(|_| anyhow!("checkpoint writer died"))?;
            if $proactive {
                rep.n_pro_ckpts += 1;
            } else {
                rep.n_reg_ckpts += 1;
            }
        }};
    }

    // Execute one real step spanning [sim_t, sim_t + sps); returns false if
    // a fault destroyed it.
    macro_rules! do_step {
        () => {{
            let loss = workload.step()?;
            rep.steps_executed += 1;
            let step_end = sim_t + sps;
            // Did a fault strike during this step?
            let mut destroyed = false;
            while next_ev.time() < step_end {
                match next_ev {
                    Event::Fault { t, .. } => {
                        pop_event!();
                        serve_fault!(t);
                        destroyed = true;
                        break;
                    }
                    Event::Prediction(_) => {
                        // Handled at step boundaries; requeue by deferring:
                        // predictions inside a step take effect after it.
                        break;
                    }
                }
            }
            if !destroyed {
                sim_t = step_end;
                since += 1;
                let total = validated + since;
                if config.log_every == 0 || total % config.log_every.max(1) == 0 {
                    rep.losses.push((total, loss));
                }
            }
            !destroyed
        }};
    }

    // Serve downtime-phase events (faults during checkpoints etc.).
    // Advance sim_t to `end` unless a fault intervenes; true if clean.
    macro_rules! advance_no_work {
        ($end:expr) => {{
            let mut clean = true;
            while next_ev.time() < $end {
                match next_ev {
                    Event::Fault { t, .. } => {
                        pop_event!();
                        serve_fault!(t);
                        clean = false;
                        break;
                    }
                    Event::Prediction(_) => {
                        pop_event!(); // ignored in this phase
                    }
                }
            }
            if clean {
                sim_t = $end;
            }
            clean
        }};
    }

    // --- main loop ---------------------------------------------------------
    // One latency sample per leader-loop pass.  The `continue 'outer`
    // jumps inside the macros bypass any end-of-iteration code, so each
    // pass is closed out (and its span recorded) at the top of the next.
    let mut decisions = Stopwatch::new();
    let mut pass_timer: Option<SpanTimer> = None;
    'outer: while validated + since < job_steps {
        if let Some(t) = pass_timer {
            decisions.record_nanos(t.elapsed_nanos());
        }
        pass_timer = Some(SpanTimer::start());
        // 1. Consume any event already due at sim_t.
        while next_ev.time() <= sim_t {
            match next_ev {
                Event::Fault { t, .. } => {
                    pop_event!();
                    serve_fault!(t);
                    continue 'outer;
                }
                Event::Prediction(p) => {
                    pop_event!();
                    if !matches!(pol.kind, PolicyKind::IgnorePredictions)
                        && p.window_end > sim_t
                    {
                        rep.n_preds_trusted += 1;
                        // Pre-window proactive checkpoint.
                        let ck_end = sim_t + sc.platform.cp;
                        if advance_no_work!(ck_end) {
                            commit_ckpt!(0.0, true); // time already advanced
                        } else {
                            continue 'outer;
                        }
                        // In-window behaviour.  The step-driven coordinator mirrors
                        // the discrete-event engine's policy logics at
                        // step granularity; randomized trust (QTrust) runs
                        // its base NoCkpt behaviour with q treated as 1 —
                        // the real system always acts on what it trusts.
                        match pol.kind {
                            PolicyKind::Instant
                            | PolicyKind::ExactPred
                            | PolicyKind::IgnorePredictions => {}
                            PolicyKind::NoCkpt | PolicyKind::QTrust { .. } => {
                                while sim_t < p.window_end
                                    && validated + since < job_steps
                                {
                                    if !do_step!() {
                                        continue 'outer;
                                    }
                                }
                            }
                            PolicyKind::WindowEndCkpt => {
                                while sim_t < p.window_end
                                    && validated + since < job_steps
                                {
                                    if !do_step!() {
                                        continue 'outer;
                                    }
                                }
                                // Terminal proactive checkpoint at t0 + I —
                                // pointless (and never taken by the
                                // engine's logic) once the job finished
                                // in-window.
                                if validated + since < job_steps {
                                    let ck_end = sim_t + sc.platform.cp;
                                    if advance_no_work!(ck_end) {
                                        commit_ckpt!(0.0, true);
                                    } else {
                                        continue 'outer;
                                    }
                                }
                            }
                            PolicyKind::WithCkpt => {
                                while sim_t < p.window_end
                                    && validated + since < job_steps
                                {
                                    for _ in 0..steps_per_pro_period {
                                        if sim_t >= p.window_end
                                            || validated + since >= job_steps
                                        {
                                            break;
                                        }
                                        if !do_step!() {
                                            continue 'outer;
                                        }
                                    }
                                    let ck_end = sim_t + sc.platform.cp;
                                    if advance_no_work!(ck_end) {
                                        commit_ckpt!(0.0, true);
                                    } else {
                                        continue 'outer;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }

        // 2. Regular-mode work.
        if period_done < steps_per_period {
            if do_step!() {
                period_done += 1;
            }
            continue 'outer;
        }

        // 3. Regular checkpoint.
        let ck_end = sim_t + sc.platform.c;
        if advance_no_work!(ck_end) {
            commit_ckpt!(0.0, false);
            period_done = 0;
        }
    }

    if let Some(t) = pass_timer {
        decisions.record_nanos(t.elapsed_nanos());
    }
    rep.decision_ns = decisions.take();

    tx.send(WriterMsg::Stop).ok();
    writer
        .join()
        .map_err(|_| anyhow!("writer thread panicked"))??;

    rep.sim_makespan = sim_t;
    let job_sim_seconds = job_steps as f64 * sps;
    rep.sim_waste = (sim_t - job_sim_seconds) / sim_t;
    rep.predicted_waste = pol
        .kind
        .grid_strategy()
        .map(|gs| waste_clipped(sc, gs, pol.tr))
        .unwrap_or(f64::NAN);
    rep.wall_seconds = wall_start.elapsed().as_secs_f64();
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;
    use workload::SyntheticWorkload;

    fn config(tag: &str, mu: f64, kind: PolicyKind) -> CoordinatorConfig {
        let scenario = Scenario {
            platform: Platform { mu, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 0.0, // steps drive the job size
        };
        let dir = std::env::temp_dir().join(format!(
            "ckptwin-coord-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        CoordinatorConfig {
            scenario,
            policy: Policy { kind, tr: 1200.0, tp: 180.0 },
            seconds_per_step: 30.0,
            total_steps: 400,
            ckpt_dir: dir,
            seed: 42,
            log_every: 10,
        }
    }

    #[test]
    fn fault_free_run_completes_all_steps() {
        let cfg = config("clean", 1e12, PolicyKind::IgnorePredictions);
        let mut w = SyntheticWorkload::new(64);
        let rep = run(&cfg, &mut w).unwrap();
        assert_eq!(rep.n_faults, 0);
        assert_eq!(rep.steps_executed, 400);
        assert_eq!(rep.steps_lost, 0);
        // waste == checkpoint overhead only: period = 36 steps of 30 s
        // + 120 s ckpt.
        assert!(rep.sim_waste > 0.0 && rep.sim_waste < 0.15, "{}", rep.sim_waste);
        assert!(rep.n_reg_ckpts > 0);
        // One decision-latency sample per leader-loop pass: at least one
        // per executed step, and the histogram books must balance.
        assert!(rep.decision_ns.count() >= rep.steps_executed);
        assert!(rep.decision_ns.quantile(0.99) >= rep.decision_ns.quantile(0.5));
    }

    #[test]
    fn faulty_run_recovers_and_finishes() {
        let cfg = config("faulty", 4000.0, PolicyKind::WithCkpt);
        let mut w = SyntheticWorkload::new(64);
        let rep = run(&cfg, &mut w).unwrap();
        assert!(rep.n_faults > 0);
        assert_eq!(rep.n_recoveries, rep.n_faults);
        // All validated work completed despite losses.
        assert!(rep.steps_executed >= 400);
        assert!(rep.sim_waste > 0.0 && rep.sim_waste < 1.0);
        // Loss curve is recorded and last sample reflects full progress.
        assert!(!rep.losses.is_empty());
        assert_eq!(rep.losses.last().unwrap().0, 400);
    }

    #[test]
    fn proactive_checkpoints_fire_for_prediction_aware_policies() {
        let cfg = config("pro", 6000.0, PolicyKind::WithCkpt);
        let mut w = SyntheticWorkload::new(16);
        let rep = run(&cfg, &mut w).unwrap();
        assert!(rep.n_preds_trusted > 0);
        assert!(rep.n_pro_ckpts >= rep.n_preds_trusted);
    }

    #[test]
    fn ignore_mode_takes_no_proactive_checkpoints() {
        let cfg = config("ign", 6000.0, PolicyKind::IgnorePredictions);
        let mut w = SyntheticWorkload::new(16);
        let rep = run(&cfg, &mut w).unwrap();
        assert_eq!(rep.n_pro_ckpts, 0);
        assert_eq!(rep.n_preds_trusted, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = config("det1", 5000.0, PolicyKind::NoCkpt);
        let mut w1 = SyntheticWorkload::new(16);
        let r1 = run(&cfg, &mut w1).unwrap();
        let cfg2 = CoordinatorConfig {
            ckpt_dir: cfg.ckpt_dir.with_extension("b"),
            ..cfg.clone()
        };
        let mut w2 = SyntheticWorkload::new(16);
        let r2 = run(&cfg2, &mut w2).unwrap();
        assert_eq!(r1.sim_makespan, r2.sim_makespan);
        assert_eq!(r1.n_faults, r2.n_faults);
        assert_eq!(r1.losses, r2.losses);
    }
}
