//! The checkpointing coordinator: the paper's scheduling algorithm run as a
//! *real system* rather than a simulation.
//!
//! The coordinator drives an actual workload (by default the AOT-compiled
//! transformer training step, see [`workload::PjrtWorkload`]) in **scaled
//! simulation time**: each unit of work represents `seconds_per_step`
//! seconds of an exascale job, and the fault process, prediction feed,
//! checkpoint costs (C, C_p) and downtime/recovery (D, R) all live on that
//! clock.  Model state is snapshotted to a durable, checksummed
//! [`checkpoint::CheckpointStore`]; an injected fault really destroys the
//! in-memory state and recovery really reloads the last checkpoint — so a
//! scheduling bug (checkpointing too rarely, trusting a bad predictor)
//! shows up as lost training steps and a worse loss curve, exactly the
//! waste the paper analyzes.
//!
//! Concurrency: the leader loop executes work and *defers checkpoint I/O*
//! to a writer thread (snapshots are cheap copies; serialization + fsync
//! happen off the hot path) — the standard "asynchronous checkpointing"
//! optimization.  The write is still charged C (or C_p) on the simulation
//! clock, faithful to the paper's cost model.

pub mod checkpoint;
pub mod workload;

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::campaign::grid::fnv1a64;
use crate::config::Scenario;
use crate::model::waste::waste_clipped;
use crate::obs::{Hist, SpanTimer, Stopwatch};
use crate::resilience::failpoint::{self, Site};
use crate::resilience::retry::Backoff;
use crate::resilience::snapshot::{
    plan_period_passes, CoordinatorSnapshot, SnapshotStore,
};
use crate::sim::trace::{Event, TraceStream};
use crate::strategy::{Policy, PolicyKind};
use checkpoint::CheckpointStore;
use workload::Workload;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Fault/predictor/cost parameters, on the simulation clock.
    pub scenario: Scenario,
    /// Checkpointing policy to run.
    pub policy: Policy,
    /// Simulated seconds of useful work represented by one workload step.
    pub seconds_per_step: f64,
    /// Job size in steps (overrides `scenario.job_size`).
    pub total_steps: u64,
    /// Checkpoint directory.
    pub ckpt_dir: PathBuf,
    /// Trace seed.
    pub seed: u64,
    /// Record the loss every this many validated steps (0 = every step).
    pub log_every: u64,
    /// Self-checkpointing of the coordinator's *own* state (`None` = off).
    pub selfckpt: Option<SelfCkptOptions>,
}

/// Options for the coordinator's own periodic state snapshot — the
/// checkpointing system checkpointing itself, at a period chosen by the
/// paper's first-order model from *measured* wall costs (see
/// [`crate::resilience::snapshot::plan_period_passes`]).
#[derive(Clone, Copy, Debug)]
pub struct SelfCkptOptions {
    /// Assumed coordinator crash rate: mean leader-loop passes between
    /// crashes (μ on the pass clock).  The chaos harness injects crashes
    /// at exactly this granularity via the `coord.pass` fail point.
    pub crash_mtbf_passes: f64,
    /// Re-run the period planner every this many snapshots (≥ 1).
    pub replan_every: u64,
}

impl Default for SelfCkptOptions {
    fn default() -> Self {
        SelfCkptOptions { crash_mtbf_passes: 200.0, replan_every: 1 }
    }
}

/// Outcome of a coordinator run.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// (validated step index, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Simulated makespan (s).
    pub sim_makespan: f64,
    /// Measured waste on the simulation clock.
    pub sim_waste: f64,
    /// The analytic model's prediction of the waste (Eqs. 3/14/10/4).
    pub predicted_waste: f64,
    pub n_faults: u64,
    pub n_recoveries: u64,
    pub n_reg_ckpts: u64,
    pub n_pro_ckpts: u64,
    pub n_preds_trusted: u64,
    /// Steps actually executed, including destroyed + recomputed ones.
    pub steps_executed: u64,
    /// Steps whose work was destroyed by faults.
    pub steps_lost: u64,
    /// Leader-loop passes completed (deterministic given the seed).
    pub passes: u64,
    /// Self-snapshots written.  Pacing is wall-driven, so this count may
    /// vary run to run; it is excluded from [`Report::fingerprint`].
    pub n_self_snaps: u64,
    /// Wall-clock seconds of the run.
    pub wall_seconds: f64,
    /// Wall-clock latency (ns) of each leader-loop pass: one scheduling
    /// decision plus the action it dispatched (step, checkpoint queue,
    /// recovery).  log2-bucketed; the tail exposes slow recoveries and
    /// checkpoint stalls.
    pub decision_ns: Hist,
}

impl Report {
    /// Order-stable hash of every deterministic field — the crash–resume
    /// equivalence oracle.  Wall-clock observables (`wall_seconds`,
    /// `decision_ns`, `n_self_snaps`) are excluded: self-snapshot pacing
    /// is wall-driven and must not perturb the simulated outcome.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(96 + 12 * self.losses.len());
        for &(step, loss) in &self.losses {
            bytes.extend_from_slice(&step.to_le_bytes());
            bytes.extend_from_slice(&loss.to_le_bytes());
        }
        for f in [self.sim_makespan, self.sim_waste, self.predicted_waste] {
            bytes.extend_from_slice(&f.to_bits().to_le_bytes());
        }
        for c in [
            self.n_faults,
            self.n_recoveries,
            self.n_reg_ckpts,
            self.n_pro_ckpts,
            self.n_preds_trusted,
            self.steps_executed,
            self.steps_lost,
            self.passes,
        ] {
            bytes.extend_from_slice(&c.to_le_bytes());
        }
        fnv1a64(&bytes)
    }
}

/// Stable hash of everything that shapes a run's deterministic outcome;
/// [`run_from`] refuses a self-snapshot taken under a different
/// configuration.
pub fn config_fingerprint(config: &CoordinatorConfig) -> u64 {
    fnv1a64(
        format!(
            "{:?}|{:?}|{}|{}|{}",
            config.scenario,
            config.policy,
            config.seconds_per_step,
            config.total_steps,
            config.seed,
        )
        .as_bytes(),
    )
}

enum WriterMsg {
    Save { step: u64, theta: Vec<f32> },
    /// Barrier: ack once all previously queued saves are durable.  Sent
    /// before every recovery so "what is on disk" is deterministic.
    Sync(mpsc::Sender<()>),
    Stop,
}

/// Run the coordinator to completion.
pub fn run(config: &CoordinatorConfig, workload: &mut dyn Workload) -> Result<Report> {
    run_from(config, workload, None)
}

/// Run the coordinator, optionally resuming from a self-snapshot a crashed
/// (or killed) earlier run left behind.  A resumed run restores the full
/// deterministic state at the snapshot's pass boundary — simulation clock,
/// counters, loss curve, workload parameters, trace-stream position — and
/// produces a [`Report`] with the *same* [`Report::fingerprint`] as an
/// uninterrupted run; `ckptwin chaos` gates on exactly that equivalence.
pub fn run_from(
    config: &CoordinatorConfig,
    workload: &mut dyn Workload,
    resume: Option<&CoordinatorSnapshot>,
) -> Result<Report> {
    let sc = &config.scenario;
    let pol = &config.policy;
    pol.validate(sc);
    let sps = config.seconds_per_step;
    assert!(sps > 0.0);
    let job_steps = config.total_steps;

    // Regular-mode period in steps (the work part of T_R).
    let steps_per_period =
        (((pol.tr - sc.platform.c) / sps).round() as u64).max(1);
    // WithCkpt proactive period in steps.
    let steps_per_pro_period =
        (((pol.tp - sc.platform.cp) / sps).round() as u64).max(1);

    let store = CheckpointStore::new(&config.ckpt_dir, 4)?;
    let (tx, rx) = mpsc::channel::<WriterMsg>();
    let writer_dir = config.ckpt_dir.clone();
    let writer = std::thread::spawn(move || -> Result<u64> {
        let store = CheckpointStore::new(&writer_dir, 4)?;
        let mut written = 0;
        while let Ok(msg) = rx.recv() {
            match msg {
                WriterMsg::Save { step, theta } => {
                    store.save(step, &theta)?;
                    written += 1;
                }
                WriterMsg::Sync(ack) => {
                    let _ = ack.send(());
                }
                WriterMsg::Stop => break,
            }
        }
        Ok(written)
    });

    let mut stream = TraceStream::new(sc, config.seed);
    let mut next_ev = stream.next_event();
    // Trace events consumed so far (the pop above is #1).  A self-snapshot
    // records this count; resume re-derives the stream from the seed and
    // fast-forwards to the same position.
    let mut events_consumed: u64 = 1;

    let cfg_fp = config_fingerprint(config);
    let wall_start = Instant::now();
    let mut rep = Report::default();
    let mut sim_t = 0.0f64;
    // Validated = secured by the last completed checkpoint; `since` = steps
    // done since then (lost on fault).
    let mut validated: u64 = 0;
    let mut since: u64 = 0;
    let mut period_done: u64 = 0; // steps completed in the current period
    let mut passes: u64 = 0; // completed leader-loop passes

    match resume {
        None => {
            // A fresh run owns the directory's future: drop checkpoints a
            // previous (crashed) run may have left past step 0 — recovery
            // must never load state from a different history.  Then take
            // checkpoint step-0 so recovery always has something to load.
            store.remove_after(0)?;
            store.save(0, &workload.snapshot())?;
        }
        Some(snap) => {
            if snap.config_fingerprint != cfg_fp {
                return Err(anyhow!(
                    "self-snapshot belongs to a different configuration \
                     ({:016x} != {:016x})",
                    snap.config_fingerprint,
                    cfg_fp
                ));
            }
            for _ in 1..snap.events_consumed {
                next_ev = stream.next_event();
            }
            events_consumed = snap.events_consumed;
            sim_t = snap.sim_t;
            validated = snap.validated;
            since = snap.since;
            period_done = snap.period_done;
            passes = snap.passes;
            let [nf, nr, nc, np, nt, se, sl] = snap.counters;
            rep.n_faults = nf;
            rep.n_recoveries = nr;
            rep.n_reg_ckpts = nc;
            rep.n_pro_ckpts = np;
            rep.n_preds_trusted = nt;
            rep.steps_executed = se;
            rep.steps_lost = sl;
            rep.losses = snap.losses.clone();
            workload.restore(snap.workload.clone())?;
            // Durable hygiene: the crashed run's async writer may have
            // persisted checkpoints *past* the snapshot point — drop them
            // so `load_latest` agrees with the restored state — and
            // re-seed `validated` in case retention already evicted it.
            store.remove_after(snap.validated)?;
            store.save(snap.validated, &snap.ckpt_theta)?;
        }
    }

    // Self-checkpointing bookkeeping.  Pacing is wall-clock-driven, but a
    // snapshot has no simulation-clock effect, so the deterministic outcome
    // (and Report::fingerprint) is identical with it on or off.
    let snap_store = match &config.selfckpt {
        Some(_) => Some(SnapshotStore::new(&config.ckpt_dir)?),
        None => None,
    };
    let mut period_passes: u64 = 16; // bootstrap until costs are measured
    let mut next_snap_pass: u64 = passes + period_passes;
    let mut pass_ns_total: u64 = 0;
    let mut snap_ns_total: u64 = 0;

    // --- helpers -----------------------------------------------------------
    macro_rules! pop_event {
        () => {{
            next_ev = stream.next_event();
            events_consumed += 1;
        }};
    }

    // Process a fault at `tf`: destroy unvalidated work, restore, serve D+R.
    macro_rules! serve_fault {
        ($tf:expr) => {{
            rep.n_faults += 1;
            period_done = 0;
            sim_t = $tf + sc.platform.d + sc.platform.r;
            // Drain the async writer before reading "latest": recovery
            // must see a deterministic durable state.
            let (ack_tx, ack_rx) = mpsc::channel();
            tx.send(WriterMsg::Sync(ack_tx))
                .map_err(|_| anyhow!("checkpoint writer died"))?;
            ack_rx
                .recv()
                .map_err(|_| anyhow!("checkpoint writer died"))?;
            let (step, theta) = store
                .load_latest()?
                .ok_or_else(|| anyhow!("no checkpoint to recover from"))?;
            debug_assert!(step <= validated);
            workload.restore(theta)?;
            // Everything past the last *durable* checkpoint is destroyed:
            // the unvalidated steps, plus any validated-on-the-sim-clock
            // steps whose async write had not landed yet.  All of them are
            // honestly re-executed by the main loop.
            rep.steps_lost += since + (validated - step);
            since = 0;
            validated = step;
            rep.n_recoveries += 1;
        }};
    }

    // Commit a checkpoint at the current sim time (charged `cost` sim s).
    macro_rules! commit_ckpt {
        ($cost:expr, $proactive:expr) => {{
            sim_t += $cost;
            validated += since;
            since = 0;
            tx.send(WriterMsg::Save {
                step: validated,
                theta: workload.snapshot(),
            })
            .map_err(|_| anyhow!("checkpoint writer died"))?;
            if $proactive {
                rep.n_pro_ckpts += 1;
            } else {
                rep.n_reg_ckpts += 1;
            }
        }};
    }

    // Execute one real step spanning [sim_t, sim_t + sps); returns false if
    // a fault destroyed it.
    macro_rules! do_step {
        () => {{
            let loss = workload.step()?;
            rep.steps_executed += 1;
            let step_end = sim_t + sps;
            // Did a fault strike during this step?
            let mut destroyed = false;
            while next_ev.time() < step_end {
                match next_ev {
                    Event::Fault { t, .. } => {
                        pop_event!();
                        serve_fault!(t);
                        destroyed = true;
                        break;
                    }
                    Event::Prediction(_) => {
                        // Handled at step boundaries; requeue by deferring:
                        // predictions inside a step take effect after it.
                        break;
                    }
                }
            }
            if !destroyed {
                sim_t = step_end;
                since += 1;
                let total = validated + since;
                if config.log_every == 0 || total % config.log_every.max(1) == 0 {
                    rep.losses.push((total, loss));
                }
            }
            !destroyed
        }};
    }

    // Serve downtime-phase events (faults during checkpoints etc.).
    // Advance sim_t to `end` unless a fault intervenes; true if clean.
    macro_rules! advance_no_work {
        ($end:expr) => {{
            let mut clean = true;
            while next_ev.time() < $end {
                match next_ev {
                    Event::Fault { t, .. } => {
                        pop_event!();
                        serve_fault!(t);
                        clean = false;
                        break;
                    }
                    Event::Prediction(_) => {
                        pop_event!(); // ignored in this phase
                    }
                }
            }
            if clean {
                sim_t = $end;
            }
            clean
        }};
    }

    // --- main loop ---------------------------------------------------------
    // One latency sample per leader-loop pass.  The `continue 'outer`
    // jumps inside the macros bypass any end-of-iteration code, so each
    // pass is closed out (and its span recorded) at the top of the next.
    let mut decisions = Stopwatch::new();
    let mut pass_timer: Option<SpanTimer> = None;
    'outer: while validated + since < job_steps {
        if let Some(t) = pass_timer {
            let ns = t.elapsed_nanos();
            pass_ns_total += ns;
            decisions.record_nanos(ns);
        }
        pass_timer = Some(SpanTimer::start());
        // 0a. Self-snapshot at the pass boundary.  The state captured here
        // is exactly the resume point: `passes` passes completed, the next
        // one not yet started — `run_from` re-executes it from the top.
        if let (Some(opts), Some(snaps)) = (&config.selfckpt, &snap_store) {
            if passes >= next_snap_pass {
                let t0 = Instant::now();
                // Drain the writer so `validated` is durable and loadable.
                let (ack_tx, ack_rx) = mpsc::channel();
                tx.send(WriterMsg::Sync(ack_tx))
                    .map_err(|_| anyhow!("checkpoint writer died"))?;
                ack_rx
                    .recv()
                    .map_err(|_| anyhow!("checkpoint writer died"))?;
                let snap = CoordinatorSnapshot {
                    config_fingerprint: cfg_fp,
                    passes,
                    sim_t,
                    validated,
                    since,
                    period_done,
                    events_consumed,
                    counters: [
                        rep.n_faults,
                        rep.n_recoveries,
                        rep.n_reg_ckpts,
                        rep.n_pro_ckpts,
                        rep.n_preds_trusted,
                        rep.steps_executed,
                        rep.steps_lost,
                    ],
                    losses: rep.losses.clone(),
                    workload: workload.snapshot(),
                    ckpt_theta: store.load(validated)?,
                };
                Backoff::default().run(|_attempt| snaps.save(&snap))?;
                rep.n_self_snaps += 1;
                snap_ns_total += t0.elapsed().as_nanos() as u64;
                // Dogfood: replan the snapshot period with the repo's own
                // first-order optimum, fed the *measured* mean pass and
                // snapshot costs and the assumed crash rate.
                if rep.n_self_snaps % opts.replan_every.max(1) == 0 {
                    let mean_pass =
                        pass_ns_total as f64 / 1e9 / passes.max(1) as f64;
                    let mean_snap = snap_ns_total as f64
                        / 1e9
                        / rep.n_self_snaps as f64;
                    period_passes = plan_period_passes(
                        mean_snap,
                        mean_pass,
                        opts.crash_mtbf_passes,
                    );
                }
                next_snap_pass = passes + period_passes;
            }
        }
        // 0b. Fail point `coord.pass`: the chaos harness crashes runs here
        // (error, panic, or hard kill) and resumes them from the snapshot.
        if let Some(inj) = failpoint::check(Site::CoordPass) {
            inj.trigger()?;
        }
        passes += 1;
        // 1. Consume any event already due at sim_t.
        while next_ev.time() <= sim_t {
            match next_ev {
                Event::Fault { t, .. } => {
                    pop_event!();
                    serve_fault!(t);
                    continue 'outer;
                }
                Event::Prediction(p) => {
                    pop_event!();
                    if !matches!(pol.kind, PolicyKind::IgnorePredictions)
                        && p.window_end > sim_t
                    {
                        rep.n_preds_trusted += 1;
                        // Pre-window proactive checkpoint.
                        let ck_end = sim_t + sc.platform.cp;
                        if advance_no_work!(ck_end) {
                            commit_ckpt!(0.0, true); // time already advanced
                        } else {
                            continue 'outer;
                        }
                        // In-window behaviour.  The step-driven coordinator mirrors
                        // the discrete-event engine's policy logics at
                        // step granularity; randomized trust (QTrust) runs
                        // its base NoCkpt behaviour with q treated as 1 —
                        // the real system always acts on what it trusts.
                        match pol.kind {
                            PolicyKind::Instant
                            | PolicyKind::ExactPred
                            | PolicyKind::IgnorePredictions => {}
                            PolicyKind::NoCkpt | PolicyKind::QTrust { .. } => {
                                while sim_t < p.window_end
                                    && validated + since < job_steps
                                {
                                    if !do_step!() {
                                        continue 'outer;
                                    }
                                }
                            }
                            PolicyKind::WindowEndCkpt => {
                                while sim_t < p.window_end
                                    && validated + since < job_steps
                                {
                                    if !do_step!() {
                                        continue 'outer;
                                    }
                                }
                                // Terminal proactive checkpoint at t0 + I —
                                // pointless (and never taken by the
                                // engine's logic) once the job finished
                                // in-window.
                                if validated + since < job_steps {
                                    let ck_end = sim_t + sc.platform.cp;
                                    if advance_no_work!(ck_end) {
                                        commit_ckpt!(0.0, true);
                                    } else {
                                        continue 'outer;
                                    }
                                }
                            }
                            PolicyKind::WithCkpt => {
                                while sim_t < p.window_end
                                    && validated + since < job_steps
                                {
                                    for _ in 0..steps_per_pro_period {
                                        if sim_t >= p.window_end
                                            || validated + since >= job_steps
                                        {
                                            break;
                                        }
                                        if !do_step!() {
                                            continue 'outer;
                                        }
                                    }
                                    let ck_end = sim_t + sc.platform.cp;
                                    if advance_no_work!(ck_end) {
                                        commit_ckpt!(0.0, true);
                                    } else {
                                        continue 'outer;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }

        // 2. Regular-mode work.
        if period_done < steps_per_period {
            if do_step!() {
                period_done += 1;
            }
            continue 'outer;
        }

        // 3. Regular checkpoint.
        let ck_end = sim_t + sc.platform.c;
        if advance_no_work!(ck_end) {
            commit_ckpt!(0.0, false);
            period_done = 0;
        }
    }

    if let Some(t) = pass_timer {
        decisions.record_nanos(t.elapsed_nanos());
    }
    rep.decision_ns = decisions.take();
    rep.passes = passes;

    tx.send(WriterMsg::Stop).ok();
    writer
        .join()
        .map_err(|_| anyhow!("writer thread panicked"))??;

    rep.sim_makespan = sim_t;
    let job_sim_seconds = job_steps as f64 * sps;
    rep.sim_waste = (sim_t - job_sim_seconds) / sim_t;
    rep.predicted_waste = pol
        .kind
        .grid_strategy()
        .map(|gs| waste_clipped(sc, gs, pol.tr))
        .unwrap_or(f64::NAN);
    rep.wall_seconds = wall_start.elapsed().as_secs_f64();
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;
    use workload::SyntheticWorkload;

    fn config(tag: &str, mu: f64, kind: PolicyKind) -> CoordinatorConfig {
        let scenario = Scenario {
            platform: Platform { mu, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 0.0, // steps drive the job size
        };
        let dir = std::env::temp_dir().join(format!(
            "ckptwin-coord-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        CoordinatorConfig {
            scenario,
            policy: Policy { kind, tr: 1200.0, tp: 180.0 },
            seconds_per_step: 30.0,
            total_steps: 400,
            ckpt_dir: dir,
            seed: 42,
            log_every: 10,
            selfckpt: None,
        }
    }

    #[test]
    fn fault_free_run_completes_all_steps() {
        let cfg = config("clean", 1e12, PolicyKind::IgnorePredictions);
        let mut w = SyntheticWorkload::new(64);
        let rep = run(&cfg, &mut w).unwrap();
        assert_eq!(rep.n_faults, 0);
        assert_eq!(rep.steps_executed, 400);
        assert_eq!(rep.steps_lost, 0);
        // waste == checkpoint overhead only: period = 36 steps of 30 s
        // + 120 s ckpt.
        assert!(rep.sim_waste > 0.0 && rep.sim_waste < 0.15, "{}", rep.sim_waste);
        assert!(rep.n_reg_ckpts > 0);
        // One decision-latency sample per leader-loop pass: at least one
        // per executed step, and the histogram books must balance.
        assert!(rep.decision_ns.count() >= rep.steps_executed);
        assert!(rep.decision_ns.quantile(0.99) >= rep.decision_ns.quantile(0.5));
    }

    #[test]
    fn faulty_run_recovers_and_finishes() {
        let cfg = config("faulty", 4000.0, PolicyKind::WithCkpt);
        let mut w = SyntheticWorkload::new(64);
        let rep = run(&cfg, &mut w).unwrap();
        assert!(rep.n_faults > 0);
        assert_eq!(rep.n_recoveries, rep.n_faults);
        // All validated work completed despite losses.
        assert!(rep.steps_executed >= 400);
        assert!(rep.sim_waste > 0.0 && rep.sim_waste < 1.0);
        // Loss curve is recorded and last sample reflects full progress.
        assert!(!rep.losses.is_empty());
        assert_eq!(rep.losses.last().unwrap().0, 400);
    }

    #[test]
    fn proactive_checkpoints_fire_for_prediction_aware_policies() {
        let cfg = config("pro", 6000.0, PolicyKind::WithCkpt);
        let mut w = SyntheticWorkload::new(16);
        let rep = run(&cfg, &mut w).unwrap();
        assert!(rep.n_preds_trusted > 0);
        assert!(rep.n_pro_ckpts >= rep.n_preds_trusted);
    }

    #[test]
    fn ignore_mode_takes_no_proactive_checkpoints() {
        let cfg = config("ign", 6000.0, PolicyKind::IgnorePredictions);
        let mut w = SyntheticWorkload::new(16);
        let rep = run(&cfg, &mut w).unwrap();
        assert_eq!(rep.n_pro_ckpts, 0);
        assert_eq!(rep.n_preds_trusted, 0);
    }

    #[test]
    fn self_snapshots_do_not_perturb_the_deterministic_outcome() {
        let base = config("snapoff", 4000.0, PolicyKind::WithCkpt);
        let mut w1 = SyntheticWorkload::new(32);
        let plain = run(&base, &mut w1).unwrap();
        assert_eq!(plain.n_self_snaps, 0);
        let with_snap = CoordinatorConfig {
            ckpt_dir: base.ckpt_dir.with_extension("snap"),
            selfckpt: Some(SelfCkptOptions::default()),
            ..base.clone()
        };
        let _ = std::fs::remove_dir_all(&with_snap.ckpt_dir);
        let mut w2 = SyntheticWorkload::new(32);
        let snapped = run(&with_snap, &mut w2).unwrap();
        assert!(snapped.n_self_snaps >= 1, "no snapshot in {} passes", snapped.passes);
        assert_eq!(snapped.fingerprint(), plain.fingerprint());
        assert_eq!(snapped.losses, plain.losses);
        assert_eq!(snapped.passes, plain.passes);
    }

    #[test]
    fn resume_from_self_snapshot_reproduces_the_golden_report() {
        let mut cfg = config("resume", 4000.0, PolicyKind::WithCkpt);
        cfg.selfckpt = Some(SelfCkptOptions::default());
        let mut w = SyntheticWorkload::new(32);
        let golden = run(&cfg, &mut w).unwrap();
        assert!(golden.n_self_snaps >= 1);
        // The completed run left its last self-snapshot behind.  Resume
        // from it with a fresh workload, exactly as a restarted process
        // would — the checkpoint dir still holds files written *after*
        // the snapshot, so this also exercises `remove_after` hygiene.
        let snap = SnapshotStore::new(&cfg.ckpt_dir)
            .unwrap()
            .load()
            .unwrap()
            .expect("snapshot written");
        assert!(snap.passes < golden.passes);
        let mut w2 = SyntheticWorkload::new(32);
        let resumed = run_from(&cfg, &mut w2, Some(&snap)).unwrap();
        assert_eq!(resumed.fingerprint(), golden.fingerprint());
        assert_eq!(resumed.losses, golden.losses);
        assert_eq!(resumed.sim_makespan, golden.sim_makespan);
        assert_eq!(resumed.steps_executed, golden.steps_executed);
    }

    #[test]
    fn resume_rejects_mismatched_configuration() {
        let mut cfg = config("fpmismatch", 1e12, PolicyKind::IgnorePredictions);
        cfg.selfckpt = Some(SelfCkptOptions::default());
        let mut w = SyntheticWorkload::new(8);
        run(&cfg, &mut w).unwrap();
        let snap = SnapshotStore::new(&cfg.ckpt_dir)
            .unwrap()
            .load()
            .unwrap()
            .expect("snapshot written");
        let mut other = cfg.clone();
        other.seed = cfg.seed + 1;
        let mut w2 = SyntheticWorkload::new(8);
        let err = run_from(&other, &mut w2, Some(&snap)).unwrap_err();
        assert!(
            err.to_string().contains("different configuration"),
            "{err}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = config("det1", 5000.0, PolicyKind::NoCkpt);
        let mut w1 = SyntheticWorkload::new(16);
        let r1 = run(&cfg, &mut w1).unwrap();
        let cfg2 = CoordinatorConfig {
            ckpt_dir: cfg.ckpt_dir.with_extension("b"),
            ..cfg.clone()
        };
        let mut w2 = SyntheticWorkload::new(16);
        let r2 = run(&cfg2, &mut w2).unwrap();
        assert_eq!(r1.sim_makespan, r2.sim_makespan);
        assert_eq!(r1.n_faults, r2.n_faults);
        assert_eq!(r1.losses, r2.losses);
    }
}
