//! Durable checkpoint store: atomic, checksummed snapshots of the model
//! state (the flat `theta` vector), with retention of the last K versions.
//!
//! File format (little-endian):
//! ```text
//! magic   "CKPTWIN1"            8 bytes
//! step    u64                   8 bytes
//! len     u64 (f32 count)       8 bytes
//! payload len * 4 bytes
//! crc32   u32 over payload      4 bytes
//! ```
//! Writes go to a temp file + `rename` so a fault (or a killed process)
//! can never leave a torn checkpoint behind — exactly the property the
//! paper's model assumes of checkpoint C.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

const MAGIC: &[u8; 8] = b"CKPTWIN1";

/// CRC-32 (IEEE 802.3); canonical implementation lives in [`crate::util`].
pub use crate::util::crc32;

/// A checkpoint directory with retention.
pub struct CheckpointStore {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointStore {
    pub fn new(dir: impl AsRef<Path>, keep: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(CheckpointStore { dir, keep: keep.max(1) })
    }

    fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt-{step:012}.bin"))
    }

    /// Atomically persist a snapshot taken at `step`.
    pub fn save(&self, step: u64, theta: &[f32]) -> Result<PathBuf> {
        let final_path = self.path_for(step);
        let tmp_path = self.dir.join(format!(".tmp-{step:012}"));
        let payload: Vec<u8> =
            theta.iter().flat_map(|f| f.to_le_bytes()).collect();
        {
            let mut f = fs::File::create(&tmp_path)
                .with_context(|| format!("creating {}", tmp_path.display()))?;
            f.write_all(MAGIC)?;
            f.write_all(&step.to_le_bytes())?;
            f.write_all(&(theta.len() as u64).to_le_bytes())?;
            f.write_all(&payload)?;
            f.write_all(&crc32(&payload).to_le_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp_path, &final_path)?;
        self.retain()?;
        Ok(final_path)
    }

    /// List available checkpoint steps, ascending.
    pub fn steps(&self) -> Result<Vec<u64>> {
        let mut steps = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name
                .strip_prefix("ckpt-")
                .and_then(|s| s.strip_suffix(".bin"))
            {
                if let Ok(step) = num.parse::<u64>() {
                    steps.push(step);
                }
            }
        }
        steps.sort_unstable();
        Ok(steps)
    }

    /// Load the snapshot for `step`.
    pub fn load(&self, step: u64) -> Result<Vec<f32>> {
        let path = self.path_for(step);
        let mut bytes = Vec::new();
        fs::File::open(&path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 28 || &bytes[..8] != MAGIC {
            return Err(anyhow!("{}: bad magic/size", path.display()));
        }
        let stored_step = u64::from_le_bytes(bytes[8..16].try_into()?);
        if stored_step != step {
            return Err(anyhow!("{}: step mismatch", path.display()));
        }
        let len = u64::from_le_bytes(bytes[16..24].try_into()?) as usize;
        let payload_end = 24 + len * 4;
        if bytes.len() != payload_end + 4 {
            return Err(anyhow!("{}: truncated", path.display()));
        }
        let payload = &bytes[24..payload_end];
        let stored_crc =
            u32::from_le_bytes(bytes[payload_end..payload_end + 4].try_into()?);
        if crc32(payload) != stored_crc {
            return Err(anyhow!("{}: checksum mismatch", path.display()));
        }
        Ok(payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Load the most recent checkpoint, if any: `(step, theta)`.
    pub fn load_latest(&self) -> Result<Option<(u64, Vec<f32>)>> {
        match self.steps()?.last() {
            None => Ok(None),
            Some(&step) => Ok(Some((step, self.load(step)?))),
        }
    }

    /// Delete every checkpoint taken after `step`.
    ///
    /// Crash–resume hygiene: the coordinator's async writer may have
    /// persisted checkpoints *ahead* of the state a resumed run restores
    /// (its snapshot captures `validated` at snapshot time).  Dropping the
    /// future ones makes `load_latest` agree with the restored state, so a
    /// replayed run serves faults from the same checkpoint the original
    /// would have.
    pub fn remove_after(&self, step: u64) -> Result<()> {
        for s in self.steps()? {
            if s > step {
                let _ = fs::remove_file(self.path_for(s));
            }
        }
        Ok(())
    }

    fn retain(&self) -> Result<()> {
        let steps = self.steps()?;
        if steps.len() > self.keep {
            for &old in &steps[..steps.len() - self.keep] {
                let _ = fs::remove_file(self.path_for(old));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ckptwin-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let store = CheckpointStore::new(tmpdir("rt"), 3).unwrap();
        let theta: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        store.save(42, &theta).unwrap();
        let loaded = store.load(42).unwrap();
        assert_eq!(theta, loaded);
        let (step, latest) = store.load_latest().unwrap().unwrap();
        assert_eq!(step, 42);
        assert_eq!(latest, theta);
    }

    #[test]
    fn retention_keeps_last_k() {
        let store = CheckpointStore::new(tmpdir("keep"), 2).unwrap();
        for step in [1u64, 2, 3, 4] {
            store.save(step, &[step as f32]).unwrap();
        }
        assert_eq!(store.steps().unwrap(), vec![3, 4]);
        assert!(store.load(1).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("corrupt");
        let store = CheckpointStore::new(&dir, 3).unwrap();
        let path = store.save(7, &[1.0, 2.0, 3.0]).unwrap();
        // Flip one payload byte.
        let mut bytes = fs::read(&path).unwrap();
        bytes[25] ^= 0xFF;
        fs::write(&path, bytes).unwrap();
        assert!(store.load(7).is_err());
    }

    #[test]
    fn empty_store() {
        let store = CheckpointStore::new(tmpdir("empty"), 3).unwrap();
        assert!(store.load_latest().unwrap().is_none());
    }

    #[test]
    fn crc32_known_vector() {
        // Standard test vector: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn remove_after_drops_future_checkpoints() {
        let store = CheckpointStore::new(tmpdir("rmafter"), 10).unwrap();
        for step in [1u64, 5, 9, 12] {
            store.save(step, &[step as f32]).unwrap();
        }
        store.remove_after(5).unwrap();
        assert_eq!(store.steps().unwrap(), vec![1, 5]);
        let (step, _) = store.load_latest().unwrap().unwrap();
        assert_eq!(step, 5);
    }
}
