//! Workloads the coordinator can checkpoint.
//!
//! Two implementations of the [`Workload`] trait:
//! * [`PjrtWorkload`] — the real thing: the transformer-LM training step
//!   executed via the AOT artifact ([`crate::runtime::train::Trainer`]),
//!   fed with a synthetic byte-level corpus generated here;
//! * [`SyntheticWorkload`] — a deterministic stand-in (geometric "loss"
//!   decay, state = step counter + pseudo-params) so coordinator logic is
//!   testable without artifacts / PJRT.

use anyhow::Result;

use crate::runtime::train::Trainer;
use crate::runtime::Runtime;
use crate::sim::rng::Rng;

/// A checkpointable unit-of-work producer.
pub trait Workload {
    /// Run one unit of work; returns a progress metric (training loss).
    fn step(&mut self) -> Result<f32>;
    /// Snapshot the full state (the checkpoint payload).
    fn snapshot(&self) -> Vec<f32>;
    /// Restore state from a snapshot.
    fn restore(&mut self, state: Vec<f32>) -> Result<()>;
    /// Human label for logs.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Synthetic corpus (shared by the real workload and the examples)
// ---------------------------------------------------------------------------

/// Generate a byte-level corpus with learnable structure: a second-order
/// Markov chain over a small alphabet with occasional noise.  A tiny
/// transformer reliably reduces its cross-entropy within a few hundred
/// steps, giving the e2e driver a meaningful loss curve.
pub fn synthetic_corpus(len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::stream(seed, 0xc0de);
    // Alphabet of 32 symbols; transition table biased to 4 successors.
    const ALPHA: usize = 32;
    let mut succ = [[0u8; 4]; ALPHA * ALPHA];
    for row in succ.iter_mut() {
        for slot in row.iter_mut() {
            *slot = rng.below(ALPHA) as u8;
        }
    }
    let mut out = Vec::with_capacity(len);
    let (mut a, mut b) = (0usize, 1usize);
    for _ in 0..len {
        let next = if rng.bernoulli(0.05) {
            rng.below(ALPHA) as u8 // noise
        } else {
            succ[a * ALPHA + b][rng.below(4)]
        };
        out.push(next + b'a' - b'a'); // symbols 0..32 map into vocab range
        a = b;
        b = next as usize;
    }
    out
}

/// Sample a training batch (batch × seq_len token ids) from the corpus.
pub fn sample_batch(
    corpus: &[u8],
    batch: usize,
    seq_len: usize,
    rng: &mut Rng,
) -> Vec<i32> {
    let mut tokens = Vec::with_capacity(batch * seq_len);
    for _ in 0..batch {
        let start = rng.below(corpus.len() - seq_len);
        tokens.extend(
            corpus[start..start + seq_len].iter().map(|&b| b as i32),
        );
    }
    tokens
}

// ---------------------------------------------------------------------------
// Real workload: PJRT transformer training
// ---------------------------------------------------------------------------

/// Transformer-LM training through the AOT artifacts.
pub struct PjrtWorkload<'rt> {
    trainer: Trainer<'rt>,
    corpus: Vec<u8>,
    rng: Rng,
    lr: f32,
    batch: usize,
    seq_len: usize,
}

impl<'rt> PjrtWorkload<'rt> {
    pub fn new(rt: &'rt Runtime, seed: u64, lr: f32) -> Result<Self> {
        let trainer = Trainer::new(rt, seed as u32)?;
        let corpus = synthetic_corpus(1 << 18, seed);
        Ok(PjrtWorkload {
            trainer,
            corpus,
            rng: Rng::stream(seed, 0xba7c4),
            lr,
            batch: rt.manifest.batch,
            seq_len: rt.manifest.seq_len,
        })
    }
}

impl Workload for PjrtWorkload<'_> {
    fn step(&mut self) -> Result<f32> {
        let tokens =
            sample_batch(&self.corpus, self.batch, self.seq_len, &mut self.rng);
        self.trainer.step(&tokens, self.lr)
    }

    fn snapshot(&self) -> Vec<f32> {
        self.trainer.snapshot()
    }

    fn restore(&mut self, state: Vec<f32>) -> Result<()> {
        self.trainer.restore(state)
    }

    fn name(&self) -> &'static str {
        "transformer-lm (PJRT)"
    }
}

// ---------------------------------------------------------------------------
// Synthetic workload (tests / artifact-free runs)
// ---------------------------------------------------------------------------

/// Deterministic pseudo-training: loss decays geometrically with steps;
/// state is (step count, a small param vector).  Restoring an old snapshot
/// rewinds the loss — so checkpoint/recovery bugs are observable.
pub struct SyntheticWorkload {
    step: u64,
    params: Vec<f32>,
}

impl SyntheticWorkload {
    pub fn new(n_params: usize) -> Self {
        SyntheticWorkload { step: 0, params: vec![0.0; n_params.max(1)] }
    }

    pub fn loss_at(step: u64) -> f32 {
        4.0 * (-(step as f32) / 200.0).exp() + 1.0
    }
}

impl Workload for SyntheticWorkload {
    fn step(&mut self) -> Result<f32> {
        self.step += 1;
        self.params[0] = self.step as f32;
        for (i, p) in self.params.iter_mut().enumerate().skip(1) {
            *p = (self.step as f32 * 0.01 + i as f32).sin();
        }
        Ok(Self::loss_at(self.step))
    }

    fn snapshot(&self) -> Vec<f32> {
        self.params.clone()
    }

    fn restore(&mut self, state: Vec<f32>) -> Result<()> {
        self.step = state[0] as u64;
        self.params = state;
        Ok(())
    }

    fn name(&self) -> &'static str {
        "synthetic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_in_vocab_range_and_deterministic() {
        let a = synthetic_corpus(10_000, 1);
        let b = synthetic_corpus(10_000, 1);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| x < 32)); // alphabet of 32 symbols
        // Structured: the distribution must be far from uniform.
        let mut counts = [0usize; 256];
        for &x in &a {
            counts[x as usize] += 1;
        }
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(nonzero <= 32, "{nonzero}");
    }

    #[test]
    fn batches_shaped_and_in_range() {
        let corpus = synthetic_corpus(10_000, 2);
        let mut rng = Rng::new(3);
        let batch = sample_batch(&corpus, 8, 128, &mut rng);
        assert_eq!(batch.len(), 8 * 128);
        assert!(batch.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn synthetic_workload_rewinds_on_restore() {
        let mut w = SyntheticWorkload::new(8);
        for _ in 0..10 {
            w.step().unwrap();
        }
        let snap = w.snapshot();
        let l10 = SyntheticWorkload::loss_at(10);
        for _ in 0..10 {
            w.step().unwrap();
        }
        let l20 = w.step().unwrap();
        assert!(l20 < l10);
        w.restore(snap).unwrap();
        let l11 = w.step().unwrap();
        assert!((l11 - SyntheticWorkload::loss_at(11)).abs() < 1e-6);
    }
}
