//! Summary statistics for repeated simulation instances (the paper reports
//! averages over 100 randomly generated instances per point).

/// Online (Welford) accumulator plus order statistics.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut s = Self::new();
        for v in iter {
            s.push(v);
        }
        s
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        let n = self.values.len() as f64;
        let delta = v - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (v - self.mean);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n - 1 denominator).
    pub fn var(&self) -> f64 {
        if self.values.len() < 2 {
            0.0
        } else {
            self.m2 / (self.values.len() - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Half-width of the normal-approximation 95% confidence interval.
    pub fn ci95(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            1.96 * self.std() / (self.values.len() as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolation percentile, q in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(f64::total_cmp);
        let pos = q * (sorted.len() - 1) as f64;
        let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample std of this classic dataset = sqrt(32/7).
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_iter((1..=100).map(|i| i as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::from_iter((0..10).map(|i| (i % 2) as f64));
        let b = Summary::from_iter((0..1000).map(|i| (i % 2) as f64));
        assert!(b.ci95() < a.ci95());
    }

    #[test]
    fn single_value() {
        let s = Summary::from_iter([3.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.median(), 3.0);
    }
}
