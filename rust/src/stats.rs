//! Summary statistics for repeated simulation instances (the paper reports
//! averages over 100 randomly generated instances per point).
//!
//! Two accumulators:
//! * [`Welford`] — constant-memory streaming mean/variance/CI with an
//!   order-deterministic merge (Chan et al.), the unit of aggregation of
//!   the campaign engine: memory stays O(cells) no matter how many
//!   instances fan out per cell.
//! * [`Summary`] — [`Welford`] plus retained values for order statistics
//!   (percentiles/median), used where quantiles are reported.
//!
//! Plus the statistical assertion toolkit shared by the conformance
//! subsystem (`crate::validate`) and the test suites:
//! * [`paired_diff`] — Welford over element-wise differences of two paired
//!   samples (the CI of a *paired* comparison, the paper's methodology);
//! * [`ks_statistic`] / [`ks_critical`] — one-sample Kolmogorov–Smirnov
//!   distance against an analytic CDF, with asymptotic critical values
//!   (goodness-of-fit oracles for `sim::distribution`);
//! * [`excess_deviation`] — the part of |observed − expected| that a
//!   CI-sized noise allowance cannot explain (tolerance verdicts).

/// Constant-memory online accumulator: Welford mean/variance plus min/max.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Welford {
    fn default() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut w = Self::new();
        for v in iter {
            w.push(v);
        }
        w
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let delta = v - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Merge another accumulator into this one (Chan et al.'s parallel
    /// update).  Floating-point results depend on merge *order*, so callers
    /// that need run-to-run determinism (the campaign scheduler) must merge
    /// partials in a fixed order regardless of completion order.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let n = na + nb;
        let delta = other.mean - self.mean;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.mean += delta * nb / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n - 1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Half-width of the normal-approximation 95% confidence interval.
    pub fn ci95(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            1.96 * self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// [`Welford`] plus retained values for order statistics.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    w: Welford,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut s = Self::new();
        for v in iter {
            s.push(v);
        }
        s
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        self.w.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    /// Sample variance (n - 1 denominator).
    pub fn var(&self) -> f64 {
        self.w.var()
    }

    pub fn std(&self) -> f64 {
        self.w.std()
    }

    /// Half-width of the normal-approximation 95% confidence interval.
    pub fn ci95(&self) -> f64 {
        self.w.ci95()
    }

    pub fn min(&self) -> f64 {
        self.w.min()
    }

    pub fn max(&self) -> f64 {
        self.w.max()
    }

    /// Linear-interpolation percentile, q in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(f64::total_cmp);
        let pos = q * (sorted.len() - 1) as f64;
        let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

// ---------------------------------------------------------------------------
// Statistical assertion toolkit
// ---------------------------------------------------------------------------

/// Welford accumulator over the element-wise differences `xs[i] - ys[i]` of
/// two paired samples.  `mean()` is the mean paired difference and `ci95()`
/// its confidence half-width — much tighter than differencing two marginal
/// CIs when the pairing (shared fault traces) is strong.  Panics when the
/// samples' lengths differ: unpaired data has no paired CI.
pub fn paired_diff(xs: &[f64], ys: &[f64]) -> Welford {
    assert_eq!(xs.len(), ys.len(), "paired samples must have equal length");
    Welford::from_iter(xs.iter().zip(ys).map(|(x, y)| x - y))
}

/// One-sample Kolmogorov–Smirnov statistic `D_n = sup_x |F_n(x) − F(x)|`
/// of `samples` against the analytic CDF `F`.  Samples need not be sorted.
pub fn ks_statistic(samples: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    assert!(!samples.is_empty(), "KS statistic of an empty sample");
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in sorted.iter().enumerate() {
        let f = cdf(x);
        // The empirical CDF steps from i/n to (i+1)/n at x: both sides of
        // the step bound the supremum.
        d = d.max((f - i as f64 / n).abs());
        d = d.max(((i + 1) as f64 / n - f).abs());
    }
    d
}

/// Asymptotic critical value of `D_n` at significance `alpha`: the
/// Kolmogorov-distribution approximation `sqrt(-ln(alpha/2) / 2) / sqrt(n)`
/// (c(0.05) ≈ 1.358, c(0.01) ≈ 1.628).  Valid for n ≳ 35.
pub fn ks_critical(n: usize, alpha: f64) -> f64 {
    assert!(n > 0 && alpha > 0.0 && alpha < 1.0);
    (-(alpha / 2.0).ln() / 2.0).sqrt() / (n as f64).sqrt()
}

/// The deviation a tolerance must explain once sampling noise is granted:
/// `max(0, |observed − expected| − noise)`, where `noise` is a CI
/// half-width on the observation.  Zero means the CI alone covers the gap.
pub fn excess_deviation(observed: f64, expected: f64, noise: f64) -> f64 {
    ((observed - expected).abs() - noise).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample std of this classic dataset = sqrt(32/7).
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_iter((1..=100).map(|i| i as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::from_iter((0..10).map(|i| (i % 2) as f64));
        let b = Summary::from_iter((0..1000).map(|i| (i % 2) as f64));
        assert!(b.ci95() < a.ci95());
    }

    #[test]
    fn single_value() {
        let s = Summary::from_iter([3.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.median(), 3.0);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..500).map(|i| ((i * 37) % 101) as f64 * 0.13).collect();
        let s = Summary::from_iter(xs.iter().copied());
        let w = Welford::from_iter(xs.iter().copied());
        assert_eq!(w.len(), s.len());
        assert!((w.mean() - s.mean()).abs() < 1e-12);
        assert!((w.var() - s.var()).abs() < 1e-12);
        assert!((w.ci95() - s.ci95()).abs() < 1e-12);
        assert_eq!(w.min(), s.min());
        assert_eq!(w.max(), s.max());
    }

    #[test]
    fn welford_merge_matches_sequential() {
        let xs: Vec<f64> = (0..300).map(|i| (i as f64 * 0.7).sin() * 10.0).collect();
        let whole = Welford::from_iter(xs.iter().copied());
        // Merge three uneven partials in order.
        let mut merged = Welford::new();
        for chunk in [&xs[..50], &xs[50..260], &xs[260..]] {
            let part = Welford::from_iter(chunk.iter().copied());
            merged.merge(&part);
        }
        assert_eq!(merged.len(), whole.len());
        assert!((merged.mean() - whole.mean()).abs() < 1e-10);
        assert!((merged.var() - whole.var()).abs() < 1e-10);
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
        // Merging the same partials in the same order is bit-deterministic.
        let mut again = Welford::new();
        for chunk in [&xs[..50], &xs[50..260], &xs[260..]] {
            again.merge(&Welford::from_iter(chunk.iter().copied()));
        }
        assert_eq!(again, merged);
    }

    #[test]
    fn paired_diff_tighter_than_marginals() {
        // Strongly paired data: y = x + small noise.  The paired CI must be
        // far tighter than either marginal CI, and the mean difference
        // recovered exactly.
        let xs: Vec<f64> = (0..200).map(|i| (i as f64 * 0.37).sin() * 5.0).collect();
        let ys: Vec<f64> =
            xs.iter().enumerate().map(|(i, x)| x + 0.5 + 0.01 * (i % 3) as f64).collect();
        let d = paired_diff(&ys, &xs);
        assert_eq!(d.len(), xs.len());
        assert!((d.mean() - 0.51).abs() < 0.01, "{}", d.mean());
        let marginal = Welford::from_iter(xs.iter().copied());
        assert!(d.ci95() < 0.1 * marginal.ci95());
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn paired_diff_rejects_unpaired() {
        paired_diff(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn ks_statistic_exact_small_cases() {
        // Single sample at the median of U(0,1): F(0.5) = 0.5, steps 0 → 1,
        // D = 0.5 on both sides.
        let d = ks_statistic(&[0.5], |x| x);
        assert!((d - 0.5).abs() < 1e-12);
        // A perfect uniform grid at midpoints: D = 1/(2n).
        let n = 100;
        let grid: Vec<f64> = (0..n).map(|i| (i as f64 + 0.5) / n as f64).collect();
        let d = ks_statistic(&grid, |x| x);
        assert!((d - 0.5 / n as f64).abs() < 1e-12, "{d}");
        // A shifted sample is far from uniform.
        let shifted: Vec<f64> = grid.iter().map(|x| (x * 0.5).min(1.0)).collect();
        assert!(ks_statistic(&shifted, |x| x) > 0.4);
    }

    #[test]
    fn ks_critical_pinned_constants() {
        // c(0.05) = 1.3581, c(0.01) = 1.6276 (classic table values).
        assert!((ks_critical(1, 0.05) - 1.3581).abs() < 1e-3);
        assert!((ks_critical(1, 0.01) - 1.6276).abs() < 1e-3);
        assert!((ks_critical(100, 0.05) - 0.13581).abs() < 1e-4);
        assert!(ks_critical(400, 0.05) < ks_critical(100, 0.05));
    }

    #[test]
    fn excess_deviation_semantics() {
        assert_eq!(excess_deviation(1.0, 1.0, 0.0), 0.0);
        assert_eq!(excess_deviation(1.2, 1.0, 0.3), 0.0); // CI covers it
        assert!((excess_deviation(1.5, 1.0, 0.2) - 0.3).abs() < 1e-12);
        assert!((excess_deviation(0.5, 1.0, 0.2) - 0.3).abs() < 1e-12); // symmetric
    }

    #[test]
    fn welford_empty_and_singleton_merge() {
        let mut w = Welford::new();
        assert_eq!(w.ci95(), 0.0);
        w.merge(&Welford::new());
        assert!(w.is_empty());
        w.merge(&Welford::from_iter([2.5]));
        assert_eq!(w.mean(), 2.5);
        assert_eq!(w.len(), 1);
        assert_eq!(w.var(), 0.0);
    }
}
