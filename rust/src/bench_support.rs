//! Micro-benchmark harness (offline environment: no criterion).
//!
//! Criterion-style reporting: warmup, N timed samples of adaptively-sized
//! batches, median / mean / min with MAD-based spread.  Benches are plain
//! `harness = false` binaries (`rust/benches/*.rs`) using this module via
//! the library crate, so `cargo bench` runs them all.
//!
//! Env knobs: `CKPTWIN_BENCH_FAST=1` shrinks sample counts (CI smoke);
//! `CKPTWIN_BENCH_SAMPLES=n` overrides the sample count.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark's collected samples (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        s[s.len() / 2]
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut devs: Vec<f64> =
            self.samples.iter().map(|s| (s - med).abs()).collect();
        devs.sort_by(f64::total_cmp);
        devs[devs.len() / 2]
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

fn n_samples() -> usize {
    if let Ok(s) = std::env::var("CKPTWIN_BENCH_SAMPLES") {
        if let Ok(n) = s.parse() {
            return n;
        }
    }
    if std::env::var("CKPTWIN_BENCH_FAST").is_ok() {
        5
    } else {
        15
    }
}

/// Run a benchmark: calls `f()` repeatedly, targeting ~`target_ms` per
/// sample, and prints a criterion-style line.  Returns the samples.
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, mut f: F) -> BenchResult {
    // Warmup + calibration: measure one call.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((target_ms / 1e3) / once.as_secs_f64())
        .clamp(1.0, 1e7) as u64;

    let n = n_samples();
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() / iters as f64);
    }
    let res = BenchResult {
        name: name.to_string(),
        samples,
        iters_per_sample: iters,
    };
    println!(
        "{:<44} time: [{} median, {} mean, {} min] ±{} (n={}, {} it/sample)",
        res.name,
        fmt_time(res.median()),
        fmt_time(res.mean()),
        fmt_time(res.min()),
        fmt_time(res.mad()),
        res.samples.len(),
        res.iters_per_sample,
    );
    res
}

/// Benchmark with a value-producing closure (result black-boxed).
pub fn bench_val<T, F: FnMut() -> T>(
    name: &str,
    target_ms: f64,
    mut f: F,
) -> BenchResult {
    bench(name, target_ms, || {
        black_box(f());
    })
}

/// Report a throughput line computed from a result.
pub fn report_throughput(res: &BenchResult, items: f64, unit: &str) {
    let per_sec = items / res.median();
    println!(
        "{:<44}   -> {:.3e} {unit}/s",
        format!("{} (throughput)", res.name),
        per_sec
    );
}

/// Path of the machine-readable bench artifact: `BENCH_PR2.json` at the
/// repository root (the parent of the crate), overridable with
/// `CKPTWIN_BENCH_JSON`.
pub fn bench_json_path() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("CKPTWIN_BENCH_JSON") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_PR2.json")
}

/// Merge `entries` into the `section` object of the bench JSON at `path`,
/// preserving other sections (each bench binary owns one section, so
/// running them in any order composes one artifact).
pub fn update_bench_json_at(
    path: &std::path::Path,
    section: &str,
    entries: &[(String, crate::jsonio::Value)],
) -> std::io::Result<()> {
    use crate::jsonio::{self, Value};
    use std::collections::BTreeMap;
    let mut root: BTreeMap<String, Value> = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| jsonio::parse(&t).ok())
        .and_then(|v| match v {
            Value::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_default();
    let mut sec = match root.remove(section) {
        Some(Value::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    for (k, v) in entries {
        sec.insert(k.clone(), v.clone());
    }
    root.insert(section.to_string(), Value::Obj(sec));
    std::fs::write(path, jsonio::to_string(&Value::Obj(root)) + "\n")
}

/// [`update_bench_json_at`] on [`bench_json_path`], logging (not failing)
/// on I/O errors so a read-only checkout never kills a bench run.
pub fn update_bench_json(section: &str, entries: &[(String, crate::jsonio::Value)]) {
    let path = bench_json_path();
    match update_bench_json_at(&path, section, entries) {
        Ok(()) => println!("bench json: updated {} [{section}]", path.display()),
        Err(e) => eprintln!("bench json: failed to write {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("CKPTWIN_BENCH_FAST", "1");
        let res = bench_val("noop", 0.5, || 1 + 1);
        assert!(!res.samples.is_empty());
        assert!(res.median() >= 0.0);
        assert!(res.min() <= res.mean() * 1.5 + 1e-9);
    }

    #[test]
    fn bench_json_sections_merge() {
        use crate::jsonio::{self, Value};
        let path = std::env::temp_dir().join(format!(
            "ckptwin-bench-json-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        update_bench_json_at(&path, "a", &[("x".into(), Value::Num(1.5))]).unwrap();
        update_bench_json_at(
            &path,
            "b",
            &[("y".into(), Value::Str("fast".into()))],
        )
        .unwrap();
        // Re-writing a section merges keys instead of clobbering others.
        update_bench_json_at(&path, "a", &[("z".into(), Value::Num(2.0))]).unwrap();
        let v = jsonio::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("a").unwrap().get("x").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("a").unwrap().get("z").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("b").unwrap().get("y").unwrap().as_str(), Some("fast"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
