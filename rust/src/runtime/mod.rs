//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! `make artifacts` runs the Python compile path once
//! (`python/compile/aot.py`), producing `artifacts/*.hlo.txt` and
//! `artifacts/manifest.json`.  This module is the only bridge between the
//! Rust coordinator and those artifacts: it loads the HLO **text** with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and memoizes the loaded executables.  Python never runs at request time.
//!
//! Submodules:
//! * [`waste_grid`] — the analytic waste-surface offload (BestPeriod search
//!   accelerator);
//! * [`train`] — the transformer-LM training-step driver used as the real
//!   workload of the end-to-end checkpointing example.

pub mod train;
pub mod waste_grid;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use crate::jsonio;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Scenario batch of the waste-grid artifact.
    pub waste_batch: usize,
    /// Period-grid width of the waste-grid artifact.
    pub waste_grid: usize,
    /// Flat parameter count of the transformer model.
    pub param_count: usize,
    /// Model batch size (sequences per training step).
    pub batch: usize,
    /// Model sequence length.
    pub seq_len: usize,
    /// Model vocabulary size (byte-level: 256).
    pub vocab: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = jsonio::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let field = |obj: &jsonio::Value, key: &str| -> Result<usize> {
            obj.get(key)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };
        let wg = v
            .get("waste_grid")
            .ok_or_else(|| anyhow!("manifest missing waste_grid"))?;
        let model = v
            .get("model")
            .ok_or_else(|| anyhow!("manifest missing model"))?;
        Ok(Manifest {
            waste_batch: field(wg, "batch")?,
            waste_grid: field(wg, "grid")?,
            param_count: field(&v, "param_count")?,
            batch: field(model, "batch")?,
            seq_len: field(model, "seq_len")?,
            vocab: field(model, "vocab")?,
        })
    }
}

/// The PJRT client plus a compile cache over the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (default `artifacts/`) and start a CPU
    /// PJRT client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Locate the artifact directory by walking up from `cwd` (so tests,
    /// examples and benches work from any subdirectory).
    pub fn discover() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Runtime::open(cand);
            }
            if !dir.pop() {
                return Err(anyhow!(
                    "no artifacts/manifest.json found; run `make artifacts`"
                ));
            }
        }
    }

    /// True if the artifacts exist (used by tests to skip gracefully).
    pub fn artifacts_present() -> bool {
        let mut dir = match std::env::current_dir() {
            Ok(d) => d,
            Err(_) => return false,
        };
        loop {
            if dir.join("artifacts/manifest.json").exists() {
                return true;
            }
            if !dir.pop() {
                return false;
            }
        }
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest entry name (memoized).
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact whose lowered function returns a tuple, and
    /// decompose the tuple into literals.
    pub fn execute_tuple(
        &self,
        name: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let outs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }
}
