//! Training-step driver: the *real workload* checkpointed by the
//! end-to-end coordinator example.
//!
//! The transformer's entire state is one flat `f32[P]` vector `theta`
//! (see `python/compile/model.py`), so a checkpoint is literally a copy of
//! that vector — the coordinator serializes it through
//! [`crate::coordinator::checkpoint`].  The driver keeps `theta` host-side
//! between steps; each step uploads it, executes the AOT-compiled
//! fwd+bwd+SGD graph, and downloads the updated vector plus the loss.

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;

/// Stateful trainer over the `train_step` / `eval_loss` artifacts.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    theta: Vec<f32>,
    pub steps_run: u64,
}

impl<'rt> Trainer<'rt> {
    /// Initialize parameters via the `init_params` artifact (seeded — the
    /// run is bit-reproducible).
    pub fn new(rt: &'rt Runtime, seed: u32) -> Result<Self> {
        let outs = rt.execute_tuple("init_params", &[xla::Literal::from(seed)])?;
        let theta: Vec<f32> =
            outs[0].to_vec().map_err(|e| anyhow!("init theta: {e:?}"))?;
        if theta.len() != rt.manifest.param_count {
            return Err(anyhow!(
                "init produced {} params, manifest says {}",
                theta.len(),
                rt.manifest.param_count
            ));
        }
        Ok(Trainer { rt, theta, steps_run: 0 })
    }

    /// Number of tokens one step consumes (batch × seq_len).
    pub fn tokens_per_step(&self) -> usize {
        self.rt.manifest.batch * self.rt.manifest.seq_len
    }

    /// Execute one training step; `tokens` must be `batch*seq_len` i32s in
    /// `[0, vocab)`.  Returns the loss.
    pub fn step(&mut self, tokens: &[i32], lr: f32) -> Result<f32> {
        let m = &self.rt.manifest;
        if tokens.len() != m.batch * m.seq_len {
            return Err(anyhow!(
                "expected {} tokens, got {}",
                m.batch * m.seq_len,
                tokens.len()
            ));
        }
        let theta_lit = xla::Literal::vec1(&self.theta);
        let tok_lit = xla::Literal::vec1(tokens)
            .reshape(&[m.batch as i64, m.seq_len as i64])
            .map_err(|e| anyhow!("reshape tokens: {e:?}"))?;
        let outs = self.rt.execute_tuple(
            "train_step",
            &[theta_lit, tok_lit, xla::Literal::from(lr)],
        )?;
        self.theta = outs[0].to_vec().map_err(|e| anyhow!("theta': {e:?}"))?;
        let loss: Vec<f32> =
            outs[1].to_vec().map_err(|e| anyhow!("loss: {e:?}"))?;
        self.steps_run += 1;
        Ok(loss[0])
    }

    /// Evaluate the loss without updating parameters.
    pub fn eval(&self, tokens: &[i32]) -> Result<f32> {
        let m = &self.rt.manifest;
        let theta_lit = xla::Literal::vec1(&self.theta);
        let tok_lit = xla::Literal::vec1(tokens)
            .reshape(&[m.batch as i64, m.seq_len as i64])
            .map_err(|e| anyhow!("reshape tokens: {e:?}"))?;
        let outs = self.rt.execute_tuple("eval_loss", &[theta_lit, tok_lit])?;
        let loss: Vec<f32> =
            outs[0].to_vec().map_err(|e| anyhow!("loss: {e:?}"))?;
        Ok(loss[0])
    }

    /// Snapshot the full model state (this IS the checkpoint payload).
    pub fn snapshot(&self) -> Vec<f32> {
        self.theta.clone()
    }

    /// Restore model state from a checkpoint payload.
    pub fn restore(&mut self, theta: Vec<f32>) -> Result<()> {
        if theta.len() != self.rt.manifest.param_count {
            return Err(anyhow!(
                "checkpoint has {} params, manifest says {}",
                theta.len(),
                self.rt.manifest.param_count
            ));
        }
        self.theta = theta;
        Ok(())
    }
}
