//! Waste-surface offload: evaluate the four closed-form wastes for a batch
//! of scenarios over a period grid with ONE artifact execution.
//!
//! The artifact has fixed shapes (`B = manifest.waste_batch` scenarios ×
//! `G = manifest.waste_grid` periods); this wrapper pads/chunks arbitrary
//! inputs to those shapes.  Padded scenario rows replicate the first row;
//! padded grid points use a large valid period — both are simply discarded
//! on the way out.

use anyhow::{anyhow, Result};

use crate::config::Scenario;
use crate::model::waste::GridStrategy;
use crate::runtime::Runtime;

/// Strategy count of the artifact output (matches `ref.N_STRATEGIES`).
pub const N_STRATEGIES: usize = 4;

/// Pack a scenario into the kernel's parameter-row layout
/// (see `python/compile/kernels/ref.py`).
pub fn scenario_row(sc: &Scenario) -> [f32; 10] {
    [
        sc.platform.mu as f32,
        sc.platform.c as f32,
        sc.platform.cp as f32,
        sc.platform.d as f32,
        sc.platform.r as f32,
        sc.predictor.precision as f32,
        sc.predictor.recall as f32,
        sc.predictor.window as f32,
        sc.e_if() as f32,
        0.0,
    ]
}

/// Waste surfaces for one scenario: `out[strategy][grid_point]`.
pub type Surface = [Vec<f32>; N_STRATEGIES];

impl Runtime {
    /// Evaluate waste surfaces for all `scenarios` over the shared period
    /// grid `tr`.  Returns one [`Surface`] per scenario.
    pub fn waste_surfaces(
        &self,
        scenarios: &[Scenario],
        tr: &[f64],
    ) -> Result<Vec<Surface>> {
        if scenarios.is_empty() || tr.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.manifest.waste_batch;
        let g = self.manifest.waste_grid;
        if tr.len() > g {
            return Err(anyhow!(
                "grid of {} exceeds artifact capacity {g}; chunk the sweep",
                tr.len()
            ));
        }

        // Pad the period grid with a large valid period.
        let pad_tr = tr.iter().copied().fold(f64::MIN, f64::max) * 2.0 + 1e4;
        let mut tr_f32: Vec<f32> = tr.iter().map(|&t| t as f32).collect();
        tr_f32.resize(g, pad_tr as f32);
        let tr_lit = xla::Literal::vec1(&tr_f32);

        let mut out = Vec::with_capacity(scenarios.len());
        for chunk in scenarios.chunks(b) {
            let mut rows = Vec::with_capacity(b * 10);
            for sc in chunk {
                rows.extend_from_slice(&scenario_row(sc));
            }
            // Pad the batch by replicating the first row.
            for _ in chunk.len()..b {
                rows.extend_from_slice(&scenario_row(&chunk[0]));
            }
            let params = xla::Literal::vec1(&rows)
                .reshape(&[b as i64, 10])
                .map_err(|e| anyhow!("reshape params: {e:?}"))?;
            let outs = self.execute_tuple("waste_grid", &[params, tr_lit.clone()])?;
            let flat: Vec<f32> = outs[0]
                .to_vec()
                .map_err(|e| anyhow!("waste output: {e:?}"))?;
            debug_assert_eq!(flat.len(), b * N_STRATEGIES * g);
            for (bi, _) in chunk.iter().enumerate() {
                let mut surface: Surface = Default::default();
                for (si, row) in surface.iter_mut().enumerate() {
                    let base = bi * N_STRATEGIES * g + si * g;
                    row.extend_from_slice(&flat[base..base + tr.len()]);
                }
                out.push(surface);
            }
        }
        Ok(out)
    }

    /// PJRT-accelerated analytic BestPeriod: argmin over the grid, for each
    /// strategy.  Returns `(best_tr, best_waste)` per strategy index
    /// (ordering = [`GridStrategy`]).
    pub fn best_periods(
        &self,
        sc: &Scenario,
        tr: &[f64],
    ) -> Result<[(f64, f64); N_STRATEGIES]> {
        let surfaces = self.waste_surfaces(std::slice::from_ref(sc), tr)?;
        let surface = &surfaces[0];
        let mut best = [(0.0f64, f64::INFINITY); N_STRATEGIES];
        for (si, row) in surface.iter().enumerate() {
            for (gi, &w) in row.iter().enumerate() {
                if (w as f64) < best[si].1 {
                    best[si] = (tr[gi], w as f64);
                }
            }
        }
        Ok(best)
    }
}

/// Map a [`GridStrategy`] to its row index in a [`Surface`].
pub fn strategy_index(s: GridStrategy) -> usize {
    s as usize
}
