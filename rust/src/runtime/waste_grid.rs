//! Waste-surface offload: evaluate the four closed-form wastes for a batch
//! of scenarios over a period grid with ONE artifact execution.
//!
//! The artifact has fixed shapes (`B = manifest.waste_batch` scenarios ×
//! `G = manifest.waste_grid` periods); this wrapper pads/chunks arbitrary
//! inputs to those shapes.  Padded scenario rows replicate the first row;
//! padded grid points use a large valid period — both are simply discarded
//! on the way out ([`pack_rows`]/[`pad_grid`]/[`unpack_chunk`], unit-tested
//! without an artifact).
//!
//! ## Precision contract (f64 → f32)
//!
//! The kernel computes in f32; scenario parameters are narrowed on entry.
//! For any *normal* f32 value the narrowing loses at most 2⁻²⁴ ≈ 6·10⁻⁸
//! relative — far below the cross-check tolerance.  What the old silent
//! `as f32` cast hid are the two failure modes outside that promise:
//! overflow (μ beyond ~3.4·10³⁸ becomes `inf`) and underflow (a precision
//! like 10⁻⁴⁰ becomes 0 or a denormal, turning the kernel's `p·μ`
//! denominator into garbage).  [`scenario_row_checked`] enforces the
//! contract — every parameter must survive the f32 round-trip within
//! [`NARROWING_REL_TOL`] — and [`Runtime::waste_surfaces`] refuses
//! unrepresentable scenarios instead of silently producing wrong grids.
//!
//! ## Cross-check gate
//!
//! [`crosscheck_waste_grid`] is the conformance-style gate unifying this
//! backend with the Rust model: the kernel's f32 surfaces must agree with
//! [`crate::model::batch`]'s f64 clipped surfaces (which are bit-identical
//! to scalar [`crate::model::waste::waste_clipped`]) within a priced
//! tolerance — [`CROSSCHECK_ABS_TOL`] + [`CROSSCHECK_REL_TOL`]·|w|,
//! covering the 10 input narrowings (≤ 10·2⁻²⁴), the ~20 f32 kernel ops,
//! and a safety factor for the `1 − (1−a)(1−b)` cancellation (the same
//! 2·10⁻⁴ bound `tests/runtime_roundtrip.rs` has pinned since PR 1).

use anyhow::{anyhow, Result};

use crate::config::Scenario;
use crate::model::waste::GridStrategy;
use crate::runtime::Runtime;

/// Strategy count of the artifact output (matches `ref.N_STRATEGIES`).
pub const N_STRATEGIES: usize = 4;

/// Maximum relative error a scenario parameter may lose in the f64 → f32
/// narrowing before [`scenario_row_checked`] rejects it.  Normal values
/// lose ≤ 2⁻²⁴ ≈ 6·10⁻⁸; anything above this tolerance means the value
/// left f32's normal range (overflow/underflow) and the kernel grid would
/// silently be garbage.
pub const NARROWING_REL_TOL: f64 = 1e-6;

/// Absolute tolerance of [`crosscheck_waste_grid`] (see module docs).
pub const CROSSCHECK_ABS_TOL: f64 = 2e-4;

/// Relative tolerance of [`crosscheck_waste_grid`].
pub const CROSSCHECK_REL_TOL: f64 = 1e-4;

/// Narrow one parameter under the precision contract.
fn narrow(name: &'static str, v: f64) -> Result<f32> {
    if !v.is_finite() {
        return Err(anyhow!("scenario parameter {name} = {v} is not finite"));
    }
    let n = v as f32;
    if !n.is_finite() {
        return Err(anyhow!("scenario parameter {name} = {v} overflows f32"));
    }
    if v != 0.0 {
        let rel = ((n as f64 - v) / v).abs();
        if rel > NARROWING_REL_TOL {
            return Err(anyhow!(
                "scenario parameter {name} = {v:e} loses {rel:.2e} relative \
                 precision in f32 (contract: ≤ {NARROWING_REL_TOL:e}); \
                 the kernel grid would be meaningless"
            ));
        }
    }
    Ok(n)
}

/// Pack a scenario into the kernel's parameter-row layout
/// (see `python/compile/kernels/ref.py`), enforcing the module's
/// precision contract: every parameter must survive the f32 narrowing
/// within [`NARROWING_REL_TOL`] relative.
pub fn scenario_row_checked(sc: &Scenario) -> Result<[f32; 10]> {
    Ok([
        narrow("mu", sc.platform.mu)?,
        narrow("c", sc.platform.c)?,
        narrow("cp", sc.platform.cp)?,
        narrow("d", sc.platform.d)?,
        narrow("r", sc.platform.r)?,
        narrow("precision", sc.predictor.precision)?,
        narrow("recall", sc.predictor.recall)?,
        narrow("window", sc.predictor.window)?,
        narrow("e_if", sc.e_if())?,
        0.0,
    ])
}

/// The pre-contract packing: a bare `as f32` per parameter.  Kept for
/// callers that pack values already known representable (tests, goldens);
/// batch entry points go through [`scenario_row_checked`].
pub fn scenario_row(sc: &Scenario) -> [f32; 10] {
    [
        sc.platform.mu as f32,
        sc.platform.c as f32,
        sc.platform.cp as f32,
        sc.platform.d as f32,
        sc.platform.r as f32,
        sc.predictor.precision as f32,
        sc.predictor.recall as f32,
        sc.predictor.window as f32,
        sc.e_if() as f32,
        0.0,
    ]
}

/// Pad the period grid to the artifact's `g` points: real periods first,
/// then a large valid pad period (twice the maximum plus 10⁴ s — far from
/// every real point, still finite in f32 for any sane grid).  The pad
/// columns are discarded by [`unpack_chunk`].
pub fn pad_grid(tr: &[f64], g: usize) -> Vec<f32> {
    let pad_tr = tr.iter().copied().fold(f64::MIN, f64::max) * 2.0 + 1e4;
    let mut tr_f32: Vec<f32> = tr.iter().map(|&t| t as f32).collect();
    tr_f32.resize(g, pad_tr as f32);
    tr_f32
}

/// Pack one scenario chunk into the artifact's `b × 10` parameter block,
/// replicating the first row into the pad rows (their outputs are
/// discarded by [`unpack_chunk`]; replication keeps them in-domain so the
/// kernel never sees uninitialized parameters).
pub fn pack_rows(chunk: &[Scenario], b: usize) -> Result<Vec<f32>> {
    assert!(!chunk.is_empty() && chunk.len() <= b);
    let mut rows = Vec::with_capacity(b * 10);
    for sc in chunk {
        rows.extend_from_slice(&scenario_row_checked(sc)?);
    }
    let first = scenario_row_checked(&chunk[0])?;
    for _ in chunk.len()..b {
        rows.extend_from_slice(&first);
    }
    Ok(rows)
}

/// Waste surfaces for one scenario: `out[strategy][grid_point]`.
pub type Surface = [Vec<f32>; N_STRATEGIES];

/// Unpack one executed chunk's flat `b × strategies × g` output into
/// per-scenario [`Surface`]s, discarding the pad rows (beyond
/// `chunk_len`) and pad grid columns (beyond `keep` periods).
pub fn unpack_chunk(
    flat: &[f32],
    b: usize,
    g: usize,
    chunk_len: usize,
    keep: usize,
) -> Vec<Surface> {
    debug_assert_eq!(flat.len(), b * N_STRATEGIES * g);
    let mut out = Vec::with_capacity(chunk_len);
    for bi in 0..chunk_len {
        let mut surface: Surface = Default::default();
        for (si, row) in surface.iter_mut().enumerate() {
            let base = bi * N_STRATEGIES * g + si * g;
            row.extend_from_slice(&flat[base..base + keep]);
        }
        out.push(surface);
    }
    out
}

impl Runtime {
    /// Evaluate waste surfaces for all `scenarios` over the shared period
    /// grid `tr`.  Returns one [`Surface`] per scenario.  Errors when the
    /// grid exceeds the artifact capacity or a scenario violates the f32
    /// precision contract ([`scenario_row_checked`]).
    pub fn waste_surfaces(
        &self,
        scenarios: &[Scenario],
        tr: &[f64],
    ) -> Result<Vec<Surface>> {
        if scenarios.is_empty() || tr.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.manifest.waste_batch;
        let g = self.manifest.waste_grid;
        if tr.len() > g {
            return Err(anyhow!(
                "grid of {} exceeds artifact capacity {g}; chunk the sweep",
                tr.len()
            ));
        }

        let tr_lit = xla::Literal::vec1(&pad_grid(tr, g));

        let mut out = Vec::with_capacity(scenarios.len());
        for chunk in scenarios.chunks(b) {
            let rows = pack_rows(chunk, b)?;
            let params = xla::Literal::vec1(&rows)
                .reshape(&[b as i64, 10])
                .map_err(|e| anyhow!("reshape params: {e:?}"))?;
            let outs = self.execute_tuple("waste_grid", &[params, tr_lit.clone()])?;
            let flat: Vec<f32> = outs[0]
                .to_vec()
                .map_err(|e| anyhow!("waste output: {e:?}"))?;
            out.extend(unpack_chunk(&flat, b, g, chunk.len(), tr.len()));
        }
        Ok(out)
    }

    /// PJRT-accelerated analytic BestPeriod: argmin over the grid, for each
    /// strategy.  Returns `(best_tr, best_waste)` per strategy index
    /// (ordering = [`GridStrategy`]).
    pub fn best_periods(
        &self,
        sc: &Scenario,
        tr: &[f64],
    ) -> Result<[(f64, f64); N_STRATEGIES]> {
        let surfaces = self.waste_surfaces(std::slice::from_ref(sc), tr)?;
        let surface = &surfaces[0];
        let mut best = [(0.0f64, f64::INFINITY); N_STRATEGIES];
        for (si, row) in surface.iter().enumerate() {
            for (gi, &w) in row.iter().enumerate() {
                if (w as f64) < best[si].1 {
                    best[si] = (tr[gi], w as f64);
                }
            }
        }
        Ok(best)
    }
}

/// Map a [`GridStrategy`] to its row index in a [`Surface`].
pub fn strategy_index(s: GridStrategy) -> usize {
    s as usize
}

/// Outcome of the kernel-vs-model cross-check gate
/// ([`crosscheck_waste_grid`]).
#[derive(Clone, Debug, Default)]
pub struct CrossCheck {
    /// Cells compared (scenarios × strategies × grid points).
    pub cells: u64,
    /// Cells beyond the priced tolerance.
    pub failures: u64,
    /// Largest |kernel − model| observed.
    pub max_abs_err: f64,
    /// `(scenario, strategy, grid)` index of the worst cell.
    pub worst: Option<(usize, usize, usize)>,
}

impl CrossCheck {
    /// The gate verdict: every cell within tolerance.
    pub fn passed(&self) -> bool {
        self.failures == 0
    }
}

/// The backend-unification gate: evaluate `scenarios × tr` through the
/// PJRT/Pallas kernel AND through [`crate::model::batch`]'s f64 clipped
/// surfaces, and compare element-wise within the priced f32 tolerance
/// (see module docs).  The f64 side is bit-identical to scalar
/// `waste_clipped`, so a pass pins kernel ≡ scalar ≡ batch in one sweep.
pub fn crosscheck_waste_grid(
    rt: &Runtime,
    scenarios: &[Scenario],
    tr: &[f64],
) -> Result<CrossCheck> {
    let kernel = rt.waste_surfaces(scenarios, tr)?;
    let (model, _) = crate::model::batch::clipped_surfaces(scenarios, tr, 0);
    let mut chk = CrossCheck::default();
    for (sci, (ks, ms)) in kernel.iter().zip(&model).enumerate() {
        for si in 0..N_STRATEGIES {
            for (gi, (&kw, &mw)) in ks[si].iter().zip(&ms[si]).enumerate() {
                chk.cells += 1;
                let err = (kw as f64 - mw).abs();
                if err > chk.max_abs_err {
                    chk.max_abs_err = err;
                    chk.worst = Some((sci, si, gi));
                }
                if err > CROSSCHECK_ABS_TOL + CROSSCHECK_REL_TOL * mw.abs() {
                    chk.failures += 1;
                }
            }
        }
    }
    Ok(chk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;

    fn sc(mu: f64, precision: f64) -> Scenario {
        Scenario {
            platform: Platform { mu, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, precision, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    #[test]
    fn checked_row_accepts_representable_scenarios() {
        let row = scenario_row_checked(&sc(60_000.0, 0.82)).unwrap();
        assert_eq!(row, scenario_row(&sc(60_000.0, 0.82)));
        assert_eq!(row[0], 60_000.0f32);
        assert_eq!(row[9], 0.0);
    }

    #[test]
    fn checked_row_rejects_f32_overflow_and_underflow() {
        // Overflow: μ beyond f32::MAX silently became inf before.
        let err = scenario_row_checked(&sc(1e39, 0.82)).unwrap_err();
        assert!(err.to_string().contains("overflows f32"), "{err}");
        // Underflow: a subnormal precision silently became ~0, turning the
        // kernel's p·μ denominator into garbage.
        let err = scenario_row_checked(&sc(60_000.0, 1e-40)).unwrap_err();
        assert!(err.to_string().contains("precision"), "{err}");
        // Non-finite parameters are rejected outright.
        let err = scenario_row_checked(&sc(f64::INFINITY, 0.82)).unwrap_err();
        assert!(err.to_string().contains("not finite"), "{err}");
        // p = 0 is exactly representable: the contract is about narrowing,
        // not about domain (the kernel clips its own domain).
        assert!(scenario_row_checked(&sc(60_000.0, 0.0)).is_ok());
    }

    #[test]
    fn pad_grid_appends_out_of_band_periods() {
        let padded = pad_grid(&[700.0, 6000.0], 5);
        assert_eq!(padded.len(), 5);
        assert_eq!(&padded[..2], &[700.0f32, 6000.0]);
        // Pad periods sit beyond every real grid point (discarded anyway,
        // but they must stay in the kernel's valid domain: tr > C).
        for &p in &padded[2..] {
            assert_eq!(p, (6000.0 * 2.0 + 1e4) as f32);
            assert!(p > 6000.0);
        }
    }

    #[test]
    fn pack_rows_replicates_first_row_into_padding() {
        let chunk = [sc(60_000.0, 0.82), sc(30_000.0, 0.4)];
        let rows = pack_rows(&chunk, 4).unwrap();
        assert_eq!(rows.len(), 4 * 10);
        let first = scenario_row(&chunk[0]);
        let second = scenario_row(&chunk[1]);
        assert_eq!(&rows[..10], &first);
        assert_eq!(&rows[10..20], &second);
        // Pad rows replicate row 0, keeping the kernel in-domain.
        assert_eq!(&rows[20..30], &first);
        assert_eq!(&rows[30..40], &first);
        // A contract violation anywhere in the chunk fails the pack.
        assert!(pack_rows(&[sc(60_000.0, 0.82), sc(1e39, 0.82)], 4).is_err());
    }

    #[test]
    fn unpack_chunk_discards_pad_rows_and_pad_periods() {
        // b = 3 scenarios × g = 4 periods, but only 2 real scenarios and
        // 2 real periods: every kept value must come from the real block,
        // every pad value (tagged 9xx) must be dropped.
        let (b, g, chunk_len, keep) = (3usize, 4usize, 2usize, 2usize);
        let mut flat = vec![0.0f32; b * N_STRATEGIES * g];
        for bi in 0..b {
            for si in 0..N_STRATEGIES {
                for gi in 0..g {
                    let real = bi < chunk_len && gi < keep;
                    flat[bi * N_STRATEGIES * g + si * g + gi] = if real {
                        (bi * 100 + si * 10 + gi) as f32
                    } else {
                        900.0 + bi as f32
                    };
                }
            }
        }
        let out = unpack_chunk(&flat, b, g, chunk_len, keep);
        assert_eq!(out.len(), chunk_len);
        for (bi, surface) in out.iter().enumerate() {
            for (si, row) in surface.iter().enumerate() {
                assert_eq!(row.len(), keep);
                for (gi, &w) in row.iter().enumerate() {
                    assert_eq!(w, (bi * 100 + si * 10 + gi) as f32);
                    assert!(w < 900.0, "pad value leaked through");
                }
            }
        }
    }

    #[test]
    fn crosscheck_tolerance_is_priced_not_guessed() {
        // 10 narrowings × 2⁻²⁴ plus ~20 f32 ops × 2⁻²⁴ plus the
        // cancellation safety factor must stay below the absolute term.
        let per_op = 2f64.powi(-24);
        assert!(30.0 * per_op < CROSSCHECK_ABS_TOL);
        // And the pinned roundtrip bound from PR 1 is exactly our floor.
        assert_eq!(CROSSCHECK_ABS_TOL, 2e-4);
    }
}
