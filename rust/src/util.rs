//! Shared constants and small numeric helpers.

/// Seconds in a (365-day) year, matching the paper's platform arithmetic
/// (`μ_ind = 125 y`, `Time_base = 10000 y / N`).
pub const SECONDS_PER_YEAR: f64 = 365.0 * 24.0 * 3600.0;

/// Seconds in a day (Table 4/5 report execution times in days).
pub const SECONDS_PER_DAY: f64 = 24.0 * 3600.0;

/// Paper §4.1 platform constants.
pub mod paper {
    /// Regular checkpoint duration (s).
    pub const C: f64 = 600.0;
    /// Recovery duration (s).
    pub const R: f64 = 600.0;
    /// Downtime (s).
    pub const D: f64 = 60.0;
    /// Individual processor MTBF (years).
    pub const MU_IND_YEARS: f64 = 125.0;
    /// Application size: `Time_base = 10000 years / N` (s for N procs).
    pub const TOTAL_WORK_YEARS: f64 = 10_000.0;
}

/// Natural-log Γ via the Lanczos approximation (g = 7, n = 9 coefficients).
///
/// Used to mean-scale the Weibull distribution: `E[X] = λ Γ(1 + 1/k)`.
/// Accurate to ~1e-13 over the range we use (arguments in [1, 3]).
pub fn ln_gamma(x: f64) -> f64 {
    // Coefficients from the standard Lanczos g=7 table.
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Γ(x) for moderate x.
pub fn gamma(x: f64) -> f64 {
    ln_gamma(x).exp()
}

/// Regularized upper incomplete gamma Q(a, x) = Γ(a, x) / Γ(a).
///
/// Used by the stationary per-processor fault model: the equilibrium
/// (residual-life) survival function of a Weibull(k, λ) renewal process is
/// `S_eq(t) = Q(1/k, (t/λ)^k)`.  Series expansion for x < a + 1, Lentz
/// continued fraction otherwise (Numerical Recipes §6.2).
pub fn gammq(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0, "gammq domain: a={a}, x={x}");
    if x == 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        // P(a,x) by series, Q = 1 - P.
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        1.0 - sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        // Q(a,x) by modified Lentz continued fraction.
        let tiny = 1e-300;
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / tiny;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < tiny {
                d = tiny;
            }
            c = b + an / c;
            if c.abs() < tiny {
                c = tiny;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        (-x + a * x.ln() - ln_gamma(a)).exp() * h
    }
}

/// Standard normal CDF Φ(z) via the Abramowitz–Stegun 7.1.26 erf
/// approximation (|error| < 1.5e-7) — the LogNormal analytic CDF needed by
/// the distribution goodness-of-fit oracles.
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// erf(x), Abramowitz–Stegun 7.1.26 (|error| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741
                    + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Clamp helper mirroring the paper's period-validity guards.
pub fn clamp(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) over `data`.
///
/// Shared integrity primitive: the coordinator checkpoint container
/// ([`crate::coordinator::checkpoint`]), the self-snapshot file
/// ([`crate::resilience::snapshot`]), and the per-record JSONL seals
/// ([`crate::jsonio::seal_record`]) all use this table-driven
/// implementation so their checksums are mutually comparable in tooling.
pub fn crc32(data: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        let mut i = 0usize;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Split `raw` on top-level commas: commas inside parentheses do not
/// split, so `qtrust(q=0.25,…)` or `biased(beta=2,r=0.7)` stay one token.
/// Shared by the CLI's `--strategies` and `--predictors` list parsers
/// (`strategy::registry::parse_strategy_list`,
/// `predictor::registry::parse_predictor_list`) and the scenario-file
/// axis lists (`scenario::compile`).
pub fn split_top_level(raw: &str) -> Vec<&str> {
    split_top_level_on(raw, ',')
}

/// Separator-parametric form of [`split_top_level`]: split `raw` on
/// top-level `sep`, where occurrences inside parentheses never split.
/// `scenario::replay` uses `sep = ';'` to walk store-key fields, where
/// predictor-model labels like `mixedwin(i1=300;i2=1200;w=0.5)` embed
/// the separator inside parens. Invariants (pinned by `tests/prop.rs`):
/// always returns at least one piece, and the pieces joined back with
/// `sep` reproduce `raw` byte-for-byte.
pub fn split_top_level_on(raw: &str, sep: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in raw.char_indices() {
        match ch {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            c if c == sep && depth == 0 => {
                out.push(&raw[start..i]);
                start = i + ch.len_utf8();
            }
            _ => {}
        }
    }
    out.push(&raw[start..]);
    out
}

/// Relative difference |a-b| / max(|a|,|b|,eps); used by tests.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_integer_values() {
        // Γ(n) = (n-1)!
        assert!((gamma(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma(5.0) - 24.0).abs() < 1e-9);
        assert!((gamma(6.0) - 120.0).abs() < 1e-8);
    }

    #[test]
    fn gamma_half() {
        // Γ(1/2) = sqrt(pi)
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn gamma_weibull_arguments() {
        // Γ(1 + 1/k) for the paper's shapes: k = 0.7 -> Γ(2.428571...),
        // k = 0.5 -> Γ(3) = 2.
        assert!((gamma(3.0) - 2.0).abs() < 1e-10);
        let g = gamma(1.0 + 1.0 / 0.7);
        assert!(g > 1.26 && g < 1.27, "{g}"); // Γ(2.42857) ≈ 1.26611
    }

    #[test]
    fn gammq_known_values() {
        // Q(1, x) = e^{-x}.
        for x in [0.1, 0.5, 1.0, 3.0, 10.0] {
            assert!((gammq(1.0, x) - (-x as f64).exp()).abs() < 1e-12, "{x}");
        }
        // Q(2, x) = (1 + x) e^{-x}.
        for x in [0.2, 1.0, 4.0, 12.0] {
            let want = (1.0 + x) * (-x as f64).exp();
            assert!((gammq(2.0, x) - want).abs() < 1e-12, "{x}");
        }
        // Q(1/2, x) = erfc(sqrt(x)): spot values (erfc(1) ≈ 0.157299).
        assert!((gammq(0.5, 1.0) - 0.157_299_207_050_285).abs() < 1e-9);
        // Bounds and monotonicity in x.
        let a = 1.0 / 0.7;
        let mut prev = 1.0;
        for i in 1..100 {
            let q = gammq(a, i as f64 * 0.1);
            assert!(q > 0.0 && q < prev, "i={i}");
            prev = q;
        }
    }

    #[test]
    fn normal_cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.0) - 0.841_344_746).abs() < 1e-6);
        assert!((normal_cdf(-1.0) - 0.158_655_254).abs() < 1e-6);
        assert!((normal_cdf(1.959_964) - 0.975).abs() < 1e-5);
        assert!(normal_cdf(8.0) > 1.0 - 1e-9);
        assert!(normal_cdf(-8.0) < 1e-9);
        // Symmetry: Φ(z) + Φ(−z) = 1.
        for z in [0.3, 0.9, 1.7, 2.6] {
            assert!((normal_cdf(z) + normal_cdf(-z) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn clamp_behaviour() {
        assert_eq!(clamp(5.0, 0.0, 10.0), 5.0);
        assert_eq!(clamp(-5.0, 0.0, 10.0), 0.0);
        assert_eq!(clamp(50.0, 0.0, 10.0), 10.0);
    }

    #[test]
    fn split_top_level_is_paren_aware() {
        assert_eq!(split_top_level("a,b"), vec!["a", "b"]);
        assert_eq!(
            split_top_level("x(k=1,j=2),y"),
            vec!["x(k=1,j=2)", "y"]
        );
        assert_eq!(split_top_level(""), vec![""]);
        assert_eq!(split_top_level("a,,b"), vec!["a", "", "b"]);
        // Unbalanced ')' does not underflow.
        assert_eq!(split_top_level("a),b"), vec!["a)", "b"]);
    }
}
