//! Closed-form optimal checkpointing periods.
//!
//! Prediction-ignoring (q = 0): Young's and Daly's classical formulas (as
//! quoted in the paper's introduction) and RFO, the paper's refined
//! first-order period minimizing Eq. (3).
//!
//! Prediction-aware (q = 1): `T_P^extr` (§3.2) and `T_R^extr` — Eq. (6) for
//! WithCkptI/NoCkptI and the §3.4 variant for Instant — with the paper's
//! validity guards.

use crate::config::Scenario;
use crate::Platform;

/// Young's formula: `T = sqrt(2 μ C) + C`.
pub fn young_period(p: &Platform) -> f64 {
    (2.0 * p.mu * p.c).sqrt() + p.c
}

/// Daly's formula as quoted in the paper: `T = sqrt(2 (μ + R) C) + C`.
pub fn daly_period(p: &Platform) -> f64 {
    (2.0 * (p.mu + p.r) * p.c).sqrt() + p.c
}

/// RFO (Refined First-Order): `T = sqrt(2 C (μ - (D + R)))`, the minimizer
/// of Eq. (3).  Guards: μ must exceed D+R (otherwise fall back to C+ε
/// territory — clamped to `max(·, 1.1 C)` like every other period here).
pub fn rfo_period(p: &Platform) -> f64 {
    let slack = (p.mu - (p.d + p.r)).max(p.c); // keep the sqrt well-defined
    guard_tr((2.0 * p.c * slack).sqrt(), p)
}

/// `T_P^extr = sqrt(((1-p) I + p E) C_p / p)`, clamped to
/// `[C_p, max(C_p, I)]` (§3.2: at least one proactive checkpoint must fit).
pub fn tp_extr(sc: &Scenario) -> f64 {
    let (p, i, e) = (sc.predictor.precision, sc.predictor.window, sc.e_if());
    let cp = sc.platform.cp;
    let raw = (((1.0 - p) * i + p * e) * cp / p).sqrt();
    raw.clamp(cp, i.max(cp))
}

/// Eq. (6): `T_R^extr` for WithCkptI and NoCkptI (both minimize the same
/// T_R-dependent fraction of the waste — §3.3).
pub fn tr_extr_window(sc: &Scenario) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let (i, e) = (sc.predictor.window, sc.e_if());
    let num = 2.0
        * pf.c
        * (p * pf.mu
            - (p * (pf.d + pf.r) + r * (pf.cp + ((1.0 - p) * i + p * e))));
    let den = p * (1.0 - r);
    guard_tr(safe_sqrt(num / den), pf)
}

/// §3.4: `T_R^extr` for Instant (window-exposure terms drop out).
pub fn tr_extr_instant(sc: &Scenario) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let e = sc.e_if();
    let num = 2.0
        * pf.c
        * (p * pf.mu - (p * (pf.d + pf.r) + r * pf.cp + p * r * e));
    let den = p * (1.0 - r);
    guard_tr(safe_sqrt(num / den), pf)
}

/// The paper's guard: `T_R` must always exceed `C`.  We clamp to `1.1 C`
/// (a period equal to C does no work at all); callers that want the pure
/// formula use the `*_raw` value before the guard.
fn guard_tr(tr: f64, p: &Platform) -> f64 {
    if !tr.is_finite() {
        return 1.1 * p.c;
    }
    tr.max(1.1 * p.c)
}

fn safe_sqrt(x: f64) -> f64 {
    if x > 0.0 {
        x.sqrt()
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec, Scenario};
    use crate::model::waste;
    use crate::sim::distribution::Law;

    fn sc(mu: f64, cp: f64, p: f64, r: f64, i: f64) -> Scenario {
        Scenario {
            platform: Platform { mu, c: 600.0, cp, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(r, p, i),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    #[test]
    fn young_daly_hand_values() {
        let p = Platform { mu: 60_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 };
        assert!((young_period(&p) - ((2.0 * 60_000.0 * 600.0f64).sqrt() + 600.0)).abs() < 1e-9);
        assert!(daly_period(&p) > young_period(&p)); // μ+R > μ
    }

    #[test]
    fn rfo_minimizes_eq3_on_grid() {
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        let opt = rfo_period(&s.platform);
        let w_opt = waste::q0(&s, opt);
        let mut best = f64::INFINITY;
        let mut best_tr = 0.0;
        for k in 1..2000 {
            let tr = 610.0 + k as f64 * 25.0;
            let w = waste::q0(&s, tr);
            if w < best {
                best = w;
                best_tr = tr;
            }
        }
        assert!(w_opt <= best + 1e-6, "formula {w_opt} vs grid {best}");
        assert!((best_tr - opt).abs() / opt < 0.05, "{best_tr} vs {opt}");
    }

    #[test]
    fn tr_extr_window_minimizes_eq10_on_grid() {
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 1200.0);
        let opt = tr_extr_window(&s);
        let w_opt = waste::nockpt(&s, opt);
        for k in 1..3000 {
            let tr = 610.0 + k as f64 * 20.0;
            assert!(
                waste::nockpt(&s, tr) >= w_opt - 1e-9,
                "tr {tr} beats formula optimum {opt}"
            );
        }
    }

    #[test]
    fn tr_extr_instant_minimizes_eq14_on_grid() {
        let s = sc(60_000.0, 1200.0, 0.4, 0.7, 900.0);
        let opt = tr_extr_instant(&s);
        let w_opt = waste::instant(&s, opt);
        for k in 1..3000 {
            let tr = 610.0 + k as f64 * 20.0;
            assert!(waste::instant(&s, tr) >= w_opt - 1e-9);
        }
    }

    #[test]
    fn tp_extr_minimizes_eq4_within_bounds() {
        let s = sc(60_000.0, 60.0, 0.82, 0.85, 3000.0);
        let tp_opt = tp_extr(&s);
        assert!(tp_opt >= s.platform.cp && tp_opt <= s.predictor.window);
        let tr = tr_extr_window(&s);
        let w_opt = waste::withckpt(&s, tr, tp_opt);
        let mut tp = s.platform.cp + 1.0;
        while tp < s.predictor.window {
            assert!(waste::withckpt(&s, tr, tp) >= w_opt - 1e-9, "tp {tp}");
            tp += 10.0;
        }
    }

    #[test]
    fn recall_zero_gives_rfo_period() {
        // Paper: "when r=0 ... we obtain the same period than without a
        // predictor".
        let s = sc(60_000.0, 600.0, 0.82, 0.0, 600.0);
        let a = tr_extr_window(&s);
        let b = rfo_period(&s.platform);
        assert!((a - b).abs() / b < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn guards_hold_in_degenerate_regimes() {
        // Tiny MTBF: formulas go imaginary; the guard must keep T_R > C.
        let s = sc(700.0, 1200.0, 0.4, 0.7, 3000.0);
        for tr in [
            rfo_period(&s.platform),
            tr_extr_window(&s),
            tr_extr_instant(&s),
        ] {
            assert!(tr > s.platform.c, "{tr}");
            assert!(tr.is_finite());
        }
    }
}
