//! Analytic waste model (§3 of the paper).
//!
//! * [`waste`] — closed-form waste of each strategy as a function of the
//!   regular period `T_R` (and proactive period `T_P`): Eqs. (3), (4),
//!   (10), (14).
//! * [`batch`] — the struct-of-arrays evaluator: whole
//!   (scenario-batch × period-grid) blocks in one pass, bit-identical to
//!   the scalar entry points (see DESIGN.md §Batched model layer).
//! * [`optimal`] — the closed-form optimal periods: Young / Daly / RFO for
//!   the prediction-ignoring policies, `T_P^extr` and the strategy-specific
//!   `T_R^extr` (Eq. 6 and the §3.3 / §3.4 variants) for the
//!   prediction-aware ones, with the paper's validity guards
//!   (`T_R > C`, `C_p ≤ T_P ≤ I`).
//!
//! The same formulas are implemented in the L1 Pallas kernel
//! (`python/compile/kernels/waste_grid.py`); `tests/runtime_roundtrip.rs`
//! checks that the PJRT artifact and this module agree to f32 precision.
//!
//! [`waste::waste_checked`] is the domain-aware entry point: the guards the
//! raw formulas silently violate (`p = 0`, `T_R ≤ C`, `μ ≤ D+R`, saturated
//! values) come back as a typed [`waste::Applicability`].  The conformance
//! subsystem ([`crate::validate`]) sweeps these formulas against the
//! simulator and gates the agreement in CI.

pub mod batch;
pub mod optimal;
pub mod waste;
