//! Closed-form waste expressions — Eqs. (3), (4), (10), (14).
//!
//! All functions take the scenario (platform + predictor) and the candidate
//! period(s); they return the *raw* formula value.  [`waste_clipped`]
//! applies the clipping used by the Pallas kernel (`[0,1]`, invalid period
//! ⇒ 1) so the two implementations are bit-comparable.

use crate::config::Scenario;

/// Eq. (3): waste of periodic checkpointing with predictions ignored
/// (q = 0) — also the sanity-check limit of all three strategies.
pub fn q0(sc: &Scenario, tr: f64) -> f64 {
    let p = &sc.platform;
    1.0 - (1.0 - p.c / tr) * (1.0 - (tr / 2.0 + p.d + p.r) / p.mu)
}

/// Eq. (14): waste of Instant with q = 1.
pub fn instant(sc: &Scenario, tr: f64) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let e = sc.e_if();
    let inner = (p * (pf.d + pf.r)
        + r * pf.cp
        + (1.0 - r) * p * tr / 2.0
        + p * r * e)
        / (p * pf.mu);
    1.0 - (1.0 - pf.c / tr) * (1.0 - inner)
}

/// Eq. (10): waste of NoCkptI with q = 1.
pub fn nockpt(sc: &Scenario, tr: f64) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let (i, e) = (sc.predictor.window, sc.e_if());
    let head = (r / (p * pf.mu)) * (1.0 - p) * i;
    let inner = (p * (pf.d + pf.r)
        + r * pf.cp
        + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e))
        / (p * pf.mu);
    1.0 - head - (1.0 - pf.c / tr) * (1.0 - inner)
}

/// Eq. (4): waste of WithCkptI with q = 1, for proactive period `tp`.
pub fn withckpt(sc: &Scenario, tr: f64, tp: f64) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let (i, e) = (sc.predictor.window, sc.e_if());
    let head = (r / (p * pf.mu))
        * (1.0 - pf.cp / tp)
        * ((1.0 - p) * i + p * (e - tp));
    let inner = (p * (pf.d + pf.r)
        + r * pf.cp
        + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e))
        / (p * pf.mu);
    1.0 - head - (1.0 - pf.c / tr) * (1.0 - inner)
}

/// Strategy index used by the waste-grid artifact (must match
/// `python/compile/kernels/ref.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridStrategy {
    Q0 = 0,
    Instant = 1,
    NoCkpt = 2,
    WithCkpt = 3,
}

/// Why a closed-form waste evaluation is outside its validity domain.
/// Each variant names one structural guard of Eqs. (3)/(4)/(10)/(14) that
/// the raw formulas do *not* enforce themselves (they silently return
/// inf, NaN or negative "waste" there).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inapplicability {
    /// `T_R ≤ C`: the period cannot even hold its own checkpoint — the
    /// `(1 − C/T_R)` efficiency factor flips sign.
    PeriodWithinCheckpoint,
    /// `μ ≤ D + R`: the platform re-faults before recovery completes on
    /// average; every formula's `(…)/μ` fraction exceeds 1.
    MtbfWithinRecovery,
    /// `p = 0` with a prediction-aware formula: Eqs. (4)/(10)/(14) divide
    /// by `p·μ` (every prediction is false — the strategies degenerate).
    ZeroPrecision,
    /// WithCkptI only: `T_P` outside `[C_p, max(C_p, I)]` — no proactive
    /// checkpoint fits the window the way Algorithm 1 assumes.
    ProactivePeriodOutsideWindow,
    /// The raw formula value fell outside (0, 1): the first-order
    /// expansion is saturated and predicts nothing quantitative.
    WasteOutOfRange,
}

impl Inapplicability {
    /// Stable snake_case label (conformance stores / `CONFORMANCE.json`).
    pub fn label(&self) -> &'static str {
        match self {
            Inapplicability::PeriodWithinCheckpoint => "period_within_checkpoint",
            Inapplicability::MtbfWithinRecovery => "mtbf_within_recovery",
            Inapplicability::ZeroPrecision => "zero_precision",
            Inapplicability::ProactivePeriodOutsideWindow => {
                "proactive_period_outside_window"
            }
            Inapplicability::WasteOutOfRange => "waste_out_of_range",
        }
    }
}

impl std::fmt::Display for Inapplicability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// A closed-form waste evaluation with its validity domain made explicit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Applicability {
    /// The formula applies; the raw (unclipped) waste is in (0, 1).
    Applicable(f64),
    /// The scenario/period pair is outside the formula's domain.
    Inapplicable(Inapplicability),
}

impl Applicability {
    /// The waste value, when applicable.
    pub fn value(self) -> Option<f64> {
        match self {
            Applicability::Applicable(w) => Some(w),
            Applicability::Inapplicable(_) => None,
        }
    }

    /// The domain violation, when inapplicable.
    pub fn reason(self) -> Option<Inapplicability> {
        match self {
            Applicability::Applicable(_) => None,
            Applicability::Inapplicable(r) => Some(r),
        }
    }
}

/// Domain-checked waste: the guards the raw formulas silently violate
/// (division by `p·μ` at `p = 0`, sign flips at `T_R ≤ C` or `μ ≤ D+R`,
/// saturated first-order values) become a typed [`Applicability`] instead
/// of an inf/NaN/negative number.  `tp` is the proactive period WithCkpt
/// evaluates Eq. (4) at; the other strategies ignore it.
pub fn waste_checked(
    sc: &Scenario,
    strat: GridStrategy,
    tr: f64,
    tp: f64,
) -> Applicability {
    use Inapplicability::*;
    let p = &sc.platform;
    if !(tr > p.c) {
        return Applicability::Inapplicable(PeriodWithinCheckpoint);
    }
    if !(p.mu > p.d + p.r) {
        return Applicability::Inapplicable(MtbfWithinRecovery);
    }
    if strat != GridStrategy::Q0 && !(sc.predictor.precision > 0.0) {
        return Applicability::Inapplicable(ZeroPrecision);
    }
    if strat == GridStrategy::WithCkpt
        && !(tp >= p.cp && tp <= sc.predictor.window.max(p.cp))
    {
        return Applicability::Inapplicable(ProactivePeriodOutsideWindow);
    }
    let raw = match strat {
        GridStrategy::Q0 => q0(sc, tr),
        GridStrategy::Instant => instant(sc, tr),
        GridStrategy::NoCkpt => nockpt(sc, tr),
        GridStrategy::WithCkpt => withckpt(sc, tr, tp),
    };
    if raw.is_finite() && raw > 0.0 && raw < 1.0 {
        Applicability::Applicable(raw)
    } else {
        Applicability::Inapplicable(WasteOutOfRange)
    }
}

/// The closed-form waste split into the paper's §2.1 loss sources, for
/// the waste-accounting audit (`ckptwin metrics`): each field is that
/// source's fraction of the makespan, and their sum reproduces the full
/// Eq. (3)/(4)/(10)/(14) value (pinned to 1e-12 relative by
/// `terms_sum_to_the_formula_value`).  The simulation-side counterpart
/// is [`crate::obs::EventCounters`]'s time decomposition divided by the
/// makespan.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WasteTerms {
    /// Regular checkpoint overhead (the `C/T_R` term).
    pub ckpt_reg: f64,
    /// Proactive checkpoint overhead: the pre-window `C_p` per trusted
    /// prediction, plus (WithCkptI) the `C_p/T_P` share of the in-window
    /// occupancy.
    pub ckpt_pro: f64,
    /// Downtime + recovery (the `(D+R)/μ` term).
    pub down: f64,
    /// Re-executed work and the remaining fault-induced loss (the `T_R/2`
    /// unpredicted-fault term, the in-window exposure, minus the paper's
    /// "head" credit for useful in-window work).
    pub reexec: f64,
}

impl WasteTerms {
    /// The reassembled waste — equals the closed-form value.
    pub fn total(&self) -> f64 {
        self.ckpt_reg + self.ckpt_pro + self.down + self.reexec
    }
}

/// Decompose the closed-form waste of `strat` at periods (`tr`, `tp`)
/// into [`WasteTerms`].  Uses the same inputs as the formula functions;
/// the caller is responsible for domain checks ([`waste_checked`]) — out
/// of domain the terms are as meaningless as the raw formula value.
pub fn waste_terms(
    sc: &Scenario,
    strat: GridStrategy,
    tr: f64,
    tp: f64,
) -> WasteTerms {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let (i, e) = (sc.predictor.window, sc.e_if());
    let eff = 1.0 - pf.c / tr; // the (1 - C/T_R) efficiency factor
    let ckpt_reg = pf.c / tr;
    let down = eff * (pf.d + pf.r) / pf.mu;
    match strat {
        // Eq. (3) = C/T + (1-C/T)·[(D+R) + T/2]/μ: no proactive mode.
        GridStrategy::Q0 => WasteTerms {
            ckpt_reg,
            ckpt_pro: 0.0,
            down,
            reexec: eff * (tr / 2.0) / pf.mu,
        },
        // Eq. (14): inner = [p(D+R) + r·Cp + (1-r)p·T/2 + p·r·E]/(pμ).
        GridStrategy::Instant => WasteTerms {
            ckpt_reg,
            ckpt_pro: eff * r * pf.cp / (p * pf.mu),
            down,
            reexec: eff * ((1.0 - r) * tr / 2.0 + r * e) / pf.mu,
        },
        // Eq. (10): like Instant plus the in-window exposure
        // W = r·[(1-p)I + p·E]/(pμ), minus the head credit
        // A = r·(1-p)I/(pμ) for useful work done during false windows.
        GridStrategy::NoCkpt => {
            let w = r * ((1.0 - p) * i + p * e) / (p * pf.mu);
            let a = r * (1.0 - p) * i / (p * pf.mu);
            WasteTerms {
                ckpt_reg,
                ckpt_pro: eff * r * pf.cp / (p * pf.mu),
                down,
                reexec: eff * ((1.0 - r) * tr / 2.0 / pf.mu + w) - a,
            }
        }
        // Eq. (4): same inner as Eq. (10); the head carries the
        // (1 - Cp/T_P) in-window work share, so the complementary
        // Cp/T_P share of A = r·[(1-p)I + p(E-T_P)]/(pμ) is proactive
        // checkpoint overhead and the rest stays with re-execution:
        //   -(1-Cp/T_P)·A  =  (Cp/T_P)·A - A.
        GridStrategy::WithCkpt => {
            let w = r * ((1.0 - p) * i + p * e) / (p * pf.mu);
            let a = r * ((1.0 - p) * i + p * (e - tp)) / (p * pf.mu);
            WasteTerms {
                ckpt_reg,
                ckpt_pro: eff * r * pf.cp / (p * pf.mu) + (pf.cp / tp) * a,
                down,
                reexec: eff * ((1.0 - r) * tr / 2.0 / pf.mu + w) - a,
            }
        }
    }
}

/// The kernel-compatible clipped waste: `clip(w, 0, 1)`, and 1.0 whenever
/// `tr <= C`.  WithCkpt uses `T_P = clamp(T_P^extr, Cp, max(Cp, I))`.
pub fn waste_clipped(sc: &Scenario, strat: GridStrategy, tr: f64) -> f64 {
    if tr <= sc.platform.c {
        return 1.0;
    }
    let raw = match strat {
        GridStrategy::Q0 => q0(sc, tr),
        GridStrategy::Instant => instant(sc, tr),
        GridStrategy::NoCkpt => nockpt(sc, tr),
        GridStrategy::WithCkpt => {
            withckpt(sc, tr, super::optimal::tp_extr(sc))
        }
    };
    raw.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec, Scenario};
    use crate::sim::distribution::Law;

    fn sc(mu: f64, cp: f64, p: f64, r: f64, i: f64) -> Scenario {
        Scenario {
            platform: Platform { mu, c: 600.0, cp, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(r, p, i),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    #[test]
    fn q0_hand_computed() {
        // mu = 60000, C = 600, D+R = 660, T = 6000:
        // waste = 1 - (1 - 0.1)(1 - 3660/60000) = 1 - 0.9*0.939 = 0.1549
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        let w = q0(&s, 6000.0);
        assert!((w - 0.1549).abs() < 1e-4, "{w}");
    }

    #[test]
    fn recall_zero_reduces_to_q0() {
        // With r = 0 predictions never fire: all q=1 wastes must equal
        // Eq. (3) (the paper notes this for Eq. (6); it holds for the
        // waste too because every prediction-dependent term carries r).
        let s = sc(60_000.0, 600.0, 0.82, 0.0, 600.0);
        for tr in [2000.0, 6000.0, 20_000.0] {
            let w0 = q0(&s, tr);
            assert!((instant(&s, tr) - w0).abs() < 1e-12);
            assert!((nockpt(&s, tr) - w0).abs() < 1e-12);
            assert!((withckpt(&s, tr, 500.0) - w0).abs() < 1e-12);
        }
    }

    #[test]
    fn instant_is_nockpt_without_window_terms() {
        // Eq. (14) = Eq. (10) with the two (1-p)I "window exposure" terms
        // removed; for I -> 0 they must coincide.
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 0.0);
        for tr in [2000.0, 6000.0] {
            assert!((instant(&s, tr) - nockpt(&s, tr)).abs() < 1e-12);
        }
    }

    #[test]
    fn formulas_consume_the_model_e_if_not_the_literal_half_window() {
        // Eqs. (4)/(10)/(14) are derived in terms of E_I^f; the biased
        // placement model changes E_I^f (β = 2 ⇒ 2I/3) without changing I,
        // and every prediction-aware formula must follow.  Eq. (3) ignores
        // the predictor entirely.
        let mut s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        let tr = 6000.0;
        let (q0_u, inst_u, nock_u, with_u) = (
            q0(&s, tr),
            instant(&s, tr),
            nockpt(&s, tr),
            withckpt(&s, tr, 650.0),
        );
        s.predictor.model = crate::config::PredModel::Biased { beta: 2.0 };
        assert_eq!(s.e_if(), 400.0);
        assert_eq!(q0(&s, tr), q0_u, "Eq. (3) is predictor-blind");
        // A later expected strike loses more in-window work: waste rises.
        assert!(instant(&s, tr) > inst_u);
        assert!(nockpt(&s, tr) > nock_u);
        assert!(withckpt(&s, tr, 650.0) > with_u);
        // β = 1 is the uniform model: bitwise-identical formulas.
        s.predictor.model = crate::config::PredModel::Biased { beta: 1.0 };
        assert_eq!(nockpt(&s, tr), nock_u);
    }

    #[test]
    fn larger_window_increases_nockpt_waste() {
        let tr = 6000.0;
        let w_small = nockpt(&sc(60_000.0, 600.0, 0.82, 0.85, 300.0), tr);
        let w_large = nockpt(&sc(60_000.0, 600.0, 0.82, 0.85, 3000.0), tr);
        assert!(w_large > w_small);
    }

    #[test]
    fn withckpt_beats_nockpt_for_large_window_cheap_cp() {
        // Large window + cheap proactive checkpoints: checkpointing inside
        // the window pays off (paper §4.2).
        let s = sc(60_000.0, 60.0, 0.82, 0.85, 3000.0);
        let tr = 6000.0;
        let tp = crate::model::optimal::tp_extr(&s);
        assert!(withckpt(&s, tr, tp) < nockpt(&s, tr));
    }

    #[test]
    fn nockpt_beats_withckpt_for_small_window() {
        // I barely above Cp: WithCkpt spends the window checkpointing.
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 700.0);
        let tr = 6000.0;
        let tp = crate::model::optimal::tp_extr(&s);
        assert!(withckpt(&s, tr, tp) >= nockpt(&s, tr) - 1e-9);
    }

    #[test]
    fn checked_guards_each_division_by_zero_edge() {
        use Inapplicability::*;
        let all = [
            GridStrategy::Q0,
            GridStrategy::Instant,
            GridStrategy::NoCkpt,
            GridStrategy::WithCkpt,
        ];
        let good = sc(60_000.0, 60.0, 0.82, 0.85, 3000.0);
        let tp = crate::model::optimal::tp_extr(&good);

        // T_R ≤ C: every formula's efficiency factor flips sign.
        for strat in all {
            assert_eq!(
                waste_checked(&good, strat, 600.0, tp),
                Applicability::Inapplicable(PeriodWithinCheckpoint),
                "{strat:?}"
            );
            assert_eq!(
                waste_checked(&good, strat, 100.0, tp).reason(),
                Some(PeriodWithinCheckpoint)
            );
        }

        // μ ≤ D + R: the raw formulas go negative, checked() classifies.
        let dead = sc(600.0, 600.0, 0.82, 0.85, 600.0);
        for strat in all {
            assert_eq!(
                waste_checked(&dead, strat, 6000.0, tp).reason(),
                Some(MtbfWithinRecovery),
                "{strat:?}"
            );
        }

        // p = 0: Eqs. (4)/(10)/(14) divide by p·μ — raw value is non-finite
        // (the silent-inf bug this guard pins), checked() classifies.
        let p0 = sc(60_000.0, 600.0, 0.0, 0.85, 600.0);
        assert!(!instant(&p0, 6000.0).is_finite());
        for strat in [GridStrategy::Instant, GridStrategy::NoCkpt, GridStrategy::WithCkpt] {
            assert_eq!(
                waste_checked(&p0, strat, 6000.0, 700.0).reason(),
                Some(ZeroPrecision),
                "{strat:?}"
            );
        }
        // …but Eq. (3) never divides by p: Q0 stays applicable.
        assert!(waste_checked(&p0, GridStrategy::Q0, 6000.0, 700.0)
            .value()
            .is_some());

        // WithCkpt: T_P must fit [C_p, max(C_p, I)].
        assert_eq!(
            waste_checked(&good, GridStrategy::WithCkpt, 6000.0, 30.0).reason(),
            Some(ProactivePeriodOutsideWindow) // below C_p = 60
        );
        assert_eq!(
            waste_checked(&good, GridStrategy::WithCkpt, 6000.0, 4000.0).reason(),
            Some(ProactivePeriodOutsideWindow) // above I = 3000
        );

        // In-domain evaluation returns the raw formula value.
        let w = waste_checked(&good, GridStrategy::NoCkpt, 6000.0, tp);
        assert_eq!(w.value(), Some(nockpt(&good, 6000.0)));
        assert_eq!(w.reason(), None);
    }

    #[test]
    fn checked_classifies_saturated_first_order_values() {
        // A barely-valid MTBF keeps the domain guards quiet but pushes the
        // raw Eq. (3) value past 1: WasteOutOfRange, not a number > 1.
        let s = sc(1000.0, 600.0, 0.82, 0.85, 600.0);
        assert!(q0(&s, 6000.0) >= 1.0);
        assert_eq!(
            waste_checked(&s, GridStrategy::Q0, 6000.0, 700.0).reason(),
            Some(Inapplicability::WasteOutOfRange)
        );
    }

    #[test]
    fn inapplicability_labels_are_stable() {
        // These strings are conformance-store/JSON identities.
        assert_eq!(
            Inapplicability::PeriodWithinCheckpoint.label(),
            "period_within_checkpoint"
        );
        assert_eq!(Inapplicability::ZeroPrecision.to_string(), "zero_precision");
        assert_eq!(
            Inapplicability::MtbfWithinRecovery.label(),
            "mtbf_within_recovery"
        );
    }

    #[test]
    fn terms_sum_to_the_formula_value() {
        // The audit's decomposition invariant: for every strategy the
        // WasteTerms reassemble the exact closed-form value (different
        // summation order, so 1e-12 relative — far below any conformance
        // tolerance).
        let scenarios = [
            sc(60_000.0, 600.0, 0.82, 0.85, 600.0),
            sc(60_000.0, 60.0, 0.82, 0.85, 3000.0),
            sc(200_000.0, 300.0, 0.95, 0.5, 900.0),
        ];
        for s in &scenarios {
            for tr in [2000.0, 6000.0, 20_000.0] {
                let tp = crate::model::optimal::tp_extr(s)
                    .clamp(s.platform.cp, s.predictor.window.max(s.platform.cp));
                for (strat, formula) in [
                    (GridStrategy::Q0, q0(s, tr)),
                    (GridStrategy::Instant, instant(s, tr)),
                    (GridStrategy::NoCkpt, nockpt(s, tr)),
                    (GridStrategy::WithCkpt, withckpt(s, tr, tp)),
                ] {
                    let t = waste_terms(s, strat, tr, tp);
                    assert!(
                        (t.total() - formula).abs() <= 1e-12 * formula.abs().max(1.0),
                        "{strat:?} tr={tr}: {} vs {formula}",
                        t.total()
                    );
                    // Overhead terms are nonnegative in-domain.
                    assert!(t.ckpt_reg >= 0.0 && t.ckpt_pro >= 0.0 && t.down >= 0.0);
                }
            }
        }
    }

    #[test]
    fn terms_recall_zero_has_no_proactive_share() {
        // r = 0: predictions never fire, so every strategy's decomposition
        // collapses onto Eq. (3)'s.
        let s = sc(60_000.0, 600.0, 0.82, 0.0, 600.0);
        let base = waste_terms(&s, GridStrategy::Q0, 6000.0, 650.0);
        for strat in [GridStrategy::Instant, GridStrategy::NoCkpt, GridStrategy::WithCkpt]
        {
            let t = waste_terms(&s, strat, 6000.0, 650.0);
            assert_eq!(t.ckpt_pro, 0.0, "{strat:?}");
            assert!((t.total() - base.total()).abs() < 1e-12, "{strat:?}");
        }
    }

    #[test]
    fn clipped_matches_kernel_semantics() {
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        assert_eq!(waste_clipped(&s, GridStrategy::Q0, 600.0), 1.0);
        assert_eq!(waste_clipped(&s, GridStrategy::Q0, 100.0), 1.0);
        let w = waste_clipped(&s, GridStrategy::Q0, 6000.0);
        assert!(w > 0.0 && w < 1.0);
    }
}
