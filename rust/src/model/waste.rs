//! Closed-form waste expressions — Eqs. (3), (4), (10), (14).
//!
//! All functions take the scenario (platform + predictor) and the candidate
//! period(s); they return the *raw* formula value.  [`waste_clipped`]
//! applies the clipping used by the Pallas kernel (`[0,1]`, invalid period
//! ⇒ 1) so the two implementations are bit-comparable.

use crate::config::Scenario;

/// Eq. (3): waste of periodic checkpointing with predictions ignored
/// (q = 0) — also the sanity-check limit of all three strategies.
pub fn q0(sc: &Scenario, tr: f64) -> f64 {
    let p = &sc.platform;
    1.0 - (1.0 - p.c / tr) * (1.0 - (tr / 2.0 + p.d + p.r) / p.mu)
}

/// Eq. (14): waste of Instant with q = 1.
pub fn instant(sc: &Scenario, tr: f64) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let e = sc.e_if();
    let inner = (p * (pf.d + pf.r)
        + r * pf.cp
        + (1.0 - r) * p * tr / 2.0
        + p * r * e)
        / (p * pf.mu);
    1.0 - (1.0 - pf.c / tr) * (1.0 - inner)
}

/// Eq. (10): waste of NoCkptI with q = 1.
pub fn nockpt(sc: &Scenario, tr: f64) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let (i, e) = (sc.predictor.window, sc.e_if());
    let head = (r / (p * pf.mu)) * (1.0 - p) * i;
    let inner = (p * (pf.d + pf.r)
        + r * pf.cp
        + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e))
        / (p * pf.mu);
    1.0 - head - (1.0 - pf.c / tr) * (1.0 - inner)
}

/// Eq. (4): waste of WithCkptI with q = 1, for proactive period `tp`.
pub fn withckpt(sc: &Scenario, tr: f64, tp: f64) -> f64 {
    let pf = &sc.platform;
    let (p, r) = (sc.predictor.precision, sc.predictor.recall);
    let (i, e) = (sc.predictor.window, sc.e_if());
    let head = (r / (p * pf.mu))
        * (1.0 - pf.cp / tp)
        * ((1.0 - p) * i + p * (e - tp));
    let inner = (p * (pf.d + pf.r)
        + r * pf.cp
        + (1.0 - r) * p * tr / 2.0
        + r * ((1.0 - p) * i + p * e))
        / (p * pf.mu);
    1.0 - head - (1.0 - pf.c / tr) * (1.0 - inner)
}

/// Strategy index used by the waste-grid artifact (must match
/// `python/compile/kernels/ref.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridStrategy {
    Q0 = 0,
    Instant = 1,
    NoCkpt = 2,
    WithCkpt = 3,
}

/// The kernel-compatible clipped waste: `clip(w, 0, 1)`, and 1.0 whenever
/// `tr <= C`.  WithCkpt uses `T_P = clamp(T_P^extr, Cp, max(Cp, I))`.
pub fn waste_clipped(sc: &Scenario, strat: GridStrategy, tr: f64) -> f64 {
    if tr <= sc.platform.c {
        return 1.0;
    }
    let raw = match strat {
        GridStrategy::Q0 => q0(sc, tr),
        GridStrategy::Instant => instant(sc, tr),
        GridStrategy::NoCkpt => nockpt(sc, tr),
        GridStrategy::WithCkpt => {
            withckpt(sc, tr, super::optimal::tp_extr(sc))
        }
    };
    raw.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec, Scenario};
    use crate::sim::distribution::Law;

    fn sc(mu: f64, cp: f64, p: f64, r: f64, i: f64) -> Scenario {
        Scenario {
            platform: Platform { mu, c: 600.0, cp, d: 60.0, r: 600.0 },
            predictor: PredictorSpec { recall: r, precision: p, window: i },
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    #[test]
    fn q0_hand_computed() {
        // mu = 60000, C = 600, D+R = 660, T = 6000:
        // waste = 1 - (1 - 0.1)(1 - 3660/60000) = 1 - 0.9*0.939 = 0.1549
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        let w = q0(&s, 6000.0);
        assert!((w - 0.1549).abs() < 1e-4, "{w}");
    }

    #[test]
    fn recall_zero_reduces_to_q0() {
        // With r = 0 predictions never fire: all q=1 wastes must equal
        // Eq. (3) (the paper notes this for Eq. (6); it holds for the
        // waste too because every prediction-dependent term carries r).
        let s = sc(60_000.0, 600.0, 0.82, 0.0, 600.0);
        for tr in [2000.0, 6000.0, 20_000.0] {
            let w0 = q0(&s, tr);
            assert!((instant(&s, tr) - w0).abs() < 1e-12);
            assert!((nockpt(&s, tr) - w0).abs() < 1e-12);
            assert!((withckpt(&s, tr, 500.0) - w0).abs() < 1e-12);
        }
    }

    #[test]
    fn instant_is_nockpt_without_window_terms() {
        // Eq. (14) = Eq. (10) with the two (1-p)I "window exposure" terms
        // removed; for I -> 0 they must coincide.
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 0.0);
        for tr in [2000.0, 6000.0] {
            assert!((instant(&s, tr) - nockpt(&s, tr)).abs() < 1e-12);
        }
    }

    #[test]
    fn larger_window_increases_nockpt_waste() {
        let tr = 6000.0;
        let w_small = nockpt(&sc(60_000.0, 600.0, 0.82, 0.85, 300.0), tr);
        let w_large = nockpt(&sc(60_000.0, 600.0, 0.82, 0.85, 3000.0), tr);
        assert!(w_large > w_small);
    }

    #[test]
    fn withckpt_beats_nockpt_for_large_window_cheap_cp() {
        // Large window + cheap proactive checkpoints: checkpointing inside
        // the window pays off (paper §4.2).
        let s = sc(60_000.0, 60.0, 0.82, 0.85, 3000.0);
        let tr = 6000.0;
        let tp = crate::model::optimal::tp_extr(&s);
        assert!(withckpt(&s, tr, tp) < nockpt(&s, tr));
    }

    #[test]
    fn nockpt_beats_withckpt_for_small_window() {
        // I barely above Cp: WithCkpt spends the window checkpointing.
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 700.0);
        let tr = 6000.0;
        let tp = crate::model::optimal::tp_extr(&s);
        assert!(withckpt(&s, tr, tp) >= nockpt(&s, tr) - 1e-9);
    }

    #[test]
    fn clipped_matches_kernel_semantics() {
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        assert_eq!(waste_clipped(&s, GridStrategy::Q0, 600.0), 1.0);
        assert_eq!(waste_clipped(&s, GridStrategy::Q0, 100.0), 1.0);
        let w = waste_clipped(&s, GridStrategy::Q0, 6000.0);
        assert!(w > 0.0 && w < 1.0);
    }
}
