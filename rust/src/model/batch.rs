//! Batched closed-form evaluator: struct-of-arrays waste surfaces.
//!
//! [`waste::waste_checked`] answers one (scenario, strategy, period) cell
//! per call, re-deriving every domain guard and every scenario-dependent
//! coefficient each time.  Campaigns, conformance sweeps and figure
//! presets ask for the *whole* (scenario-batch B × period-grid G) block at
//! once, so this module evaluates it as one:
//!
//! * **Guard hoisting** — the scenario-dependent guards (`μ ≤ D+R`,
//!   `p = 0` for the prediction-aware formulas, the WithCkpt `T_P` window
//!   fit) are decided once per row, not once per cell.  A guarded row
//!   classifies all its cells without touching the formula arithmetic
//!   (the `guard_skipped` counter).  Only `T_R ≤ C` remains per-cell — it
//!   depends on the grid point — and it is checked in the classification
//!   pass, outside the arithmetic loop.
//! * **Coefficient hoisting** — every `T_R`-independent subexpression of
//!   Eqs. (3)/(4)/(10)/(14) is computed once per row ([`RowCoeffs`]).
//!   Hoisting preserves the scalar expression *trees* (only complete
//!   subtrees are factored out), so each cell's f64 value is **bit
//!   identical** to the corresponding [`waste::waste_checked`] /
//!   [`waste::waste_clipped`] call — value *and* `Inapplicability`
//!   reason.  Pinned by `tests/batch_model.rs` across the full
//!   strategy × predictor registry cross-product.
//! * **Tight inner loops** — the raw values land in a reused f64 scratch
//!   buffer via straight-line, branch-free loops the compiler can
//!   autovectorize; classification happens in a second pass.
//! * **Sharding** — [`waste_surfaces`] fans scenario rows out over the
//!   campaign work-stealing scheduler (one [`BatchEvaluator`] per worker,
//!   results in input order, thread-count deterministic).
//!
//! Two output semantics, matching the two scalar entry points:
//! checked ([`Applicability`] per cell — the conformance/model side) and
//! clipped (kernel semantics: `T_R ≤ C ⇒ 1`, clamp to `[0,1]`, WithCkpt
//! at `T_P^extr` — the figure presets and the PJRT/Pallas cross-check).
//!
//! See DESIGN.md §Batched model layer for the block layout and the
//! 3-step recipe for adding a strategy column.

use crate::config::Scenario;
use crate::model::waste::{Applicability, GridStrategy, Inapplicability};

/// The four surface rows of a block, in artifact order (= the strategy
/// index layout of `python/compile/kernels/ref.py`).
pub const STRATEGIES: [GridStrategy; 4] = [
    GridStrategy::Q0,
    GridStrategy::Instant,
    GridStrategy::NoCkpt,
    GridStrategy::WithCkpt,
];

/// Batch-evaluator telemetry (`ckptwin metrics` → `METRICS.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BatchStats {
    /// (row × grid) blocks evaluated (one per `eval_row`/`clipped_row`).
    pub blocks: u64,
    /// Total cells classified (applicable or not).
    pub cells: u64,
    /// Cells classified by a hoisted row guard or the per-cell
    /// `T_R ≤ C` check — i.e. without evaluating any formula arithmetic.
    pub guard_skipped: u64,
    /// Wall-clock of the sharded [`waste_surfaces`] call that produced
    /// these stats (0 for single-row accumulation).
    pub elapsed_secs: f64,
}

impl BatchStats {
    pub fn merge(&mut self, other: &BatchStats) {
        self.blocks += other.blocks;
        self.cells += other.cells;
        self.guard_skipped += other.guard_skipped;
        self.elapsed_secs += other.elapsed_secs;
    }

    /// Classified cells per second of wall-clock.
    pub fn cells_per_sec(&self) -> f64 {
        if self.elapsed_secs > 0.0 {
            self.cells as f64 / self.elapsed_secs
        } else {
            0.0
        }
    }

    /// Fraction of cells classified without formula arithmetic.
    pub fn guard_skip_rate(&self) -> f64 {
        if self.cells > 0 {
            self.guard_skipped as f64 / self.cells as f64
        } else {
            0.0
        }
    }

    fn delta(&self, since: &BatchStats) -> BatchStats {
        BatchStats {
            blocks: self.blocks - since.blocks,
            cells: self.cells - since.cells,
            guard_skipped: self.guard_skipped - since.guard_skipped,
            elapsed_secs: 0.0,
        }
    }
}

/// The `T_R`-independent coefficients of one (scenario, strategy, `T_P`)
/// row.  Every field is a *complete subtree* of the scalar formula's
/// expression tree ([`waste::q0`]/[`waste::instant`]/[`waste::nockpt`]/
/// [`waste::withckpt`]), so substituting it back into the per-cell
/// remainder reproduces the scalar result bit for bit — IEEE f64
/// arithmetic is deterministic, and only the *schedule* changes, never
/// the operation tree.
#[derive(Clone, Copy, Debug)]
struct RowCoeffs {
    /// Platform loads shared by every kernel.
    c: f64,
    mu: f64,
    d: f64,
    r: f64,
    /// `p·(D+R) + r·C_p` — the `T_R`-free prefix of the aware numerators.
    a: f64,
    /// `(1−r)·p` — the coefficient of the `T_R/2` numerator term.
    k: f64,
    /// `p·μ` — the aware denominator.
    denom: f64,
    /// Instant: the `p·r·E` tail term of Eq. (14)'s numerator.
    pre: f64,
    /// NoCkpt/WithCkpt: the `r·((1−p)I + p·E)` tail term (Eqs. 10/4).
    rw: f64,
    /// NoCkpt: `1 − head` with `head = (r/(pμ))·(1−p)·I` (Eq. 10).
    omh_nockpt: f64,
    /// WithCkpt: `1 − head(T_P)` (Eq. 4).
    omh_withckpt: f64,
}

impl RowCoeffs {
    /// Hoist the row constants.  The bindings mirror the scalar formula
    /// bodies token for token — do not "simplify" them: any re-association
    /// breaks the bit-identity contract.
    fn new(sc: &Scenario, tp: f64) -> RowCoeffs {
        let pf = &sc.platform;
        let (p, r) = (sc.predictor.precision, sc.predictor.recall);
        let (i, e) = (sc.predictor.window, sc.e_if());
        let head_nockpt = (r / (p * pf.mu)) * (1.0 - p) * i;
        let head_withckpt = (r / (p * pf.mu))
            * (1.0 - pf.cp / tp)
            * ((1.0 - p) * i + p * (e - tp));
        RowCoeffs {
            c: pf.c,
            mu: pf.mu,
            d: pf.d,
            r: pf.r,
            a: p * (pf.d + pf.r) + r * pf.cp,
            k: (1.0 - r) * p,
            denom: p * pf.mu,
            pre: p * r * e,
            rw: r * ((1.0 - p) * i + p * e),
            omh_nockpt: 1.0 - head_nockpt,
            omh_withckpt: 1.0 - head_withckpt,
        }
    }

    /// Fill `raw[j]` with the unguarded formula value at `grid[j]`.
    /// Straight-line loops over the scratch buffer: no branches, no calls —
    /// the autovectorization surface.
    fn fill(&self, strat: GridStrategy, grid: &[f64], raw: &mut [f64]) {
        debug_assert_eq!(grid.len(), raw.len());
        match strat {
            // Eq. (3): 1 − (1 − C/T)·(1 − (T/2 + D + R)/μ).
            GridStrategy::Q0 => {
                let (c, mu, d, r) = (self.c, self.mu, self.d, self.r);
                for (w, &tr) in raw.iter_mut().zip(grid) {
                    *w = 1.0
                        - (1.0 - c / tr) * (1.0 - (tr / 2.0 + d + r) / mu);
                }
            }
            // Eq. (14): inner = (a + k·T/2 + p·r·E)/(pμ).
            GridStrategy::Instant => {
                let (c, a, k, pre, denom) =
                    (self.c, self.a, self.k, self.pre, self.denom);
                for (w, &tr) in raw.iter_mut().zip(grid) {
                    let inner = (a + k * tr / 2.0 + pre) / denom;
                    *w = 1.0 - (1.0 - c / tr) * (1.0 - inner);
                }
            }
            // Eq. (10): (1 − head) − (1 − C/T)·(1 − (a + k·T/2 + rw)/(pμ)).
            GridStrategy::NoCkpt => {
                let (c, a, k, rw, denom, omh) =
                    (self.c, self.a, self.k, self.rw, self.denom, self.omh_nockpt);
                for (w, &tr) in raw.iter_mut().zip(grid) {
                    let inner = (a + k * tr / 2.0 + rw) / denom;
                    *w = omh - (1.0 - c / tr) * (1.0 - inner);
                }
            }
            // Eq. (4): same inner as Eq. (10), head carries the T_P share.
            GridStrategy::WithCkpt => {
                let (c, a, k, rw, denom, omh) = (
                    self.c,
                    self.a,
                    self.k,
                    self.rw,
                    self.denom,
                    self.omh_withckpt,
                );
                for (w, &tr) in raw.iter_mut().zip(grid) {
                    let inner = (a + k * tr / 2.0 + rw) / denom;
                    *w = omh - (1.0 - c / tr) * (1.0 - inner);
                }
            }
        }
    }
}

/// The hoisted row guard: the first [`Inapplicability`] (in
/// [`waste::waste_checked`]'s guard order, after the per-cell `T_R ≤ C`
/// check) that holds for *every* cell of the row, or `None`.
fn row_guard(sc: &Scenario, strat: GridStrategy, tp: f64) -> Option<Inapplicability> {
    let p = &sc.platform;
    if !(p.mu > p.d + p.r) {
        return Some(Inapplicability::MtbfWithinRecovery);
    }
    if strat != GridStrategy::Q0 && !(sc.predictor.precision > 0.0) {
        return Some(Inapplicability::ZeroPrecision);
    }
    if strat == GridStrategy::WithCkpt
        && !(tp >= p.cp && tp <= sc.predictor.window.max(p.cp))
    {
        return Some(Inapplicability::ProactivePeriodOutsideWindow);
    }
    None
}

/// One scenario's four checked waste surfaces over a shared period grid:
/// `rows[strategy_index][grid_point]` (strategy order = [`STRATEGIES`]).
#[derive(Clone, Debug, Default)]
pub struct CheckedSurface {
    pub rows: [Vec<Applicability>; 4],
}

impl CheckedSurface {
    /// The row for `strat` (artifact index layout).
    pub fn row(&self, strat: GridStrategy) -> &[Applicability] {
        &self.rows[strat as usize]
    }
}

/// The reusable evaluator: a scratch buffer plus accumulated stats.
/// One instance per worker thread; creation is cheap.
#[derive(Debug, Default)]
pub struct BatchEvaluator {
    scratch: Vec<f64>,
    pub stats: BatchStats,
}

impl BatchEvaluator {
    pub fn new() -> BatchEvaluator {
        BatchEvaluator::default()
    }

    /// Evaluate one (scenario, strategy, `T_P`) row over `grid`, appending
    /// one [`Applicability`] per grid point to `out` (cleared first).
    /// Bit-identical — value and reason — to calling
    /// [`waste::waste_checked`] per cell.
    pub fn eval_row(
        &mut self,
        sc: &Scenario,
        strat: GridStrategy,
        tp: f64,
        grid: &[f64],
        out: &mut Vec<Applicability>,
    ) {
        out.clear();
        out.reserve(grid.len());
        self.stats.blocks += 1;
        self.stats.cells += grid.len() as u64;
        let c = sc.platform.c;
        if let Some(g) = row_guard(sc, strat, tp) {
            // Guarded row: no arithmetic at all.  The per-cell T_R ≤ C
            // guard still takes precedence (waste_checked checks it first).
            self.stats.guard_skipped += grid.len() as u64;
            out.extend(grid.iter().map(|&tr| {
                Applicability::Inapplicable(if !(tr > c) {
                    Inapplicability::PeriodWithinCheckpoint
                } else {
                    g
                })
            }));
            return;
        }
        let coeffs = RowCoeffs::new(sc, tp);
        self.scratch.clear();
        self.scratch.resize(grid.len(), 0.0);
        coeffs.fill(strat, grid, &mut self.scratch);
        for (&tr, &raw) in grid.iter().zip(&self.scratch) {
            out.push(if !(tr > c) {
                self.stats.guard_skipped += 1;
                Applicability::Inapplicable(
                    Inapplicability::PeriodWithinCheckpoint,
                )
            } else if raw.is_finite() && raw > 0.0 && raw < 1.0 {
                Applicability::Applicable(raw)
            } else {
                Applicability::Inapplicable(Inapplicability::WasteOutOfRange)
            });
        }
    }

    /// [`Self::eval_row`] for all four strategies of one scenario.
    /// WithCkpt evaluates Eq. (4) at `tp`; the others ignore it.
    pub fn surface(
        &mut self,
        sc: &Scenario,
        tp: f64,
        grid: &[f64],
    ) -> CheckedSurface {
        let mut out = CheckedSurface::default();
        for strat in STRATEGIES {
            let mut row = Vec::new();
            self.eval_row(sc, strat, tp, grid, &mut row);
            out.rows[strat as usize] = row;
        }
        out
    }

    /// Kernel-semantics row: bit-identical to [`waste::waste_clipped`] per
    /// cell (`T_R ≤ C ⇒ 1`, clamp `[0,1]`, WithCkpt at the row's
    /// `T_P^extr`).  This is the figure presets' analytic column and the
    /// f64 side of the PJRT/Pallas cross-check gate.
    pub fn clipped_row(
        &mut self,
        sc: &Scenario,
        strat: GridStrategy,
        grid: &[f64],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.reserve(grid.len());
        self.stats.blocks += 1;
        self.stats.cells += grid.len() as u64;
        // waste_clipped evaluates WithCkpt at T_P^extr unconditionally; the
        // scalar recomputes it per cell, the batch hoists it (pure fn of
        // the scenario — identical bits either way).
        let tp = crate::model::optimal::tp_extr(sc);
        let coeffs = RowCoeffs::new(sc, tp);
        self.scratch.clear();
        self.scratch.resize(grid.len(), 0.0);
        coeffs.fill(strat, grid, &mut self.scratch);
        let c = sc.platform.c;
        for (&tr, &raw) in grid.iter().zip(&self.scratch) {
            out.push(if tr <= c {
                self.stats.guard_skipped += 1;
                1.0
            } else {
                raw.clamp(0.0, 1.0)
            });
        }
    }

    /// All four clipped rows of one scenario (artifact row order).
    pub fn clipped_surface(
        &mut self,
        sc: &Scenario,
        grid: &[f64],
    ) -> [Vec<f64>; 4] {
        let mut out: [Vec<f64>; 4] = Default::default();
        for strat in STRATEGIES {
            let mut row = Vec::new();
            self.clipped_row(sc, strat, grid, &mut row);
            out[strat as usize] = row;
        }
        out
    }
}

/// Evaluate checked surfaces for a whole scenario batch over a shared
/// grid, sharded across the campaign scheduler (`threads` = 0 ⇒ all
/// cores).  `items[i] = (scenario, tp)`; results come back in input
/// order and are thread-count deterministic.  Returns the merged stats
/// with the call's wall-clock.
pub fn waste_surfaces(
    items: &[(Scenario, f64)],
    grid: &[f64],
    threads: usize,
) -> (Vec<CheckedSurface>, BatchStats) {
    use crate::campaign::scheduler;
    let timer = crate::obs::SpanTimer::start();
    struct Worker {
        ev: BatchEvaluator,
        seen: BatchStats,
    }
    let out = scheduler::run_units_stateful(
        items.len(),
        threads,
        || Worker { ev: BatchEvaluator::new(), seen: BatchStats::default() },
        |w: &mut Worker, u| {
            let (sc, tp) = &items[u];
            let surface = w.ev.surface(sc, *tp, grid);
            let delta = w.ev.stats.delta(&w.seen);
            w.seen = w.ev.stats;
            (surface, delta)
        },
    );
    let mut stats = BatchStats::default();
    let mut surfaces = Vec::with_capacity(out.len());
    for (surface, delta) in out {
        stats.merge(&delta);
        surfaces.push(surface);
    }
    stats.elapsed_secs = timer.elapsed_secs();
    (surfaces, stats)
}

/// Clipped surfaces for a scenario batch (kernel semantics), sharded like
/// [`waste_surfaces`].  The f64 reference side of the waste-grid artifact
/// cross-check.
pub fn clipped_surfaces(
    scenarios: &[Scenario],
    grid: &[f64],
    threads: usize,
) -> (Vec<[Vec<f64>; 4]>, BatchStats) {
    use crate::campaign::scheduler;
    let timer = crate::obs::SpanTimer::start();
    struct Worker {
        ev: BatchEvaluator,
        seen: BatchStats,
    }
    let out = scheduler::run_units_stateful(
        scenarios.len(),
        threads,
        || Worker { ev: BatchEvaluator::new(), seen: BatchStats::default() },
        |w: &mut Worker, u| {
            let surface = w.ev.clipped_surface(&scenarios[u], grid);
            let delta = w.ev.stats.delta(&w.seen);
            w.seen = w.ev.stats;
            (surface, delta)
        },
    );
    let mut stats = BatchStats::default();
    let mut surfaces = Vec::with_capacity(out.len());
    for (surface, delta) in out {
        stats.merge(&delta);
        surfaces.push(surface);
    }
    stats.elapsed_secs = timer.elapsed_secs();
    (surfaces, stats)
}

/// Analytic BestPeriod over a clipped surface: `(best_tr, best_waste)`
/// per strategy (artifact order), first minimum winning ties — the
/// f64 twin of [`crate::runtime::Runtime::best_periods`].
pub fn best_periods_clipped(
    sc: &Scenario,
    grid: &[f64],
) -> [(f64, f64); 4] {
    let mut ev = BatchEvaluator::new();
    let surface = ev.clipped_surface(sc, grid);
    let mut best = [(0.0f64, f64::INFINITY); 4];
    for (si, row) in surface.iter().enumerate() {
        for (gi, &w) in row.iter().enumerate() {
            if w < best[si].1 {
                best[si] = (grid[gi], w);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec, Scenario};
    use crate::model::waste::{waste_checked, waste_clipped};
    use crate::sim::distribution::Law;

    fn sc(mu: f64, cp: f64, p: f64, r: f64, i: f64) -> Scenario {
        Scenario {
            platform: Platform { mu, c: 600.0, cp, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(r, p, i),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    fn grid() -> Vec<f64> {
        vec![100.0, 600.0, 660.0, 2000.0, 6000.0, 20_000.0, 2e5, 2e6]
    }

    fn assert_bitwise(tag: &str, got: Applicability, want: Applicability) {
        match (got, want) {
            (Applicability::Applicable(g), Applicability::Applicable(w)) => {
                assert_eq!(g.to_bits(), w.to_bits(), "{tag}: {g} vs {w}");
            }
            _ => assert_eq!(got, want, "{tag}"),
        }
    }

    #[test]
    fn rows_match_scalar_checked_bitwise() {
        let scenarios = [
            sc(60_000.0, 600.0, 0.82, 0.85, 600.0),
            sc(60_000.0, 60.0, 0.82, 0.85, 3000.0),
            sc(1000.0, 600.0, 0.82, 0.85, 600.0), // saturated values
            sc(600.0, 600.0, 0.82, 0.85, 600.0),  // μ ≤ D+R row guard
            sc(60_000.0, 600.0, 0.0, 0.85, 600.0), // p = 0 row guard
        ];
        let g = grid();
        let mut ev = BatchEvaluator::new();
        let mut row = Vec::new();
        for s in &scenarios {
            let tp = crate::model::optimal::tp_extr(s)
                .clamp(s.platform.cp, s.predictor.window.max(s.platform.cp));
            for strat in STRATEGIES {
                ev.eval_row(s, strat, tp, &g, &mut row);
                assert_eq!(row.len(), g.len());
                for (j, &tr) in g.iter().enumerate() {
                    assert_bitwise(
                        &format!("{strat:?} tr={tr}"),
                        row[j],
                        waste_checked(s, strat, tr, tp),
                    );
                }
            }
        }
    }

    #[test]
    fn withckpt_tp_guard_is_hoisted_but_identical() {
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        let mut ev = BatchEvaluator::new();
        let mut row = Vec::new();
        // T_P below C_p and above the window: both classify every cell.
        for tp in [30.0, 4000.0] {
            ev.eval_row(&s, GridStrategy::WithCkpt, tp, &grid(), &mut row);
            for (j, &tr) in grid().iter().enumerate() {
                assert_eq!(row[j], waste_checked(&s, GridStrategy::WithCkpt, tr, tp), "tr={tr}");
            }
        }
    }

    #[test]
    fn clipped_rows_match_scalar_clipped_bitwise() {
        let scenarios = [
            sc(60_000.0, 600.0, 0.82, 0.85, 600.0),
            sc(60_000.0, 60.0, 0.82, 0.85, 3000.0),
            sc(1000.0, 600.0, 0.82, 0.85, 600.0),
        ];
        let g = grid();
        let mut ev = BatchEvaluator::new();
        let mut row = Vec::new();
        for s in &scenarios {
            for strat in STRATEGIES {
                ev.clipped_row(s, strat, &g, &mut row);
                for (j, &tr) in g.iter().enumerate() {
                    assert_eq!(
                        row[j].to_bits(),
                        waste_clipped(s, strat, tr).to_bits(),
                        "{strat:?} tr={tr}"
                    );
                }
            }
        }
    }

    #[test]
    fn stats_count_blocks_cells_and_guard_skips() {
        let mut ev = BatchEvaluator::new();
        let mut row = Vec::new();
        let g = grid();
        // p = 0 row: every aware cell is guard-skipped.
        let p0 = sc(60_000.0, 600.0, 0.0, 0.85, 600.0);
        ev.eval_row(&p0, GridStrategy::Instant, 700.0, &g, &mut row);
        assert_eq!(ev.stats.blocks, 1);
        assert_eq!(ev.stats.cells, g.len() as u64);
        assert_eq!(ev.stats.guard_skipped, g.len() as u64);
        assert_eq!(ev.stats.guard_skip_rate(), 1.0);
        // An unguarded Q0 row only skips the two T_R ≤ C cells.
        ev.eval_row(&p0, GridStrategy::Q0, 700.0, &g, &mut row);
        assert_eq!(ev.stats.blocks, 2);
        assert_eq!(ev.stats.guard_skipped, g.len() as u64 + 2);
        assert!(ev.stats.guard_skip_rate() < 1.0);
    }

    #[test]
    fn sharded_surfaces_are_thread_count_deterministic() {
        let items: Vec<(Scenario, f64)> = [
            sc(60_000.0, 600.0, 0.82, 0.85, 600.0),
            sc(60_000.0, 60.0, 0.82, 0.85, 3000.0),
            sc(200_000.0, 300.0, 0.95, 0.5, 900.0),
            sc(600.0, 600.0, 0.82, 0.85, 600.0),
        ]
        .into_iter()
        .map(|s| {
            let tp = crate::model::optimal::tp_extr(&s)
                .clamp(s.platform.cp, s.predictor.window.max(s.platform.cp));
            (s, tp)
        })
        .collect();
        let g = grid();
        let (a, sa) = waste_surfaces(&items, &g, 1);
        let (b, sb) = waste_surfaces(&items, &g, 4);
        assert_eq!(a.len(), items.len());
        for (x, y) in a.iter().zip(&b) {
            for strat in STRATEGIES {
                assert_eq!(x.row(strat), y.row(strat));
            }
        }
        // Stats are schedule-independent (wall-clock aside).
        assert_eq!(sa.blocks, sb.blocks);
        assert_eq!(sa.cells, sb.cells);
        assert_eq!(sa.guard_skipped, sb.guard_skipped);
        assert_eq!(sa.cells, (items.len() * 4 * g.len()) as u64);
    }

    #[test]
    fn best_periods_clipped_finds_the_grid_argmin() {
        let s = sc(60_000.0, 60.0, 0.82, 0.85, 3000.0);
        let g: Vec<f64> = (0..257)
            .map(|k| 700.0 * (4e5f64 / 700.0).powf(k as f64 / 256.0))
            .collect();
        let best = best_periods_clipped(&s, &g);
        for (si, strat) in STRATEGIES.iter().enumerate() {
            let (btr, bw) = best[si];
            assert!(bw > 0.0 && bw < 1.0, "{strat:?}");
            // No grid point beats the reported argmin.
            for &tr in &g {
                assert!(waste_clipped(&s, *strat, tr) >= bw, "{strat:?} tr={tr}");
            }
            assert!(g.contains(&btr));
        }
    }

    #[test]
    fn empty_grid_yields_empty_rows() {
        let s = sc(60_000.0, 600.0, 0.82, 0.85, 600.0);
        let mut ev = BatchEvaluator::new();
        let mut row = Vec::new();
        ev.eval_row(&s, GridStrategy::Q0, 700.0, &[], &mut row);
        assert!(row.is_empty());
        assert_eq!(ev.stats.cells, 0);
        let (surfaces, stats) = waste_surfaces(&[], &grid(), 2);
        assert!(surfaces.is_empty());
        assert_eq!(stats.cells, 0);
    }
}
