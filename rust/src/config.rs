//! Configuration: platform, predictor, scenario — plus the paper's presets
//! and a small TOML-subset loader (offline environment: no serde), so
//! experiments can be described declaratively and launched from the CLI.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::sim::distribution::Law;
use crate::util::{paper, SECONDS_PER_YEAR};

/// Fault-tolerance characteristics of the platform (§2.1, §2.3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Platform {
    /// Platform MTBF μ in seconds (μ = μ_ind / N).
    pub mu: f64,
    /// Regular checkpoint duration C (s).
    pub c: f64,
    /// Proactive checkpoint duration C_p (s).
    pub cp: f64,
    /// Downtime D (s).
    pub d: f64,
    /// Recovery duration R (s).
    pub r: f64,
}

impl Platform {
    /// The paper's platform for `n_procs` processors:
    /// μ = μ_ind/N with μ_ind = 125 years, C = R = 600 s, D = 60 s.
    pub fn paper(n_procs: u64, cp_ratio: f64) -> Self {
        let mu = paper::MU_IND_YEARS * SECONDS_PER_YEAR / n_procs as f64;
        Platform {
            mu,
            c: paper::C,
            cp: cp_ratio * paper::C,
            d: paper::D,
            r: paper::R,
        }
    }
}

/// Window-placement semantics of a predictor — the *model* half of the
/// predictor axis (the numeric half is [`PredictorSpec`]'s r/p/I).
///
/// The paper's §2.2 predictor announces fixed-length windows with the
/// fault uniform inside ([`PredModel::Paper`]); its companion surveys
/// (arXiv:1207.6936, arXiv:1302.3752) describe real predictors whose
/// windows vary in size and whose placement is anything but uniform.
/// Each variant dispatches to a [`crate::predictor::model::PredictorModel`]
/// implementation (the behaviour: how windows are drawn per announcement),
/// mirroring how [`crate::strategy::PolicyKind`] dispatches to
/// `PolicyLogic` — and, like there, the *open* axis is the registry
/// ([`crate::predictor::registry`]): adding a model means a trait impl, a
/// variant here, and one registry row.
///
/// The enum itself carries the closed-form-facing properties (E_I^f,
/// window bounds, placement slack), so `model::waste` / `model::optimal`
/// never need the boxed behaviour object.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PredModel {
    /// The paper's §2.2 predictor: fixed window length I, fault placed
    /// uniformly in-window (E_I^f = I/2), exact lead time C_p.
    Paper,
    /// Non-uniform in-window placement: the fault's position in the window
    /// is `I · U^(1/β)` (density `β x^(β−1)/I^β`), so E_I^f = I·β/(β+1).
    /// β = 1 is uniform; β > 1 biases the fault late in the window, β < 1
    /// early.  The closed forms stay valid with the per-model E_I^f.
    Biased { beta: f64 },
    /// Two-class heterogeneous window sizes: each announcement (true or
    /// false) uses window length `i1` with probability `w`, else `i2` —
    /// the fixed-I assumption of Eqs. (4)/(10)/(14) does not hold
    /// (classified `non_uniform_window` by `validate::domain`).  The
    /// spec's `window` field keeps the grid-axis value for store keys; the
    /// drawn windows use `i1`/`i2` only.
    MixedWindow { i1: f64, i2: f64, w: f64 },
    /// Noisy window placement: the announced window is shifted by
    /// Gaussian noise `σ·Z` (clamped to ±3σ so trace look-ahead stays
    /// bounded).  The lead time C_p stays exact, but the fault can fall
    /// outside its announced window — effective recall drops below r, so
    /// the closed forms (which assume nominal r) do not apply.
    Jitter { sigma: f64 },
    /// Per-announcement confidence classes: announcements come from a
    /// high-precision class (probability `frac` of all announcements,
    /// precision `p_hi`) or a low one (`p_lo`), with overall precision
    /// `frac·p_hi + (1−frac)·p_lo`.  Low-class announcements carry trust
    /// weight `p_lo/p_hi`, which scales the §3.1 trust probability q —
    /// pairing naturally with the `QTrust` policy (confidence-weighted
    /// randomized trust).
    Classed { p_hi: f64, p_lo: f64, frac: f64 },
}

impl PredModel {
    /// Canonical label, appended to campaign/conformance store keys for
    /// non-paper models (paper cells keep their pre-registry keys
    /// byte-identical — see [`crate::campaign::Cell::scenario_key`]).
    pub fn label(&self) -> String {
        match self {
            PredModel::Paper => "paper".to_string(),
            PredModel::Biased { beta } => format!("biased(beta={beta})"),
            PredModel::MixedWindow { i1, i2, w } => {
                format!("mixedwin(i1={i1};i2={i2};w={w})")
            }
            PredModel::Jitter { sigma } => format!("jitter(sigma={sigma})"),
            PredModel::Classed { p_hi, p_lo, frac } => {
                format!("classed(p_hi={p_hi};p_lo={p_lo};frac={frac})")
            }
        }
    }

    /// Inverse of [`PredModel::label`]: parse a canonical label back into
    /// the model. `scenario::replay` uses this to rebuild a cell from its
    /// store key; round-tripping is pinned by `parse_label(m.label()) == m`.
    pub fn parse_label(raw: &str) -> Result<PredModel, String> {
        let raw = raw.trim();
        if raw == "paper" {
            return Ok(PredModel::Paper);
        }
        let (name, rest) = raw
            .split_once('(')
            .ok_or_else(|| format!("bad predictor-model label '{raw}'"))?;
        let inner = rest
            .strip_suffix(')')
            .ok_or_else(|| format!("unbalanced parens in predictor-model label '{raw}'"))?;
        let mut params = std::collections::BTreeMap::new();
        for piece in inner.split(';') {
            let (k, v) = piece
                .split_once('=')
                .ok_or_else(|| format!("bad predictor-model param '{piece}' in '{raw}'"))?;
            let v: f64 = v
                .trim()
                .parse()
                .map_err(|_| format!("bad number '{v}' in predictor-model label '{raw}'"))?;
            params.insert(k.trim().to_string(), v);
        }
        let need = |key: &str| -> Result<f64, String> {
            params
                .get(key)
                .copied()
                .ok_or_else(|| format!("predictor-model label '{raw}' is missing '{key}'"))
        };
        let model = match name {
            "biased" => PredModel::Biased { beta: need("beta")? },
            "mixedwin" => PredModel::MixedWindow {
                i1: need("i1")?,
                i2: need("i2")?,
                w: need("w")?,
            },
            "jitter" => PredModel::Jitter { sigma: need("sigma")? },
            "classed" => PredModel::Classed {
                p_hi: need("p_hi")?,
                p_lo: need("p_lo")?,
                frac: need("frac")?,
            },
            other => return Err(format!("unknown predictor model '{other}'")),
        };
        Ok(model)
    }
}

impl fmt::Display for PredModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Fault-predictor characteristics (§2.2): recall r, precision p, window
/// length I, and the window-placement [`PredModel`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictorSpec {
    /// Recall r: fraction of faults that are predicted.
    pub recall: f64,
    /// Precision p: fraction of predictions that are correct.
    pub precision: f64,
    /// Prediction-window length I (s).  [`PredModel::MixedWindow`] draws
    /// its own sizes and uses this only as the grid-axis label.
    pub window: f64,
    /// Window-placement semantics (see [`PredModel`]).
    pub model: PredModel,
}

impl PredictorSpec {
    /// Predictor A [Yu et al. 2011]: p = 0.82, r = 0.85.
    pub fn paper_a(window: f64) -> Self {
        PredictorSpec {
            recall: 0.85,
            precision: 0.82,
            window,
            model: PredModel::Paper,
        }
    }

    /// Predictor B [Zheng et al. 2010]: p = 0.4, r = 0.7.
    pub fn paper_b(window: f64) -> Self {
        PredictorSpec {
            recall: 0.7,
            precision: 0.4,
            window,
            model: PredModel::Paper,
        }
    }

    /// The paper's uniform/fixed-I predictor with explicit r/p.
    pub fn paper(recall: f64, precision: f64, window: f64) -> Self {
        PredictorSpec { recall, precision, window, model: PredModel::Paper }
    }

    /// Expected fault position within the window, E_I^f — the quantity the
    /// closed forms (Eqs. 4/10/14, `T_P^extr`, `T_R^extr`) consume.  Model
    /// dispatched: the paper's I/2 is just the uniform-placement case.
    pub fn e_if(&self) -> f64 {
        match self.model {
            PredModel::Paper
            | PredModel::Jitter { .. }
            | PredModel::Classed { .. } => self.window / 2.0,
            PredModel::Biased { beta } => self.window * beta / (beta + 1.0),
            PredModel::MixedWindow { i1, i2, w } => {
                (w * i1 + (1.0 - w) * i2) / 2.0
            }
        }
    }

    /// The longest window this predictor can announce (trace look-ahead).
    pub fn max_window(&self) -> f64 {
        match self.model {
            PredModel::MixedWindow { i1, i2, .. } => i1.max(i2),
            _ => self.window,
        }
    }

    /// Largest backward shift of a window start relative to its
    /// uniform-placement position (the trace generators widen their
    /// look-ahead by this; nonzero only for [`PredModel::Jitter`]).
    pub fn placement_slack(&self) -> f64 {
        match self.model {
            PredModel::Jitter { sigma } => 3.0 * sigma,
            _ => 0.0,
        }
    }

    /// Mean time between predicted events μ_P = pμ / r (§2.3).
    pub fn mu_p(&self, mu: f64) -> f64 {
        self.precision * mu / self.recall
    }

    /// Mean time between unpredicted faults μ_NP = μ / (1 - r) (§2.3).
    pub fn mu_np(&self, mu: f64) -> f64 {
        mu / (1.0 - self.recall)
    }

    /// Mean time between *false* predictions: μ_P / (1-p) = pμ / (r(1-p)).
    pub fn mu_false(&self, mu: f64) -> f64 {
        self.mu_p(mu) / (1.0 - self.precision)
    }

    /// Mean time between events of any kind, 1/μ_e = 1/μ_P + 1/μ_NP.
    pub fn mu_e(&self, mu: f64) -> f64 {
        1.0 / (1.0 / self.mu_p(mu) + 1.0 / self.mu_np(mu))
    }
}

/// How the fault trace is generated.
///
/// The paper's simulator builds the platform trace from **per-processor**
/// failure traces (the methodology of [Bougeret et al. SC'11], which the
/// paper's experimental section follows): N i.i.d. renewal processes, one
/// per processor, all starting *fresh* at t = 0, merged.  For Exponential
/// laws this is exactly a platform-level Poisson process of rate N/μ_ind;
/// for Weibull with shape k < 1 the fresh start matters enormously — the
/// platform sees the superposed infant-mortality transient, with an
/// effective fault rate far above the steady-state 1/μ during a days-long
/// job.  This is what makes Daly/RFO sit far from BestPeriod in the
/// paper's Weibull figures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultModel {
    /// One platform-level renewal process with mean μ (steady-state view).
    PlatformRenewal,
    /// Superposition of `n` fresh per-processor renewal processes, each
    /// with mean μ_ind = n·μ (the paper's simulator).
    PerProcessor { n: u64 },
    /// Like [`FaultModel::PerProcessor`] but in stationary state: each
    /// processor's first failure follows the equilibrium residual-life
    /// distribution, so the platform rate is exactly 1/μ from t = 0.
    /// Ablation variant — shows how much of the Weibull effect is the
    /// fresh-start transient (see DESIGN.md §Fault-model).
    PerProcessorStationary { n: u64 },
}

/// A full experiment scenario: platform + predictor + laws + job size.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub platform: Platform,
    pub predictor: PredictorSpec,
    /// Law of fault inter-arrival times (mean-scaled to μ, or to μ_ind per
    /// processor under [`FaultModel::PerProcessor`]).
    pub fault_law: Law,
    /// Law of false-prediction inter-arrival times (mean-scaled to μ_false).
    pub false_pred_law: Law,
    /// Fault-trace structure (see [`FaultModel`]).
    pub fault_model: FaultModel,
    /// Application size Time_base (s of useful work).
    pub job_size: f64,
}

impl Scenario {
    /// The paper's scenario for N processors: Time_base = 10000 y / N,
    /// per-processor fault traces.
    pub fn paper(
        n_procs: u64,
        cp_ratio: f64,
        predictor: PredictorSpec,
        fault_law: Law,
        false_pred_law: Law,
    ) -> Self {
        Scenario {
            platform: Platform::paper(n_procs, cp_ratio),
            predictor,
            fault_law,
            false_pred_law,
            fault_model: FaultModel::PerProcessor { n: n_procs },
            job_size: paper::TOTAL_WORK_YEARS * SECONDS_PER_YEAR
                / n_procs as f64,
        }
    }

    /// Expected fault position within the window, E_I^f — delegates to the
    /// predictor model ([`PredictorSpec::e_if`]; the paper's uniform
    /// placement gives I/2, other models expose their own value).
    pub fn e_if(&self) -> f64 {
        self.predictor.e_if()
    }
}

// ---------------------------------------------------------------------------
// TOML-subset config files
// ---------------------------------------------------------------------------

/// Error raised by the config parser.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Parsed `[section] key = value` structure (strings unquoted, numbers raw).
#[derive(Debug, Default)]
pub struct RawConfig {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse a TOML-subset document: `[section]` headers, `key = value`
    /// pairs, `#` comments.  No arrays/tables-in-arrays/multiline strings.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = RawConfig::default();
        let mut section = String::from("root");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError(format!(
                        "line {}: unterminated section header", lineno + 1
                    )))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                ConfigError(format!("line {}: expected key = value", lineno + 1))
            })?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| ConfigError(format!("{section}.{key}: not a number: {s}"))),
        }
    }
}

/// Load a scenario from a TOML-subset file.  Recognized keys:
///
/// ```toml
/// [platform]
/// procs = 65536         # or: mu = 60134.0 (seconds)
/// c = 600.0
/// cp = 600.0
/// d = 60.0
/// r = 600.0
/// job_size = 4.8e9      # optional; default 10000y/N
///
/// [predictor]
/// recall = 0.85
/// precision = 0.82
/// window = 1200.0
/// model = "biased(beta=2)"  # optional placement model; default "paper"
///
/// [laws]
/// fault = "weibull0.7"  # exponential | weibullK | uniform
/// false_pred = "exponential"
/// ```
pub fn scenario_from_file(path: &Path) -> Result<Scenario, ConfigError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError(format!("{}: {e}", path.display())))?;
    scenario_from_str(&text)
}

/// Parse a scenario from config text (see [`scenario_from_file`]).
pub fn scenario_from_str(text: &str) -> Result<Scenario, ConfigError> {
    let raw = RawConfig::parse(text)?;
    let procs = raw.get_f64("platform", "procs")?;
    let mu = match (raw.get_f64("platform", "mu")?, procs) {
        (Some(mu), _) => mu,
        (None, Some(n)) => paper::MU_IND_YEARS * SECONDS_PER_YEAR / n,
        (None, None) => {
            return Err(ConfigError("platform.mu or platform.procs required".into()))
        }
    };
    let c = raw.get_f64("platform", "c")?.unwrap_or(paper::C);
    let platform = Platform {
        mu,
        c,
        cp: raw.get_f64("platform", "cp")?.unwrap_or(c),
        d: raw.get_f64("platform", "d")?.unwrap_or(paper::D),
        r: raw.get_f64("platform", "r")?.unwrap_or(paper::R),
    };
    let job_size = match (raw.get_f64("platform", "job_size")?, procs) {
        (Some(j), _) => j,
        (None, Some(n)) => paper::TOTAL_WORK_YEARS * SECONDS_PER_YEAR / n,
        (None, None) => {
            return Err(ConfigError("platform.job_size required when mu given".into()))
        }
    };
    let recall = raw
        .get_f64("predictor", "recall")?
        .ok_or_else(|| ConfigError("predictor.recall required".into()))?;
    let precision = raw
        .get_f64("predictor", "precision")?
        .ok_or_else(|| ConfigError("predictor.precision required".into()))?;
    let window = raw
        .get_f64("predictor", "window")?
        .ok_or_else(|| ConfigError("predictor.window required".into()))?;
    // Optional window-placement model, named like a registry predictor
    // (`model = "biased(beta=2)"`).  The explicit recall/precision keys
    // are the only source of r/p in a config file: an r/p written inside
    // the model string is rejected (two places stating the same number is
    // a contradiction waiting to happen), and rows that pin their own
    // values (`a`/`b`) or imply one (`classed`'s precision is its class
    // mix) must agree with the keys — silently simulating different
    // numbers than the file states would be worse than an error.
    let predictor = match raw.get("predictor", "model") {
        None => PredictorSpec { recall, precision, window, model: PredModel::Paper },
        Some(s) => {
            let (mut id, explicit) =
                crate::predictor::registry::PredictorId::parse_with_explicit(s)
                    .map_err(|e| ConfigError(format!("predictor.model: {e}")))?;
            if explicit.iter().any(|k| *k == "r" || *k == "p") {
                return Err(ConfigError(format!(
                    "predictor.model '{s}': set recall/precision via the \
                     explicit keys, not inside the model string"
                )));
            }
            // Thread the file keys into the row's r/p parameters.
            for (key, file_val) in [("r", recall), ("p", precision)] {
                if id.has_param(key) {
                    id = id
                        .with_param(key, file_val)
                        .map_err(|e| ConfigError(format!("predictor.model: {e}")))?;
                }
            }
            let spec = id.spec(window);
            if (spec.recall - recall).abs() > 1e-9
                || (spec.precision - precision).abs() > 1e-9
            {
                return Err(ConfigError(format!(
                    "predictor.model '{s}' implies recall {} / precision {}, \
                     but the file sets recall {recall} / precision {precision} \
                     — make them agree (classed precision is frac*p_hi + (1-frac)*p_lo)",
                    spec.recall, spec.precision,
                )));
            }
            spec
        }
    };
    let fault_law = raw
        .get("laws", "fault")
        .map(|s| Law::parse(s).ok_or_else(|| ConfigError(format!("bad law: {s}"))))
        .transpose()?
        .unwrap_or(Law::Exponential);
    let false_pred_law = raw
        .get("laws", "false_pred")
        .map(|s| Law::parse(s).ok_or_else(|| ConfigError(format!("bad law: {s}"))))
        .transpose()?
        .unwrap_or(fault_law);
    // Per-processor traces when the processor count is known (the paper's
    // simulator); `model = "platform"` forces the steady-state renewal.
    let fault_model = match (raw.get("laws", "model"), procs) {
        (Some("platform"), _) | (_, None) => FaultModel::PlatformRenewal,
        (_, Some(n)) => {
            // `platform.procs = 0` would build a zero-processor pool the
            // per-proc generator cannot sample from (its pool scan would
            // never terminate); reject it here instead of at trace time.
            if n as u64 == 0 {
                return Err(ConfigError(
                    "platform.procs must be >= 1 for the per-processor fault \
                     model (use model = \"platform\" for the renewal model)"
                        .into(),
                ));
            }
            FaultModel::PerProcessor { n: n as u64 }
        }
    };
    Ok(Scenario { platform, predictor, fault_law, false_pred_law, fault_model, job_size })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_platform_mtbf() {
        // §4.1's prose ("N = 2^16 = 16,384", "μ = 4,010 min") is internally
        // inconsistent (2^16 = 65,536; 16,384 = 2^14).  Tables 4–5 settle
        // it: Daly at "2^16 procs" takes 81.3 days on a job of
        // 10000y/N — only N = 65,536 (job 55.7 days) is feasible.  So we
        // take N literally: 2^16..2^19.
        let p = Platform::paper(1 << 16, 1.0);
        let mu_min = p.mu / 60.0;
        assert!((mu_min - 1002.5).abs() < 5.0, "{mu_min}");
        // N = 2^19 ⇒ μ ≈ 125 min ≈ 2 hours ≈ 7500 s (paper: "the platform
        // MTBF is equal to 7500 s" for 2^19 — consistent ✓).
        let p = Platform::paper(1 << 19, 1.0);
        assert!((p.mu - 7519.0).abs() < 20.0, "{}", p.mu);
    }

    #[test]
    fn derived_rates_consistent() {
        // 1/μ_e = 1/μ_P + 1/μ_NP.
        let spec = PredictorSpec::paper_a(600.0);
        let mu = 100_000.0;
        let lhs = 1.0 / spec.mu_e(mu);
        let rhs = 1.0 / spec.mu_p(mu) + 1.0 / spec.mu_np(mu);
        assert!((lhs - rhs).abs() < 1e-12);
        // r/μ = p/μ_P.
        assert!(
            (spec.recall / mu - spec.precision / spec.mu_p(mu)).abs() < 1e-12
        );
    }

    #[test]
    fn paper_job_size() {
        let s = Scenario::paper(
            1 << 16,
            1.0,
            PredictorSpec::paper_a(300.0),
            Law::Exponential,
            Law::Exponential,
        );
        // 10000 y / 65536 ≈ 0.1526 y ≈ 4.81e6 s ≈ 55.7 days.
        let days = s.job_size / 86_400.0;
        assert!((days - 55.7).abs() < 0.5, "{days}");
    }

    #[test]
    fn toml_subset_roundtrip() {
        let text = r#"
# comment
[platform]
procs = 65536
c = 600.0
cp = 60.0   # cheap proactive checkpoints

[predictor]
recall = 0.7
precision = 0.4
window = 900

[laws]
fault = "weibull0.7"
false_pred = "uniform"
"#;
        let s = scenario_from_str(text).unwrap();
        assert_eq!(s.platform.cp, 60.0);
        assert_eq!(s.predictor.window, 900.0);
        assert_eq!(s.fault_law, Law::Weibull { shape: 0.7 });
        assert_eq!(s.false_pred_law, Law::Uniform);
        assert!((s.platform.mu - Platform::paper(65536, 1.0).mu).abs() < 1e-6);
    }

    #[test]
    fn config_errors_are_reported() {
        assert!(scenario_from_str("[platform]\nc = x\n").is_err());
        assert!(scenario_from_str("key_without_section\n").is_err());
        assert!(scenario_from_str("[predictor]\nrecall = 0.5\n").is_err());
    }

    #[test]
    fn e_if_dispatches_on_the_predictor_model() {
        let mut spec = PredictorSpec::paper_a(600.0);
        assert_eq!(spec.e_if(), 300.0);
        assert_eq!(spec.max_window(), 600.0);
        assert_eq!(spec.placement_slack(), 0.0);
        // β = 2 biases faults late: E = 2I/3.
        spec.model = PredModel::Biased { beta: 2.0 };
        assert!((spec.e_if() - 400.0).abs() < 1e-12);
        // β = 1 recovers the uniform I/2.
        spec.model = PredModel::Biased { beta: 1.0 };
        assert!((spec.e_if() - 300.0).abs() < 1e-12);
        spec.model = PredModel::MixedWindow { i1: 300.0, i2: 1200.0, w: 0.5 };
        assert_eq!(spec.e_if(), 375.0); // (0.5·300 + 0.5·1200)/2
        assert_eq!(spec.max_window(), 1200.0);
        spec.model = PredModel::Jitter { sigma: 100.0 };
        assert_eq!(spec.e_if(), 300.0);
        assert_eq!(spec.placement_slack(), 300.0);
        // The scenario delegates to the spec.
        let mut sc = Scenario::paper(
            1 << 16,
            1.0,
            PredictorSpec::paper_a(600.0),
            Law::Exponential,
            Law::Exponential,
        );
        sc.predictor.model = PredModel::Biased { beta: 3.0 };
        assert!((sc.e_if() - 450.0).abs() < 1e-12);
    }

    #[test]
    fn model_labels_are_stable_store_identities() {
        assert_eq!(PredModel::Paper.label(), "paper");
        assert_eq!(PredModel::Biased { beta: 2.0 }.label(), "biased(beta=2)");
        assert_eq!(
            PredModel::MixedWindow { i1: 300.0, i2: 1200.0, w: 0.5 }.label(),
            "mixedwin(i1=300;i2=1200;w=0.5)"
        );
        assert_eq!(
            PredModel::Jitter { sigma: 120.0 }.to_string(),
            "jitter(sigma=120)"
        );
        assert_eq!(
            PredModel::Classed { p_hi: 0.95, p_lo: 0.6, frac: 0.5 }.label(),
            "classed(p_hi=0.95;p_lo=0.6;frac=0.5)"
        );
    }

    #[test]
    fn config_file_predictor_model_key() {
        let text = r#"
[platform]
procs = 65536

[predictor]
recall = 0.7
precision = 0.4
window = 900
model = "biased(beta=2)"
"#;
        let s = scenario_from_str(text).unwrap();
        assert_eq!(s.predictor.model, PredModel::Biased { beta: 2.0 });
        assert_eq!(s.predictor.recall, 0.7);
        assert_eq!(s.predictor.precision, 0.4);
        assert!(scenario_from_str(
            "[platform]\nprocs = 65536\n[predictor]\nrecall = 0.7\n\
             precision = 0.4\nwindow = 900\nmodel = \"frob\"\n"
        )
        .is_err());
        // Rows that pin or imply r/p must agree with the explicit keys:
        // predictor "a" is r=0.85/p=0.82, and classed's precision is its
        // class mix — contradictions are errors, not silent overrides.
        assert!(scenario_from_str(
            "[platform]\nprocs = 65536\n[predictor]\nrecall = 0.7\n\
             precision = 0.4\nwindow = 900\nmodel = \"a\"\n"
        )
        .is_err());
        // An r/p written inside the model string is rejected outright —
        // the explicit keys are the only source, so the file can never
        // state two different numbers for one quantity (even when they
        // happen to agree, or to equal the registry default).
        for model in ["biased(beta=2;r=0.5)", "biased(beta=2;r=0.85)", "paper(p=0.4)"] {
            assert!(
                scenario_from_str(&format!(
                    "[platform]\nprocs = 65536\n[predictor]\nrecall = 0.5\n\
                     precision = 0.4\nwindow = 900\nmodel = \"{model}\"\n"
                ))
                .is_err(),
                "{model}"
            );
        }
        assert!(scenario_from_str(
            "[platform]\nprocs = 65536\n[predictor]\nrecall = 0.85\n\
             precision = 0.9\nwindow = 900\n\
             model = \"classed(p_hi=0.95;p_lo=0.6;frac=0.5)\"\n"
        )
        .is_err());
        // …and the implied classed precision parses cleanly.
        let s = scenario_from_str(
            "[platform]\nprocs = 65536\n[predictor]\nrecall = 0.85\n\
             precision = 0.775\nwindow = 900\n\
             model = \"classed(p_hi=0.95;p_lo=0.6;frac=0.5)\"\n",
        )
        .unwrap();
        assert_eq!(
            s.predictor.model,
            PredModel::Classed { p_hi: 0.95, p_lo: 0.6, frac: 0.5 }
        );
    }

    #[test]
    fn zero_procs_per_proc_model_is_rejected() {
        // `procs = 0` under the per-processor fault model used to build a
        // zero-processor pool whose generator looped forever; it is a
        // config error now.  `mu` is given explicitly so the rejection is
        // exercised on the fault-model path, not the μ derivation.
        let err = scenario_from_str(
            "[platform]\nprocs = 0\nmu = 60134.0\njob_size = 1e6\n\
             [predictor]\nrecall = 0.85\nprecision = 0.82\nwindow = 900\n",
        )
        .unwrap_err();
        assert!(err.0.contains("procs must be >= 1"), "{}", err.0);
        // The explicit platform-renewal model never builds a pool, so the
        // same count stays accepted there.
        let s = scenario_from_str(
            "[platform]\nprocs = 0\nmu = 60134.0\njob_size = 1e6\n\
             [predictor]\nrecall = 0.85\nprecision = 0.82\nwindow = 900\n\
             [laws]\nmodel = \"platform\"\n",
        )
        .unwrap();
        assert_eq!(s.fault_model, FaultModel::PlatformRenewal);
    }
}
