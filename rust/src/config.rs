//! Configuration: platform, predictor, scenario — plus the paper's presets
//! and a small TOML-subset loader (offline environment: no serde), so
//! experiments can be described declaratively and launched from the CLI.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::sim::distribution::Law;
use crate::util::{paper, SECONDS_PER_YEAR};

/// Fault-tolerance characteristics of the platform (§2.1, §2.3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Platform {
    /// Platform MTBF μ in seconds (μ = μ_ind / N).
    pub mu: f64,
    /// Regular checkpoint duration C (s).
    pub c: f64,
    /// Proactive checkpoint duration C_p (s).
    pub cp: f64,
    /// Downtime D (s).
    pub d: f64,
    /// Recovery duration R (s).
    pub r: f64,
}

impl Platform {
    /// The paper's platform for `n_procs` processors:
    /// μ = μ_ind/N with μ_ind = 125 years, C = R = 600 s, D = 60 s.
    pub fn paper(n_procs: u64, cp_ratio: f64) -> Self {
        let mu = paper::MU_IND_YEARS * SECONDS_PER_YEAR / n_procs as f64;
        Platform {
            mu,
            c: paper::C,
            cp: cp_ratio * paper::C,
            d: paper::D,
            r: paper::R,
        }
    }
}

/// Fault-predictor characteristics (§2.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictorSpec {
    /// Recall r: fraction of faults that are predicted.
    pub recall: f64,
    /// Precision p: fraction of predictions that are correct.
    pub precision: f64,
    /// Prediction-window length I (s).
    pub window: f64,
}

impl PredictorSpec {
    /// Predictor A [Yu et al. 2011]: p = 0.82, r = 0.85.
    pub fn paper_a(window: f64) -> Self {
        PredictorSpec { recall: 0.85, precision: 0.82, window }
    }

    /// Predictor B [Zheng et al. 2010]: p = 0.4, r = 0.7.
    pub fn paper_b(window: f64) -> Self {
        PredictorSpec { recall: 0.7, precision: 0.4, window }
    }

    /// Mean time between predicted events μ_P = pμ / r (§2.3).
    pub fn mu_p(&self, mu: f64) -> f64 {
        self.precision * mu / self.recall
    }

    /// Mean time between unpredicted faults μ_NP = μ / (1 - r) (§2.3).
    pub fn mu_np(&self, mu: f64) -> f64 {
        mu / (1.0 - self.recall)
    }

    /// Mean time between *false* predictions: μ_P / (1-p) = pμ / (r(1-p)).
    pub fn mu_false(&self, mu: f64) -> f64 {
        self.mu_p(mu) / (1.0 - self.precision)
    }

    /// Mean time between events of any kind, 1/μ_e = 1/μ_P + 1/μ_NP.
    pub fn mu_e(&self, mu: f64) -> f64 {
        1.0 / (1.0 / self.mu_p(mu) + 1.0 / self.mu_np(mu))
    }
}

/// How the fault trace is generated.
///
/// The paper's simulator builds the platform trace from **per-processor**
/// failure traces (the methodology of [Bougeret et al. SC'11], which the
/// paper's experimental section follows): N i.i.d. renewal processes, one
/// per processor, all starting *fresh* at t = 0, merged.  For Exponential
/// laws this is exactly a platform-level Poisson process of rate N/μ_ind;
/// for Weibull with shape k < 1 the fresh start matters enormously — the
/// platform sees the superposed infant-mortality transient, with an
/// effective fault rate far above the steady-state 1/μ during a days-long
/// job.  This is what makes Daly/RFO sit far from BestPeriod in the
/// paper's Weibull figures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultModel {
    /// One platform-level renewal process with mean μ (steady-state view).
    PlatformRenewal,
    /// Superposition of `n` fresh per-processor renewal processes, each
    /// with mean μ_ind = n·μ (the paper's simulator).
    PerProcessor { n: u64 },
    /// Like [`FaultModel::PerProcessor`] but in stationary state: each
    /// processor's first failure follows the equilibrium residual-life
    /// distribution, so the platform rate is exactly 1/μ from t = 0.
    /// Ablation variant — shows how much of the Weibull effect is the
    /// fresh-start transient (see DESIGN.md §Fault-model).
    PerProcessorStationary { n: u64 },
}

/// A full experiment scenario: platform + predictor + laws + job size.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub platform: Platform,
    pub predictor: PredictorSpec,
    /// Law of fault inter-arrival times (mean-scaled to μ, or to μ_ind per
    /// processor under [`FaultModel::PerProcessor`]).
    pub fault_law: Law,
    /// Law of false-prediction inter-arrival times (mean-scaled to μ_false).
    pub false_pred_law: Law,
    /// Fault-trace structure (see [`FaultModel`]).
    pub fault_model: FaultModel,
    /// Application size Time_base (s of useful work).
    pub job_size: f64,
}

impl Scenario {
    /// The paper's scenario for N processors: Time_base = 10000 y / N,
    /// per-processor fault traces.
    pub fn paper(
        n_procs: u64,
        cp_ratio: f64,
        predictor: PredictorSpec,
        fault_law: Law,
        false_pred_law: Law,
    ) -> Self {
        Scenario {
            platform: Platform::paper(n_procs, cp_ratio),
            predictor,
            fault_law,
            false_pred_law,
            fault_model: FaultModel::PerProcessor { n: n_procs },
            job_size: paper::TOTAL_WORK_YEARS * SECONDS_PER_YEAR
                / n_procs as f64,
        }
    }

    /// Expected fault position within the window, E_I^f.  Fault positions
    /// are drawn uniformly over the window in the trace generator, so this
    /// is I/2 (the paper's default assumption).
    pub fn e_if(&self) -> f64 {
        self.predictor.window / 2.0
    }
}

// ---------------------------------------------------------------------------
// TOML-subset config files
// ---------------------------------------------------------------------------

/// Error raised by the config parser.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Parsed `[section] key = value` structure (strings unquoted, numbers raw).
#[derive(Debug, Default)]
pub struct RawConfig {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse a TOML-subset document: `[section]` headers, `key = value`
    /// pairs, `#` comments.  No arrays/tables-in-arrays/multiline strings.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = RawConfig::default();
        let mut section = String::from("root");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError(format!(
                        "line {}: unterminated section header", lineno + 1
                    )))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                ConfigError(format!("line {}: expected key = value", lineno + 1))
            })?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| ConfigError(format!("{section}.{key}: not a number: {s}"))),
        }
    }
}

/// Load a scenario from a TOML-subset file.  Recognized keys:
///
/// ```toml
/// [platform]
/// procs = 65536         # or: mu = 60134.0 (seconds)
/// c = 600.0
/// cp = 600.0
/// d = 60.0
/// r = 600.0
/// job_size = 4.8e9      # optional; default 10000y/N
///
/// [predictor]
/// recall = 0.85
/// precision = 0.82
/// window = 1200.0
///
/// [laws]
/// fault = "weibull0.7"  # exponential | weibullK | uniform
/// false_pred = "exponential"
/// ```
pub fn scenario_from_file(path: &Path) -> Result<Scenario, ConfigError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError(format!("{}: {e}", path.display())))?;
    scenario_from_str(&text)
}

/// Parse a scenario from config text (see [`scenario_from_file`]).
pub fn scenario_from_str(text: &str) -> Result<Scenario, ConfigError> {
    let raw = RawConfig::parse(text)?;
    let procs = raw.get_f64("platform", "procs")?;
    let mu = match (raw.get_f64("platform", "mu")?, procs) {
        (Some(mu), _) => mu,
        (None, Some(n)) => paper::MU_IND_YEARS * SECONDS_PER_YEAR / n,
        (None, None) => {
            return Err(ConfigError("platform.mu or platform.procs required".into()))
        }
    };
    let c = raw.get_f64("platform", "c")?.unwrap_or(paper::C);
    let platform = Platform {
        mu,
        c,
        cp: raw.get_f64("platform", "cp")?.unwrap_or(c),
        d: raw.get_f64("platform", "d")?.unwrap_or(paper::D),
        r: raw.get_f64("platform", "r")?.unwrap_or(paper::R),
    };
    let job_size = match (raw.get_f64("platform", "job_size")?, procs) {
        (Some(j), _) => j,
        (None, Some(n)) => paper::TOTAL_WORK_YEARS * SECONDS_PER_YEAR / n,
        (None, None) => {
            return Err(ConfigError("platform.job_size required when mu given".into()))
        }
    };
    let predictor = PredictorSpec {
        recall: raw
            .get_f64("predictor", "recall")?
            .ok_or_else(|| ConfigError("predictor.recall required".into()))?,
        precision: raw
            .get_f64("predictor", "precision")?
            .ok_or_else(|| ConfigError("predictor.precision required".into()))?,
        window: raw
            .get_f64("predictor", "window")?
            .ok_or_else(|| ConfigError("predictor.window required".into()))?,
    };
    let fault_law = raw
        .get("laws", "fault")
        .map(|s| Law::parse(s).ok_or_else(|| ConfigError(format!("bad law: {s}"))))
        .transpose()?
        .unwrap_or(Law::Exponential);
    let false_pred_law = raw
        .get("laws", "false_pred")
        .map(|s| Law::parse(s).ok_or_else(|| ConfigError(format!("bad law: {s}"))))
        .transpose()?
        .unwrap_or(fault_law);
    // Per-processor traces when the processor count is known (the paper's
    // simulator); `model = "platform"` forces the steady-state renewal.
    let fault_model = match (raw.get("laws", "model"), procs) {
        (Some("platform"), _) | (_, None) => FaultModel::PlatformRenewal,
        (_, Some(n)) => FaultModel::PerProcessor { n: n as u64 },
    };
    Ok(Scenario { platform, predictor, fault_law, false_pred_law, fault_model, job_size })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_platform_mtbf() {
        // §4.1's prose ("N = 2^16 = 16,384", "μ = 4,010 min") is internally
        // inconsistent (2^16 = 65,536; 16,384 = 2^14).  Tables 4–5 settle
        // it: Daly at "2^16 procs" takes 81.3 days on a job of
        // 10000y/N — only N = 65,536 (job 55.7 days) is feasible.  So we
        // take N literally: 2^16..2^19.
        let p = Platform::paper(1 << 16, 1.0);
        let mu_min = p.mu / 60.0;
        assert!((mu_min - 1002.5).abs() < 5.0, "{mu_min}");
        // N = 2^19 ⇒ μ ≈ 125 min ≈ 2 hours ≈ 7500 s (paper: "the platform
        // MTBF is equal to 7500 s" for 2^19 — consistent ✓).
        let p = Platform::paper(1 << 19, 1.0);
        assert!((p.mu - 7519.0).abs() < 20.0, "{}", p.mu);
    }

    #[test]
    fn derived_rates_consistent() {
        // 1/μ_e = 1/μ_P + 1/μ_NP.
        let spec = PredictorSpec::paper_a(600.0);
        let mu = 100_000.0;
        let lhs = 1.0 / spec.mu_e(mu);
        let rhs = 1.0 / spec.mu_p(mu) + 1.0 / spec.mu_np(mu);
        assert!((lhs - rhs).abs() < 1e-12);
        // r/μ = p/μ_P.
        assert!(
            (spec.recall / mu - spec.precision / spec.mu_p(mu)).abs() < 1e-12
        );
    }

    #[test]
    fn paper_job_size() {
        let s = Scenario::paper(
            1 << 16,
            1.0,
            PredictorSpec::paper_a(300.0),
            Law::Exponential,
            Law::Exponential,
        );
        // 10000 y / 65536 ≈ 0.1526 y ≈ 4.81e6 s ≈ 55.7 days.
        let days = s.job_size / 86_400.0;
        assert!((days - 55.7).abs() < 0.5, "{days}");
    }

    #[test]
    fn toml_subset_roundtrip() {
        let text = r#"
# comment
[platform]
procs = 65536
c = 600.0
cp = 60.0   # cheap proactive checkpoints

[predictor]
recall = 0.7
precision = 0.4
window = 900

[laws]
fault = "weibull0.7"
false_pred = "uniform"
"#;
        let s = scenario_from_str(text).unwrap();
        assert_eq!(s.platform.cp, 60.0);
        assert_eq!(s.predictor.window, 900.0);
        assert_eq!(s.fault_law, Law::Weibull { shape: 0.7 });
        assert_eq!(s.false_pred_law, Law::Uniform);
        assert!((s.platform.mu - Platform::paper(65536, 1.0).mu).abs() < 1e-6);
    }

    #[test]
    fn config_errors_are_reported() {
        assert!(scenario_from_str("[platform]\nc = x\n").is_err());
        assert!(scenario_from_str("key_without_section\n").is_err());
        assert!(scenario_from_str("[predictor]\nrecall = 0.5\n").is_err());
    }
}
