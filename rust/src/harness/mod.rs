//! Experiment harness: regenerates every figure and table of the paper's
//! evaluation (§4).
//!
//! * [`figures`] — Figures 2–13 (waste vs platform size), 14–17 (waste vs
//!   period T_R), 18–21 (waste vs window size I);
//! * [`tables`] — Tables 4–5 (job execution times in days, gains vs Daly);
//! * [`plot`] — ASCII plots for terminal inspection (CSV is the primary
//!   output, under `results/`).
//!
//! Simulations are parallelized across instances through the campaign
//! engine's work-stealing pool (`campaign::scheduler` — a shared atomic
//! work queue over scoped std threads; the offline environment provides no
//! rayon/tokio), and the figure/table grid runners drive their scenario
//! grids through `campaign::run_cells`.  Instance counts default to the
//! paper's 100 and can be overridden with the `CKPTWIN_INSTANCES`
//! environment variable (benches use small counts).

pub mod figures;
pub mod plot;
pub mod tables;

use crate::config::Scenario;
#[cfg(test)]
use crate::sim::engine::simulate;
use crate::sim::engine::SimOutcome;
use crate::stats::Summary;
use crate::strategy::{best_period, registry, Policy, PolicyKind};

/// Paper platform sizes: N = 2^16 … 2^19.
pub const PAPER_PROCS: [u64; 4] = [1 << 16, 1 << 17, 1 << 18, 1 << 19];
/// Paper prediction-window sizes (s).
pub const PAPER_WINDOWS: [f64; 5] = [300.0, 600.0, 900.0, 1200.0, 3000.0];
/// Paper proactive-checkpoint cost ratios C_p / C.
pub const PAPER_CP_RATIOS: [f64; 3] = [1.0, 0.1, 2.0];

/// Number of random instances per point (paper: 100).
pub fn default_instances() -> usize {
    std::env::var("CKPTWIN_INSTANCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100)
}

/// Run `policy` on `n` instances (seeds 0..n) in parallel; returns the
/// waste summary and the mean makespan (seconds).
pub fn run_instances(sc: &Scenario, policy: &Policy, n: usize) -> (Summary, f64) {
    let seeds: Vec<u64> = (0..n as u64).collect();
    let outcomes = run_seeds(sc, policy, &seeds);
    let waste = Summary::from_iter(outcomes.iter().map(|o| o.waste()));
    let makespan =
        outcomes.iter().map(|o| o.makespan).sum::<f64>() / outcomes.len() as f64;
    (waste, makespan)
}

/// Simulate the given seeds in parallel (scoped threads).
pub fn run_seeds(sc: &Scenario, policy: &Policy, seeds: &[u64]) -> Vec<SimOutcome> {
    run_seeds_capped(sc, policy, seeds, f64::INFINITY)
}

/// [`run_seeds`] with a makespan cap (see `engine::simulate_from_capped`);
/// used by period sweeps that deliberately visit terrible periods.
///
/// Seeds are claimed one at a time from the campaign scheduler's shared
/// work queue (not statically chunked), so one heavy-tailed instance no
/// longer serializes a whole chunk at the tail of the run.  Each worker
/// recycles its flat trace buffers through a [`TraceArena`], so the sweep
/// allocates nothing per event.
pub fn run_seeds_capped(
    sc: &Scenario,
    policy: &Policy,
    seeds: &[u64],
    cap: f64,
) -> Vec<SimOutcome> {
    use crate::campaign::scheduler;
    use crate::sim::engine::simulate_from_capped;
    use crate::sim::trace::TraceArena;
    scheduler::run_units_stateful(
        seeds.len(),
        0,
        TraceArena::new,
        |arena: &mut TraceArena, i| {
            let seed = seeds[i];
            let mut stream = arena.stream(sc, seed);
            let out = simulate_from_capped(sc, policy, 1.0, seed, &mut stream, cap);
            arena.recycle(stream);
            out
        },
    )
}

/// One heuristic's result at one scenario point.
#[derive(Clone, Debug)]
pub struct HeuristicResult {
    pub name: String,
    /// Mean simulated waste.
    pub waste: f64,
    /// 95% CI half-width of the waste.
    pub waste_ci: f64,
    /// Mean makespan (s).
    pub makespan: f64,
    /// Waste predicted by the analytic model (NaN for BestPeriod twins).
    pub analytic_waste: f64,
    /// The regular period the heuristic used.
    pub tr: f64,
}

/// Evaluate the paper's heuristic set on one scenario.
///
/// `n` instances for the named heuristics.  If `best_period_seeds > 0`, the
/// four BestPeriod twins are added (searched with that many seeds — the
/// brute force is expensive, the paper does the same sweep offline).
pub fn evaluate_heuristics(
    sc: &Scenario,
    n: usize,
    best_period_seeds: usize,
) -> Vec<HeuristicResult> {
    use crate::model::waste::waste_clipped;
    let mut out = Vec::new();
    for strat in registry::paper_set() {
        let pol = strat.policy(sc);
        let (waste, makespan) = run_instances(sc, &pol, n);
        out.push(HeuristicResult {
            name: strat.to_string(),
            waste: waste.mean(),
            waste_ci: waste.ci95(),
            makespan,
            analytic_waste: pol
                .kind
                .grid_strategy()
                .map(|gs| waste_clipped(sc, gs, pol.tr))
                .unwrap_or(f64::NAN),
            tr: pol.tr,
        });
    }
    out.extend(best_period_results(sc, n, best_period_seeds));
    out
}

/// The four BestPeriod twins for one scenario: `T_R` found by brute-force
/// search over `best_period_seeds` instances, then evaluated on `n`
/// instances (seeds 0..n).  Empty when `best_period_seeds == 0`.
pub fn best_period_results(
    sc: &Scenario,
    n: usize,
    best_period_seeds: usize,
) -> Vec<HeuristicResult> {
    best_period_results_seeded(sc, n, best_period_seeds, |i| i)
}

/// [`best_period_results`] with caller-supplied evaluation seeds — the
/// campaign-driven figure runners pass each cell's own seed streams so the
/// twin rows are trace-paired with the named-heuristic rows of the same
/// scenario point.
pub fn best_period_results_seeded(
    sc: &Scenario,
    n: usize,
    best_period_seeds: usize,
    seed_of: impl Fn(u64) -> u64,
) -> Vec<HeuristicResult> {
    use crate::campaign::scheduler;
    use crate::sim::engine::simulate_from;
    use crate::sim::trace::TraceCache;

    if best_period_seeds == 0 {
        return Vec::new();
    }
    let bp_seeds: Vec<u64> = (1000..1000 + best_period_seeds as u64).collect();
    let eval_seeds: Vec<u64> = (0..n as u64).map(seed_of).collect();
    let variants: [(&str, PolicyKind); 4] = [
        ("BestPeriod-NoPred", PolicyKind::IgnorePredictions),
        ("BestPeriod-Instant", PolicyKind::Instant),
        ("BestPeriod-NoCkptI", PolicyKind::NoCkpt),
        ("BestPeriod-WithCkptI", PolicyKind::WithCkpt),
    ];
    let tp = registry::default_tp(sc);

    // One trace memo per search seed, shared by all four variant searches:
    // every candidate of every twin replays the same traces (and pays
    // generation once per seed, not once per (variant, candidate, seed)).
    let mut caches: Vec<TraceCache> =
        bp_seeds.iter().map(|&s| TraceCache::new(sc, s)).collect();
    let cfg = best_period::SearchConfig::adaptive(24, 8);
    let searched: Vec<(&str, Policy)> = variants
        .iter()
        .map(|&(name, kind)| {
            let bp = best_period::search_with(sc, kind, tp, &bp_seeds, &cfg, &mut caches);
            (name, Policy { kind, tr: bp.tr, tp })
        })
        .collect();

    // Evaluate the four twins per seed over one shared trace each — the
    // twin rows stay trace-paired with each other and with the
    // named-heuristic rows of the same scenario point.
    let per_seed: Vec<Vec<SimOutcome>> =
        scheduler::run_units(eval_seeds.len(), 0, |i| {
            let seed = eval_seeds[i];
            let mut cache = TraceCache::new(sc, seed);
            searched
                .iter()
                .map(|(_, pol)| simulate_from(sc, pol, 1.0, seed, cache.replay()))
                .collect()
        });

    searched
        .iter()
        .enumerate()
        .map(|(vi, (name, pol))| {
            let waste =
                Summary::from_iter(per_seed.iter().map(|outs| outs[vi].waste()));
            let makespan = per_seed.iter().map(|outs| outs[vi].makespan).sum::<f64>()
                / per_seed.len() as f64;
            HeuristicResult {
                name: name.to_string(),
                waste: waste.mean(),
                waste_ci: waste.ci95(),
                makespan,
                analytic_waste: f64::NAN,
                tr: pol.tr,
            }
        })
        .collect()
}

/// Write CSV rows to `results/<name>.csv` (creating the directory); returns
/// the path written.
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut text = String::with_capacity(rows.len() * 64 + header.len() + 1);
    text.push_str(header);
    text.push('\n');
    for row in rows {
        text.push_str(row);
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;

    fn small_scenario() -> Scenario {
        Scenario {
            platform: Platform { mu: 30_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e6,
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let sc = small_scenario();
        let pol = registry::get("RFO").unwrap().policy(&sc);
        let seeds: Vec<u64> = (0..16).collect();
        let par = run_seeds(&sc, &pol, &seeds);
        let ser: Vec<_> =
            seeds.iter().map(|&s| simulate(&sc, &pol, s)).collect();
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.makespan, b.makespan);
        }
    }

    #[test]
    fn evaluate_heuristics_returns_full_set() {
        let sc = small_scenario();
        let res = evaluate_heuristics(&sc, 4, 2);
        assert_eq!(res.len(), 9); // 5 named + 4 BestPeriod
        for r in &res {
            assert!(r.waste > 0.0 && r.waste < 1.0, "{}: {}", r.name, r.waste);
            assert!(r.makespan > sc.job_size);
        }
        // BestPeriod twins never much worse than their named counterpart.
        let get = |n: &str| res.iter().find(|r| r.name == n).unwrap().waste;
        assert!(get("BestPeriod-NoCkptI") <= get("NoCkptI") + 0.02);
    }
}
