//! Minimal ASCII line plots for terminal inspection of figure data.
//!
//! The CSV files under `results/` are the primary artifact (gnuplot- and
//! pandas-ready); these plots exist so `ckptwin figure --id N` gives an
//! immediate visual check of the paper's trends without leaving the shell.

/// One named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render series on a `width` × `height` character canvas with axes.
pub fn render(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let width = width.max(20);
    let height = height.max(5);
    let pts: Vec<(f64, f64)> =
        series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 == x0 {
        x1 = x0 + 1.0;
    }
    if y1 == y0 {
        y1 = y0 + 1.0;
    }

    let mut canvas = vec![vec![b' '; width]; height];
    let marks = [b'*', b'+', b'o', b'x', b'#', b'@', b'%', b'&', b'~'];
    for (si, s) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in &s.points {
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy;
            canvas[row][cx] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in canvas.iter().enumerate() {
        let yval = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:8.3} |"));
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("{:8} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:10}{x0:<12.4}{:>w$.4}\n", "", x1, w = width - 12));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "  {} = {}\n",
            marks[si % marks.len()] as char,
            s.name
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_axes_and_legend() {
        let s = vec![
            Series {
                name: "up".into(),
                points: (0..20).map(|i| (i as f64, i as f64 * 2.0)).collect(),
            },
            Series {
                name: "down".into(),
                points: (0..20).map(|i| (i as f64, 40.0 - i as f64)).collect(),
            },
        ];
        let text = render("test", &s, 40, 10);
        assert!(text.contains("test"));
        assert!(text.contains("* = up"));
        assert!(text.contains("+ = down"));
        assert!(text.lines().count() > 10);
    }

    #[test]
    fn empty_series_no_panic() {
        let text = render("empty", &[], 40, 10);
        assert!(text.contains("no data"));
    }

    #[test]
    fn constant_series_no_panic() {
        let s = vec![Series { name: "flat".into(), points: vec![(1.0, 2.0)] }];
        let text = render("flat", &s, 30, 6);
        assert!(text.contains("flat"));
    }
}
