//! Tables 4 and 5: job execution times (in days) under the different
//! checkpointing policies, with gains relative to Daly.
//!
//! Table 4: Weibull shape 0.7; Table 5: Weibull shape 0.5.  Columns:
//! I ∈ {300, 1200, 3000} × N ∈ {2^16, 2^19}; rows: Daly, RFO, then
//! {NoCkptI, WithCkptI, Instant} for predictor A (p=.82, r=.85) and
//! predictor B (p=.4, r=.7).

use crate::campaign::{self, CampaignOptions, Cell};
use crate::config::PredictorSpec;
use crate::sim::distribution::Law;
use crate::strategy::{registry, StrategyId};
use crate::util::SECONDS_PER_DAY;

use super::write_csv;

/// One table cell: mean execution time in days + gain vs the Daly cell.
/// (Named `TableCell` to distinguish it from a campaign [`Cell`].)
#[derive(Clone, Copy, Debug)]
pub struct TableCell {
    pub days: f64,
    /// Gain relative to Daly (fraction, e.g. 0.18 = 18%); 0 for Daly.
    pub gain: f64,
}

/// A full table: `cells[row][col]`.
#[derive(Clone, Debug)]
pub struct Table {
    pub id: u8,
    pub shape: f64,
    pub row_names: Vec<String>,
    /// Column labels, e.g. "I=300s/2^16".
    pub col_names: Vec<String>,
    pub cells: Vec<Vec<TableCell>>,
}

/// Window × procs column grid of Tables 4/5.
pub const TABLE_WINDOWS: [f64; 3] = [300.0, 1200.0, 3000.0];
pub const TABLE_PROCS: [u64; 2] = [1 << 16, 1 << 19];

/// Rows of the table: (label, strategy, predictor; None = no predictor).
fn table_rows() -> Vec<(String, StrategyId, Option<bool>)> {
    let strat = |n: &str| registry::get(n).expect("registered");
    let mut rows = vec![
        ("Daly".to_string(), strat("Daly"), None),
        ("RFO".to_string(), strat("RFO"), None),
    ];
    for (tag, is_a) in [("p=0.82,r=0.85", true), ("p=0.4,r=0.7", false)] {
        for name in ["NoCkptI", "WithCkptI", "Instant"] {
            rows.push((format!("{name} [{tag}]"), strat(name), Some(is_a)));
        }
    }
    rows
}

/// Compute Table 4 (`shape = 0.7`) or Table 5 (`shape = 0.5`).
///
/// All (row × column) cells are expanded up front into campaign cells and
/// executed together on the work-stealing pool — the heavy Weibull columns
/// no longer serialize behind each other.
pub fn run_table(id: u8, shape: f64, instances: usize) -> std::io::Result<Table> {
    let law = Law::Weibull { shape };
    let rows = table_rows();
    let mut col_names = Vec::new();
    for &w in &TABLE_WINDOWS {
        for &n in &TABLE_PROCS {
            col_names.push(format!("I={w}s/2^{}", n.trailing_zeros()));
        }
    }

    // One campaign cell per (column, row), in column-major order.
    let mut campaign_cells = Vec::new();
    for &window in &TABLE_WINDOWS {
        for &procs in &TABLE_PROCS {
            for (_, strat, pred) in &rows {
                let spec = match pred {
                    Some(false) => PredictorSpec::paper_b(window),
                    // Prediction-ignoring rows: predictor is irrelevant to
                    // the policy; keep A's event stream for the trace.
                    Some(true) | None => PredictorSpec::paper_a(window),
                };
                campaign_cells.push(Cell::new(
                    procs,
                    1.0,
                    law,
                    law,
                    spec,
                    strat.clone(),
                    1.0,
                ));
            }
        }
    }
    let opt = CampaignOptions { instances, block: 0, threads: 0 };
    let (outcomes, _) = campaign::run_cells(&campaign_cells, &opt, None)
        .expect("in-memory campaign has no store to fail");

    let mut cells = vec![Vec::with_capacity(col_names.len()); rows.len()];
    for col in outcomes.chunks(rows.len()) {
        // Daly baseline for this column (row 0, predictor-independent).
        let daly_days = col[0].makespan.mean() / SECONDS_PER_DAY;
        for (ri, outcome) in col.iter().enumerate() {
            let days = outcome.makespan.mean() / SECONDS_PER_DAY;
            let gain = if ri == 0 { 0.0 } else { 1.0 - days / daly_days };
            cells[ri].push(TableCell { days, gain });
        }
    }
    let table = Table {
        id,
        shape,
        row_names: rows.into_iter().map(|(n, _, _)| n).collect(),
        col_names,
        cells,
    };
    // CSV artifact.
    let mut csv = Vec::new();
    for (ri, name) in table.row_names.iter().enumerate() {
        for (ci, col) in table.col_names.iter().enumerate() {
            let cell = table.cells[ri][ci];
            csv.push(format!(
                "{id},{shape},{name},{col},{:.2},{:.3}",
                cell.days, cell.gain
            ));
        }
    }
    write_csv(
        &format!("table{id}"),
        "table,shape,heuristic,column,days,gain_vs_daly",
        &csv,
    )?;
    Ok(table)
}

/// Render the table as aligned text, paper-style (days + gain %).
pub fn render(table: &Table) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Table {} — execution time (days), Weibull k={}, gains vs Daly\n",
        table.id, table.shape
    ));
    let w0 = table
        .row_names
        .iter()
        .map(|r| r.len())
        .max()
        .unwrap_or(8)
        .max(8);
    out.push_str(&format!("{:w0$}", ""));
    for col in &table.col_names {
        out.push_str(&format!(" | {col:>16}"));
    }
    out.push('\n');
    for (ri, name) in table.row_names.iter().enumerate() {
        out.push_str(&format!("{name:w0$}"));
        for cell in &table.cells[ri] {
            if ri == 0 {
                out.push_str(&format!(" | {:>16}", format!("{:.1}", cell.days)));
            } else {
                out.push_str(&format!(
                    " | {:>16}",
                    format!("{:.1} ({:.0}%)", cell.days, cell.gain * 100.0)
                ));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rows_match_paper_layout() {
        let rows = table_rows();
        assert_eq!(rows.len(), 8); // Daly, RFO, 3×A, 3×B
        assert_eq!(rows[0].0, "Daly");
        assert!(rows[2].0.starts_with("NoCkptI"));
    }

    #[test]
    fn small_table_smoke() {
        // 2 instances just to exercise the plumbing (not paper-accurate).
        let t = run_table(4, 0.7, 2).unwrap();
        assert_eq!(t.cells.len(), 8);
        assert_eq!(t.cells[0].len(), 6);
        for row in &t.cells {
            for cell in row {
                assert!(cell.days.is_finite() && cell.days > 0.0);
            }
        }
        // Daly row has zero gain by construction.
        assert!(t.cells[0].iter().all(|c| c.gain == 0.0));
        let text = render(&t);
        assert!(text.contains("Daly"));
        assert!(text.contains("I=300s/2^16"));
    }
}
