//! Figure runners: one per figure family of the paper's evaluation.
//!
//! | Figures | Content                                                   |
//! |---------|-----------------------------------------------------------|
//! | 2–7     | waste vs N, false predictions ~ failure law               |
//! | 8–13    | waste vs N, false predictions ~ Uniform                   |
//! | 14–17   | waste vs period T_R (RFO + prediction-aware, + analytic)  |
//! | 18–21   | waste vs window size I                                    |
//!
//! Figures 2–13 iterate {predictor A, B} × {C_p = C, 0.1C, 2C}; each figure
//! is a 3 (distribution) × 5 (window size) panel over the 4 platform sizes.
//! Every runner returns its CSV rows and writes `results/figN.csv`.

use crate::campaign::{self, CampaignOptions, CellOutcome, Grid, PredictorId};
use crate::config::{PredictorSpec, Scenario};
use crate::sim::distribution::Law;
use crate::strategy::registry;

use super::{
    best_period_results_seeded, write_csv, HeuristicResult, PAPER_PROCS,
    PAPER_WINDOWS,
};

/// The three failure distributions of §4.1.
pub const PAPER_LAWS: [Law; 3] = [
    Law::Exponential,
    Law::Weibull { shape: 0.7 },
    Law::Weibull { shape: 0.5 },
];

/// Static description of one waste-vs-N figure (Figures 2–13).
#[derive(Clone, Copy, Debug)]
pub struct WasteVsNSpec {
    pub id: u8,
    /// Predictor A (p=.82, r=.85) or B (p=.4, r=.7).
    pub predictor_a: bool,
    /// C_p / C.
    pub cp_ratio: f64,
    /// False-prediction arrivals: failure law (Figs 2–7) or Uniform (8–13).
    pub uniform_false_preds: bool,
}

/// All twelve waste-vs-N figures.
pub fn waste_vs_n_specs() -> Vec<WasteVsNSpec> {
    let mut specs = Vec::new();
    let mut id = 2;
    for uniform in [false, true] {
        for predictor_a in [true, false] {
            for cp_ratio in [1.0, 0.1, 2.0] {
                specs.push(WasteVsNSpec {
                    id,
                    predictor_a,
                    cp_ratio,
                    uniform_false_preds: uniform,
                });
                id += 1;
            }
        }
    }
    specs
}

fn predictor(a: bool, window: f64) -> PredictorSpec {
    if a {
        PredictorSpec::paper_a(window)
    } else {
        PredictorSpec::paper_b(window)
    }
}

/// The registry identifier of a paper predictor ("a" or "b").
fn predictor_id(a: bool) -> PredictorId {
    crate::predictor::registry::get(if a { "a" } else { "b" })
        .expect("paper predictors are registered")
}

/// CSV header shared by the waste-vs-N and waste-vs-I figures.
pub const WASTE_HEADER: &str =
    "figure,distribution,window,procs,heuristic,tr,waste,waste_ci,analytic_waste,makespan_days";

fn push_rows(
    rows: &mut Vec<String>,
    fig: u8,
    law: Law,
    window: f64,
    procs: u64,
    results: &[HeuristicResult],
) {
    for r in results {
        rows.push(format!(
            "{fig},{},{window},{procs},{},{:.1},{:.6},{:.6},{:.6},{:.3}",
            law.label(),
            r.name,
            r.tr,
            r.waste,
            r.waste_ci,
            r.analytic_waste,
            r.makespan / crate::util::SECONDS_PER_DAY,
        ));
    }
}

/// Convert one scenario point's cell outcomes into the harness's result
/// rows.  The analytic column is fetched as one batched clipped surface
/// over the chunk's periods ([`crate::model::batch`] — bit-identical to
/// per-cell `waste_clipped`), then each strategy row reads its own
/// (strategy, period) entry.
fn outcome_results(chunk: &[CellOutcome]) -> Vec<HeuristicResult> {
    use crate::model::batch::BatchEvaluator;
    let sc = chunk[0].cell.scenario();
    let trs: Vec<f64> = chunk.iter().map(|o| o.tr).collect();
    let surface = BatchEvaluator::new().clipped_surface(&sc, &trs);
    chunk
        .iter()
        .enumerate()
        .map(|(i, o)| HeuristicResult {
            name: o.cell.strategy.to_string(),
            waste: o.waste.mean(),
            waste_ci: o.waste.ci95(),
            makespan: o.makespan.mean(),
            analytic_waste: o
                .cell
                .strategy
                .grid_strategy()
                .map(|gs| surface[gs as usize][i])
                .unwrap_or(f64::NAN),
            tr: o.tr,
        })
        .collect()
}

/// Execute a figure grid through the campaign engine and format its CSV
/// rows (one group of strategy rows — plus optional BestPeriod twins — per
/// scenario point).  Cells are parallelized across the whole grid by the
/// work-stealing pool, not point by point.
fn waste_rows_via_campaign(
    fig: u8,
    grid: &Grid,
    instances: usize,
    best_period_seeds: usize,
) -> Vec<String> {
    let opt = CampaignOptions { instances, block: 0, threads: 0 };
    let outcomes = campaign::evaluate_grid(grid, &opt);
    let per_point = grid.strategies.len();
    let mut rows = Vec::new();
    for chunk in outcomes.chunks(per_point) {
        let cell = &chunk[0].cell;
        let results = outcome_results(chunk);
        push_rows(
            &mut rows,
            fig,
            cell.fault_law,
            cell.predictor.window,
            cell.procs,
            &results,
        );
        if best_period_seeds > 0 {
            // Evaluate the twins on the cell's own seed streams so they
            // stay trace-paired with the strategy rows above.
            let bp = best_period_results_seeded(
                &cell.scenario(),
                instances,
                best_period_seeds,
                |i| cell.instance_seed(i),
            );
            push_rows(
                &mut rows,
                fig,
                cell.fault_law,
                cell.predictor.window,
                cell.procs,
                &bp,
            );
        }
    }
    rows
}

/// Run one waste-vs-N figure; returns the CSV rows written.
pub fn run_waste_vs_n(
    spec: &WasteVsNSpec,
    instances: usize,
    best_period_seeds: usize,
) -> std::io::Result<Vec<String>> {
    let grid = Grid {
        procs: PAPER_PROCS.to_vec(),
        cp_ratios: vec![spec.cp_ratio],
        fault_laws: PAPER_LAWS.to_vec(),
        uniform_false_preds: spec.uniform_false_preds,
        predictors: vec![predictor_id(spec.predictor_a)],
        windows: PAPER_WINDOWS.to_vec(),
        strategies: registry::paper_set(),
        scale: 1.0,
        platform_shards: vec![1],
    };
    let rows = waste_rows_via_campaign(spec.id, &grid, instances, best_period_seeds);
    write_csv(&format!("fig{}", spec.id), WASTE_HEADER, &rows)?;
    Ok(rows)
}

/// Re-emit a waste-vs-N figure preset in the scenario language.  The
/// committed `scenarios/figN.ckpt` files are exactly this output (pinned
/// by `tests/scenario.rs`), so the declarative suites can never drift
/// from the harness presets: both the [`run_waste_vs_n`] grid and the
/// compiled file reduce to `Grid::paper()` restricted to the spec's
/// predictor and C_p ratio.
pub fn waste_vs_n_scenario(spec: &WasteVsNSpec) -> String {
    use crate::scenario::ast::{Entry, ScenarioFile, Section};
    let entry = |key: &str, value: String| Entry { key: key.to_string(), value, line: 0 };
    let section = |name: &str, entries: Vec<Entry>| Section {
        name: name.to_string(),
        line: 0,
        entries,
    };
    let mut axes = vec![
        entry("cp-ratios", format!("{}", spec.cp_ratio)),
        entry(
            "predictors",
            (if spec.predictor_a { "a" } else { "b" }).to_string(),
        ),
    ];
    if spec.uniform_false_preds {
        axes.push(entry("uniform-fp", "true".to_string()));
    }
    // paper() holds 2 C_p ratios × 2 predictors; a figure pins one of each.
    let cells = Grid::paper().len() / 4;
    ScenarioFile {
        sections: vec![
            section(
                "suite",
                vec![
                    entry("name", format!("fig{}", spec.id)),
                    entry("kind", "campaign".to_string()),
                    entry("base", "paper".to_string()),
                ],
            ),
            section("axes", axes),
            section("expect", vec![entry("cells", cells.to_string())]),
        ],
    }
    .render()
}

/// Figures 14–17: waste as a function of the period T_R.
/// (14, 15) = predictor A at N = 2^16, 2^19; (16, 17) = predictor B.
#[derive(Clone, Copy, Debug)]
pub struct WasteVsTrSpec {
    pub id: u8,
    pub predictor_a: bool,
    pub procs: u64,
}

pub fn waste_vs_tr_specs() -> [WasteVsTrSpec; 4] {
    [
        WasteVsTrSpec { id: 14, predictor_a: true, procs: 1 << 16 },
        WasteVsTrSpec { id: 15, predictor_a: true, procs: 1 << 19 },
        WasteVsTrSpec { id: 16, predictor_a: false, procs: 1 << 16 },
        WasteVsTrSpec { id: 17, predictor_a: false, procs: 1 << 19 },
    ]
}

pub const TR_HEADER: &str =
    "figure,distribution,window,procs,heuristic,tr,waste,waste_ci,analytic_waste";

/// Run one waste-vs-T_R figure over a geometric T_R grid.
pub fn run_waste_vs_tr(
    spec: &WasteVsTrSpec,
    instances: usize,
    grid_points: usize,
) -> std::io::Result<Vec<String>> {
    use crate::model::batch::BatchEvaluator;
    use crate::strategy::{Policy, PolicyKind};

    // The paper's T_R plots use I = 600 s, C_p = C, failure-law FPs.
    let window = 600.0;
    let mut rows = Vec::new();
    let mut ev = BatchEvaluator::new();
    for law in PAPER_LAWS {
        let sc = Scenario::paper(
            spec.procs,
            1.0,
            predictor(spec.predictor_a, window),
            law,
            law,
        );
        let c = sc.platform.c;
        let lo = 1.1 * c;
        let hi = (sc.job_size).min(400.0 * c);
        let ratio = (hi / lo).powf(1.0 / (grid_points - 1) as f64);
        let heuristics: [(&str, PolicyKind); 4] = [
            ("RFO", PolicyKind::IgnorePredictions),
            ("Instant", PolicyKind::Instant),
            ("NoCkptI", PolicyKind::NoCkpt),
            ("WithCkptI", PolicyKind::WithCkpt),
        ];
        let tp = registry::default_tp(&sc);
        // The analytic columns: one batched clipped row per heuristic over
        // the whole T_R grid (bit-identical to per-cell `waste_clipped`).
        let trs: Vec<f64> =
            (0..grid_points).map(|k| lo * ratio.powi(k as i32)).collect();
        let analytic: Vec<Vec<f64>> = heuristics
            .iter()
            .map(|(_, kind)| match kind.grid_strategy() {
                Some(gs) => {
                    let mut row = Vec::new();
                    ev.clipped_row(&sc, gs, &trs, &mut row);
                    row
                }
                None => vec![f64::NAN; trs.len()],
            })
            .collect();
        for (k, &tr) in trs.iter().enumerate() {
            for (h, (name, kind)) in heuristics.iter().enumerate() {
                let pol = Policy { kind: *kind, tr, tp };
                // Terrible periods in the sweep are capped (waste saturates
                // near 1 anyway); see engine::simulate_from_capped.
                let cap = 50.0 * sc.job_size + 100.0 * sc.platform.mu;
                let seeds: Vec<u64> = (0..instances as u64).collect();
                let outs = super::run_seeds_capped(&sc, &pol, &seeds, cap);
                let waste = crate::stats::Summary::from_iter(
                    outs.iter().map(|o| o.waste()),
                );
                rows.push(format!(
                    "{},{},{window},{},{name},{tr:.1},{:.6},{:.6},{:.6}",
                    spec.id,
                    law.label(),
                    spec.procs,
                    waste.mean(),
                    waste.ci95(),
                    analytic[h][k],
                ));
            }
        }
        // Reference: where the named strategies put their periods.
        for strat in registry::paper_set() {
            let pol = strat.policy(&sc);
            rows.push(format!(
                "{},{},{window},{},{strat}-period,{:.1},,,",
                spec.id,
                law.label(),
                spec.procs,
                pol.tr,
            ));
        }
    }
    write_csv(&format!("fig{}", spec.id), TR_HEADER, &rows)?;
    Ok(rows)
}

/// Figures 18–21: waste as a function of the window size I.
#[derive(Clone, Copy, Debug)]
pub struct WasteVsISpec {
    pub id: u8,
    pub predictor_a: bool,
    pub procs: u64,
}

pub fn waste_vs_i_specs() -> [WasteVsISpec; 4] {
    [
        WasteVsISpec { id: 18, predictor_a: true, procs: 1 << 16 },
        WasteVsISpec { id: 19, predictor_a: true, procs: 1 << 19 },
        WasteVsISpec { id: 20, predictor_a: false, procs: 1 << 16 },
        WasteVsISpec { id: 21, predictor_a: false, procs: 1 << 19 },
    ]
}

/// Window sweep used by Figures 18–21.
pub const I_SWEEP: [f64; 7] = [150.0, 300.0, 600.0, 900.0, 1200.0, 2100.0, 3000.0];

/// Run one waste-vs-I figure.
pub fn run_waste_vs_i(
    spec: &WasteVsISpec,
    instances: usize,
    best_period_seeds: usize,
) -> std::io::Result<Vec<String>> {
    let grid = Grid {
        procs: vec![spec.procs],
        cp_ratios: vec![1.0],
        fault_laws: PAPER_LAWS.to_vec(),
        uniform_false_preds: false,
        predictors: vec![predictor_id(spec.predictor_a)],
        windows: I_SWEEP.to_vec(),
        strategies: registry::paper_set(),
        scale: 1.0,
        platform_shards: vec![1],
    };
    let rows = waste_rows_via_campaign(spec.id, &grid, instances, best_period_seeds);
    write_csv(&format!("fig{}", spec.id), WASTE_HEADER, &rows)?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_waste_vs_n_specs() {
        let specs = waste_vs_n_specs();
        assert_eq!(specs.len(), 12);
        assert_eq!(specs[0].id, 2);
        assert_eq!(specs[11].id, 13);
        // Figures 2-7 use failure-law FPs, 8-13 uniform.
        assert!(specs[..6].iter().all(|s| !s.uniform_false_preds));
        assert!(specs[6..].iter().all(|s| s.uniform_false_preds));
        // Cp ratios cycle C, 0.1C, 2C.
        assert_eq!(specs[0].cp_ratio, 1.0);
        assert_eq!(specs[1].cp_ratio, 0.1);
        assert_eq!(specs[2].cp_ratio, 2.0);
    }

    #[test]
    fn figure_ids_cover_paper() {
        let ids: Vec<u8> = waste_vs_n_specs()
            .iter()
            .map(|s| s.id)
            .chain(waste_vs_tr_specs().iter().map(|s| s.id))
            .chain(waste_vs_i_specs().iter().map(|s| s.id))
            .collect();
        assert_eq!(ids, (2..=21).collect::<Vec<u8>>());
    }
}
