//! Log2-bucketed histograms: fixed-size, allocation-free, mergeable.
//!
//! Values are `u64` (the natural unit is nanoseconds for latency, or raw
//! event counts); bucket `i >= 1` covers `[2^(i-1), 2^i - 1]`, bucket 0
//! holds exact zeros.  Recording is a couple of integer ops — cheap enough
//! for per-step coordinator timing — and merging is element-wise addition,
//! which makes the shard-merge dataflow of
//! [`crate::obs::registry::MetricsRegistry`] exact and associative.

/// Number of buckets: zeros + one per bit of a `u64`.
pub const BUCKETS: usize = 65;

/// A log2-bucketed histogram over `u64` samples.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hist {
    counts: [u64; BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: [0; BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

/// The bucket index for a sample: 0 for 0, else `1 + floor(log2(v))`.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// The inclusive value range `[lo, hi]` covered by bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    match i {
        0 => (0, 0),
        64 => (1 << 63, u64::MAX),
        _ => (1 << (i - 1), (1 << i) - 1),
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Record one sample.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (element-wise; associative
    /// and commutative, so shard merge order never matters).
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the `q`-quantile
    /// (`q` in `[0, 1]`).  Bucket resolution: a factor of 2.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bounds(i).1.min(self.max);
            }
        }
        self.max
    }

    /// Non-empty buckets as `(lo, hi, count)` triples, low to high.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let (lo, hi) = bucket_bounds(i);
                (lo, hi, c)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        // 0 is its own bucket; v >= 1 lands in 1 + floor(log2 v).
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of(u64::MAX), 64);
        // Every bucket's bounds round-trip through bucket_of.
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(bucket_of(lo), i, "lo of bucket {i}");
            assert_eq!(bucket_of(hi), i, "hi of bucket {i}");
        }
        // Buckets tile u64 with no gaps.
        for i in 1..BUCKETS {
            assert_eq!(bucket_bounds(i).0, bucket_bounds(i - 1).1 + 1);
        }
    }

    #[test]
    fn record_and_stats() {
        let mut h = Hist::new();
        for v in [0u64, 1, 2, 3, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1006);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 201.2).abs() < 1e-12);
        let nz = h.nonzero_buckets();
        assert_eq!(nz[0], (0, 0, 1)); // the zero
        assert_eq!(nz[1], (1, 1, 1)); // 1
        assert_eq!(nz[2], (2, 3, 2)); // 2 and 3
    }

    #[test]
    fn quantiles_bound_by_buckets() {
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        // p50 of 1..=1000 is 500, whose bucket is [256, 511].
        assert_eq!(h.quantile(0.5), 511);
        // p100 clamps to the observed max, not the bucket's upper bound.
        assert_eq!(h.quantile(1.0), 1000);
        assert_eq!(h.quantile(0.0), bucket_bounds(bucket_of(1)).1);
        assert_eq!(Hist::new().quantile(0.5), 0);
    }

    #[test]
    fn merge_is_associative_and_matches_sequential() {
        let samples: Vec<u64> =
            (0..200).map(|i| (i * i * 2654435761u64) >> 13).collect();
        // Sequential reference.
        let mut all = Hist::new();
        for &v in &samples {
            all.record(v);
        }
        // Three shards, merged in both association orders.
        let mut shards = [Hist::new(), Hist::new(), Hist::new()];
        for (i, &v) in samples.iter().enumerate() {
            shards[i % 3].record(v);
        }
        let mut left = shards[0].clone(); // (a + b) + c
        left.merge(&shards[1]);
        left.merge(&shards[2]);
        let mut bc = shards[1].clone(); // a + (b + c)
        bc.merge(&shards[2]);
        let mut right = shards[0].clone();
        right.merge(&bc);
        assert_eq!(left, right);
        assert_eq!(left, all);
    }
}
