//! Lightweight span timers: measure a wall-clock interval in nanoseconds
//! and feed it straight into a log2 histogram.
//!
//! A [`SpanTimer`] is a thin `Instant` wrapper; [`Stopwatch`] accumulates
//! many spans into a [`Hist`] (the coordinator's per-step decision latency
//! uses one).  Timers are *observability only* — simulated time lives in
//! the engine; nothing here may influence simulation results.

use std::time::Instant;

use crate::obs::hist::Hist;

/// One in-flight timed span.
#[derive(Clone, Copy, Debug)]
pub struct SpanTimer {
    start: Instant,
}

impl SpanTimer {
    /// Start timing now.
    pub fn start() -> SpanTimer {
        SpanTimer { start: Instant::now() }
    }

    /// Nanoseconds since `start()` (saturated to `u64`).
    pub fn elapsed_nanos(&self) -> u64 {
        let n = self.start.elapsed().as_nanos();
        u64::try_from(n).unwrap_or(u64::MAX)
    }

    /// Seconds since `start()`.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// A histogram-backed accumulator of timed spans.
#[derive(Clone, Debug, Default)]
pub struct Stopwatch {
    hist: Hist,
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        Stopwatch::default()
    }

    /// Time one closure and record its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = SpanTimer::start();
        let out = f();
        self.hist.record(t.elapsed_nanos());
        out
    }

    /// Record an externally measured span (nanoseconds).
    pub fn record_nanos(&mut self, nanos: u64) {
        self.hist.record(nanos);
    }

    /// The accumulated latency histogram.
    pub fn hist(&self) -> &Hist {
        &self.hist
    }

    /// Take the histogram out, leaving an empty one.
    pub fn take(&mut self) -> Hist {
        std::mem::take(&mut self.hist)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_monotonic() {
        let t = SpanTimer::start();
        let a = t.elapsed_nanos();
        let b = t.elapsed_nanos();
        assert!(b >= a);
        assert!(t.elapsed_secs() >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates_spans() {
        let mut sw = Stopwatch::new();
        let x = sw.time(|| 2 + 2);
        assert_eq!(x, 4);
        sw.record_nanos(1024);
        assert_eq!(sw.hist().count(), 2);
        let h = sw.take();
        assert_eq!(h.count(), 2);
        assert!(sw.hist().is_empty());
    }
}
