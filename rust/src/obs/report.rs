//! `METRICS.json` (schema `ckptwin-metrics/1`): the machine-readable
//! telemetry artifact the `ckptwin metrics` subcommand emits and CI
//! uploads.
//!
//! This module only knows how to render observability primitives
//! ([`Hist`], [`EventCounters`], [`MetricsRegistry`]) into
//! [`crate::jsonio::Value`] trees and assemble them into the versioned
//! document; the *content* of the campaign / audit / coordinator sections
//! is built by the caller (`main::cmd_metrics`), keeping `obs` free of
//! upward dependencies.

use std::collections::BTreeMap;
use std::path::Path;

use crate::jsonio::Value;
use crate::obs::hist::Hist;
use crate::obs::registry::MetricsRegistry;
use crate::obs::EventCounters;

/// The artifact's schema tag; bump on breaking layout changes.
pub const SCHEMA: &str = "ckptwin-metrics/1";

fn num_or_null(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

/// Render a histogram: summary stats, tail quantiles, and the non-empty
/// log2 buckets as `[lo, hi, count]` triples.
pub fn hist_json(h: &Hist) -> Value {
    let mut o = BTreeMap::new();
    o.insert("count".into(), Value::Num(h.count() as f64));
    o.insert("sum".into(), Value::Num(h.sum() as f64));
    if h.is_empty() {
        o.insert("min".into(), Value::Null);
        o.insert("max".into(), Value::Null);
        o.insert("mean".into(), Value::Null);
    } else {
        o.insert("min".into(), Value::Num(h.min() as f64));
        o.insert("max".into(), Value::Num(h.max() as f64));
        o.insert("mean".into(), num_or_null(h.mean()));
    }
    for (name, q) in [("p50", 0.50), ("p90", 0.90), ("p99", 0.99)] {
        o.insert(
            name.into(),
            if h.is_empty() {
                Value::Null
            } else {
                Value::Num(h.quantile(q) as f64)
            },
        );
    }
    o.insert(
        "buckets".into(),
        Value::Arr(
            h.nonzero_buckets()
                .into_iter()
                .map(|(lo, hi, n)| {
                    Value::Arr(vec![
                        Value::Num(lo as f64),
                        Value::Num(hi as f64),
                        Value::Num(n as f64),
                    ])
                })
                .collect(),
        ),
    );
    Value::Obj(o)
}

/// Render an [`EventCounters`]: every event count and the time
/// decomposition, plus the derived totals.
pub fn counters_json(c: &EventCounters) -> Value {
    let mut o = BTreeMap::new();
    for (k, v) in [
        ("n_faults", c.n_faults),
        ("n_predicted_faults", c.n_predicted_faults),
        ("n_preds_seen", c.n_preds_seen),
        ("n_preds_trusted", c.n_preds_trusted),
        ("n_preds_ignored", c.n_preds_ignored),
        ("n_preds_overlapped", c.n_preds_overlapped),
        ("n_reg_ckpts", c.n_reg_ckpts),
        ("n_pro_ckpts", c.n_pro_ckpts),
        ("n_ckpts_aborted", c.n_ckpts_aborted),
        ("n_rollbacks", c.n_rollbacks),
        ("n_down_stints", c.n_down_stints),
    ] {
        o.insert(k.into(), Value::Num(v as f64));
    }
    for (k, v) in [
        ("time_work", c.time_work),
        ("time_ckpt_reg", c.time_ckpt_reg),
        ("time_ckpt_pro", c.time_ckpt_pro),
        ("time_reexec", c.time_reexec),
        ("time_down", c.time_down),
        ("time_idle", c.time_idle),
        ("time_total", c.time_total()),
    ] {
        o.insert(k.into(), num_or_null(v));
    }
    Value::Obj(o)
}

/// Render a full registry: counters and gauges as flat maps, histograms
/// via [`hist_json`].
pub fn registry_json(r: &MetricsRegistry) -> Value {
    let mut o = BTreeMap::new();
    o.insert(
        "counters".into(),
        Value::Obj(
            r.counters()
                .map(|(k, v)| (k.to_string(), Value::Num(v as f64)))
                .collect(),
        ),
    );
    o.insert(
        "gauges".into(),
        Value::Obj(
            r.gauges().map(|(k, v)| (k.to_string(), num_or_null(v))).collect(),
        ),
    );
    o.insert(
        "hists".into(),
        Value::Obj(
            r.hists().map(|(k, h)| (k.to_string(), hist_json(h))).collect(),
        ),
    );
    Value::Obj(o)
}

/// Assemble the versioned document: `{"schema": ..., "registry": ...}`
/// plus the caller-built named sections (campaign, audit, coordinator).
pub fn metrics_json(registry: &MetricsRegistry, sections: &[(&str, Value)]) -> Value {
    let mut doc = BTreeMap::new();
    doc.insert("schema".into(), Value::Str(SCHEMA.into()));
    doc.insert("registry".into(), registry_json(registry));
    for (name, section) in sections {
        doc.insert((*name).to_string(), section.clone());
    }
    Value::Obj(doc)
}

/// Write a metrics document (creating parent directories); returns the
/// serialized length in bytes.
pub fn write_json(path: &Path, doc: &Value) -> std::io::Result<usize> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let text = crate::jsonio::to_string(doc);
    std::fs::write(path, &text)?;
    Ok(text.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_json_has_stats_and_buckets() {
        let mut h = Hist::default();
        for v in [0u64, 3, 3, 900] {
            h.record(v);
        }
        let doc = hist_json(&h);
        assert_eq!(doc.get("count").unwrap().as_usize(), Some(4));
        assert_eq!(doc.get("min").unwrap().as_usize(), Some(0));
        assert_eq!(doc.get("max").unwrap().as_usize(), Some(900));
        let buckets = match doc.get("buckets").unwrap() {
            Value::Arr(v) => v,
            _ => panic!("buckets must be an array"),
        };
        assert_eq!(buckets.len(), 3); // zero bucket, [2,3], [512,1023]
        // Empty histogram: stats are null, buckets empty.
        let empty = hist_json(&Hist::default());
        assert_eq!(empty.get("mean"), Some(&Value::Null));
        assert_eq!(empty.get("p99"), Some(&Value::Null));
    }

    #[test]
    fn counters_json_lists_every_field() {
        let c = EventCounters {
            n_faults: 3,
            time_work: 120.5,
            ..EventCounters::default()
        };
        let doc = counters_json(&c);
        assert_eq!(doc.get("n_faults").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("time_work").unwrap().as_f64(), Some(120.5));
        assert_eq!(doc.get("time_total").unwrap().as_f64(), Some(120.5));
        if let Value::Obj(m) = &doc {
            assert_eq!(m.len(), 11 + 7);
        } else {
            panic!("counters must render as an object");
        }
    }

    #[test]
    fn document_roundtrips_through_the_parser() {
        let mut reg = MetricsRegistry::default();
        reg.add("campaign.sim_events", 42);
        reg.set_gauge("pool.hit_rate", 0.75);
        reg.observe("coordinator.decision_ns", 1024);
        let mut section = BTreeMap::new();
        section.insert("cells_per_sec".into(), Value::Num(10.0));
        let doc = metrics_json(&reg, &[("campaign", Value::Obj(section))]);
        let text = crate::jsonio::to_string(&doc);
        let back = crate::jsonio::parse(&text).expect("valid JSON");
        assert_eq!(back.get("schema").and_then(Value::as_str), Some(SCHEMA));
        assert_eq!(
            back.get("registry")
                .unwrap()
                .get("counters")
                .unwrap()
                .get("campaign.sim_events")
                .unwrap()
                .as_usize(),
            Some(42)
        );
        assert_eq!(
            back.get("campaign").unwrap().get("cells_per_sec").unwrap().as_f64(),
            Some(10.0)
        );
    }

    #[test]
    fn write_json_creates_dirs() {
        let dir = std::env::temp_dir()
            .join(format!("ckptwin-metrics-json-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/METRICS.json");
        let doc = metrics_json(&MetricsRegistry::default(), &[]);
        let n = write_json(&path, &doc).unwrap();
        assert!(n > 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::jsonio::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
