//! Observability: zero-dependency telemetry for the whole stack.
//!
//! Four small pieces:
//!
//! * [`Recorder`] — the engine's event sink.  Every method has an empty
//!   `#[inline]` default, so the engine monomorphized over
//!   [`NoopRecorder`] compiles the hooks away entirely: the default
//!   simulation path is the pre-telemetry hot path, and
//!   `tests/fast_path.rs` stays bit-identical.  Recorders observe; they
//!   never touch the engine's RNG streams, so an *enabled* recorder is
//!   also bit-identical to a disabled one.
//! * [`EventCounters`] — the standard recorder: event counts plus the
//!   paper's §2.1 time decomposition (work / regular ckpt / proactive
//!   ckpt / re-executed / down / idle).  Its [`EventCounters::audit`]
//!   checks the waste-accounting identity against a
//!   [`crate::sim::engine::SimOutcome`]: the decomposed times must tile
//!   the makespan and reconcile with `waste()`.
//! * [`registry::MetricsRegistry`] — sharded counters / gauges / log2
//!   histograms ([`hist::Hist`]), merged at worker join (no hot-path
//!   locks).
//! * [`span::SpanTimer`] / [`span::Stopwatch`] — wall-clock span timing
//!   feeding histograms (coordinator decision latency).
//!
//! [`report`] assembles everything into the `METRICS.json` artifact
//! (schema `ckptwin-metrics/1`) behind `ckptwin metrics`.

pub mod hist;
pub mod registry;
pub mod report;
pub mod span;

pub use hist::Hist;
pub use registry::MetricsRegistry;
pub use span::{SpanTimer, Stopwatch};

use crate::sim::engine::SimOutcome;

/// The engine's telemetry sink.  All methods default to empty inline
/// bodies: a `NoopRecorder` engine is the plain engine.
///
/// Contract: implementations must be pure observers.  They may not read
/// or advance any RNG, and the engine calls them only *after* its own
/// accounting for the same event — enabling a recorder can never change
/// a simulation result (pinned by `tests/metrics.rs` against the
/// `fast_path` goldens).
pub trait Recorder {
    /// A fault struck at simulated time `t` (`predicted`: trace metadata —
    /// was it covered by a prediction?).
    #[inline]
    fn fault(&mut self, t: f64, predicted: bool) {
        let _ = (t, predicted);
    }

    /// A prediction announcement arrived (trusted or not).
    #[inline]
    fn prediction_seen(&mut self) {}

    /// A prediction was trusted: the proactive sequence starts.
    #[inline]
    fn prediction_trusted(&mut self) {}

    /// A prediction was dropped: the §3.1 coin said no, or the policy
    /// never listens (q = 0 mode).
    #[inline]
    fn prediction_ignored(&mut self) {}

    /// A prediction arrived while the engine was busy (proactive
    /// sequence or downtime) and was dropped — prediction-aware
    /// policies only.
    #[inline]
    fn prediction_overlapped(&mut self) {}

    /// `amount` seconds of useful work were executed (possibly destroyed
    /// later; see [`Recorder::rollback`]).
    #[inline]
    fn work(&mut self, amount: f64) {
        let _ = amount;
    }

    /// A checkpoint completed (`duration` seconds; `proactive`: C_p vs C).
    #[inline]
    fn ckpt_committed(&mut self, duration: f64, proactive: bool) {
        let _ = (duration, proactive);
    }

    /// A checkpoint was destroyed or abandoned `elapsed` seconds in; the
    /// time is accounted as idle (§3.1).
    #[inline]
    fn ckpt_aborted(&mut self, elapsed: f64) {
        let _ = elapsed;
    }

    /// A fault destroyed `work_lost` seconds of unsaved work (it will be
    /// re-executed).
    #[inline]
    fn rollback(&mut self, work_lost: f64) {
        let _ = work_lost;
    }

    /// One downtime + recovery stint of `elapsed` seconds (a fault during
    /// D + R restarts the stint; each stint is reported separately).
    #[inline]
    fn downtime(&mut self, elapsed: f64) {
        let _ = elapsed;
    }
}

/// The default sink: records nothing, costs nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {}

/// Forwarding impl so callers can keep ownership of their recorder and
/// hand the engine a `&mut` (the engine is generic over `R: Recorder` by
/// value).
impl<R: Recorder + ?Sized> Recorder for &mut R {
    #[inline]
    fn fault(&mut self, t: f64, predicted: bool) {
        (**self).fault(t, predicted);
    }
    #[inline]
    fn prediction_seen(&mut self) {
        (**self).prediction_seen();
    }
    #[inline]
    fn prediction_trusted(&mut self) {
        (**self).prediction_trusted();
    }
    #[inline]
    fn prediction_ignored(&mut self) {
        (**self).prediction_ignored();
    }
    #[inline]
    fn prediction_overlapped(&mut self) {
        (**self).prediction_overlapped();
    }
    #[inline]
    fn work(&mut self, amount: f64) {
        (**self).work(amount);
    }
    #[inline]
    fn ckpt_committed(&mut self, duration: f64, proactive: bool) {
        (**self).ckpt_committed(duration, proactive);
    }
    #[inline]
    fn ckpt_aborted(&mut self, elapsed: f64) {
        (**self).ckpt_aborted(elapsed);
    }
    #[inline]
    fn rollback(&mut self, work_lost: f64) {
        (**self).rollback(work_lost);
    }
    #[inline]
    fn downtime(&mut self, elapsed: f64) {
        (**self).downtime(elapsed);
    }
}

/// Standard engine recorder: event counts + the §2.1 time decomposition.
///
/// The float fields accumulate the *same values in the same order* as the
/// engine's own accounting, so `time_reexec` / `time_down` / `time_idle`
/// equal the outcome's `work_lost` / `time_down` / `time_idle` bit for
/// bit; the regular/proactive checkpoint split and the makespan tiling
/// hold to summation-order tolerance (1e-6 relative, the same bound
/// `Timeline::validate` uses).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EventCounters {
    pub n_faults: u64,
    pub n_predicted_faults: u64,
    pub n_preds_seen: u64,
    pub n_preds_trusted: u64,
    pub n_preds_ignored: u64,
    pub n_preds_overlapped: u64,
    pub n_reg_ckpts: u64,
    pub n_pro_ckpts: u64,
    pub n_ckpts_aborted: u64,
    pub n_rollbacks: u64,
    pub n_down_stints: u64,
    /// Useful work executed, including work later destroyed (s).
    pub time_work: f64,
    /// Completed regular checkpoints (s).
    pub time_ckpt_reg: f64,
    /// Completed proactive checkpoints (s).
    pub time_ckpt_pro: f64,
    /// Work destroyed by faults — will be re-executed (s).
    pub time_reexec: f64,
    /// Downtime + recovery (s).
    pub time_down: f64,
    /// Aborted-checkpoint idle time (s).
    pub time_idle: f64,
}

impl Recorder for EventCounters {
    #[inline]
    fn fault(&mut self, _t: f64, predicted: bool) {
        self.n_faults += 1;
        self.n_predicted_faults += predicted as u64;
    }
    #[inline]
    fn prediction_seen(&mut self) {
        self.n_preds_seen += 1;
    }
    #[inline]
    fn prediction_trusted(&mut self) {
        self.n_preds_trusted += 1;
    }
    #[inline]
    fn prediction_ignored(&mut self) {
        self.n_preds_ignored += 1;
    }
    #[inline]
    fn prediction_overlapped(&mut self) {
        self.n_preds_overlapped += 1;
    }
    #[inline]
    fn work(&mut self, amount: f64) {
        self.time_work += amount;
    }
    #[inline]
    fn ckpt_committed(&mut self, duration: f64, proactive: bool) {
        if proactive {
            self.n_pro_ckpts += 1;
            self.time_ckpt_pro += duration;
        } else {
            self.n_reg_ckpts += 1;
            self.time_ckpt_reg += duration;
        }
    }
    #[inline]
    fn ckpt_aborted(&mut self, elapsed: f64) {
        self.n_ckpts_aborted += 1;
        self.time_idle += elapsed;
    }
    #[inline]
    fn rollback(&mut self, work_lost: f64) {
        self.n_rollbacks += 1;
        self.time_reexec += work_lost;
    }
    #[inline]
    fn downtime(&mut self, elapsed: f64) {
        self.n_down_stints += 1;
        self.time_down += elapsed;
    }
}

impl EventCounters {
    /// Fold another simulation's counters into this one (campaign-level
    /// aggregation; exact for the integer fields).
    pub fn merge(&mut self, o: &EventCounters) {
        self.n_faults += o.n_faults;
        self.n_predicted_faults += o.n_predicted_faults;
        self.n_preds_seen += o.n_preds_seen;
        self.n_preds_trusted += o.n_preds_trusted;
        self.n_preds_ignored += o.n_preds_ignored;
        self.n_preds_overlapped += o.n_preds_overlapped;
        self.n_reg_ckpts += o.n_reg_ckpts;
        self.n_pro_ckpts += o.n_pro_ckpts;
        self.n_ckpts_aborted += o.n_ckpts_aborted;
        self.n_rollbacks += o.n_rollbacks;
        self.n_down_stints += o.n_down_stints;
        self.time_work += o.time_work;
        self.time_ckpt_reg += o.time_ckpt_reg;
        self.time_ckpt_pro += o.time_ckpt_pro;
        self.time_reexec += o.time_reexec;
        self.time_down += o.time_down;
        self.time_idle += o.time_idle;
    }

    /// Total checkpoint time (regular + proactive).
    pub fn time_ckpt(&self) -> f64 {
        self.time_ckpt_reg + self.time_ckpt_pro
    }

    /// Sum of the full time decomposition — must tile the makespan.
    pub fn time_total(&self) -> f64 {
        self.time_work
            + self.time_ckpt_reg
            + self.time_ckpt_pro
            + self.time_down
            + self.time_idle
    }

    /// The waste-accounting audit against one simulation's outcome.
    ///
    /// Identities checked (tol = 1e-6 relative, the `Timeline::validate`
    /// bound; integer counters and same-order float sums are exact):
    ///
    /// 1. every shared event counter matches the outcome's;
    /// 2. `seen == trusted + ignored + overlapped` (every announcement is
    ///    classified exactly once);
    /// 3. `time_reexec == work_lost`, `time_down`, `time_idle` — bit
    ///    equal (same values, same accumulation order);
    /// 4. `time_ckpt_reg + time_ckpt_pro == time_ckpt` (the split tiles
    ///    the combined figure);
    /// 5. `time_work == job_size + work_lost` (executed work = useful
    ///    work + re-executed work — also holds for capped partial runs);
    /// 6. **tiling**: `work + ckpt + down + idle == makespan`, which with
    ///    (5) is exactly `waste() == (makespan - job_size)/makespan`.
    pub fn audit(&self, out: &SimOutcome) -> Result<(), String> {
        let tol = 1e-6 * out.makespan.max(1.0);
        let int = |name: &str, a: u64, b: u64| {
            if a == b {
                Ok(())
            } else {
                Err(format!("{name}: counters {a} != outcome {b}"))
            }
        };
        int("n_faults", self.n_faults, out.n_faults)?;
        int("n_predicted_faults", self.n_predicted_faults, out.n_predicted_faults)?;
        int("n_preds_seen", self.n_preds_seen, out.n_preds_seen)?;
        int("n_preds_trusted", self.n_preds_trusted, out.n_preds_trusted)?;
        int("n_reg_ckpts", self.n_reg_ckpts, out.n_reg_ckpts)?;
        int("n_pro_ckpts", self.n_pro_ckpts, out.n_pro_ckpts)?;
        let classified =
            self.n_preds_trusted + self.n_preds_ignored + self.n_preds_overlapped;
        int("preds classified", classified, out.n_preds_seen)?;
        let bit = |name: &str, a: f64, b: f64| {
            if a == b {
                Ok(())
            } else {
                Err(format!("{name}: counters {a} != outcome {b} (bit identity)"))
            }
        };
        bit("time_reexec/work_lost", self.time_reexec, out.work_lost)?;
        bit("time_down", self.time_down, out.time_down)?;
        bit("time_idle", self.time_idle, out.time_idle)?;
        let near = |name: &str, a: f64, b: f64| {
            if (a - b).abs() <= tol {
                Ok(())
            } else {
                Err(format!("{name}: {a} vs {b} (tol {tol})"))
            }
        };
        near("ckpt split", self.time_ckpt(), out.time_ckpt)?;
        near("work identity", self.time_work, out.job_size + out.work_lost)?;
        near("makespan tiling", self.time_total(), out.makespan)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_merge_adds_everything() {
        let mut a = EventCounters::default();
        a.fault(1.0, true);
        a.work(10.0);
        a.ckpt_committed(2.0, false);
        let mut b = EventCounters::default();
        b.fault(2.0, false);
        b.ckpt_committed(3.0, true);
        b.downtime(4.0);
        a.merge(&b);
        assert_eq!(a.n_faults, 2);
        assert_eq!(a.n_predicted_faults, 1);
        assert_eq!(a.n_reg_ckpts, 1);
        assert_eq!(a.n_pro_ckpts, 1);
        assert_eq!(a.time_ckpt(), 5.0);
        assert_eq!(a.time_down, 4.0);
        assert_eq!(a.time_work, 10.0);
    }

    #[test]
    fn audit_rejects_a_cooked_decomposition() {
        // An outcome whose books don't balance must be caught — the audit
        // is not vacuous.
        let mut c = EventCounters::default();
        c.work(100.0);
        c.ckpt_committed(10.0, false);
        let mut out = SimOutcome {
            makespan: 110.0,
            job_size: 100.0,
            n_reg_ckpts: 1,
            time_ckpt: 10.0,
            ..SimOutcome::default()
        };
        assert!(c.audit(&out).is_ok());
        out.makespan = 115.0; // 5 unaccounted seconds
        let err = c.audit(&out).unwrap_err();
        assert!(err.contains("tiling"), "{err}");
    }
}
