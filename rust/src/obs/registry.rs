//! Sharded metrics registry: counters, gauges and log2 histograms.
//!
//! Concurrency model: there are **no hot-path locks**.  Each campaign
//! worker owns a private `MetricsRegistry` shard (created by the
//! scheduler's per-worker `init`, like its `TracePool`), records into it
//! freely, and the shards are merged into one registry when the workers
//! join.  Counter and histogram merges are exact integer addition —
//! associative and commutative — so the merged totals are independent of
//! worker count and join order (the same bit-determinism contract the
//! campaign's Welford block merge follows).
//!
//! Names are `&'static str` so recording never allocates; the convention
//! is `layer.noun` (`campaign.sim_events`, `pool.hits`,
//! `coordinator.decision_ns`).

use std::collections::BTreeMap;

use crate::obs::hist::Hist;

/// One metrics shard (also the merged root — merging is closed).
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Hist>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to a counter (creating it at 0).
    #[inline]
    pub fn add(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    /// Increment a counter by one.
    #[inline]
    pub fn inc(&mut self, name: &'static str) {
        self.add(name, 1);
    }

    /// Set a gauge to its latest value.
    #[inline]
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    /// Record one sample into a histogram (creating it empty).
    #[inline]
    pub fn observe(&mut self, name: &'static str, v: u64) {
        self.hists.entry(name).or_default().record(v);
    }

    /// Counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.counters.iter().map(|(&k, &v)| (k, v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.gauges.iter().map(|(&k, &v)| (k, v))
    }

    pub fn hists(&self) -> impl Iterator<Item = (&'static str, &Hist)> + '_ {
        self.hists.iter().map(|(&k, v)| (k, v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Fold a worker shard into this registry: counters and histograms
    /// add element-wise; gauges are last-writer-wins (they describe the
    /// run, not a sum — merge order only matters if two shards set the
    /// same gauge, which the naming convention avoids).
    pub fn merge(&mut self, shard: &MetricsRegistry) {
        for (&k, &v) in &shard.counters {
            self.add(k, v);
        }
        for (&k, &v) in &shard.gauges {
            self.gauges.insert(k, v);
        }
        for (&k, h) in &shard.hists {
            self.hists.entry(k).or_default().merge(h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_hists_roundtrip() {
        let mut m = MetricsRegistry::new();
        m.inc("a");
        m.add("a", 4);
        m.set_gauge("g", 2.5);
        m.observe("h", 3);
        m.observe("h", 300);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.counter("nope"), 0);
        assert_eq!(m.gauge("g"), Some(2.5));
        assert_eq!(m.hist("h").unwrap().count(), 2);
        assert!(!m.is_empty());
    }

    #[test]
    fn shard_merge_equals_sequential_recording() {
        // The same event stream recorded into 3 shards (round-robin) and
        // merged must equal one registry that saw everything.
        let mut seq = MetricsRegistry::new();
        let mut shards =
            vec![MetricsRegistry::new(), MetricsRegistry::new(), MetricsRegistry::new()];
        for i in 0..100u64 {
            let shard = &mut shards[(i % 3) as usize];
            seq.inc("events");
            shard.inc("events");
            seq.observe("lat", i * 17);
            shard.observe("lat", i * 17);
        }
        let mut merged = MetricsRegistry::new();
        // Merge in a scrambled order: totals must not care.
        for s in [&shards[2], &shards[0], &shards[1]] {
            merged.merge(s);
        }
        assert_eq!(merged.counter("events"), seq.counter("events"));
        assert_eq!(merged.hist("lat").unwrap(), seq.hist("lat").unwrap());
    }

    #[test]
    fn gauge_merge_is_last_wins() {
        let mut a = MetricsRegistry::new();
        let mut b = MetricsRegistry::new();
        a.set_gauge("g", 1.0);
        b.set_gauge("g", 2.0);
        a.merge(&b);
        assert_eq!(a.gauge("g"), Some(2.0));
    }
}
