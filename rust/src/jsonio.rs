//! Minimal JSON reader/writer (offline environment: no serde).
//!
//! Covers the full JSON grammar minus exotic escapes; used to consume
//! `artifacts/manifest.json` and to emit experiment metadata.  Also hosts
//! [`JsonlAppender`], the resumable-JSONL primitive shared by the campaign
//! result store and the conformance store.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write as _};
use std::path::Path;

use anyhow::Context;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b" \t\r\n".contains(b))
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        while self.peek().is_some_and(|b| {
            b.is_ascii_digit() || b"+-.eE".contains(&b)
        }) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(JsonError { offset: start, message: "bad number".into() })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            // \uXXXX (BMP only; enough for manifests).
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .and_then(char::from_u32);
                            match hex {
                                Some(ch) => {
                                    out.push(ch);
                                    self.pos += 4;
                                }
                                None => return self.err("bad \\u escape"),
                            }
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(b) => {
                    // Copy raw UTF-8 bytes through.
                    out.push(b as char);
                    if b < 0x80 {
                        self.pos += 1;
                    } else {
                        // Multibyte: decode properly.
                        out.pop();
                        let s = std::str::from_utf8(&self.bytes[self.pos..])
                            .map_err(|_| JsonError {
                                offset: self.pos,
                                message: "bad utf-8".into(),
                            })?;
                        let ch = s.chars().next().unwrap();
                        out.push(ch);
                        self.pos += ch.len_utf8();
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a value (compact).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v);
    out
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for ch in s.chars() {
                match ch {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, &Value::Str(k.clone()));
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

/// Integrity verdict of [`check_record`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecordCheck {
    /// Has a `crc` field and it matches the record body.
    Clean,
    /// No `crc` field — a record written before seals existed.  Accepted:
    /// pinned goldens and old stores must keep loading.
    Legacy,
    /// Has a `crc` field that does not match: interior corruption.
    Corrupt,
}

/// Serialize `obj` with a `crc` seal field: CRC-32 (hex, 8 digits) over
/// the canonical serialization of the object *without* the seal.  Because
/// [`to_string`]∘[`parse`] is a fixed point on our own output, the seal
/// re-verifies byte-identically after any number of reload cycles.
pub fn seal_record(mut obj: BTreeMap<String, Value>) -> String {
    obj.remove("crc");
    let body = to_string(&Value::Obj(obj.clone()));
    let crc = crate::util::crc32(body.as_bytes());
    obj.insert("crc".into(), Value::Str(format!("{crc:08x}")));
    to_string(&Value::Obj(obj))
}

/// Verify the `crc` seal of a parsed record (see [`seal_record`]).
pub fn check_record(v: &Value) -> RecordCheck {
    let Value::Obj(map) = v else {
        return RecordCheck::Legacy;
    };
    let Some(Value::Str(stored)) = map.get("crc") else {
        return RecordCheck::Legacy;
    };
    let Ok(stored) = u32::from_str_radix(stored, 16) else {
        return RecordCheck::Corrupt;
    };
    let mut body = map.clone();
    body.remove("crc");
    if crate::util::crc32(to_string(&Value::Obj(body)).as_bytes()) == stored {
        RecordCheck::Clean
    } else {
        RecordCheck::Corrupt
    }
}

/// The crash-consistent half of a resumable JSONL store: open (optionally
/// truncating), replay existing lines through a caller-supplied parser,
/// repair a torn final line, and append flushed lines.
///
/// Contract shared by `campaign::store::Store` and
/// `validate::store::ConformanceStore`:
/// * every append is one line, flushed before the call returns, so an
///   interrupt loses at most the line in flight;
/// * an unparseable line during replay (the torn tail of an interrupted
///   write) is counted in [`JsonlAppender::skipped_lines`], not an error;
/// * if the file does not end in `\n`, a newline is appended on open so
///   the next record starts on a fresh line;
/// * duplicate-key semantics (last-wins) belong to the caller's replay
///   callback — this type only sees lines.
pub struct JsonlAppender {
    file: File,
    /// Unparseable lines skipped during replay.
    pub skipped_lines: usize,
}

impl JsonlAppender {
    /// Open `path` (creating parent directories and the file as needed).
    /// With `truncate`, existing content is discarded; otherwise every
    /// non-empty existing line is passed to `on_line`, which returns
    /// whether it parsed (false ⇒ counted as skipped).
    pub fn open(
        path: &Path,
        truncate: bool,
        mut on_line: impl FnMut(&str) -> bool,
    ) -> anyhow::Result<JsonlAppender> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let mut skipped_lines = 0;
        if !truncate && path.exists() {
            let reader = BufReader::new(
                File::open(path)
                    .with_context(|| format!("opening {}", path.display()))?,
            );
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                if !on_line(&line) {
                    skipped_lines += 1;
                }
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .append(!truncate)
            .write(true)
            .truncate(truncate)
            .open(path)
            .with_context(|| format!("opening {} for append", path.display()))?;
        // Repair a torn tail: if the last line was cut before its newline,
        // terminate it so the next append starts on a fresh line.
        if !truncate {
            let len = file.metadata()?.len();
            if len > 0 {
                let mut last = [0u8; 1];
                let mut probe = File::open(path)?;
                std::io::Seek::seek(&mut probe, std::io::SeekFrom::End(-1))?;
                std::io::Read::read_exact(&mut probe, &mut last)?;
                if last[0] != b'\n' {
                    file.write_all(b"\n")?;
                    file.flush()?;
                }
            }
        }
        Ok(JsonlAppender { file, skipped_lines })
    }

    /// Append one serialized record (the newline is added here) and flush
    /// it to disk before returning.
    ///
    /// Fail point `jsonl.tail`: `mode=torn` flushes a deterministic
    /// partial prefix of the line (no newline) before erroring — exactly
    /// the torn tail an interrupt mid-`write` leaves behind; `transient`
    /// errors without writing; `kill` tears then aborts the process.
    pub fn append_line(&mut self, line: &str) -> anyhow::Result<()> {
        use crate::resilience::failpoint::{self, Mode, Site};
        if let Some(inj) = failpoint::check(Site::JsonlTail) {
            match inj.mode {
                Mode::Torn => {
                    self.tear(line, inj.hit)?;
                    return Err(inj.to_error());
                }
                Mode::Kill => {
                    self.tear(line, inj.hit)?;
                    failpoint::kill_now(&inj);
                }
                _ => inj.trigger()?,
            }
        }
        self.file.write_all(line.as_bytes())?;
        self.file.write_all(b"\n")?;
        self.file.flush()?;
        Ok(())
    }

    /// Write a deterministic strict prefix of `line` (cut position derived
    /// from the injection hit count) with no trailing newline.
    fn tear(&mut self, line: &str, hit: u64) -> anyhow::Result<()> {
        if line.len() >= 2 {
            let cut = 1 + (hit as usize).wrapping_mul(7919) % (line.len() - 1);
            self.file.write_all(&line.as_bytes()[..cut])?;
            self.file.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
  "format": "hlo-text",
  "waste_grid": {"batch": 64, "grid": 512},
  "model": {"vocab": 256, "d_model": 128},
  "param_count": 475648,
  "flags": [true, false, null],
  "pi": 3.14
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        assert_eq!(
            v.get("waste_grid").unwrap().get("batch").unwrap().as_usize(),
            Some(64)
        );
        assert_eq!(v.get("param_count").unwrap().as_usize(), Some(475648));
        assert_eq!(v.get("pi").unwrap().as_f64(), Some(3.14));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let v = parse(text).unwrap();
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'single': 1}").is_err());
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let v = parse(r#""café μ""#).unwrap();
        assert_eq!(v.as_str(), Some("café μ"));
    }

    #[test]
    fn seal_and_check_record() {
        let mut obj = BTreeMap::new();
        obj.insert("hash".to_string(), Value::Str("00ab".into()));
        obj.insert("waste".to_string(), Value::Num(0.125));
        let line = seal_record(obj.clone());
        let v = parse(&line).unwrap();
        assert_eq!(check_record(&v), RecordCheck::Clean);
        // Sealing is stable across a reload cycle: parse → re-seal → same line.
        let Value::Obj(m) = v.clone() else { unreachable!() };
        assert_eq!(seal_record(m), line);
        // A record without a seal is legacy, not corrupt.
        assert_eq!(check_record(&Value::Obj(obj)), RecordCheck::Legacy);
        // Any body mutation breaks the seal.
        let tampered = line.replace("0.125", "0.126");
        assert_eq!(check_record(&parse(&tampered).unwrap()), RecordCheck::Corrupt);
        // A mangled crc field is corrupt too.
        let v = parse(&line.replace("\"crc\":\"", "\"crc\":\"zz")).unwrap();
        assert_eq!(check_record(&v), RecordCheck::Corrupt);
        // Non-objects can't carry a seal.
        assert_eq!(check_record(&Value::Num(1.0)), RecordCheck::Legacy);
    }

    #[test]
    fn jsonl_appender_replays_and_repairs_torn_tail() {
        let path = std::env::temp_dir().join(format!(
            "ckptwin-jsonl-appender-{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        {
            let mut f = JsonlAppender::open(&path, true, |_| true).unwrap();
            f.append_line(r#"{"a":1}"#).unwrap();
            f.append_line(r#"{"a":2}"#).unwrap();
        }
        // Tear the file mid-record.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"a\":3");
        std::fs::write(&path, &text).unwrap();
        let mut lines = Vec::new();
        let mut f = JsonlAppender::open(&path, false, |l| {
            let ok = parse(l).is_ok();
            if ok {
                lines.push(l.to_string());
            }
            ok
        })
        .unwrap();
        assert_eq!(lines, [r#"{"a":1}"#, r#"{"a":2}"#]);
        assert_eq!(f.skipped_lines, 1);
        // The torn tail was newline-terminated, so this append starts
        // cleanly on its own line.
        f.append_line(r#"{"a":4}"#).unwrap();
        drop(f);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with("{\"a\":3\n{\"a\":4}\n"), "{text}");
        // Truncating open discards everything.
        let f = JsonlAppender::open(&path, true, |_| panic!("no replay")).unwrap();
        assert_eq!(f.skipped_lines, 0);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "");
        let _ = std::fs::remove_file(&path);
    }
}
