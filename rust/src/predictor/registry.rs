//! Data-driven predictor registry: stable string names + parameter maps —
//! the predictor-axis mirror of [`crate::strategy::registry`].
//!
//! The predictor axis is **open**: every predictor the stack can simulate —
//! campaign grids, the conformance sweeps, the `ckptwin` CLI — is a row in
//! this registry, addressed by a [`PredictorId`] (a registered name plus a
//! fully materialized parameter map).  Adding a predictor means adding a
//! [`crate::predictor::model::PredictorModel`] implementation (behaviour),
//! a [`crate::config::PredModel`] variant (dispatch + closed-form
//! properties + `validate::domain` classification), and one registry row
//! here; no campaign, harness or CLI edits.
//!
//! Identifier grammar (round-trips through [`PredictorId`]'s `FromStr` /
//! `Display` pair — the same grammar as strategy identifiers):
//!
//! ```text
//!   a                         the paper's predictor A (canonical name)
//!   paper-b                   aliases parse case-insensitively
//!   biased(beta=2)            parameters as key=value, ';' separated
//!   mixedwin(i1=300;i2=1200;w=0.5)
//! ```
//!
//! A [`PredictorId`] plus a window-axis value materializes into a
//! [`PredictorSpec`] ([`PredictorId::spec`]); the spec — not the id — is
//! what campaign cells carry, so store keys stay derived from the
//! predictor's *parameters* (`p=…;r=…;I=…`, plus a `pm=<model>` suffix
//! for non-paper models) and existing paper-predictor keys are
//! byte-identical to their pre-registry form.
//!
//! Registered predictors:
//!
//! | name | model | notes |
//! |------|-------|-------|
//! | `a` | paper | Yu et al. 2011: p = 0.82, r = 0.85 |
//! | `b` | paper | Zheng et al. 2010: p = 0.4, r = 0.7 |
//! | `paper(r;p)` | paper | the §2.2 predictor with explicit r/p |
//! | `biased(beta;r;p)` | non-uniform placement | E_I^f = I·β/(β+1), closed forms stay valid |
//! | `mixedwin(i1;i2;w;r;p)` | two window classes | breaks fixed-I ⇒ classified `non_uniform_window` |
//! | `jitter(sigma;r;p)` | noisy placement | faults can escape ⇒ `noisy_window_placement` |
//! | `classed(p_hi;p_lo;frac;r)` | confidence classes | trust weights pair with `QTrust` ⇒ `confidence_classes` |

use std::fmt;
use std::str::FromStr;

use crate::config::{PredModel, PredictorSpec};
use crate::strategy::registry::ParamDef;

/// One registry row: everything the stack needs to name, parse, describe
/// and materialize a predictor.
pub struct PredictorDef {
    /// Canonical display name.
    pub name: &'static str,
    /// Lowercase aliases accepted by the parser.
    pub aliases: &'static [&'static str],
    /// One-line description for `ckptwin predictors`.
    pub summary: &'static str,
    /// Accepted parameters (empty for the fixed paper predictors).
    pub params: &'static [ParamDef],
    spec: fn(&PredictorId, f64) -> PredictorSpec,
}

const P_R: ParamDef = ParamDef { key: "r", default: 0.85, min: 0.0, max: 1.0 };
const P_P: ParamDef = ParamDef { key: "p", default: 0.82, min: 0.0, max: 1.0 };
const P_BETA: ParamDef =
    ParamDef { key: "beta", default: 2.0, min: 0.05, max: 20.0 };
const P_I1: ParamDef =
    ParamDef { key: "i1", default: 300.0, min: 1.0, max: 1e7 };
const P_I2: ParamDef =
    ParamDef { key: "i2", default: 1200.0, min: 1.0, max: 1e7 };
const P_W: ParamDef = ParamDef { key: "w", default: 0.5, min: 0.0, max: 1.0 };
const P_SIGMA: ParamDef =
    ParamDef { key: "sigma", default: 120.0, min: 0.0, max: 1e6 };
const P_PHI: ParamDef =
    ParamDef { key: "p_hi", default: 0.95, min: 0.01, max: 1.0 };
const P_PLO: ParamDef =
    ParamDef { key: "p_lo", default: 0.6, min: 0.01, max: 1.0 };
const P_FRAC: ParamDef =
    ParamDef { key: "frac", default: 0.5, min: 0.0, max: 1.0 };

fn spec_a(_: &PredictorId, window: f64) -> PredictorSpec {
    PredictorSpec::paper_a(window)
}
fn spec_b(_: &PredictorId, window: f64) -> PredictorSpec {
    PredictorSpec::paper_b(window)
}
fn spec_paper(id: &PredictorId, window: f64) -> PredictorSpec {
    PredictorSpec::paper(id.param("r"), id.param("p"), window)
}
fn spec_biased(id: &PredictorId, window: f64) -> PredictorSpec {
    PredictorSpec {
        recall: id.param("r"),
        precision: id.param("p"),
        window,
        model: PredModel::Biased { beta: id.param("beta") },
    }
}
fn spec_mixedwin(id: &PredictorId, window: f64) -> PredictorSpec {
    PredictorSpec {
        recall: id.param("r"),
        precision: id.param("p"),
        window,
        model: PredModel::MixedWindow {
            i1: id.param("i1"),
            i2: id.param("i2"),
            w: id.param("w"),
        },
    }
}
fn spec_jitter(id: &PredictorId, window: f64) -> PredictorSpec {
    PredictorSpec {
        recall: id.param("r"),
        precision: id.param("p"),
        window,
        model: PredModel::Jitter { sigma: id.param("sigma") },
    }
}
fn spec_classed(id: &PredictorId, window: f64) -> PredictorSpec {
    let (p_hi, p_lo, frac) =
        (id.param("p_hi"), id.param("p_lo"), id.param("frac"));
    PredictorSpec {
        recall: id.param("r"),
        // Overall precision is implied by the class mix.
        precision: frac * p_hi + (1.0 - frac) * p_lo,
        window,
        model: PredModel::Classed { p_hi, p_lo, frac },
    }
}

/// The registry itself.  Order is presentation order (`ckptwin
/// predictors`); lookups are by name/alias, never by index.
static DEFS: &[PredictorDef] = &[
    PredictorDef {
        name: "a",
        aliases: &["paper-a", "yu11"],
        summary: "paper predictor A [Yu'11]: p=0.82 r=0.85, uniform fixed-I",
        params: &[],
        spec: spec_a,
    },
    PredictorDef {
        name: "b",
        aliases: &["paper-b", "zheng10"],
        summary: "paper predictor B [Zheng'10]: p=0.4 r=0.7, uniform fixed-I",
        params: &[],
        spec: spec_b,
    },
    PredictorDef {
        name: "paper",
        aliases: &["uniform"],
        summary: "the S2.2 uniform fixed-I predictor with explicit r/p",
        params: &[P_R, P_P],
        spec: spec_paper,
    },
    PredictorDef {
        name: "biased",
        aliases: &["beta-placed"],
        summary: "non-uniform in-window placement: E_I^f = I*beta/(beta+1)",
        params: &[P_BETA, P_R, P_P],
        spec: spec_biased,
    },
    PredictorDef {
        name: "mixedwin",
        aliases: &["mixed-window", "mixed"],
        summary: "two-class window sizes: i1 with prob w, else i2",
        params: &[P_I1, P_I2, P_W, P_R, P_P],
        spec: spec_mixedwin,
    },
    PredictorDef {
        name: "jitter",
        aliases: &["noisy-lead"],
        summary: "window placement jittered by clamped Gaussian sigma noise",
        params: &[P_SIGMA, P_R, P_P],
        spec: spec_jitter,
    },
    PredictorDef {
        name: "classed",
        aliases: &["confidence", "two-class"],
        summary: "hi/lo confidence classes; lo trust weight pairs with QTrust",
        params: &[P_PHI, P_PLO, P_FRAC, P_R],
        spec: spec_classed,
    },
];

fn find_def(token: &str) -> Option<&'static PredictorDef> {
    let lower = token.to_ascii_lowercase();
    DEFS.iter().find(|d| {
        d.name.eq_ignore_ascii_case(token) || d.aliases.contains(&lower.as_str())
    })
}

/// A parsed predictor identifier: registered name + fully materialized
/// parameter values (defaults filled in at parse time, so two identifiers
/// naming the same predictor compare and display identically).
#[derive(Clone, Debug, PartialEq)]
pub struct PredictorId {
    name: &'static str,
    /// `(key, value)` in the registry's declaration order.
    params: Vec<(&'static str, f64)>,
}

impl PredictorId {
    /// The predictor registered under `def`, with default parameters.
    pub fn with_defaults(def: &'static PredictorDef) -> PredictorId {
        PredictorId {
            name: def.name,
            params: def.params.iter().map(|p| (p.key, p.default)).collect(),
        }
    }

    /// Parse an identifier: `name` or `name(k=v;k2=v2)` (',' also accepted
    /// as a parameter separator).  See the module docs for the grammar.
    pub fn parse(s: &str) -> Result<PredictorId, String> {
        Ok(Self::parse_with_explicit(s)?.0)
    }

    /// [`PredictorId::parse`] that also reports which parameter keys the
    /// identifier *explicitly* supplied (canonical key names, in supply
    /// order).  Config files use this to reject r/p written inside a
    /// `model = "…"` string — the file's explicit recall/precision keys
    /// are the only source there — without re-implementing the grammar.
    pub fn parse_with_explicit(
        s: &str,
    ) -> Result<(PredictorId, Vec<&'static str>), String> {
        let s = s.trim();
        let (base, args) = match s.split_once('(') {
            None => (s, None),
            Some((base, rest)) => {
                let inner = rest.strip_suffix(')').ok_or_else(|| {
                    format!("predictor '{s}': missing closing ')'")
                })?;
                (base.trim(), Some(inner))
            }
        };
        let def = find_def(base).ok_or_else(|| {
            format!(
                "unknown predictor '{base}' (known: {})",
                DEFS.iter().map(|d| d.name).collect::<Vec<_>>().join(", ")
            )
        })?;
        let mut id = PredictorId::with_defaults(def);
        let mut explicit = Vec::new();
        if let Some(args) = args {
            for kv in args.split([';', ',']).map(str::trim).filter(|t| !t.is_empty()) {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    format!("{}: expected key=value, got '{kv}'", def.name)
                })?;
                let v: f64 = v.trim().parse().map_err(|_| {
                    format!("{}: parameter '{kv}' is not a number", def.name)
                })?;
                explicit.push(id.set_param(def, k.trim(), v)?);
            }
        }
        id.check_cross_params()?;
        Ok((id, explicit))
    }

    /// Cross-parameter constraints the per-parameter ranges cannot
    /// express.  Checked after parse and after every `with_param`, so an
    /// invalid combination errors loudly instead of degenerating silently.
    fn check_cross_params(&self) -> Result<(), String> {
        if self.name == "classed" {
            let (p_hi, p_lo) = (self.param("p_hi"), self.param("p_lo"));
            if p_lo > p_hi {
                return Err(format!(
                    "classed: p_lo = {p_lo} must not exceed p_hi = {p_hi} \
                     (the high class is the more precise one; swap them)"
                ));
            }
        }
        Ok(())
    }

    /// Set a declared parameter; returns the canonical key that was set.
    fn set_param(
        &mut self,
        def: &'static PredictorDef,
        key: &str,
        val: f64,
    ) -> Result<&'static str, String> {
        let pd = def
            .params
            .iter()
            .find(|p| p.key.eq_ignore_ascii_case(key))
            .ok_or_else(|| {
                format!("{}: unknown parameter '{key}'", def.name)
            })?;
        if !val.is_finite() || !(pd.min..=pd.max).contains(&val) {
            return Err(format!(
                "{}: {} = {val} outside [{}, {}]",
                def.name, pd.key, pd.min, pd.max
            ));
        }
        for slot in &mut self.params {
            if slot.0 == pd.key {
                slot.1 = val;
            }
        }
        Ok(pd.key)
    }

    /// A copy with `key` set to `val` (validated against the registry).
    pub fn with_param(mut self, key: &str, val: f64) -> Result<PredictorId, String> {
        let def = self.def();
        self.set_param(def, key, val)?;
        self.check_cross_params()?;
        Ok(self)
    }

    fn def(&self) -> &'static PredictorDef {
        DEFS.iter()
            .find(|d| d.name == self.name)
            .expect("PredictorId only constructed from registry rows")
    }

    /// Canonical registered name (`"a"`, `"biased"`, …).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Does this predictor's registry row declare parameter `key`?
    pub fn has_param(&self, key: &str) -> bool {
        self.params.iter().any(|(k, _)| *k == key)
    }

    /// The value of a declared parameter.  Panics on undeclared keys —
    /// construction guarantees every declared parameter is present.
    pub fn param(&self, key: &str) -> f64 {
        self.params
            .iter()
            .find(|(k, _)| *k == key)
            .unwrap_or_else(|| panic!("{}: no parameter '{key}'", self.name))
            .1
    }

    /// One-line description (for `ckptwin predictors`).
    pub fn summary(&self) -> &'static str {
        self.def().summary
    }

    /// Materialize the spec this predictor announces at window-axis value
    /// `window` (the mixed-window model draws its own sizes and keeps
    /// `window` only as the axis label).
    pub fn spec(&self, window: f64) -> PredictorSpec {
        (self.def().spec)(self, window)
    }
}

impl fmt::Display for PredictorId {
    /// Canonical form: registered name, every parameter materialized.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name)?;
        if !self.params.is_empty() {
            f.write_str("(")?;
            for (i, (k, v)) in self.params.iter().enumerate() {
                if i > 0 {
                    f.write_str(";")?;
                }
                write!(f, "{k}={v}")?;
            }
            f.write_str(")")?;
        }
        Ok(())
    }
}

impl FromStr for PredictorId {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        PredictorId::parse(s)
    }
}

/// Look up a predictor by canonical name or alias, with default parameters.
pub fn get(name: &str) -> Option<PredictorId> {
    find_def(name).map(PredictorId::with_defaults)
}

/// The paper's two reference predictors (the pre-registry campaign axis).
pub fn paper_pair() -> Vec<PredictorId> {
    vec![get("a").expect("registered"), get("b").expect("registered")]
}

/// Every registered predictor with default parameters, in registry order.
/// The generic invariant and conformance suites iterate this, so new
/// registrations get coverage for free.
pub fn all_defaults() -> Vec<PredictorId> {
    DEFS.iter().map(PredictorId::with_defaults).collect()
}

/// The registry rows themselves (for `ckptwin predictors` and docs).
pub fn catalog() -> impl Iterator<Item = &'static PredictorDef> {
    DEFS.iter()
}

/// Parse a comma-separated predictor list, paren-aware: commas inside a
/// `name(k=v,…)` parameter list do not split entries.  Used by the CLI's
/// `--predictors` axis (same splitter as `--strategies`).
pub fn parse_predictor_list(raw: &str) -> Result<Vec<PredictorId>, String> {
    let mut out = Vec::new();
    for tok in crate::util::split_top_level(raw) {
        let tok = tok.trim();
        if !tok.is_empty() {
            out.push(PredictorId::parse(tok)?);
        }
    }
    if out.is_empty() {
        return Err("empty predictor list".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_round_trips_for_every_registered_predictor() {
        for id in all_defaults() {
            let label = id.to_string();
            let back: PredictorId = label.parse().unwrap_or_else(|e| {
                panic!("'{label}' failed to re-parse: {e}")
            });
            assert_eq!(back, id, "round trip of '{label}'");
            assert_eq!(back.to_string(), label);
        }
    }

    #[test]
    fn non_default_params_round_trip() {
        for raw in [
            "biased(beta=3;r=0.7;p=0.4)",
            "mixedwin(i1=150;i2=2400;w=0.3;r=0.85;p=0.82)",
            "jitter(sigma=300;r=0.85;p=0.82)",
        ] {
            let id = PredictorId::parse(raw).unwrap();
            assert_eq!(id.to_string(), raw);
            assert_eq!(PredictorId::parse(&id.to_string()).unwrap(), id);
        }
        // ',' is accepted as a parameter separator on input.
        assert_eq!(
            PredictorId::parse("biased(beta=3)").unwrap(),
            PredictorId::parse("Biased(beta=3,)").unwrap()
        );
        // parse_with_explicit reports exactly the supplied keys
        // (canonical names), defaults stay implicit.
        let (id, explicit) =
            PredictorId::parse_with_explicit("biased(beta=3;R=0.7)").unwrap();
        assert_eq!(explicit, vec!["beta", "r"]);
        assert_eq!(id.param("p"), 0.82);
        assert!(PredictorId::parse_with_explicit("a").unwrap().1.is_empty());
    }

    #[test]
    fn aliases_and_errors() {
        for (alias, canonical) in [
            ("A", "a"),
            ("paper-b", "b"),
            ("yu11", "a"),
            ("uniform", "paper"),
            ("mixed", "mixedwin"),
            ("noisy-lead", "jitter"),
            ("confidence", "classed"),
        ] {
            assert_eq!(PredictorId::parse(alias).unwrap().name(), canonical);
        }
        assert!(PredictorId::parse("nope").is_err());
        assert!(PredictorId::parse("biased(beta=0)").is_err()); // below min
        assert!(PredictorId::parse("biased(frob=1)").is_err());
        assert!(PredictorId::parse("biased(beta=2").is_err()); // missing ')'
        assert!(PredictorId::parse("a(r=0.5)").is_err()); // no params
        assert!(PredictorId::parse("jitter(sigma=nan)").is_err());
        // Cross-parameter constraint: an inverted class pair would
        // silently degenerate to the paper predictor — reject it instead,
        // on parse and on with_param alike.
        assert!(PredictorId::parse("classed(p_hi=0.3;p_lo=0.9)").is_err());
        assert!(get("classed").unwrap().with_param("p_lo", 0.99).is_err());
        assert!(get("classed").unwrap().with_param("p_lo", 0.9).is_ok());
    }

    #[test]
    fn specs_materialize_correctly() {
        let a = get("a").unwrap().spec(600.0);
        assert_eq!(a, PredictorSpec::paper_a(600.0));
        let b = get("b").unwrap().spec(900.0);
        assert_eq!(b, PredictorSpec::paper_b(900.0));
        // Generic paper row with defaults == predictor A numbers.
        assert_eq!(get("paper").unwrap().spec(600.0), a);

        let biased = PredictorId::parse("biased(beta=2)").unwrap().spec(600.0);
        assert_eq!(biased.model, PredModel::Biased { beta: 2.0 });
        assert!((biased.e_if() - 400.0).abs() < 1e-12);

        let mixed = get("mixedwin").unwrap().spec(600.0);
        assert_eq!(
            mixed.model,
            PredModel::MixedWindow { i1: 300.0, i2: 1200.0, w: 0.5 }
        );
        assert_eq!(mixed.max_window(), 1200.0);

        // Classed: overall precision implied by the class mix.
        let classed = get("classed").unwrap().spec(600.0);
        assert!((classed.precision - (0.5 * 0.95 + 0.5 * 0.6)).abs() < 1e-12);
        assert_eq!(
            classed.model,
            PredModel::Classed { p_hi: 0.95, p_lo: 0.6, frac: 0.5 }
        );
    }

    #[test]
    fn predictor_list_parsing_is_paren_aware() {
        let ids = parse_predictor_list(
            "a, biased(beta=2,r=0.7) ,mixedwin(i1=300,i2=1200,w=0.5)",
        )
        .unwrap();
        assert_eq!(ids.len(), 3);
        assert_eq!(ids[0].name(), "a");
        assert_eq!(ids[1].param("beta"), 2.0);
        assert_eq!(ids[1].param("r"), 0.7);
        assert_eq!(ids[2].param("i2"), 1200.0);
        assert!(parse_predictor_list("").is_err());
        assert!(parse_predictor_list("a,,b").is_ok());
        assert!(parse_predictor_list("a,bogus").is_err());
    }

    #[test]
    fn paper_pair_matches_the_old_axis() {
        let pair = paper_pair();
        assert_eq!(pair.len(), 2);
        assert_eq!(pair[0].spec(600.0), PredictorSpec::paper_a(600.0));
        assert_eq!(pair[1].spec(600.0), PredictorSpec::paper_b(600.0));
    }
}
