//! Fault-predictor simulation: the prediction-model trait ([`model`]), the
//! data-driven predictor [`registry`], and the *online* feed for the
//! coordinator and log-replay paths.
//!
//! The trace module (`sim::trace`) generates merged event streams for the
//! discrete-event simulator.  The coordinator and `ckptwin replay`, by
//! contrast, run against a known fault schedule and need the predictor as
//! an online component: given the (secret) schedule of injected faults,
//! emit the prediction feed the application would observe — true
//! predictions for a `recall` fraction of faults (windows placed by the
//! spec's [`crate::config::PredModel`]), plus false predictions at rate
//! `1/μ_false`, each announced `C_p` (lead time) before its window opens.
//!
//! [`feed`] and the trace streams share one substream implementation
//! (`sim::trace::pred_gens` — same RNG stream ids, same model behaviour,
//! same §2.2 before-t = 0 drop), so for identical (fault schedule, seed)
//! pairs the online feed and the offline trace emit **bit-identical**
//! announcement sequences (`tests/predictor_models.rs` pins this; the
//! historical implementation used a private RNG wiring and could drift).
//!
//! Table 6 presets from the paper's related-work survey are provided for
//! the predictor-sweep example.

pub mod model;
pub mod registry;

pub use registry::PredictorId;

use crate::config::PredictorSpec;
use crate::sim::distribution::Law;
use crate::sim::trace::{pred_gens, Event, Prediction};

/// One announced prediction, in simulated seconds — exactly the trace
/// layer's [`Prediction`] (one type, one code path; the old standalone
/// `Announcement` struct was a field-for-field duplicate).
pub type Announcement = Prediction;

/// Generate the prediction feed for a known fault schedule on `[0, horizon)`.
///
/// Returns announcements sorted by `notify_t`.  Predicted faults whose
/// notification would fall before t = 0 are silently dropped (equivalently
/// reclassified as unpredicted, §2.2).
///
/// Runs on the same substream generators as the trace streams
/// (`sim::trace::pred_gens`), so the announcements are bit-identical to
/// the prediction events a [`crate::sim::trace::TraceStream`] with the
/// same seed produces for the same fault arrivals.
pub fn feed(
    faults: &[f64],
    spec: &PredictorSpec,
    cp: f64,
    mu: f64,
    false_pred_law: Law,
    horizon: f64,
    seed: u64,
) -> Vec<Announcement> {
    let (mut fault_gen, mut fp_gen) =
        pred_gens(spec, cp, mu, false_pred_law, seed);
    let mut out = Vec::new();
    for &tf in faults {
        if let (_, Some(Event::Prediction(p))) = fault_gen.events(tf) {
            out.push(p);
        }
    }
    let mut last_raw = 0.0;
    loop {
        let ev = fp_gen.next(&mut last_raw);
        if last_raw >= horizon {
            break;
        }
        if let Some(Event::Prediction(p)) = ev {
            out.push(p);
        }
    }
    out.sort_by(|a, b| a.notify_t.total_cmp(&b.notify_t));
    out
}

/// For each fault (in input order), is it inside some true-positive window
/// of the feed?
///
/// Complexity: O(F log F + W log W) — true-positive windows are sorted
/// once and swept with a two-pointer scan over the sorted faults.  Window
/// lengths within one feed may vary (the mixed-window model), so the left
/// pointer retires a window only once it is out of reach of the *longest*
/// window length.  Shared by [`score`] and the log-replay trace
/// synthesizer ([`crate::sim::tracefile::LogTrace`]), which used to
/// rescan quadratically.
pub fn covered(faults: &[f64], feed: &[Announcement]) -> Vec<bool> {
    let mut wins: Vec<(f64, f64)> = feed
        .iter()
        .filter(|a| a.true_positive)
        .map(|a| (a.window_start, a.window_end))
        .collect();
    wins.sort_by(|a, b| a.0.total_cmp(&b.0));
    let max_len = wins.iter().map(|w| w.1 - w.0).fold(0.0, f64::max);
    let mut order: Vec<usize> = (0..faults.len()).collect();
    order.sort_by(|&a, &b| faults[a].total_cmp(&faults[b]));

    let mut out = vec![false; faults.len()];
    let mut lo = 0usize;
    for &fi in &order {
        let tf = faults[fi];
        while lo < wins.len() && wins[lo].0 < tf - max_len {
            lo += 1;
        }
        let mut j = lo;
        while j < wins.len() && wins[j].0 <= tf {
            if wins[j].1 >= tf {
                out[fi] = true;
                break;
            }
            j += 1;
        }
    }
    out
}

/// Score a feed against the fault schedule: measured (recall, precision).
///
/// **Convention:** an empty feed scores precision 0.0, not NaN — a
/// predictor that announces nothing has no correct announcements, and the
/// 0.0 keeps sweep aggregations (means over scored feeds) NaN-free.
/// Symmetrically, an empty fault schedule scores recall 0.0.
///
/// Because §2.2 reclassifies pre-t = 0 announcements as unpredicted (they
/// are dropped from the feed), the measured recall of a short schedule
/// sits *below* the nominal r — the early faults' windows were never
/// announced, so nothing covers them.  Models whose windows can miss
/// their fault (`jitter`) depress it further; both effects are the
/// predictor's *effective* quality, which is exactly what this measures.
pub fn score(faults: &[f64], feed: &[Announcement]) -> (f64, f64) {
    if feed.is_empty() {
        return (0.0, 0.0);
    }
    let true_pos = feed.iter().filter(|a| a.true_positive).count();
    let precision = true_pos as f64 / feed.len() as f64;
    let n_covered = covered(faults, feed).into_iter().filter(|&c| c).count();
    (n_covered as f64 / faults.len().max(1) as f64, precision)
}

/// Predictor characteristics surveyed in the paper's Table 6.
/// (lead time, precision, recall, window size if known — windows the
/// sources left unspecified are represented with the paper's test sizes.)
pub fn table6_presets() -> Vec<(&'static str, PredictorSpec)> {
    vec![
        ("Zheng'10-300s", PredictorSpec::paper(0.70, 0.40, 300.0)),
        ("Zheng'10-600s", PredictorSpec::paper(0.60, 0.35, 600.0)),
        ("Yu'11-accurate", PredictorSpec::paper(0.852, 0.823, 600.0)),
        ("Yu'11-period", PredictorSpec::paper(0.652, 0.648, 600.0)),
        ("Gainaru'12", PredictorSpec::paper(0.43, 0.93, 300.0)),
        ("Fulp'08", PredictorSpec::paper(0.75, 0.70, 600.0)),
        ("Liang'07-1h", PredictorSpec::paper(0.30, 0.20, 3600.0)),
        ("Liang'07-6h", PredictorSpec::paper(0.90, 0.40, 21_600.0)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::distribution::Distribution;
    use crate::sim::rng::Rng;

    fn spec() -> PredictorSpec {
        PredictorSpec::paper(0.85, 0.82, 600.0)
    }

    fn fault_schedule(n: usize, mean: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let d = Distribution::new(Law::Exponential, mean);
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += d.sample(&mut rng);
                t
            })
            .collect()
    }

    #[test]
    fn feed_sorted_and_windows_well_formed() {
        let faults = fault_schedule(500, 1000.0, 1);
        let horizon = faults.last().unwrap() + 1000.0;
        let f = feed(&faults, &spec(), 60.0, 1000.0, Law::Exponential, horizon, 2);
        assert!(!f.is_empty());
        for w in f.windows(2) {
            assert!(w[0].notify_t <= w[1].notify_t);
        }
        for a in &f {
            assert!((a.window_end - a.window_start - 600.0).abs() < 1e-9);
            assert!((a.window_start - a.notify_t - 60.0).abs() < 1e-9);
            assert_eq!(a.weight, 1.0, "paper predictor is single-class");
        }
    }

    #[test]
    fn measured_recall_precision_near_spec() {
        let faults = fault_schedule(4000, 5000.0, 3);
        let horizon = faults.last().unwrap() + 1000.0;
        let f = feed(&faults, &spec(), 60.0, 5000.0, Law::Exponential, horizon, 4);
        let (recall, precision) = score(&faults, &f);
        assert!((recall - 0.85).abs() < 0.05, "recall {recall}");
        assert!((precision - 0.82).abs() < 0.05, "precision {precision}");
    }

    #[test]
    fn perfect_predictor_yields_no_false_positives() {
        let faults = fault_schedule(100, 1000.0, 5);
        let horizon = faults.last().unwrap() + 1000.0;
        let mut s = spec();
        s.precision = 1.0;
        s.recall = 1.0;
        let f = feed(&faults, &s, 60.0, 1000.0, Law::Exponential, horizon, 6);
        assert!(f.iter().all(|a| a.true_positive));
    }

    #[test]
    fn empty_feed_scores_zero_not_nan() {
        let faults = [100.0, 200.0];
        let (recall, precision) = score(&faults, &[]);
        assert_eq!(recall, 0.0);
        assert_eq!(precision, 0.0);
        // Empty fault schedule: recall 0 by the same convention.
        let f = vec![Announcement {
            notify_t: 0.0,
            window_start: 10.0,
            window_end: 20.0,
            true_positive: false,
            weight: 1.0,
        }];
        let (recall, precision) = score(&[], &f);
        assert_eq!(recall, 0.0);
        assert_eq!(precision, 0.0);
    }

    #[test]
    fn two_pointer_matches_brute_force() {
        let faults = fault_schedule(800, 700.0, 11);
        let horizon = faults.last().unwrap() + 1000.0;
        let f = feed(&faults, &spec(), 60.0, 700.0, Law::Exponential, horizon, 12);
        let (recall, precision) = score(&faults, &f);
        // Reference: the original quadratic scan.
        let covered = faults
            .iter()
            .filter(|&&tf| {
                f.iter().any(|a| {
                    a.true_positive && tf >= a.window_start && tf <= a.window_end
                })
            })
            .count();
        let tp = f.iter().filter(|a| a.true_positive).count();
        assert_eq!(recall, covered as f64 / faults.len() as f64);
        assert_eq!(precision, tp as f64 / f.len() as f64);
    }

    #[test]
    fn table6_presets_sane() {
        for (name, p) in table6_presets() {
            assert!(p.recall > 0.0 && p.recall <= 1.0, "{name}");
            assert!(p.precision > 0.0 && p.precision <= 1.0, "{name}");
            assert!(p.window > 0.0, "{name}");
        }
    }
}
