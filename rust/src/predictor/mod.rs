//! Fault-predictor simulation for the *online* coordinator.
//!
//! The trace module (`sim::trace`) generates merged event streams for the
//! discrete-event simulator.  The coordinator, by contrast, runs a real
//! workload in scaled wall-clock time and needs the predictor as an online
//! component: given the (secret) schedule of injected faults, emit the
//! prediction feed the application would observe — true predictions for a
//! `recall` fraction of faults (window placed so the fault is uniform
//! inside it), plus false predictions at rate `1/μ_false`, each announced
//! `C_p` (lead time) before its window opens.
//!
//! Table 6 presets from the paper's related-work survey are provided for
//! the predictor-sweep example.

use crate::config::PredictorSpec;
use crate::sim::distribution::{Distribution, Law};
use crate::sim::rng::Rng;

/// One announced prediction, in simulated seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Announcement {
    /// When the application learns of the prediction.
    pub notify_t: f64,
    pub window_start: f64,
    pub window_end: f64,
    /// Metadata for scoring the predictor afterwards (not visible to the
    /// checkpointing policy).
    pub true_positive: bool,
}

/// Generate the prediction feed for a known fault schedule on `[0, horizon)`.
///
/// Returns announcements sorted by `notify_t`.  Predicted faults whose
/// notification would fall before t = 0 are silently dropped (equivalently
/// reclassified as unpredicted, §2.2).
pub fn feed(
    faults: &[f64],
    spec: &PredictorSpec,
    cp: f64,
    mu: f64,
    false_pred_law: Law,
    horizon: f64,
    seed: u64,
) -> Vec<Announcement> {
    let mut rng = Rng::stream(seed, 0xfeed);
    let mut out = Vec::new();
    for &tf in faults {
        if rng.bernoulli(spec.recall) {
            let offset = rng.range(0.0, spec.window);
            let ws = tf - offset;
            if ws - cp >= 0.0 {
                out.push(Announcement {
                    notify_t: ws - cp,
                    window_start: ws,
                    window_end: ws + spec.window,
                    true_positive: true,
                });
            }
        }
    }
    if spec.recall > 0.0 && spec.precision < 1.0 {
        let dist = Distribution::new(false_pred_law, spec.mu_false(mu));
        let mut t = 0.0;
        loop {
            t += dist.sample(&mut rng);
            if t >= horizon {
                break;
            }
            if t - cp >= 0.0 {
                out.push(Announcement {
                    notify_t: t - cp,
                    window_start: t,
                    window_end: t + spec.window,
                    true_positive: false,
                });
            }
        }
    }
    out.sort_by(|a, b| a.notify_t.total_cmp(&b.notify_t));
    out
}

/// Score a feed against the fault schedule: measured (recall, precision).
///
/// **Convention:** an empty feed scores precision 0.0, not NaN — a
/// predictor that announces nothing has no correct announcements, and the
/// 0.0 keeps sweep aggregations (means over scored feeds) NaN-free.
/// Symmetrically, an empty fault schedule scores recall 0.0.
///
/// Complexity: O(F log F + W log W) — true-positive windows are sorted
/// once and swept with a two-pointer scan over the sorted faults (the
/// previous implementation was O(F × W), quadratic in the feed length).
pub fn score(faults: &[f64], feed: &[Announcement]) -> (f64, f64) {
    if feed.is_empty() {
        return (0.0, 0.0);
    }
    let true_pos = feed.iter().filter(|a| a.true_positive).count();
    let precision = true_pos as f64 / feed.len() as f64;

    // Sorted true-positive windows.  Window lengths within one feed may
    // vary in principle, so the left pointer retires a window only once it
    // is out of reach of the *longest* window length.
    let mut wins: Vec<(f64, f64)> = feed
        .iter()
        .filter(|a| a.true_positive)
        .map(|a| (a.window_start, a.window_end))
        .collect();
    wins.sort_by(|a, b| a.0.total_cmp(&b.0));
    let max_len = wins.iter().map(|w| w.1 - w.0).fold(0.0, f64::max);
    let mut sorted_faults = faults.to_vec();
    sorted_faults.sort_by(f64::total_cmp);

    let mut lo = 0usize;
    let mut covered = 0usize;
    for &tf in &sorted_faults {
        while lo < wins.len() && wins[lo].0 < tf - max_len {
            lo += 1;
        }
        let mut j = lo;
        while j < wins.len() && wins[j].0 <= tf {
            if wins[j].1 >= tf {
                covered += 1;
                break;
            }
            j += 1;
        }
    }
    (covered as f64 / sorted_faults.len().max(1) as f64, precision)
}

/// Predictor characteristics surveyed in the paper's Table 6.
/// (lead time, precision, recall, window size if known — windows the
/// sources left unspecified are represented with the paper's test sizes.)
pub fn table6_presets() -> Vec<(&'static str, PredictorSpec)> {
    vec![
        ("Zheng'10-300s", PredictorSpec { recall: 0.70, precision: 0.40, window: 300.0 }),
        ("Zheng'10-600s", PredictorSpec { recall: 0.60, precision: 0.35, window: 600.0 }),
        ("Yu'11-accurate", PredictorSpec { recall: 0.852, precision: 0.823, window: 600.0 }),
        ("Yu'11-period", PredictorSpec { recall: 0.652, precision: 0.648, window: 600.0 }),
        ("Gainaru'12", PredictorSpec { recall: 0.43, precision: 0.93, window: 300.0 }),
        ("Fulp'08", PredictorSpec { recall: 0.75, precision: 0.70, window: 600.0 }),
        ("Liang'07-1h", PredictorSpec { recall: 0.30, precision: 0.20, window: 3600.0 }),
        ("Liang'07-6h", PredictorSpec { recall: 0.90, precision: 0.40, window: 21_600.0 }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> PredictorSpec {
        PredictorSpec { recall: 0.85, precision: 0.82, window: 600.0 }
    }

    fn fault_schedule(n: usize, mean: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let d = Distribution::new(Law::Exponential, mean);
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += d.sample(&mut rng);
                t
            })
            .collect()
    }

    #[test]
    fn feed_sorted_and_windows_well_formed() {
        let faults = fault_schedule(500, 1000.0, 1);
        let horizon = faults.last().unwrap() + 1000.0;
        let f = feed(&faults, &spec(), 60.0, 1000.0, Law::Exponential, horizon, 2);
        assert!(!f.is_empty());
        for w in f.windows(2) {
            assert!(w[0].notify_t <= w[1].notify_t);
        }
        for a in &f {
            assert!((a.window_end - a.window_start - 600.0).abs() < 1e-9);
            assert!((a.window_start - a.notify_t - 60.0).abs() < 1e-9);
        }
    }

    #[test]
    fn measured_recall_precision_near_spec() {
        let faults = fault_schedule(4000, 5000.0, 3);
        let horizon = faults.last().unwrap() + 1000.0;
        let f = feed(&faults, &spec(), 60.0, 5000.0, Law::Exponential, horizon, 4);
        let (recall, precision) = score(&faults, &f);
        assert!((recall - 0.85).abs() < 0.05, "recall {recall}");
        assert!((precision - 0.82).abs() < 0.05, "precision {precision}");
    }

    #[test]
    fn perfect_predictor_yields_no_false_positives() {
        let faults = fault_schedule(100, 1000.0, 5);
        let horizon = faults.last().unwrap() + 1000.0;
        let mut s = spec();
        s.precision = 1.0;
        s.recall = 1.0;
        let f = feed(&faults, &s, 60.0, 1000.0, Law::Exponential, horizon, 6);
        assert!(f.iter().all(|a| a.true_positive));
    }

    #[test]
    fn empty_feed_scores_zero_not_nan() {
        let faults = [100.0, 200.0];
        let (recall, precision) = score(&faults, &[]);
        assert_eq!(recall, 0.0);
        assert_eq!(precision, 0.0);
        // Empty fault schedule: recall 0 by the same convention.
        let f = vec![Announcement {
            notify_t: 0.0,
            window_start: 10.0,
            window_end: 20.0,
            true_positive: false,
        }];
        let (recall, precision) = score(&[], &f);
        assert_eq!(recall, 0.0);
        assert_eq!(precision, 0.0);
    }

    #[test]
    fn two_pointer_matches_brute_force() {
        let faults = fault_schedule(800, 700.0, 11);
        let horizon = faults.last().unwrap() + 1000.0;
        let f = feed(&faults, &spec(), 60.0, 700.0, Law::Exponential, horizon, 12);
        let (recall, precision) = score(&faults, &f);
        // Reference: the original quadratic scan.
        let covered = faults
            .iter()
            .filter(|&&tf| {
                f.iter().any(|a| {
                    a.true_positive && tf >= a.window_start && tf <= a.window_end
                })
            })
            .count();
        let tp = f.iter().filter(|a| a.true_positive).count();
        assert_eq!(recall, covered as f64 / faults.len() as f64);
        assert_eq!(precision, tp as f64 / f.len() as f64);
    }

    #[test]
    fn table6_presets_sane() {
        for (name, p) in table6_presets() {
            assert!(p.recall > 0.0 && p.recall <= 1.0, "{name}");
            assert!(p.precision > 0.0 && p.precision <= 1.0, "{name}");
            assert!(p.window > 0.0, "{name}");
        }
    }
}
