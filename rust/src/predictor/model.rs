//! Prediction-window behaviour: the [`PredictorModel`] trait and its
//! implementations — the predictor-axis mirror of `sim::policy`'s
//! `PolicyLogic`.
//!
//! A predictor model answers two questions, consuming its substream RNG in
//! a **fixed, documented order** (that order is the bit-identity contract
//! between the offline trace generators in `sim::trace` and the online
//! [`crate::predictor::feed`], which share these implementations):
//!
//! 1. [`PredictorModel::true_window`] — given a fault at `tf`, is it
//!    predicted (the recall coin, always the first draw), and if so where
//!    does its announced window sit?
//! 2. [`PredictorModel::false_shape`] — what window shape (length, trust
//!    weight) does a false prediction announce?  Its start is always the
//!    raw arrival the shared generator drew, so the false-prediction
//!    substream stays in notify order by construction.
//!
//! Lead time (`C_p` before the window start) and the before-t = 0
//! announcement-drop convention (§2.2: "reclassified as unpredicted") are
//! handled by the shared generators, not per model, so every model
//! inherits them identically.
//!
//! The closed-form-facing properties of a model (E_I^f, window bounds,
//! placement slack) live on [`crate::config::PredModel`] — cheap pure
//! data, no boxed object needed by `model::waste`/`model::optimal`.
//!
//! | model | [`PredModel`] | behaviour |
//! |-------|---------------|-----------|
//! | [`PaperModel`]      | `Paper`         | fixed I, fault uniform in-window (§2.2) |
//! | [`BiasedModel`]     | `Biased{beta}`  | fault position `I·U^(1/β)`, E_I^f = I·β/(β+1) |
//! | [`MixedWindowModel`]| `MixedWindow{…}`| window length i1 w.p. w, else i2 (true + false windows) |
//! | [`JitterModel`]     | `Jitter{sigma}` | window shifted by clamped Gaussian noise; faults can escape |
//! | [`ClassedModel`]    | `Classed{…}`    | hi/lo confidence classes; lo carries trust weight p_lo/p_hi |
//!
//! To add a model: implement [`PredictorModel`] here, add a
//! [`crate::config::PredModel`] variant (with its E_I^f/window-bound
//! properties and a `validate::domain` classification arm), and register a
//! named entry in [`crate::predictor::registry`] — campaign grids, the
//! harness and the CLI pick it up with no further edits.

use crate::config::{PredModel, PredictorSpec};
use crate::sim::rng::Rng;

/// A drawn prediction window, before lead-time handling: the shared
/// generators announce it `C_p` before `start` (dropping announcements
/// that would land before t = 0).
#[derive(Clone, Copy, Debug)]
pub struct DrawnWindow {
    /// Window start t0.
    pub start: f64,
    /// Window length (t0 + len is the window end).
    pub len: f64,
    /// Per-announcement trust weight: multiplies the engine's §3.1 trust
    /// probability q.  1.0 for single-class predictors; < 1.0 for the
    /// low-confidence class of [`ClassedModel`].
    pub weight: f64,
    /// Does the announced window actually contain the fault?  True for
    /// every exact-placement model; [`JitterModel`] windows can miss.
    pub covers: bool,
}

/// Per-announcement window semantics of a predictor (see module docs).
///
/// RNG contract: `true_window` draws the recall coin **first** and returns
/// `None` (no further draws) when it fails; every extra draw a model makes
/// is its own business, but the order must be deterministic — the trace
/// and feed paths replay it from identical stream seeds.
///
/// False predictions only get to choose a *shape* (length, trust weight):
/// their start is the raw arrival the shared generator drew, by
/// construction — so the false-prediction substream is always generated
/// in notify order, which the flat trace's merge relies on (a model that
/// could shift false window starts would silently break that invariant).
///
/// `Send + Sync`: one instance is shared by the fault and
/// false-prediction generators of a trace.
pub trait PredictorModel: Send + Sync {
    /// The recall decision and window placement for the fault at `tf`.
    fn true_window(&self, rng: &mut Rng, tf: f64) -> Option<DrawnWindow>;

    /// The (length, trust weight) of a false prediction's window; the
    /// shared generator anchors it at the drawn arrival time.
    fn false_shape(&self, rng: &mut Rng) -> (f64, f64);
}

/// Instantiate the behaviour object for a spec's [`PredModel`] — the
/// single dispatch point, mirroring `EngineBuilder::run`'s kind dispatch.
pub fn instantiate(spec: &PredictorSpec) -> Box<dyn PredictorModel> {
    let (r, i) = (spec.recall, spec.window);
    match spec.model {
        PredModel::Paper => Box::new(PaperModel { recall: r, window: i }),
        PredModel::Biased { beta } => {
            Box::new(BiasedModel { recall: r, window: i, beta })
        }
        PredModel::MixedWindow { i1, i2, w } => {
            Box::new(MixedWindowModel { recall: r, i1, i2, w })
        }
        PredModel::Jitter { sigma } => {
            Box::new(JitterModel { recall: r, window: i, sigma })
        }
        PredModel::Classed { p_hi, p_lo, frac } => {
            Box::new(ClassedModel::new(r, i, p_hi, p_lo, frac))
        }
    }
}

/// §2.2: fixed window length I, fault uniform in-window.  RNG order:
/// recall coin, then the uniform offset — exactly the pre-trait
/// `FaultGen`, so the paper predictor's streams are bit-identical
/// (`tests/fast_path.rs` pins this).
pub struct PaperModel {
    pub recall: f64,
    pub window: f64,
}

impl PredictorModel for PaperModel {
    fn true_window(&self, rng: &mut Rng, tf: f64) -> Option<DrawnWindow> {
        if !rng.bernoulli(self.recall) {
            return None;
        }
        let offset = rng.range(0.0, self.window);
        Some(DrawnWindow {
            start: tf - offset,
            len: self.window,
            weight: 1.0,
            covers: true,
        })
    }

    fn false_shape(&self, _rng: &mut Rng) -> (f64, f64) {
        (self.window, 1.0)
    }
}

/// Non-uniform in-window placement: fault position `I·U^(1/β)` from the
/// window start (β = 1 is uniform).  RNG order: recall coin, position
/// draw.
pub struct BiasedModel {
    pub recall: f64,
    pub window: f64,
    pub beta: f64,
}

impl PredictorModel for BiasedModel {
    fn true_window(&self, rng: &mut Rng, tf: f64) -> Option<DrawnWindow> {
        if !rng.bernoulli(self.recall) {
            return None;
        }
        let offset = self.window * rng.f64().powf(1.0 / self.beta);
        Some(DrawnWindow {
            start: tf - offset,
            len: self.window,
            weight: 1.0,
            covers: true,
        })
    }

    fn false_shape(&self, _rng: &mut Rng) -> (f64, f64) {
        (self.window, 1.0)
    }
}

/// Two-class heterogeneous window sizes: every announcement — true or
/// false — uses length `i1` with probability `w`, else `i2`; the fault is
/// uniform inside whichever window was drawn.  RNG order (true): recall
/// coin, size coin, offset; (false): size coin.
pub struct MixedWindowModel {
    pub recall: f64,
    pub i1: f64,
    pub i2: f64,
    pub w: f64,
}

impl MixedWindowModel {
    fn draw_len(&self, rng: &mut Rng) -> f64 {
        if rng.bernoulli(self.w) {
            self.i1
        } else {
            self.i2
        }
    }
}

impl PredictorModel for MixedWindowModel {
    fn true_window(&self, rng: &mut Rng, tf: f64) -> Option<DrawnWindow> {
        if !rng.bernoulli(self.recall) {
            return None;
        }
        let len = self.draw_len(rng);
        let offset = rng.range(0.0, len);
        Some(DrawnWindow { start: tf - offset, len, weight: 1.0, covers: true })
    }

    fn false_shape(&self, rng: &mut Rng) -> (f64, f64) {
        (self.draw_len(rng), 1.0)
    }
}

/// Noisy window placement: uniform placement plus Gaussian noise `σ·Z` on
/// the window start, Z clamped to ±3 (keeps the trace look-ahead bounded
/// by `PredictorSpec::placement_slack` = 3σ).  The lead time stays exactly
/// `C_p`; the fault can fall outside its announced window, in which case
/// the announcement is recorded as a false positive and the fault as
/// unpredicted (honest trace metadata — `predictor::score` measures the
/// *effective* recall/precision).  RNG order: recall coin, offset, two
/// noise uniforms (Box–Muller).
pub struct JitterModel {
    pub recall: f64,
    pub window: f64,
    pub sigma: f64,
}

impl PredictorModel for JitterModel {
    fn true_window(&self, rng: &mut Rng, tf: f64) -> Option<DrawnWindow> {
        if !rng.bernoulli(self.recall) {
            return None;
        }
        let offset = rng.range(0.0, self.window);
        // Box–Muller, clamped to ±3σ.
        let (u1, u2) = (rng.f64_open(), rng.f64());
        let z = (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f64::consts::PI * u2).cos();
        let noise = self.sigma * z.clamp(-3.0, 3.0);
        let start = tf - offset + noise;
        let covers = tf >= start && tf <= start + self.window;
        Some(DrawnWindow { start, len: self.window, weight: 1.0, covers })
    }

    fn false_shape(&self, _rng: &mut Rng) -> (f64, f64) {
        (self.window, 1.0)
    }
}

/// Per-announcement confidence classes (precision `p_hi` / `p_lo`,
/// `frac` of announcements in the high class).  Window placement is the
/// paper's uniform fixed-I; what changes is the trust weight each
/// announcement carries: 1.0 for the high class, `p_lo/p_hi` for the low
/// one.  Class frequencies are consistent with the overall precision
/// `p = frac·p_hi + (1−frac)·p_lo` by Bayes: P(hi | true) =
/// `frac·p_hi/p`, P(hi | false) = `frac·(1−p_hi)/(1−p)`.  RNG order
/// (true): recall coin, offset, class coin; (false): class coin.
pub struct ClassedModel {
    pub recall: f64,
    pub window: f64,
    /// P(high class | true announcement).
    hi_given_true: f64,
    /// P(high class | false announcement).
    hi_given_false: f64,
    /// Trust weight of the low class (p_lo/p_hi, capped at 1).
    weight_lo: f64,
}

impl ClassedModel {
    pub fn new(recall: f64, window: f64, p_hi: f64, p_lo: f64, frac: f64) -> Self {
        let p = frac * p_hi + (1.0 - frac) * p_lo;
        let hi_given_true = if p > 0.0 { (frac * p_hi / p).min(1.0) } else { 0.0 };
        let hi_given_false = if p < 1.0 {
            (frac * (1.0 - p_hi) / (1.0 - p)).min(1.0)
        } else {
            0.0
        };
        ClassedModel {
            recall,
            window,
            hi_given_true,
            hi_given_false,
            weight_lo: (p_lo / p_hi).min(1.0),
        }
    }

    fn weight(&self, rng: &mut Rng, p_hi_class: f64) -> f64 {
        if rng.bernoulli(p_hi_class) {
            1.0
        } else {
            self.weight_lo
        }
    }
}

impl PredictorModel for ClassedModel {
    fn true_window(&self, rng: &mut Rng, tf: f64) -> Option<DrawnWindow> {
        if !rng.bernoulli(self.recall) {
            return None;
        }
        let offset = rng.range(0.0, self.window);
        let weight = self.weight(rng, self.hi_given_true);
        Some(DrawnWindow {
            start: tf - offset,
            len: self.window,
            weight,
            covers: true,
        })
    }

    fn false_shape(&self, rng: &mut Rng) -> (f64, f64) {
        (self.window, self.weight(rng, self.hi_given_false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(model: PredModel) -> PredictorSpec {
        PredictorSpec { recall: 1.0, precision: 0.8, window: 600.0, model }
    }

    #[test]
    fn paper_model_consumes_rng_like_the_seed_generator() {
        // coin + uniform offset, in that order — the bit-identity contract.
        let m = instantiate(&spec(PredModel::Paper));
        let mut rng = Rng::new(7);
        let mut reference = Rng::new(7);
        let w = m.true_window(&mut rng, 10_000.0).expect("recall 1");
        assert!(reference.bernoulli(1.0));
        let offset = reference.range(0.0, 600.0);
        assert_eq!(w.start, 10_000.0 - offset);
        assert_eq!(w.len, 600.0);
        assert_eq!(w.weight, 1.0);
        assert!(w.covers);
        // False-window shapes draw nothing for the paper model.
        let before = rng.clone().next_u64();
        let (len, weight) = m.false_shape(&mut rng);
        assert_eq!(rng.next_u64(), before);
        assert_eq!((len, weight), (600.0, 1.0));
    }

    #[test]
    fn biased_mean_position_matches_e_if() {
        let sp = spec(PredModel::Biased { beta: 2.0 });
        let m = instantiate(&sp);
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| {
                let w = m.true_window(&mut rng, 1e6).unwrap();
                1e6 - w.start // fault position within the window
            })
            .sum::<f64>()
            / n as f64;
        assert!((mean - sp.e_if()).abs() < 5.0, "{mean} vs {}", sp.e_if());
        assert!((sp.e_if() - 400.0).abs() < 1e-12);
    }

    #[test]
    fn mixedwin_draws_both_sizes_at_rate_w() {
        let m = instantiate(&spec(PredModel::MixedWindow {
            i1: 300.0,
            i2: 1200.0,
            w: 0.25,
        }));
        let mut rng = Rng::new(2);
        let n = 10_000;
        let mut small = 0;
        for _ in 0..n {
            let w = m.true_window(&mut rng, 1e6).unwrap();
            assert!(w.len == 300.0 || w.len == 1200.0);
            // The fault always sits inside the drawn window.
            assert!(1e6 >= w.start && 1e6 <= w.start + w.len);
            small += (w.len == 300.0) as usize;
        }
        let frac = small as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "{frac}");
        // False-window shapes draw sizes too.
        let (len, _) = m.false_shape(&mut rng);
        assert!(len == 300.0 || len == 1200.0);
    }

    #[test]
    fn jitter_keeps_noise_bounded_and_sometimes_misses() {
        let sigma = 400.0;
        let sp = spec(PredModel::Jitter { sigma });
        let m = instantiate(&sp);
        let mut rng = Rng::new(3);
        let n = 10_000;
        let mut missed = 0;
        for _ in 0..n {
            let w = m.true_window(&mut rng, 1e6).unwrap();
            // start ≥ tf − I − 3σ (the look-ahead bound trace gen relies on).
            assert!(w.start >= 1e6 - sp.window - sp.placement_slack() - 1e-9);
            assert!(w.start <= 1e6 + sp.placement_slack() + 1e-9);
            let covers = 1e6 >= w.start && 1e6 <= w.start + w.len;
            assert_eq!(covers, w.covers);
            missed += !w.covers as usize;
        }
        // σ comparable to I: a solid fraction of windows miss their fault.
        let miss = missed as f64 / n as f64;
        assert!(miss > 0.1 && miss < 0.9, "{miss}");
    }

    #[test]
    fn classed_weights_and_frequencies_are_bayes_consistent() {
        let (p_hi, p_lo, frac) = (0.95, 0.6, 0.5);
        let m = ClassedModel::new(1.0, 600.0, p_hi, p_lo, frac);
        let p = frac * p_hi + (1.0 - frac) * p_lo;
        assert!((m.hi_given_true - frac * p_hi / p).abs() < 1e-12);
        assert!(
            (m.hi_given_false - frac * (1.0 - p_hi) / (1.0 - p)).abs() < 1e-12
        );
        // Total-probability check: P(hi) = frac.
        let p_hi_total =
            m.hi_given_true * p + m.hi_given_false * (1.0 - p);
        assert!((p_hi_total - frac).abs() < 1e-12);
        let mut rng = Rng::new(4);
        let n = 20_000;
        let mut hi = 0;
        for _ in 0..n {
            let w = m.true_window(&mut rng, 1e6).unwrap();
            assert!(w.weight == 1.0 || (w.weight - p_lo / p_hi).abs() < 1e-12);
            hi += (w.weight == 1.0) as usize;
        }
        let observed = hi as f64 / n as f64;
        assert!((observed - m.hi_given_true).abs() < 0.02, "{observed}");
    }
}
