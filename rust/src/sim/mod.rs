//! Discrete-event simulation substrate.
//!
//! The paper evaluates its strategies with a discrete-event simulator fed by
//! random fault traces (Exponential or Weibull inter-arrival) merged with a
//! trace of false predictions (§4.1).  This module rebuilds that substrate
//! from scratch:
//!
//! * [`rng`] — a seeded, splittable PRNG (xoshiro256**), no external crates;
//! * [`distribution`] — Exponential / Weibull / Uniform inter-arrival laws,
//!   mean-scaled so each trace's expectation matches the platform MTBF;
//! * [`trace`] — lazy, time-sorted event streams (faults, true predictions
//!   with their windows, false predictions);
//! * [`engine`] — the two-mode scheduling simulator (Algorithm 1 and the
//!   simpler variants), which executes a policy against a trace and
//!   produces a [`engine::SimOutcome`];
//! * [`policy`] — the [`policy::PolicyLogic`] trait: the per-strategy
//!   decisions (announcement trust, in-window behaviour, period
//!   resumption) the engine's monomorphized main loop is generic over.

pub mod distribution;
pub mod engine;
pub mod policy;
pub mod rng;
pub mod timeline;
pub mod tracefile;
pub mod trace;
