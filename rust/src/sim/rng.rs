//! Seeded, splittable PRNG: xoshiro256** seeded through SplitMix64.
//!
//! The environment is offline (no `rand` crate), so the generator is
//! implemented here.  xoshiro256** passes BigCrush and is the default
//! generator of several language runtimes; SplitMix64 is the recommended
//! seeder.  Streams are split deterministically so that every (instance,
//! scenario) pair sees an independent, reproducible sequence.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for (seed, stream) without consuming
    /// this generator — deterministic fan-out for parallel instances.
    pub fn stream(seed: u64, stream: u64) -> Self {
        // Mix the stream id through SplitMix64 so nearby ids decorrelate.
        let mut sm = seed ^ 0xA076_1D64_78BD_642F_u64.wrapping_mul(stream | 1);
        let _ = splitmix64(&mut sm);
        Rng::new(splitmix64(&mut sm) ^ stream.rotate_left(17))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a `ln` argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Rng::stream(7, 0);
        let mut b = Rng::stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        let mut min = 1.0f64;
        let mut max = 0.0f64;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            min = min.min(x);
            max = max.max(x);
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!(min < 0.001 && max > 0.999);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(4);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
