//! Inter-arrival time distributions, mean-scaled to the platform MTBF.
//!
//! The paper's simulations (§4.1) draw fault inter-arrival times from an
//! Exponential law or from Weibull laws with shape 0.5 / 0.7, always scaled
//! so the expectation equals the platform MTBF μ.  False predictions are
//! drawn either from the same law or from a Uniform law (Figures 8–13),
//! scaled to the false-prediction inter-arrival mean `pμ / (r(1-p))`.

use crate::sim::rng::Rng;
use crate::util::gamma;

/// An inter-arrival law with unit-free shape; `mean` fixes the scale.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Law {
    /// Exponential (memoryless; the theoretical baseline).
    Exponential,
    /// Weibull with the given shape parameter k (k < 1 ⇒ infant mortality,
    /// representative of real platforms [Schroeder&Gibson'06]).
    Weibull { shape: f64 },
    /// LogNormal with log-space standard deviation σ — heavier-tailed than
    /// any Weibull the paper sweeps (all moments exist but the tail decays
    /// sub-exponentially in log scale), giving campaigns a stress law
    /// beyond the paper's envelope.  Mean-scaled: X = e^{m + σZ} with
    /// m = ln(mean) − σ²/2 so E[X] = mean.
    LogNormal { sigma: f64 },
    /// Uniform on [0, 2·mean] (used for false-prediction arrivals in
    /// Figures 8–13).
    Uniform,
}

impl Law {
    /// Human-readable label used in CSV outputs.
    pub fn label(&self) -> String {
        match self {
            Law::Exponential => "exponential".to_string(),
            Law::Weibull { shape } => format!("weibull{shape}"),
            Law::LogNormal { sigma } => format!("lognormal{sigma}"),
            Law::Uniform => "uniform".to_string(),
        }
    }

    /// Squared coefficient of variation CV² = Var/E² of the law (scale
    /// free).  Drives the conformance tolerance's finite-horizon renewal
    /// term: the expected event count of a renewal process over [0, T]
    /// exceeds T/mean by ≈ (CV² − 1)/2 (the asymptotic renewal-function
    /// constant), which is 0 exactly for the Exponential law.
    pub fn cv2(&self) -> f64 {
        match self {
            Law::Exponential => 1.0,
            // E[X^m] = λ^m Γ(1 + m/k) ⇒ CV² = Γ(1+2/k)/Γ(1+1/k)² − 1.
            Law::Weibull { shape } => {
                let g1 = gamma(1.0 + 1.0 / shape);
                gamma(1.0 + 2.0 / shape) / (g1 * g1) - 1.0
            }
            Law::LogNormal { sigma } => (sigma * sigma).exp() - 1.0,
            // U(0, 2m): Var = (2m)²/12 = m²/3.
            Law::Uniform => 1.0 / 3.0,
        }
    }

    /// Parse a label: "exponential" | "weibull0.7" | "lognormal1.2" |
    /// "uniform".
    pub fn parse(s: &str) -> Option<Law> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "exp" | "exponential" => Some(Law::Exponential),
            "uniform" => Some(Law::Uniform),
            _ => {
                if let Some(rest) = s.strip_prefix("weibull") {
                    rest.parse::<f64>().ok().map(|shape| Law::Weibull { shape })
                } else if let Some(rest) = s.strip_prefix("lognormal") {
                    rest.parse::<f64>().ok().map(|sigma| Law::LogNormal { sigma })
                } else {
                    None
                }
            }
        }
    }
}

/// A law + mean: a concrete sampler for inter-arrival times.
#[derive(Clone, Copy, Debug)]
pub struct Distribution {
    pub law: Law,
    pub mean: f64,
    /// Cached Weibull scale λ = mean / Γ(1 + 1/k).
    scale: f64,
}

impl Distribution {
    pub fn new(law: Law, mean: f64) -> Self {
        assert!(mean > 0.0, "mean must be positive, got {mean}");
        let scale = match law {
            Law::Weibull { shape } => {
                assert!(shape > 0.0, "Weibull shape must be positive");
                mean / gamma(1.0 + 1.0 / shape)
            }
            Law::LogNormal { sigma } => {
                assert!(sigma > 0.0, "LogNormal sigma must be positive");
                // e^m = mean · e^{−σ²/2} ⇒ E[e^{m+σZ}] = mean.
                mean * (-0.5 * sigma * sigma).exp()
            }
            _ => mean,
        };
        Distribution { law, mean, scale }
    }

    /// Analytic CDF F(x) of this mean-scaled law — the reference the
    /// Kolmogorov–Smirnov goodness-of-fit oracles compare [`Self::sample`]
    /// against (`crate::stats::ks_statistic`).
    pub fn cdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            return 0.0;
        }
        match self.law {
            Law::Exponential => 1.0 - (-x / self.scale).exp(),
            Law::Weibull { shape } => 1.0 - (-(x / self.scale).powf(shape)).exp(),
            // scale = e^m, so ln x − m = ln(x / scale).
            Law::LogNormal { sigma } => {
                crate::util::normal_cdf((x / self.scale).ln() / sigma)
            }
            Law::Uniform => (x / (2.0 * self.scale)).min(1.0),
        }
    }

    /// Draw one inter-arrival time (strictly positive).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self.law {
            Law::Exponential => {
                // Inverse CDF; f64_open avoids ln(0).
                -self.scale * rng.f64_open().ln()
            }
            Law::Weibull { shape } => {
                let u = rng.f64_open();
                self.scale * (-u.ln()).powf(1.0 / shape)
            }
            Law::LogNormal { sigma } => {
                // Box–Muller (one draw of the pair); u1 open avoids ln(0).
                let u1 = rng.f64_open();
                let u2 = rng.f64();
                let z = (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
                self.scale * (sigma * z).exp()
            }
            Law::Uniform => rng.range(0.0, 2.0 * self.scale).max(f64::MIN_POSITIVE),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical_mean(d: &Distribution, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn exponential_mean_scaled() {
        let d = Distribution::new(Law::Exponential, 1000.0);
        let m = empirical_mean(&d, 200_000, 1);
        assert!((m - 1000.0).abs() / 1000.0 < 0.02, "{m}");
    }

    #[test]
    fn weibull_mean_scaled() {
        for shape in [0.5, 0.7, 1.0, 2.0] {
            let d = Distribution::new(Law::Weibull { shape }, 500.0);
            // Heavy-tailed at k=0.5: needs more samples for the mean.
            let m = empirical_mean(&d, 400_000, 2);
            assert!(
                (m - 500.0).abs() / 500.0 < 0.05,
                "shape {shape}: mean {m}"
            );
        }
    }

    #[test]
    fn weibull_shape1_equals_exponential_law() {
        // Weibull(k=1, λ) IS Exponential(λ); check via quantile agreement.
        let w = Distribution::new(Law::Weibull { shape: 1.0 }, 700.0);
        let e = Distribution::new(Law::Exponential, 700.0);
        assert!((w.scale - e.scale).abs() < 1e-9);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let d = Distribution::new(Law::Uniform, 250.0);
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = d.sample(&mut rng);
            assert!(x > 0.0 && x < 500.0);
            sum += x;
        }
        let m = sum / 100_000.0;
        assert!((m - 250.0).abs() / 250.0 < 0.02, "{m}");
    }

    #[test]
    fn lognormal_mean_scaled_and_quantiles() {
        let sigma = 1.2;
        let mean = 800.0;
        let d = Distribution::new(Law::LogNormal { sigma }, mean);
        let mut rng = Rng::new(9);
        let n = 400_000;
        let mut samples: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let m = samples.iter().sum::<f64>() / n as f64;
        // CV = sqrt(e^{σ²} − 1) ≈ 1.8 at σ = 1.2: the mean needs many
        // samples but converges; 3% tolerance is ~7 stderr.
        assert!((m - mean).abs() / mean < 0.03, "mean {m}");
        samples.sort_by(f64::total_cmp);
        // Quantile sanity: the median is e^m = mean·e^{−σ²/2}, and the
        // Φ(1) ≈ 0.8413 quantile is e^{m+σ}.
        let e_m = mean * (-0.5 * sigma * sigma).exp();
        let med = samples[n / 2];
        assert!((med - e_m).abs() / e_m < 0.02, "median {med} vs {e_m}");
        let q = samples.partition_point(|&x| x <= e_m * sigma.exp()) as f64 / n as f64;
        assert!((q - 0.8413).abs() < 0.01, "Φ(1) quantile {q}");
    }

    #[test]
    fn lognormal_heavier_tailed_than_weibull() {
        // At matched means, the LogNormal σ=1.2 P99.9 exceeds the
        // Weibull k=0.7 P99.9 — the point of adding the law.
        let tail = |law: Law, seed: u64| {
            let d = Distribution::new(law, 1000.0);
            let mut rng = Rng::new(seed);
            let mut xs: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
            xs.sort_by(f64::total_cmp);
            xs[(xs.len() as f64 * 0.999) as usize]
        };
        let ln_tail = tail(Law::LogNormal { sigma: 1.2 }, 10);
        let wb_tail = tail(Law::Weibull { shape: 0.7 }, 10);
        assert!(ln_tail > wb_tail, "lognormal {ln_tail} vs weibull {wb_tail}");
    }

    #[test]
    fn samples_strictly_positive() {
        for law in [
            Law::Exponential,
            Law::Weibull { shape: 0.5 },
            Law::LogNormal { sigma: 1.2 },
            Law::Uniform,
        ] {
            let d = Distribution::new(law, 1.0);
            let mut rng = Rng::new(4);
            for _ in 0..10_000 {
                assert!(d.sample(&mut rng) > 0.0);
            }
        }
    }

    #[test]
    fn ks_goodness_of_fit_against_analytic_cdfs() {
        use crate::stats::{ks_critical, ks_statistic};
        // Fixed seeds make these deterministic; the bound is 2× the 5%
        // asymptotic critical value — astronomically unlikely to trip for a
        // correct sampler (p ~ 1e-14 per draw), yet an order of magnitude
        // below the distance any real sampler bug (wrong scale, wrong
        // branch, closed-vs-open interval) produces.
        let n = 20_000;
        let bound = 2.0 * ks_critical(n, 0.05);
        for (law, seed) in [
            (Law::Exponential, 101u64),
            (Law::Weibull { shape: 0.7 }, 102),
            (Law::Weibull { shape: 0.5 }, 103),
            (Law::Weibull { shape: 2.0 }, 104),
            (Law::LogNormal { sigma: 1.2 }, 105),
            (Law::Uniform, 106),
        ] {
            let d = Distribution::new(law, 700.0);
            let mut rng = Rng::new(seed);
            let samples: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
            let ks = ks_statistic(&samples, |x| d.cdf(x));
            assert!(ks < bound, "{}: D = {ks} vs bound {bound}", law.label());
        }
    }

    #[test]
    fn ks_rejects_the_wrong_cdf() {
        use crate::stats::{ks_critical, ks_statistic};
        // Positive control: exponential samples tested against the
        // Weibull-0.7 CDF must be rejected decisively — the oracle has
        // power, not just tolerance.
        let exp = Distribution::new(Law::Exponential, 700.0);
        let weib = Distribution::new(Law::Weibull { shape: 0.7 }, 700.0);
        let mut rng = Rng::new(107);
        let samples: Vec<f64> = (0..20_000).map(|_| exp.sample(&mut rng)).collect();
        let ks = ks_statistic(&samples, |x| weib.cdf(x));
        assert!(ks > 8.0 * ks_critical(20_000, 0.01), "D = {ks}");
        // And a mis-scaled mean is also caught.
        let shifted = Distribution::new(Law::Exponential, 900.0);
        let ks = ks_statistic(&samples, |x| shifted.cdf(x));
        assert!(ks > 5.0 * ks_critical(20_000, 0.01), "D = {ks}");
    }

    #[test]
    fn quantile_spot_checks_against_closed_forms() {
        // Median and upper-quartile of each law, empirically vs closed
        // form: Exp median = λ ln 2; Weibull q-quantile = λ(−ln(1−q))^{1/k};
        // LogNormal median = e^m = mean·e^{−σ²/2}; Uniform median = mean.
        let n = 200_000;
        let mean = 1000.0;
        let quantile = |law: Law, seed: u64, q: f64| -> f64 {
            let d = Distribution::new(law, mean);
            let mut rng = Rng::new(seed);
            let mut xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
            xs.sort_by(f64::total_cmp);
            xs[(q * n as f64) as usize]
        };
        let ln2 = std::f64::consts::LN_2;
        let exp_med = quantile(Law::Exponential, 201, 0.5);
        assert!((exp_med - mean * ln2).abs() / (mean * ln2) < 0.02, "{exp_med}");
        for shape in [0.5, 0.7] {
            let lambda = mean / crate::util::gamma(1.0 + 1.0 / shape);
            let want = lambda * ln2.powf(1.0 / shape);
            let got = quantile(Law::Weibull { shape }, 202, 0.5);
            assert!((got - want).abs() / want < 0.03, "k={shape}: {got} vs {want}");
            let want75 = lambda * (-(0.25f64).ln()).powf(1.0 / shape);
            let got75 = quantile(Law::Weibull { shape }, 203, 0.75);
            assert!((got75 - want75).abs() / want75 < 0.03, "k={shape}: {got75}");
        }
        let sigma = 0.8;
        let want = mean * (-0.5 * sigma * sigma).exp();
        let got = quantile(Law::LogNormal { sigma }, 204, 0.5);
        assert!((got - want).abs() / want < 0.02, "{got} vs {want}");
        let got = quantile(Law::Uniform, 205, 0.5);
        assert!((got - mean).abs() / mean < 0.02, "{got}");
    }

    #[test]
    fn cv2_known_values() {
        assert_eq!(Law::Exponential.cv2(), 1.0);
        assert!((Law::Uniform.cv2() - 1.0 / 3.0).abs() < 1e-12);
        // Weibull k=1 IS exponential; k=0.5: Γ(5)/Γ(3)² − 1 = 24/4 − 1 = 5.
        assert!((Law::Weibull { shape: 1.0 }.cv2() - 1.0).abs() < 1e-9);
        assert!((Law::Weibull { shape: 0.5 }.cv2() - 5.0).abs() < 1e-6);
        // k=0.7 sits between; heavier shapes are *less* variable.
        let c07 = Law::Weibull { shape: 0.7 }.cv2();
        assert!(c07 > 1.0 && c07 < 5.0, "{c07}");
        assert!(Law::Weibull { shape: 2.0 }.cv2() < 1.0);
        // LogNormal: e^{σ²} − 1.
        let s = 1.2f64;
        assert!((Law::LogNormal { sigma: s }.cv2() - ((s * s).exp() - 1.0)).abs() < 1e-12);
    }

    #[test]
    fn cdf_is_a_distribution_function() {
        for law in [
            Law::Exponential,
            Law::Weibull { shape: 0.7 },
            Law::LogNormal { sigma: 1.2 },
            Law::Uniform,
        ] {
            let d = Distribution::new(law, 500.0);
            assert_eq!(d.cdf(0.0), 0.0);
            assert_eq!(d.cdf(-5.0), 0.0);
            let mut prev = 0.0;
            for k in 1..200 {
                let f = d.cdf(k as f64 * 50.0);
                assert!((0.0..=1.0).contains(&f));
                assert!(f >= prev, "{}: CDF not monotone", law.label());
                prev = f;
            }
            assert!(d.cdf(1e9) > 0.999, "{}", law.label());
        }
    }

    #[test]
    fn label_parse_roundtrip() {
        for law in [
            Law::Exponential,
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.5 },
            Law::LogNormal { sigma: 1.2 },
            Law::Uniform,
        ] {
            assert_eq!(Law::parse(&law.label()), Some(law));
        }
        assert_eq!(Law::parse("nope"), None);
        assert_eq!(Law::parse("lognormal"), None);
    }
}
