//! Inter-arrival time distributions, mean-scaled to the platform MTBF.
//!
//! The paper's simulations (§4.1) draw fault inter-arrival times from an
//! Exponential law or from Weibull laws with shape 0.5 / 0.7, always scaled
//! so the expectation equals the platform MTBF μ.  False predictions are
//! drawn either from the same law or from a Uniform law (Figures 8–13),
//! scaled to the false-prediction inter-arrival mean `pμ / (r(1-p))`.

use crate::sim::rng::Rng;
use crate::util::gamma;

/// An inter-arrival law with unit-free shape; `mean` fixes the scale.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Law {
    /// Exponential (memoryless; the theoretical baseline).
    Exponential,
    /// Weibull with the given shape parameter k (k < 1 ⇒ infant mortality,
    /// representative of real platforms [Schroeder&Gibson'06]).
    Weibull { shape: f64 },
    /// LogNormal with log-space standard deviation σ — heavier-tailed than
    /// any Weibull the paper sweeps (all moments exist but the tail decays
    /// sub-exponentially in log scale), giving campaigns a stress law
    /// beyond the paper's envelope.  Mean-scaled: X = e^{m + σZ} with
    /// m = ln(mean) − σ²/2 so E[X] = mean.
    LogNormal { sigma: f64 },
    /// Uniform on [0, 2·mean] (used for false-prediction arrivals in
    /// Figures 8–13).
    Uniform,
}

impl Law {
    /// Human-readable label used in CSV outputs.
    pub fn label(&self) -> String {
        match self {
            Law::Exponential => "exponential".to_string(),
            Law::Weibull { shape } => format!("weibull{shape}"),
            Law::LogNormal { sigma } => format!("lognormal{sigma}"),
            Law::Uniform => "uniform".to_string(),
        }
    }

    /// Parse a label: "exponential" | "weibull0.7" | "lognormal1.2" |
    /// "uniform".
    pub fn parse(s: &str) -> Option<Law> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "exp" | "exponential" => Some(Law::Exponential),
            "uniform" => Some(Law::Uniform),
            _ => {
                if let Some(rest) = s.strip_prefix("weibull") {
                    rest.parse::<f64>().ok().map(|shape| Law::Weibull { shape })
                } else if let Some(rest) = s.strip_prefix("lognormal") {
                    rest.parse::<f64>().ok().map(|sigma| Law::LogNormal { sigma })
                } else {
                    None
                }
            }
        }
    }
}

/// A law + mean: a concrete sampler for inter-arrival times.
#[derive(Clone, Copy, Debug)]
pub struct Distribution {
    pub law: Law,
    pub mean: f64,
    /// Cached Weibull scale λ = mean / Γ(1 + 1/k).
    scale: f64,
}

impl Distribution {
    pub fn new(law: Law, mean: f64) -> Self {
        assert!(mean > 0.0, "mean must be positive, got {mean}");
        let scale = match law {
            Law::Weibull { shape } => {
                assert!(shape > 0.0, "Weibull shape must be positive");
                mean / gamma(1.0 + 1.0 / shape)
            }
            Law::LogNormal { sigma } => {
                assert!(sigma > 0.0, "LogNormal sigma must be positive");
                // e^m = mean · e^{−σ²/2} ⇒ E[e^{m+σZ}] = mean.
                mean * (-0.5 * sigma * sigma).exp()
            }
            _ => mean,
        };
        Distribution { law, mean, scale }
    }

    /// Draw one inter-arrival time (strictly positive).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self.law {
            Law::Exponential => {
                // Inverse CDF; f64_open avoids ln(0).
                -self.scale * rng.f64_open().ln()
            }
            Law::Weibull { shape } => {
                let u = rng.f64_open();
                self.scale * (-u.ln()).powf(1.0 / shape)
            }
            Law::LogNormal { sigma } => {
                // Box–Muller (one draw of the pair); u1 open avoids ln(0).
                let u1 = rng.f64_open();
                let u2 = rng.f64();
                let z = (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
                self.scale * (sigma * z).exp()
            }
            Law::Uniform => rng.range(0.0, 2.0 * self.scale).max(f64::MIN_POSITIVE),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical_mean(d: &Distribution, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn exponential_mean_scaled() {
        let d = Distribution::new(Law::Exponential, 1000.0);
        let m = empirical_mean(&d, 200_000, 1);
        assert!((m - 1000.0).abs() / 1000.0 < 0.02, "{m}");
    }

    #[test]
    fn weibull_mean_scaled() {
        for shape in [0.5, 0.7, 1.0, 2.0] {
            let d = Distribution::new(Law::Weibull { shape }, 500.0);
            // Heavy-tailed at k=0.5: needs more samples for the mean.
            let m = empirical_mean(&d, 400_000, 2);
            assert!(
                (m - 500.0).abs() / 500.0 < 0.05,
                "shape {shape}: mean {m}"
            );
        }
    }

    #[test]
    fn weibull_shape1_equals_exponential_law() {
        // Weibull(k=1, λ) IS Exponential(λ); check via quantile agreement.
        let w = Distribution::new(Law::Weibull { shape: 1.0 }, 700.0);
        let e = Distribution::new(Law::Exponential, 700.0);
        assert!((w.scale - e.scale).abs() < 1e-9);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let d = Distribution::new(Law::Uniform, 250.0);
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = d.sample(&mut rng);
            assert!(x > 0.0 && x < 500.0);
            sum += x;
        }
        let m = sum / 100_000.0;
        assert!((m - 250.0).abs() / 250.0 < 0.02, "{m}");
    }

    #[test]
    fn lognormal_mean_scaled_and_quantiles() {
        let sigma = 1.2;
        let mean = 800.0;
        let d = Distribution::new(Law::LogNormal { sigma }, mean);
        let mut rng = Rng::new(9);
        let n = 400_000;
        let mut samples: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let m = samples.iter().sum::<f64>() / n as f64;
        // CV = sqrt(e^{σ²} − 1) ≈ 1.8 at σ = 1.2: the mean needs many
        // samples but converges; 3% tolerance is ~7 stderr.
        assert!((m - mean).abs() / mean < 0.03, "mean {m}");
        samples.sort_by(f64::total_cmp);
        // Quantile sanity: the median is e^m = mean·e^{−σ²/2}, and the
        // Φ(1) ≈ 0.8413 quantile is e^{m+σ}.
        let e_m = mean * (-0.5 * sigma * sigma).exp();
        let med = samples[n / 2];
        assert!((med - e_m).abs() / e_m < 0.02, "median {med} vs {e_m}");
        let q = samples.partition_point(|&x| x <= e_m * sigma.exp()) as f64 / n as f64;
        assert!((q - 0.8413).abs() < 0.01, "Φ(1) quantile {q}");
    }

    #[test]
    fn lognormal_heavier_tailed_than_weibull() {
        // At matched means, the LogNormal σ=1.2 P99.9 exceeds the
        // Weibull k=0.7 P99.9 — the point of adding the law.
        let tail = |law: Law, seed: u64| {
            let d = Distribution::new(law, 1000.0);
            let mut rng = Rng::new(seed);
            let mut xs: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
            xs.sort_by(f64::total_cmp);
            xs[(xs.len() as f64 * 0.999) as usize]
        };
        let ln_tail = tail(Law::LogNormal { sigma: 1.2 }, 10);
        let wb_tail = tail(Law::Weibull { shape: 0.7 }, 10);
        assert!(ln_tail > wb_tail, "lognormal {ln_tail} vs weibull {wb_tail}");
    }

    #[test]
    fn samples_strictly_positive() {
        for law in [
            Law::Exponential,
            Law::Weibull { shape: 0.5 },
            Law::LogNormal { sigma: 1.2 },
            Law::Uniform,
        ] {
            let d = Distribution::new(law, 1.0);
            let mut rng = Rng::new(4);
            for _ in 0..10_000 {
                assert!(d.sample(&mut rng) > 0.0);
            }
        }
    }

    #[test]
    fn label_parse_roundtrip() {
        for law in [
            Law::Exponential,
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.5 },
            Law::LogNormal { sigma: 1.2 },
            Law::Uniform,
        ] {
            assert_eq!(Law::parse(&law.label()), Some(law));
        }
        assert_eq!(Law::parse("nope"), None);
        assert_eq!(Law::parse("lognormal"), None);
    }
}
