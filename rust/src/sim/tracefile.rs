//! Failure-log ingestion: run the strategies against *recorded* fault
//! traces instead of synthetic ones.
//!
//! The paper's conclusion names this as future work: "refine the assessment
//! of the usefulness of prediction with trace-based failure and prediction
//! logs from current large-scale supercomputers".  This module provides:
//!
//! * a plain failure-log format (one fault timestamp per line, `#`
//!   comments — the shape of published LANL/BlueGene availability logs
//!   after normalization);
//! * a reader/writer pair;
//! * [`LogTrace`]: an [`EventSource`] that replays a recorded fault log and
//!   synthesizes the prediction feed a predictor with the given (r, p, I)
//!   characteristics would have produced for it — so any real log can be
//!   pushed through every heuristic via `ckptwin replay`.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::{PredictorSpec, Scenario};
use crate::predictor;
use crate::sim::distribution::Law;
use crate::sim::trace::{Event, EventSource};

/// Write a failure log: one fault time (seconds, ascending) per line.
pub fn write_failure_log(path: &Path, faults: &[f64]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "# ckptwin failure log: one fault time (s) per line")?;
    for &t in faults {
        writeln!(f, "{t:.3}")?;
    }
    Ok(())
}

/// Read a failure log; validates ascending order and non-negativity.
pub fn read_failure_log(path: &Path) -> Result<Vec<f64>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut out = Vec::new();
    let mut prev = f64::NEG_INFINITY;
    for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let body = line.split('#').next().unwrap_or("").trim();
        if body.is_empty() {
            continue;
        }
        let t: f64 = body.parse().map_err(|_| {
            anyhow!("{}:{}: not a number: {body}", path.display(), lineno + 1)
        })?;
        if t < 0.0 || t < prev {
            return Err(anyhow!(
                "{}:{}: fault times must be non-negative and ascending",
                path.display(),
                lineno + 1
            ));
        }
        prev = t;
        out.push(t);
    }
    Ok(out)
}

/// An [`EventSource`] replaying a recorded fault log with a synthesized
/// prediction feed.  After the log is exhausted, a guard fault far past the
/// horizon keeps the engine semantics intact (jobs should complete first).
pub struct LogTrace {
    events: Vec<Event>,
    pos: usize,
    guard_t: f64,
}

impl LogTrace {
    /// Build from a fault log and predictor characteristics.  `seed` fixes
    /// which faults get predicted and where the windows fall.
    pub fn new(
        faults: &[f64],
        spec: &PredictorSpec,
        cp: f64,
        mu: f64,
        false_pred_law: Law,
        seed: u64,
    ) -> Self {
        let horizon = faults.last().copied().unwrap_or(0.0) + 10.0 * mu;
        let feed =
            predictor::feed(faults, spec, cp, mu, false_pred_law, horizon, seed);
        // Which faults are covered by a window of the feed (=> predicted)?
        // One shared two-pointer sweep (predictor::covered) instead of the
        // old per-fault rescan of the whole feed.
        let covered = predictor::covered(faults, &feed);
        let mut events: Vec<Event> = Vec::with_capacity(faults.len() + feed.len());
        for (&tf, &predicted) in faults.iter().zip(&covered) {
            events.push(Event::Fault { t: tf, predicted });
        }
        // The feed's announcements ARE trace predictions (one shared type).
        events.extend(feed.into_iter().map(Event::Prediction));
        events.sort_by(|a, b| a.time().total_cmp(&b.time()));
        LogTrace { events, pos: 0, guard_t: horizon * 1e3 + 1e12 }
    }

    /// Number of events in the replayed window.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl EventSource for LogTrace {
    fn next_event(&mut self) -> Event {
        if self.pos < self.events.len() {
            let ev = self.events[self.pos];
            self.pos += 1;
            ev
        } else {
            // Inexhaustible guard: pushes the "next event" far beyond any
            // plausible makespan.
            self.guard_t *= 2.0;
            Event::Fault { t: self.guard_t, predicted: false }
        }
    }
}

/// Run one policy against a recorded log (fresh [`LogTrace`] per call).
pub fn replay(
    sc: &Scenario,
    policy: &crate::strategy::Policy,
    faults: &[f64],
    seed: u64,
) -> crate::sim::engine::SimOutcome {
    let trace = LogTrace::new(
        faults,
        &sc.predictor,
        sc.platform.cp,
        sc.platform.mu,
        sc.false_pred_law,
        seed,
    );
    crate::sim::engine::simulate_from(sc, policy, 1.0, seed, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform};
    use crate::sim::rng::Rng;
    use crate::strategy::registry;

    fn scenario(mu: f64) -> Scenario {
        Scenario {
            platform: Platform { mu, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e6,
        }
    }

    fn synth_log(n: usize, mean: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let d = crate::sim::distribution::Distribution::new(
            Law::Exponential,
            mean,
        );
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += d.sample(&mut rng);
                t
            })
            .collect()
    }

    #[test]
    fn log_roundtrip() {
        let faults = synth_log(200, 30_000.0, 1);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ckptwin-log-{}.txt", std::process::id()));
        write_failure_log(&path, &faults).unwrap();
        let back = read_failure_log(&path).unwrap();
        assert_eq!(faults.len(), back.len());
        for (a, b) in faults.iter().zip(&back) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn read_rejects_unsorted() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ckptwin-bad-{}.txt", std::process::id()));
        std::fs::write(&path, "100.0\n50.0\n").unwrap();
        assert!(read_failure_log(&path).is_err());
    }

    #[test]
    fn replay_completes_and_prediction_aware_wins() {
        let sc = scenario(30_000.0);
        let faults = synth_log(400, sc.platform.mu, 7);
        let ign = replay(&sc, &registry::get("RFO").unwrap().policy(&sc), &faults, 3);
        let aware = replay(&sc, &registry::get("NoCkptI").unwrap().policy(&sc), &faults, 3);
        assert!(ign.makespan >= sc.job_size);
        assert!(aware.makespan >= sc.job_size);
        assert!(ign.n_faults > 0);
        assert!(
            aware.waste() < ign.waste() + 0.02,
            "aware {} vs ignore {}",
            aware.waste(),
            ign.waste()
        );
    }

    #[test]
    fn empty_log_runs_fault_free() {
        let sc = scenario(30_000.0);
        let out = replay(&sc, &registry::get("Daly").unwrap().policy(&sc), &[], 1);
        assert_eq!(out.n_faults, 0);
        let pol = registry::get("Daly").unwrap().policy(&sc);
        let ideal = sc.platform.c / pol.tr;
        assert!((out.waste() - ideal).abs() < 0.01);
    }
}
