//! Lazy, time-sorted event streams: faults, true predictions (with their
//! windows), and false predictions.
//!
//! Following §4.1 of the paper: a random fault trace (Exponential or Weibull
//! inter-arrival, mean μ) is generated; each fault is *predicted* with
//! probability r (the recall), and its window is placed by the scenario's
//! predictor model ([`crate::predictor::model::PredictorModel`] — the
//! paper's model places the fault uniformly inside a fixed-length window
//! `[ws, ws + I]`, hence E_I^f = I/2; other registered models bias the
//! placement, mix window sizes, jitter the placement, or attach
//! confidence classes).  The prediction is made available exactly `C_p`
//! seconds before the window starts (§2.2 — earlier predictions are
//! indistinguishable, later ones useless).  A second, independent trace of
//! *false* predictions is generated with inter-arrival mean
//! `μ_P/(1-p) = pμ/(r(1-p))`, from either the same law or a Uniform law
//! (Figures 8–13), window shapes from the same model.  Both traces are
//! merged into one stream sorted by *engine-visible* time (prediction
//! notify time, fault strike time).  The substream generators
//! (`FaultGen`/`FpGen`) are also the implementation of the online
//! `predictor::feed`, so the offline trace and the online coordinator
//! consume one code path.
//!
//! The stream is unbounded and lazy: the simulated makespan is not known in
//! advance, so events are produced on demand with just enough look-ahead
//! (window + C_p) to guarantee global time order.
//!
//! Two interchangeable implementations produce the *same* event sequence
//! (same RNG streams, same total order; `tests/fast_path.rs` proves them
//! bit-identical):
//!
//! * [`TraceStream`] — the seed implementation: a `BinaryHeap` merge that
//!   pays a pop-and-refill per event.  Kept as the reference for golden
//!   tests and baselines (and by the coordinator, which is not hot).
//! * [`FlatTrace`] — the fast path: batched generation into flat,
//!   time-sorted `Vec<Event>` buffers (one horizon's worth of faults and
//!   false predictions per batch, two-pointer merged).  The per-processor
//!   Weibull superposition runs on a two-level timer wheel (`PerProcWheel`)
//!   instead of a heap: O(1) amortized insert/pop for the near-monotone
//!   renewal workload, struct-of-arrays buckets scanned linearly instead
//!   of pointer-chasing sift-downs.  With buffers (including the wheel's,
//!   see [`WheelBufs`]) recycled through a [`TraceArena`], steady-state
//!   simulation performs zero allocations per event.  The heap-based
//!   `PerProcSource` stays as the reference implementation inside
//!   [`TraceStream`]; `tests/fast_path.rs` and `tests/scale.rs` pin the
//!   two bit-identical (same RNG draw order).
//!
//! For platforms too large for one source, a sharded source (see
//! [`TraceCache::sharded`]) splits the processor pool into per-shard wheel
//! sources with derived seed streams and merges their heads — the campaign
//! layer uses this to spread one 10^6-proc platform across workers (see
//! DESIGN.md §Platform scale-out).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::config::{FaultModel, PredictorSpec, Scenario};
use crate::predictor::model::PredictorModel;
use crate::sim::distribution::{Distribution, Law};
use crate::sim::rng::Rng;
use crate::util::gamma;

/// A prediction event, visible to the engine at `notify_t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    /// When the predictor announces the window (= window_start - C_p).
    pub notify_t: f64,
    /// Window start t0.
    pub window_start: f64,
    /// Window end t0 + I.
    pub window_end: f64,
    /// True positive (an actual fault lies inside the window)?
    /// The engine must NOT branch on this — it is trace metadata used by
    /// statistics and tests only.
    pub true_positive: bool,
    /// Per-announcement trust weight: multiplies the engine's §3.1 trust
    /// probability q.  1.0 for single-class predictors (the paper's);
    /// confidence-classed predictors discount their low class (see
    /// [`crate::predictor::model::ClassedModel`]).  Unlike
    /// `true_positive`, the engine *may* branch on this — it is part of
    /// what the predictor announces.
    pub weight: f64,
}

/// An event as seen by the simulation engine, in visible-time order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A fault strikes at `t`. `predicted` is trace metadata (stats only).
    Fault { t: f64, predicted: bool },
    /// A prediction window is announced.
    Prediction(Prediction),
}

impl Event {
    /// The time at which the engine learns about this event.
    pub fn time(&self) -> f64 {
        match self {
            Event::Fault { t, .. } => *t,
            Event::Prediction(p) => p.notify_t,
        }
    }

    fn rank(&self) -> u8 {
        // Deterministic tie-break: faults before predictions at equal time.
        match self {
            Event::Fault { .. } => 0,
            Event::Prediction(_) => 1,
        }
    }
}

/// The total event order shared by the heap and flat implementations:
/// visible time, faults before predictions on ties.
fn event_order(a: &Event, b: &Event) -> Ordering {
    a.time()
        .total_cmp(&b.time())
        .then_with(|| a.rank().cmp(&b.rank()))
}

/// Min-heap wrapper with a total order on (time, rank).
#[derive(Clone, Copy, Debug)]
struct HeapEvent(Event);

impl PartialEq for HeapEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapEvent {}
impl PartialOrd for HeapEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest event.
        event_order(&other.0, &self.0)
    }
}

/// Total-ordered f64 wrapper for the per-processor failure heap.
#[derive(Clone, Copy, Debug, PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.total_cmp(&self.0) // reversed: min-heap
    }
}

/// Superposition of `n` independent per-processor Weibull(k, λ_ind)
/// renewal processes — the paper's fault-trace generator
/// (see [`FaultModel::PerProcessor`]).
///
/// Two start conventions:
/// * **fresh** (`stationary = false`, the paper's simulator and our
///   default): every processor starts a new lifetime at t = 0.  With
///   k < 1 the platform sees the superposed infant-mortality transient —
///   an effective fault rate far above 1/μ over a days-long job.  This is
///   what separates the Weibull results from the Exponential ones in the
///   paper's figures and tables.
/// * **stationary** (`stationary = true`, ablation): each processor's
///   first failure follows the *equilibrium* residual-life distribution,
///   whose survival is `S_eq(t) = Q(1/k, (t/λ)^k)` (regularized upper
///   incomplete gamma); the platform rate is exactly 1/μ.
///
/// Processors are i.i.d., so un-failed processors need no individual state:
/// the source keeps (i) a *pool count* of processors whose first failure
/// lies beyond the materialization `horizon`, and (ii) a priority structure
/// of materialized failure times.  Extending the horizon thins the pool
/// with geometric skipping over the conditional failure probability —
/// O(number of failures), never O(n).  Every popped failure pushes that
/// processor's next renewal (a fresh Weibull lifetime from the failure
/// instant).
///
/// The sampling math and RNG draw order live here; the priority structure
/// is supplied by the wrapper ([`PerProcSource`]'s `BinaryHeap` or
/// [`PerProcWheel`]'s timer wheel).  Because `extend_into` draws the RNG in
/// pool-index order — independent of where the failure times are stored —
/// and every pop draws exactly one renewal, any wrapper that pops times in
/// ascending `total_cmp` order produces a bit-identical platform trace.
struct PerProcCore {
    rng: Rng,
    shape: f64,
    /// Per-processor Weibull scale λ_ind = μ_ind / Γ(1 + 1/k).
    lambda: f64,
    stationary: bool,
    pool: u64,
    horizon: f64,
    step: f64,
}

/// Advance the geometric-skipping cursor: from processor index `i`, skip
/// `skip_f` non-failing processors (an f64 sampled as floor(lnU/ln(1-q))).
/// Returns the index of the next failing processor, or `None` when the
/// skip leaves the pool.  Integer-exact at any pool size: comparing
/// `i as f64 + skip_f >= pool as f64` in f64 loses precision once indices
/// exceed 2^53, silently failing (or double-counting) processors on
/// ≥ petascale pools, so the skip is saturated into u64 arithmetic first.
fn advance_index(i: u64, skip_f: f64, pool: u64) -> Option<u64> {
    if !skip_f.is_finite() || skip_f < 0.0 {
        return None;
    }
    // Saturate: any skip beyond u64::MAX is beyond every real pool.
    let skip = if skip_f >= u64::MAX as f64 { u64::MAX } else { skip_f as u64 };
    let idx = i.checked_add(skip)?;
    if idx >= pool {
        None
    } else {
        Some(idx)
    }
}

impl PerProcCore {
    fn new(
        n: u64,
        shape: f64,
        mu_ind: f64,
        step: f64,
        rng: Rng,
        stationary: bool,
    ) -> Self {
        // n = 0 has no failure to materialize, ever: next() would loop
        // forever extending the horizon.  Rejected at config parse and CLI
        // too; this is the last line of defence for programmatic callers.
        assert!(n > 0, "per-processor fault model requires n >= 1 processors");
        PerProcCore {
            rng,
            shape,
            lambda: mu_ind / gamma(1.0 + 1.0 / shape),
            stationary,
            pool: n,
            horizon: 0.0,
            step: step.max(1.0),
        }
    }

    /// (t/λ)^k — the cumulative hazard at t.
    #[inline]
    fn hazard(&self, t: f64) -> f64 {
        (t / self.lambda).powf(self.shape)
    }

    /// Survival function of a pool processor's first failure:
    /// fresh lifetime `exp(-(t/λ)^k)` or equilibrium residual life
    /// `Q(1/k, (t/λ)^k)`.
    #[inline]
    fn pool_survival(&self, t: f64) -> f64 {
        if self.stationary {
            crate::util::gammq(1.0 / self.shape, self.hazard(t))
        } else {
            (-self.hazard(t)).exp()
        }
    }

    /// Invert the pool survival on [h1, h2]: find t with S(t) = target.
    fn invert_survival(&self, h1: f64, h2: f64, target: f64) -> f64 {
        if !self.stationary {
            // Closed form: t = λ (-ln S)^{1/k}.
            let st = target.max(f64::MIN_POSITIVE);
            return self.lambda * (-st.ln()).powf(1.0 / self.shape);
        }
        let (mut lo, mut hi) = (h1, h2);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.pool_survival(mid) > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Materialize all pool (first-)failures in (horizon, horizon + step],
    /// handing each failure time to `push`.  Called by the wrapper when its
    /// structure holds nothing at or before the horizon.
    fn extend_into(&mut self, mut push: impl FnMut(f64)) {
        let h1 = self.horizon;
        let h2 = self.horizon + self.step;
        let (s1, s2) = (self.pool_survival(h1), self.pool_survival(h2));
        // Conditional first-failure probability in (h1, h2] given none yet.
        let q = if s1 > 0.0 { (s1 - s2) / s1 } else { 1.0 };
        self.horizon = h2;
        if q <= 0.0 || self.pool == 0 {
            return;
        }
        if q >= 1.0 - 1e-15 {
            // Everything fails this window.
            for _ in 0..self.pool {
                let u = self.rng.f64();
                let target = s1 - u * (s1 - s2);
                push(self.invert_survival(h1, h2, target));
            }
            self.pool = 0;
            return;
        }
        // Geometric skipping: next success index jump ~ floor(lnU/ln(1-q)).
        let ln1q = (1.0 - q).ln();
        let mut i: u64 = 0;
        let mut failures: u64 = 0;
        loop {
            let u = self.rng.f64_open();
            let skip = (u.ln() / ln1q).floor();
            let Some(idx) = advance_index(i, skip, self.pool) else {
                break;
            };
            // Processor idx fails in (h1, h2]; inverse-CDF its failure time.
            let u2 = self.rng.f64();
            let target = s1 - u2 * (s1 - s2);
            push(self.invert_survival(h1, h2, target));
            failures += 1;
            i = idx + 1;
            if i >= self.pool {
                break;
            }
        }
        self.pool -= failures;
    }

    /// The failed processor's next renewal: a fresh Weibull lifetime from
    /// the failure instant `t`.  Exactly one RNG draw per pop — part of the
    /// bit-identity contract between wrappers.
    #[inline]
    fn renew(&mut self, t: f64) -> f64 {
        let u = self.rng.f64_open();
        t + self.lambda * (-u.ln()).powf(1.0 / self.shape)
    }
}

/// Heap-backed per-processor superposition — the reference implementation
/// (used by [`TraceStream`]; [`FlatTrace`] runs the wheel).
struct PerProcSource {
    core: PerProcCore,
    heap: BinaryHeap<OrdF64>,
}

impl PerProcSource {
    fn new(
        n: u64,
        shape: f64,
        mu_ind: f64,
        step: f64,
        rng: Rng,
        stationary: bool,
    ) -> Self {
        PerProcSource {
            core: PerProcCore::new(n, shape, mu_ind, step, rng, stationary),
            heap: BinaryHeap::new(),
        }
    }

    /// Next platform failure time (monotone non-decreasing).
    fn next(&mut self) -> f64 {
        loop {
            if let Some(&OrdF64(t)) = self.heap.peek() {
                if t <= self.core.horizon || self.core.pool == 0 {
                    self.heap.pop();
                    let renewal = self.core.renew(t);
                    self.heap.push(OrdF64(renewal));
                    return t;
                }
            }
            let Self { core, heap } = self;
            core.extend_into(|t| heap.push(OrdF64(t)));
        }
    }
}

/// Number of buckets per wheel level.  256 level-0 buckets of width
/// `step/64` give a level-0 span of 4 materialization steps; 256 level-1
/// buckets of that span cover 1024 steps before anything lands in the
/// unsorted far-future overflow.
const WHEEL_BUCKETS: usize = 256;

/// The recyclable struct-of-arrays storage of a [`PerProcWheel`]: two
/// rings of flat time buckets plus the far-future overflow vector.
/// Travels through [`TraceBufs`] / [`TraceArena`] so repeated simulations
/// reuse the bucket allocations — zero per-event allocation at
/// steady state, like the event buffers.
#[derive(Default)]
pub struct WheelBufs {
    level0: Vec<Vec<f64>>,
    level1: Vec<Vec<f64>>,
    far: Vec<f64>,
}

impl WheelBufs {
    fn reset(&mut self) {
        self.level0.resize_with(WHEEL_BUCKETS, Vec::new);
        self.level1.resize_with(WHEEL_BUCKETS, Vec::new);
        for b in self.level0.iter_mut().chain(self.level1.iter_mut()) {
            b.clear();
        }
        self.far.clear();
    }
}

/// Scale-out health counters of a timer wheel (see
/// `obs::MetricsRegistry` wiring in `ckptwin metrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WheelStats {
    /// Failure times popped off the wheel.
    pub pops: u64,
    /// Empty level-0 buckets skipped while seeking the next event
    /// (amortized cost driver: bucket scans per event).
    pub bucket_scans: u64,
    /// Items moved down from level 1 or redistributed from the far-future
    /// overflow during a rebase.
    pub overflow_promotions: u64,
    /// Failure times currently resident in the wheel.
    pub occupancy: u64,
}

/// Two-level timer wheel over failure times: the calendar-queue
/// replacement for the per-processor `BinaryHeap`.
///
/// Layout: level 0 is a ring of [`WHEEL_BUCKETS`] buckets of width
/// `g = step/64` starting at `base0`; level 1 is a ring of
/// [`WHEEL_BUCKETS`] coarse buckets of width `span0 = 256·g` starting at
/// `base1`; times at or beyond `base1 + span1` wait unsorted in `far`.
/// Insert is O(1): two subtract-divide-index steps.  Pop drains the active
/// level-0 bucket (sorted on activation — buckets are small, a handful of
/// renewals each), advances across empty buckets, promotes the next coarse
/// bucket down when level 0 is exhausted, and rebases the whole wheel onto
/// `min(far)` when both levels run dry.
///
/// Why ordering holds: every insert is ≥ the last popped time (renewals
/// strictly advance; `extend_into` only materializes beyond the old
/// horizon, and pops stop at the horizon while the pool is non-empty), so
/// nothing ever lands behind the cursor; and the far boundary
/// `base1 + span1` is fixed between full rebases, so every far-resident
/// time exceeds every level-resident time.
struct TimerWheel {
    g: f64,
    span0: f64,
    span1: f64,
    base0: f64,
    base1: f64,
    /// Active level-0 bucket (index into `bufs.level0`).
    cur0: usize,
    /// Next level-1 coarse bucket to promote.  Invariant:
    /// `base0 = base1 + (cur1 - 1)·span0`.
    cur1: usize,
    /// Consumed prefix of the active (sorted) level-0 bucket.
    pos: usize,
    active_sorted: bool,
    len: u64,
    bufs: WheelBufs,
    stats: WheelStats,
}

impl TimerWheel {
    fn new(step: f64, mut bufs: WheelBufs) -> Self {
        bufs.reset();
        let g = step / 64.0;
        let span0 = g * WHEEL_BUCKETS as f64;
        TimerWheel {
            g,
            span0,
            span1: span0 * WHEEL_BUCKETS as f64,
            base0: 0.0,
            base1: 0.0,
            cur0: 0,
            cur1: 1,
            pos: 0,
            active_sorted: false,
            len: 0,
            bufs,
            stats: WheelStats::default(),
        }
    }

    /// File `t` into its bucket (no length bookkeeping — see [`insert`]).
    fn place(&mut self, t: f64) {
        let rel0 = t - self.base0;
        if rel0 < self.span0 {
            // Clamps absorb float rounding at bucket edges: an index below
            // the cursor (t at the very start of the active bucket) joins
            // the active bucket; an index of WHEEL_BUCKETS (t at the very
            // end of the span) joins the last bucket.
            let idx = ((rel0 / self.g) as usize)
                .min(WHEEL_BUCKETS - 1)
                .max(self.cur0);
            if idx == self.cur0 && self.active_sorted {
                // Same-bucket renewal: keep the consumed-prefix invariant
                // by sorted-inserting into the unconsumed tail.
                let tail = &self.bufs.level0[idx][self.pos..];
                let at = self.pos
                    + tail.partition_point(|x| x.total_cmp(&t) == Ordering::Less);
                self.bufs.level0[idx].insert(at, t);
            } else {
                self.bufs.level0[idx].push(t);
            }
            return;
        }
        let rel1 = t - self.base1;
        if rel1 < self.span1 {
            let idx = ((rel1 / self.span0) as usize)
                .min(WHEEL_BUCKETS - 1)
                .max(self.cur1);
            self.bufs.level1[idx].push(t);
            return;
        }
        self.bufs.far.push(t);
    }

    fn insert(&mut self, t: f64) {
        self.place(t);
        self.len += 1;
    }

    /// Earliest resident time, or `None` when the wheel is empty.  Pops in
    /// ascending `total_cmp` order — the heap-equivalence contract.
    fn pop_min(&mut self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        loop {
            // Drain the active level-0 bucket.
            while self.cur0 < WHEEL_BUCKETS {
                let bucket = &mut self.bufs.level0[self.cur0];
                if self.pos < bucket.len() {
                    if !self.active_sorted {
                        bucket.sort_unstable_by(|a, b| a.total_cmp(b));
                        self.active_sorted = true;
                    }
                    let t = bucket[self.pos];
                    self.pos += 1;
                    self.len -= 1;
                    self.stats.pops += 1;
                    return Some(t);
                }
                bucket.clear();
                self.pos = 0;
                self.active_sorted = false;
                self.cur0 += 1;
                self.stats.bucket_scans += 1;
            }
            // Level 0 exhausted: promote the next non-empty coarse bucket.
            while self.cur1 < WHEEL_BUCKETS && self.bufs.level1[self.cur1].is_empty()
            {
                self.cur1 += 1;
                self.stats.bucket_scans += 1;
            }
            if self.cur1 < WHEEL_BUCKETS {
                let j = self.cur1;
                self.base0 = self.base1 + j as f64 * self.span0;
                self.cur0 = 0;
                self.pos = 0;
                self.active_sorted = false;
                self.cur1 = j + 1;
                let items = std::mem::take(&mut self.bufs.level1[j]);
                self.stats.overflow_promotions += items.len() as u64;
                for t in &items {
                    let idx =
                        (((t - self.base0) / self.g) as usize).min(WHEEL_BUCKETS - 1);
                    self.bufs.level0[idx].push(*t);
                }
                // Hand the emptied coarse bucket's allocation back.
                self.bufs.level1[j] = { let mut v = items; v.clear(); v };
                continue;
            }
            // Both levels dry: rebase the wheel onto the far-future
            // overflow (len > 0 guarantees it is non-empty).
            let start = self
                .bufs
                .far
                .iter()
                .copied()
                .min_by(|a, b| a.total_cmp(b))
                .expect("wheel len > 0 with empty levels implies far items");
            self.base0 = start;
            self.base1 = start;
            self.cur0 = 0;
            self.cur1 = 1;
            self.pos = 0;
            self.active_sorted = false;
            let far = std::mem::take(&mut self.bufs.far);
            self.stats.overflow_promotions += far.len() as u64;
            for t in far {
                self.place(t);
            }
        }
    }
}

/// Wheel-backed per-processor superposition: the same sampling core (and
/// the same RNG draw order — bit-identical platform trace) as
/// [`PerProcSource`], with the `BinaryHeap` replaced by a [`TimerWheel`].
struct PerProcWheel {
    core: PerProcCore,
    wheel: TimerWheel,
}

impl PerProcWheel {
    fn new(
        n: u64,
        shape: f64,
        mu_ind: f64,
        step: f64,
        rng: Rng,
        stationary: bool,
        bufs: WheelBufs,
    ) -> Self {
        let core = PerProcCore::new(n, shape, mu_ind, step, rng, stationary);
        let wheel = TimerWheel::new(core.step, bufs);
        PerProcWheel { core, wheel }
    }

    /// Next platform failure time — the exact pop/renew/extend protocol of
    /// [`PerProcSource::next`].
    fn next(&mut self) -> f64 {
        loop {
            if let Some(t) = self.peek() {
                if t <= self.core.horizon || self.core.pool == 0 {
                    let t = self.wheel.pop_min().expect("peeked");
                    let renewal = self.core.renew(t);
                    self.wheel.insert(renewal);
                    return t;
                }
            }
            let Self { core, wheel } = self;
            core.extend_into(|t| wheel.insert(t));
        }
    }

    /// Earliest resident time without consuming it.
    fn peek(&mut self) -> Option<f64> {
        // pop_min leaves the popped value at `pos - 1` of the active
        // bucket; rewinding the consumed prefix un-pops it.
        let t = self.wheel.pop_min()?;
        self.wheel.pos -= 1;
        self.wheel.len += 1;
        self.wheel.stats.pops -= 1;
        Some(t)
    }

    fn stats(&self) -> WheelStats {
        WheelStats { occupancy: self.wheel.len, ..self.wheel.stats }
    }

    /// Recover the bucket storage for recycling.
    fn into_bufs(self) -> WheelBufs {
        self.wheel.bufs
    }
}

/// Derive shard `j`'s seed from the trace seed: a splitmix-style avalanche
/// of (seed, shard index), so per-shard `Rng::stream(...)` streams are
/// decorrelated from each other and from the unsharded stream.
fn shard_seed(seed: u64, shard: u32) -> u64 {
    let mut z = seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(shard as u64 + 1);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// S independent wheel sub-sources over a partition of the processor pool,
/// merged by a linear min-scan over their head times.  The superposition
/// of the S sub-superpositions is distributed identically to the single
/// n-processor source (processors are i.i.d.), but draws different RNG
/// streams — a shards ≠ 1 cell is its own trace definition, keyed by the
/// campaign's `;shards=` axis.
struct ShardedSource {
    subs: Vec<PerProcWheel>,
    /// Next undelivered failure time of each sub-source.
    heads: Vec<f64>,
    merges: u64,
}

impl ShardedSource {
    fn next(&mut self) -> f64 {
        // Linear min over S heads; ties break to the lowest shard index.
        let mut k = 0;
        for (j, t) in self.heads.iter().enumerate().skip(1) {
            if t.total_cmp(&self.heads[k]) == Ordering::Less {
                k = j;
            }
        }
        let t = self.heads[k];
        self.heads[k] = self.subs[k].next();
        self.merges += 1;
        t
    }
}

/// The fault arrival process feeding a trace.
enum FaultSource {
    /// Single renewal process at the platform level.
    Platform { dist: Distribution, rng: Rng, last: f64 },
    /// Per-processor superposition — heap reference implementation.
    PerProc(PerProcSource),
    /// Per-processor superposition — timer-wheel fast path.
    Wheel(PerProcWheel),
    /// Per-shard wheel sources merged by head time.
    Sharded(ShardedSource),
}

impl FaultSource {
    /// The per-processor superposition parameters of a scenario:
    /// `(n, shape, stationary)` — or `None` when the scenario runs a
    /// platform-level renewal process.
    ///
    /// A superposition of (fresh or stationary) exponential processes IS a
    /// Poisson process of rate n/μ_ind = 1/μ — the cheap platform
    /// equivalent is used.  LogNormal has no per-processor superposition
    /// implemented (the pool-thinning source is Weibull-specific), so it
    /// runs as a platform-level renewal process under every fault model
    /// (see DESIGN.md §Fault-model).
    fn per_proc_params(scenario: &Scenario) -> Option<(u64, f64, bool)> {
        match (scenario.fault_model, scenario.fault_law) {
            (FaultModel::PerProcessor { n }, Law::Weibull { shape }) => {
                Some((n, shape, false))
            }
            (FaultModel::PerProcessorStationary { n }, Law::Weibull { shape }) => {
                Some((n, shape, true))
            }
            _ => None,
        }
    }

    /// Platform-level renewal process (the non-superposed laws/models).
    fn platform(scenario: &Scenario, seed: u64) -> FaultSource {
        FaultSource::Platform {
            dist: Distribution::new(scenario.fault_law, scenario.platform.mu),
            rng: Rng::stream(seed, 0xf4017),
            last: 0.0,
        }
    }

    /// Materialization step of the per-processor sources: half the job (one
    /// extension usually suffices) but at least 50 platform MTBFs.
    fn step(scenario: &Scenario) -> f64 {
        (scenario.job_size * 0.5).max(50.0 * scenario.platform.mu)
    }

    /// Build the scenario's fault arrival process — heap-backed reference
    /// (the [`TraceStream`] seed path).  Shared wiring (same RNG stream
    /// ids, same model dispatch) with the fast constructors below is what
    /// keeps all paths bit-identical.
    fn for_scenario(scenario: &Scenario, seed: u64) -> FaultSource {
        match Self::per_proc_params(scenario) {
            None => Self::platform(scenario, seed),
            Some((n, shape, stationary)) => FaultSource::PerProc(PerProcSource::new(
                n,
                shape,
                scenario.platform.mu * n as f64, // μ_ind
                Self::step(scenario),
                Rng::stream(seed, 0xf4017),
                stationary,
            )),
        }
    }

    /// The fast-path equivalent of [`FaultSource::for_scenario`]: identical
    /// RNG wiring, timer wheel instead of heap, recycled bucket storage.
    fn for_scenario_fast(
        scenario: &Scenario,
        seed: u64,
        bufs: WheelBufs,
    ) -> FaultSource {
        match Self::per_proc_params(scenario) {
            None => Self::platform(scenario, seed),
            Some((n, shape, stationary)) => FaultSource::Wheel(PerProcWheel::new(
                n,
                shape,
                scenario.platform.mu * n as f64,
                Self::step(scenario),
                Rng::stream(seed, 0xf4017),
                stationary,
                bufs,
            )),
        }
    }

    /// Shard the scenario's processor pool into `shards` wheel sub-sources
    /// with derived seeds (see [`shard_seed`]) and merge their heads.
    /// Scenarios without a per-processor superposition (and `shards <= 1`)
    /// fall back to the unsharded fast path — sharding only changes the
    /// trace where a pool exists to split.
    fn for_scenario_sharded(scenario: &Scenario, seed: u64, shards: u32) -> FaultSource {
        let Some((n, shape, stationary)) = Self::per_proc_params(scenario) else {
            return Self::platform(scenario, seed);
        };
        if shards <= 1 || u64::from(shards) >= n {
            return Self::for_scenario_fast(scenario, seed, WheelBufs::default());
        }
        let s = u64::from(shards);
        let mut subs = Vec::with_capacity(shards as usize);
        for j in 0..shards {
            // First n % S shards take the remainder processor each.
            let n_j = n / s + u64::from(u64::from(j) < n % s);
            subs.push(PerProcWheel::new(
                n_j,
                shape,
                scenario.platform.mu * n as f64, // per-proc MTBF is global
                Self::step(scenario),
                Rng::stream(shard_seed(seed, j), 0xf4017),
                stationary,
                WheelBufs::default(),
            ));
        }
        let heads = subs.iter_mut().map(PerProcWheel::next).collect();
        FaultSource::Sharded(ShardedSource { subs, heads, merges: 0 })
    }

    fn next(&mut self) -> f64 {
        match self {
            FaultSource::Platform { dist, rng, last } => {
                *last += dist.sample(rng);
                *last
            }
            FaultSource::PerProc(src) => src.next(),
            FaultSource::Wheel(src) => src.next(),
            FaultSource::Sharded(src) => src.next(),
        }
    }

    /// Timer-wheel health counters (summed over shards), plus the shard
    /// merge count — `None` for sources without a wheel.
    fn wheel_stats(&self) -> Option<(WheelStats, u64)> {
        match self {
            FaultSource::Platform { .. } | FaultSource::PerProc(_) => None,
            FaultSource::Wheel(src) => Some((src.stats(), 0)),
            FaultSource::Sharded(src) => {
                let mut agg = WheelStats::default();
                for sub in &src.subs {
                    let s = sub.stats();
                    agg.pops += s.pops;
                    agg.bucket_scans += s.bucket_scans;
                    agg.overflow_promotions += s.overflow_promotions;
                    agg.occupancy += s.occupancy;
                }
                Some((agg, src.merges))
            }
        }
    }
}

/// Fault-substream event construction: the predictor model's recall coin
/// and window placement, plus the too-late-to-announce reclassification.
/// One shared implementation — used by the heap stream, the flat stream
/// AND the online `predictor::feed` — so every consumer draws the RNG
/// identically (that sharing is what makes the offline trace and the
/// online feed emit bit-identical announcements).
pub(crate) struct FaultGen {
    rng: Rng,
    model: Arc<dyn PredictorModel>,
    cp: f64,
}

impl FaultGen {
    /// Events for the fault striking at `tf`: the fault itself and, when
    /// predicted and announceable, its window.  RNG order is the model's
    /// contract ([`crate::predictor::model`]); the paper model draws the
    /// recall coin then a uniform window offset (E_I^f = I/2), exactly as
    /// the pre-trait generator did.
    pub(crate) fn events(&mut self, tf: f64) -> (Event, Option<Event>) {
        if let Some(w) = self.model.true_window(&mut self.rng, tf) {
            let notify = w.start - self.cp;
            if notify >= 0.0 {
                return (
                    Event::Fault { t: tf, predicted: w.covers },
                    Some(Event::Prediction(Prediction {
                        notify_t: notify,
                        window_start: w.start,
                        window_end: w.start + w.len,
                        true_positive: w.covers,
                        weight: w.weight,
                    })),
                );
            }
            // Prediction would be announced before t = 0: too late to act —
            // reclassify as unpredicted (§2.2).
        }
        (Event::Fault { t: tf, predicted: false }, None)
    }
}

/// False-prediction substream: raw window starts from `dist` (None when the
/// predictor emits no false predictions — p = 1 or r = 0), window shape
/// from the predictor model, announced `C_p` early; windows whose
/// announcement would land before t = 0 are dropped.
pub(crate) struct FpGen {
    dist: Option<Distribution>,
    rng: Rng,
    model: Arc<dyn PredictorModel>,
    cp: f64,
}

impl FpGen {
    /// Advance the raw cursor; returns the announcement event, if any.
    /// The window start IS the raw arrival (models choose only the shape),
    /// so this substream is generated in notify order by construction —
    /// the flat trace's merge relies on that.
    pub(crate) fn next(&mut self, last_raw: &mut f64) -> Option<Event> {
        let Some(dist) = self.dist else {
            *last_raw = f64::INFINITY;
            return None;
        };
        *last_raw += dist.sample(&mut self.rng);
        let (len, weight) = self.model.false_shape(&mut self.rng);
        let ws = *last_raw;
        let notify = ws - self.cp;
        if notify >= 0.0 {
            return Some(Event::Prediction(Prediction {
                notify_t: notify,
                window_start: ws,
                window_end: ws + len,
                true_positive: false,
                weight,
            }));
        }
        None
    }
}

/// The two prediction substream generators, wired identically for the
/// offline trace streams and the online [`crate::predictor::feed`]: same
/// stream ids, same model behaviour, same lead-time and t = 0 handling.
pub(crate) fn pred_gens(
    pred: &PredictorSpec,
    cp: f64,
    mu: f64,
    false_pred_law: Law,
    seed: u64,
) -> (FaultGen, FpGen) {
    let fp_dist = if pred.recall > 0.0 && pred.precision < 1.0 {
        Some(Distribution::new(false_pred_law, pred.mu_false(mu)))
    } else {
        None
    };
    // One behaviour object per trace, shared by both substreams.
    let model: Arc<dyn PredictorModel> =
        Arc::from(crate::predictor::model::instantiate(pred));
    let fault_gen = FaultGen {
        rng: Rng::stream(seed, 0x0fa17),
        model: Arc::clone(&model),
        cp,
    };
    let fp_gen = FpGen { dist: fp_dist, rng: Rng::stream(seed, 0xfa15e), model, cp };
    (fault_gen, fp_gen)
}

/// The three substream generators of a trace, wired identically for every
/// stream implementation ([`TraceStream`] and [`FlatTrace`]) — only the
/// fault-source backing differs, and the backings are bit-identical.
fn trace_parts_with(
    scenario: &Scenario,
    seed: u64,
    faults: FaultSource,
) -> (FaultSource, FaultGen, FpGen) {
    let (fault_gen, fp_gen) = pred_gens(
        &scenario.predictor,
        scenario.platform.cp,
        scenario.platform.mu,
        scenario.false_pred_law,
        seed,
    );
    (faults, fault_gen, fp_gen)
}

fn trace_parts(scenario: &Scenario, seed: u64) -> (FaultSource, FaultGen, FpGen) {
    trace_parts_with(scenario, seed, FaultSource::for_scenario(scenario, seed))
}

/// Unbounded, lazily generated, time-sorted event stream (heap-merged
/// reference implementation; see [`FlatTrace`] for the fast path).
pub struct TraceStream {
    faults: FaultSource,
    fault_gen: FaultGen,
    fp_gen: FpGen,
    /// Largest gap between a raw arrival and its earliest visible event:
    /// the predictor's longest window plus any placement slack (the lead
    /// time `cp` is added where the bound is applied).  Equals the window
    /// length I for the paper predictor.
    lookback: f64,
    cp: f64,
    last_fault_raw: f64,
    last_fp_raw: f64,
    heap: BinaryHeap<HeapEvent>,
}

impl TraceStream {
    /// Build the stream for a scenario.  `seed` fixes the whole trace: two
    /// strategies given the same (scenario, seed) see the *same* faults and
    /// predictions, as in the paper's per-instance comparisons.
    pub fn new(scenario: &Scenario, seed: u64) -> Self {
        let (faults, fault_gen, fp_gen) = trace_parts(scenario, seed);
        TraceStream {
            faults,
            fault_gen,
            fp_gen,
            lookback: scenario.predictor.max_window()
                + scenario.predictor.placement_slack(),
            cp: scenario.platform.cp,
            last_fault_raw: 0.0,
            last_fp_raw: 0.0,
            heap: BinaryHeap::new(),
        }
    }

    fn gen_fault(&mut self) {
        self.last_fault_raw = self.faults.next();
        let (fault, pred) = self.fault_gen.events(self.last_fault_raw);
        if let Some(p) = pred {
            self.heap.push(HeapEvent(p));
        }
        self.heap.push(HeapEvent(fault));
    }

    fn gen_fp(&mut self) {
        if let Some(ev) = self.fp_gen.next(&mut self.last_fp_raw) {
            self.heap.push(HeapEvent(ev));
        }
    }

    /// Produce the next event in visible-time order (never exhausts).
    pub fn next_event(&mut self) -> Event {
        loop {
            if let Some(HeapEvent(ev)) = self.heap.peek() {
                // A future raw arrival at time t can create an event no
                // earlier than t - lookback - cp; once both cursors are
                // past this horizon, the heap minimum is globally minimal.
                let safe = ev.time() + self.lookback + self.cp;
                if self.last_fault_raw > safe && self.last_fp_raw > safe {
                    return self.heap.pop().unwrap().0;
                }
            }
            if self.last_fault_raw <= self.last_fp_raw {
                self.gen_fault();
            } else {
                self.gen_fp();
            }
        }
    }

    /// Collect all events with visible time < `horizon` (test helper).
    pub fn take_until(&mut self, horizon: f64) -> Vec<Event> {
        let mut out = Vec::new();
        loop {
            let ev = self.next_event();
            if ev.time() >= horizon {
                // Push back so callers could continue (rarely needed).
                self.heap.push(HeapEvent(ev));
                return out;
            }
            out.push(ev);
        }
    }
}

/// Anything that can feed the engine a time-sorted event stream.
pub trait EventSource {
    fn next_event(&mut self) -> Event;
}

impl EventSource for TraceStream {
    fn next_event(&mut self) -> Event {
        TraceStream::next_event(self)
    }
}

impl<S: EventSource + ?Sized> EventSource for &mut S {
    fn next_event(&mut self) -> Event {
        (**self).next_event()
    }
}

/// The reusable flat buffers of a [`FlatTrace`]: pending fault-substream
/// events, pending false predictions, the merged batch being emitted, and
/// the timer wheel's bucket storage ([`WheelBufs`]).  Recycled through a
/// [`TraceArena`] so repeated simulations allocate nothing once the
/// buffers reach steady-state capacity.
#[derive(Default)]
pub struct TraceBufs {
    fault: Vec<Event>,
    fp: Vec<Event>,
    merged: Vec<Event>,
    wheel: WheelBufs,
}

impl TraceBufs {
    fn clear(&mut self) {
        self.fault.clear();
        self.fp.clear();
        self.merged.clear();
    }
}

/// Flat-buffer fast path: the same event sequence as [`TraceStream`], but
/// generated a horizon batch at a time instead of a heap op per event.
///
/// Each refill advances the emission horizon by one chunk, drains the raw
/// arrival processes far enough (horizon + window + C_p) that every event
/// below the horizon is known, sorts the fault-substream scratch vector
/// (predictions can precede earlier faults' strikes, so it is not generated
/// in order), and two-pointer merges it with the (naturally ordered)
/// false-prediction vector into the emission buffer.  Events beyond the
/// horizon stay in their scratch vectors for the next batch.
pub struct FlatTrace {
    faults: FaultSource,
    fault_gen: FaultGen,
    fp_gen: FpGen,
    /// See [`TraceStream`]: max window + placement slack.
    lookback: f64,
    cp: f64,
    last_fault_raw: f64,
    last_fp_raw: f64,
    /// Events with visible time < `horizon` have been merged already.
    horizon: f64,
    /// Horizon advance per refill (a few dozen platform MTBFs: enough to
    /// amortize the batch bookkeeping, small enough not to overshoot the
    /// makespan by much).
    chunk: f64,
    bufs: TraceBufs,
    pos: usize,
}

impl FlatTrace {
    /// Build the fast stream for a scenario (same seeding contract as
    /// [`TraceStream::new`]).
    pub fn new(scenario: &Scenario, seed: u64) -> Self {
        Self::with_bufs(scenario, seed, TraceBufs::default())
    }

    /// [`FlatTrace::new`] reusing previously allocated buffers (see
    /// [`TraceArena`]).  The wheel bucket storage rides inside `bufs` and
    /// is handed to the per-processor source when the scenario has one.
    pub fn with_bufs(scenario: &Scenario, seed: u64, mut bufs: TraceBufs) -> Self {
        bufs.clear();
        let wheel_bufs = std::mem::take(&mut bufs.wheel);
        let faults = FaultSource::for_scenario_fast(scenario, seed, wheel_bufs);
        Self::from_source(scenario, seed, faults, bufs)
    }

    /// A [`FlatTrace`] whose platform is split into `shards` per-shard
    /// wheel sources with derived seeds (see [`TraceCache::sharded`]).
    pub fn sharded(scenario: &Scenario, seed: u64, shards: u32) -> Self {
        let faults = FaultSource::for_scenario_sharded(scenario, seed, shards);
        Self::from_source(scenario, seed, faults, TraceBufs::default())
    }

    fn from_source(
        scenario: &Scenario,
        seed: u64,
        faults: FaultSource,
        bufs: TraceBufs,
    ) -> Self {
        let (faults, fault_gen, fp_gen) = trace_parts_with(scenario, seed, faults);
        let lookback = scenario.predictor.max_window()
            + scenario.predictor.placement_slack();
        let cp = scenario.platform.cp;
        FlatTrace {
            faults,
            fault_gen,
            fp_gen,
            lookback,
            cp,
            last_fault_raw: 0.0,
            last_fp_raw: 0.0,
            horizon: 0.0,
            chunk: (32.0 * scenario.platform.mu).max(8.0 * (lookback + cp)),
            bufs,
            pos: 0,
        }
    }

    /// Recover the buffers for reuse (see [`TraceArena::recycle`]),
    /// reclaiming the wheel's bucket storage when the source had one.
    pub fn into_bufs(self) -> TraceBufs {
        let mut bufs = self.bufs;
        if let FaultSource::Wheel(w) = self.faults {
            bufs.wheel = w.into_bufs();
        }
        bufs
    }

    /// Timer-wheel health counters and shard merge count of the backing
    /// fault source — `None` when the scenario runs a platform-level
    /// renewal process or the heap reference.  See `ckptwin metrics`.
    pub fn wheel_stats(&self) -> Option<(WheelStats, u64)> {
        self.faults.wheel_stats()
    }

    /// Generate and merge the next non-empty batch of events.
    fn refill(&mut self) {
        loop {
            let h = self.horizon + self.chunk;
            // Any event with visible time < h comes from a raw arrival at
            // or before h + lookback + cp (a fault strikes at its arrival;
            // a window opens at most lookback + cp after its announcement),
            // so draining both processes to there completes the batch.
            let gen_to = h + self.lookback + self.cp;
            while self.last_fault_raw <= gen_to {
                self.last_fault_raw = self.faults.next();
                let (fault, pred) = self.fault_gen.events(self.last_fault_raw);
                self.bufs.fault.push(fault);
                if let Some(p) = pred {
                    self.bufs.fault.push(p);
                }
            }
            while self.last_fp_raw <= gen_to {
                if let Some(ev) = self.fp_gen.next(&mut self.last_fp_raw) {
                    self.bufs.fp.push(ev);
                }
            }
            self.horizon = h;
            // In-place sort (carried tail + new events); the fp vector is
            // generated in notify order and needs none.
            self.bufs.fault.sort_unstable_by(event_order);
            self.bufs.merged.clear();
            self.pos = 0;
            let (mut i, mut j) = (0usize, 0usize);
            loop {
                let take_fault = match (self.bufs.fault.get(i), self.bufs.fp.get(j)) {
                    (None, None) => break,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (Some(a), Some(b)) => event_order(a, b) != Ordering::Greater,
                };
                let ev = if take_fault { self.bufs.fault[i] } else { self.bufs.fp[j] };
                if ev.time() >= h {
                    break; // beyond the horizon: belongs to a later batch
                }
                if take_fault {
                    i += 1;
                } else {
                    j += 1;
                }
                self.bufs.merged.push(ev);
            }
            self.bufs.fault.drain(..i);
            self.bufs.fp.drain(..j);
            if !self.bufs.merged.is_empty() {
                return;
            }
        }
    }
}

impl EventSource for FlatTrace {
    fn next_event(&mut self) -> Event {
        while self.pos == self.bufs.merged.len() {
            self.refill();
        }
        let ev = self.bufs.merged[self.pos];
        self.pos += 1;
        ev
    }
}

/// Recycler for [`TraceBufs`]: hand buffers from finished streams to new
/// ones so back-to-back simulations (a worker thread draining a campaign
/// queue, a harness seed sweep) allocate nothing per instance — and nothing
/// per event.
#[derive(Default)]
pub struct TraceArena {
    spare: Vec<TraceBufs>,
}

impl TraceArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A [`FlatTrace`] backed by recycled buffers when any are available.
    pub fn stream(&mut self, scenario: &Scenario, seed: u64) -> FlatTrace {
        FlatTrace::with_bufs(scenario, seed, self.spare.pop().unwrap_or_default())
    }

    /// Return a finished stream's buffers to the arena.
    pub fn recycle(&mut self, stream: FlatTrace) {
        self.spare.push(stream.into_bufs());
    }
}

/// Which generator backs a [`TraceCache`].
enum CacheSource {
    Fast(FlatTrace),
    Reference(TraceStream),
}

/// Memoized trace: generates events once and replays them for any number
/// of simulations of the SAME (scenario, seed).
///
/// The BestPeriod brute-force search simulates dozens of candidate periods
/// against identical traces, and the campaign runs several strategy
/// variants per fault environment; without caching, trace generation (RNG +
/// heaps + per-processor thinning) is regenerated per candidate and costs
/// a significant fraction of each run.  `TraceCache` pays it once.
pub struct TraceCache {
    source: CacheSource,
    events: Vec<Event>,
}

impl TraceCache {
    /// A cache backed by the flat fast path (the default).
    pub fn new(scenario: &Scenario, seed: u64) -> Self {
        TraceCache {
            source: CacheSource::Fast(FlatTrace::new(scenario, seed)),
            events: Vec::new(),
        }
    }

    /// A cache backed by a platform sharded into `shards` per-shard wheel
    /// sources (see [`FlatTrace::sharded`]).  `shards <= 1` — or a
    /// scenario without a per-processor pool to split — is exactly
    /// [`TraceCache::new`].
    pub fn sharded(scenario: &Scenario, seed: u64, shards: u32) -> Self {
        TraceCache {
            source: CacheSource::Fast(FlatTrace::sharded(scenario, seed, shards)),
            events: Vec::new(),
        }
    }

    /// A cache backed by the heap-merged seed stream — baselines and
    /// golden equivalence tests only.
    pub fn reference(scenario: &Scenario, seed: u64) -> Self {
        TraceCache {
            source: CacheSource::Reference(TraceStream::new(scenario, seed)),
            events: Vec::new(),
        }
    }

    /// Wheel/shard counters of the backing stream (see
    /// [`FlatTrace::wheel_stats`]).
    pub fn wheel_stats(&self) -> Option<(WheelStats, u64)> {
        match &self.source {
            CacheSource::Fast(s) => s.wheel_stats(),
            CacheSource::Reference(_) => None,
        }
    }

    /// A fresh replay cursor over this cache.
    pub fn replay(&mut self) -> Replay<'_> {
        Replay { cache: self, pos: 0 }
    }

    /// Materialize one more event from the backing stream.
    fn extend(&mut self) {
        let ev = match &mut self.source {
            CacheSource::Fast(s) => s.next_event(),
            CacheSource::Reference(s) => s.next_event(),
        };
        self.events.push(ev);
    }

    /// Events materialized so far (diagnostics; also the unit of the
    /// [`crate::campaign::TracePool`] memory budget).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Cursor over a [`TraceCache`]; extends the cache on demand.
pub struct Replay<'a> {
    cache: &'a mut TraceCache,
    pos: usize,
}

impl EventSource for Replay<'_> {
    fn next_event(&mut self) -> Event {
        if self.pos == self.cache.events.len() {
            self.cache.extend();
        }
        let ev = self.cache.events[self.pos];
        self.pos += 1;
        ev
    }
}

/// Measured platform fault rate (faults per second) of the scenario's
/// trace over `[0, horizon)` — the *true* superposed process, as opposed
/// to the `1/μ` approximation the closed forms assume.  Consumed by the
/// scale-conformance guard (`validate::domain::platform_rate_check`),
/// which compares the two at N = 10^4..10^6.
pub fn measured_fault_rate(scenario: &Scenario, seed: u64, horizon: f64) -> f64 {
    let mut ts = FlatTrace::new(scenario, seed);
    let mut faults = 0u64;
    loop {
        let ev = ts.next_event();
        if ev.time() >= horizon {
            return faults as f64 / horizon;
        }
        if matches!(ev, Event::Fault { .. }) {
            faults += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PredictorSpec, Scenario};
    use crate::sim::distribution::Law;

    fn scenario(recall: f64, precision: f64, window: f64) -> Scenario {
        Scenario {
            platform: crate::config::Platform {
                mu: 1000.0,
                c: 100.0,
                cp: 50.0,
                d: 10.0,
                r: 100.0,
            },
            predictor: PredictorSpec::paper(recall, precision, window),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e6,
        }
    }

    #[test]
    fn events_sorted_by_visible_time() {
        let sc = scenario(0.85, 0.82, 600.0);
        let mut ts = TraceStream::new(&sc, 1);
        let evs = ts.take_until(200_000.0);
        assert!(evs.len() > 100);
        for w in evs.windows(2) {
            assert!(w[0].time() <= w[1].time(), "{w:?}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let sc = scenario(0.7, 0.4, 300.0);
        let a = TraceStream::new(&sc, 9).take_until(50_000.0);
        let b = TraceStream::new(&sc, 9).take_until(50_000.0);
        assert_eq!(a, b);
        let c = TraceStream::new(&sc, 10).take_until(50_000.0);
        assert_ne!(a, c);
    }

    #[test]
    fn fault_rate_matches_mu() {
        let sc = scenario(0.85, 0.82, 600.0);
        let horizon = 2_000_000.0;
        let mut ts = TraceStream::new(&sc, 2);
        let faults = ts
            .take_until(horizon)
            .iter()
            .filter(|e| matches!(e, Event::Fault { .. }))
            .count();
        let expected = horizon / sc.platform.mu;
        let rel = (faults as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{faults} vs {expected}");
    }

    #[test]
    fn recall_fraction_of_faults_predicted() {
        let sc = scenario(0.85, 0.82, 600.0);
        let mut ts = TraceStream::new(&sc, 3);
        let evs = ts.take_until(3_000_000.0);
        let (mut pred, mut tot) = (0usize, 0usize);
        for e in &evs {
            if let Event::Fault { predicted, .. } = e {
                tot += 1;
                pred += *predicted as usize;
            }
        }
        let frac = pred as f64 / tot as f64;
        assert!((frac - 0.85).abs() < 0.03, "{frac} over {tot}");
    }

    #[test]
    fn predicted_fault_lies_inside_its_window() {
        let sc = scenario(1.0, 1.0, 600.0); // every fault predicted, no FPs
        let mut ts = TraceStream::new(&sc, 4);
        let evs = ts.take_until(1_000_000.0);
        let mut openings: Vec<Prediction> = Vec::new();
        let mut checked = 0;
        for e in &evs {
            match e {
                Event::Prediction(p) => {
                    assert!(p.true_positive);
                    assert!((p.window_end - p.window_start - 600.0).abs() < 1e-9);
                    assert!((p.window_start - p.notify_t - 50.0).abs() < 1e-9);
                    openings.push(*p);
                }
                Event::Fault { t, predicted: true } => {
                    // The matching window is the one containing t.
                    let hit = openings
                        .iter()
                        .any(|p| *t >= p.window_start && *t <= p.window_end);
                    assert!(hit, "fault at {t} outside every window");
                    checked += 1;
                }
                Event::Fault { predicted: false, .. } => {}
            }
        }
        assert!(checked > 100);
    }

    #[test]
    fn false_prediction_rate() {
        let sc = scenario(0.7, 0.4, 300.0);
        // μ_false = pμ/(r(1-p)) = 0.4*1000/(0.7*0.6) ≈ 952.4
        let mu_false = sc.predictor.mu_false(sc.platform.mu);
        let horizon = 3_000_000.0;
        let mut ts = TraceStream::new(&sc, 5);
        let fps = ts
            .take_until(horizon)
            .iter()
            .filter(
                |e| matches!(e, Event::Prediction(p) if !p.true_positive),
            )
            .count();
        let expected = horizon / mu_false;
        let rel = (fps as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{fps} vs {expected}");
    }

    #[test]
    fn perfect_precision_has_no_false_predictions() {
        let sc = scenario(0.9, 1.0, 300.0);
        let mut ts = TraceStream::new(&sc, 6);
        let fps = ts
            .take_until(500_000.0)
            .iter()
            .filter(
                |e| matches!(e, Event::Prediction(p) if !p.true_positive),
            )
            .count();
        assert_eq!(fps, 0);
    }

    #[test]
    fn zero_recall_means_no_predictions() {
        let sc = scenario(0.0, 0.5, 300.0);
        let mut ts = TraceStream::new(&sc, 7);
        let evs = ts.take_until(500_000.0);
        assert!(evs
            .iter()
            .all(|e| matches!(e, Event::Fault { predicted: false, .. })));
    }

    fn paper_scenario(model: FaultModel, shape: f64) -> Scenario {
        let n = 1u64 << 18;
        let mut sc = Scenario::paper(
            n,
            1.0,
            PredictorSpec::paper_a(600.0),
            Law::Weibull { shape },
            Law::Weibull { shape },
        );
        sc.fault_model = model;
        sc
    }

    fn fault_count(sc: &Scenario, horizon: f64, seed: u64) -> usize {
        TraceStream::new(sc, seed)
            .take_until(horizon)
            .iter()
            .filter(|e| matches!(e, Event::Fault { .. }))
            .count()
    }

    #[test]
    fn stationary_per_proc_rate_is_one_over_mu() {
        let sc = paper_scenario(
            FaultModel::PerProcessorStationary { n: 1 << 18 },
            0.7,
        );
        let horizon = 60.0 * sc.platform.mu;
        let mut total = 0usize;
        for seed in 0..12 {
            total += fault_count(&sc, horizon, seed);
        }
        let expected = 12.0 * horizon / sc.platform.mu;
        let rel = (total as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{total} vs {expected}");
    }

    #[test]
    fn fresh_per_proc_rate_exceeds_one_over_mu() {
        // Infant mortality: the fresh-start transient fault rate is far
        // above the steady state for k < 1 over a job-sized horizon.
        let sc = paper_scenario(FaultModel::PerProcessor { n: 1 << 18 }, 0.7);
        let horizon = 60.0 * sc.platform.mu;
        let count = fault_count(&sc, horizon, 3);
        let steady = horizon / sc.platform.mu;
        assert!(
            count as f64 > 3.0 * steady,
            "fresh rate {count} vs steady {steady}"
        );
        // And k = 0.5 is even more extreme than k = 0.7.
        let sc5 = paper_scenario(FaultModel::PerProcessor { n: 1 << 18 }, 0.5);
        let count5 = fault_count(&sc5, horizon, 3);
        assert!(count5 > count, "{count5} vs {count}");
    }

    #[test]
    fn per_proc_stream_sorted_and_deterministic() {
        for model in [
            FaultModel::PerProcessor { n: 1 << 16 },
            FaultModel::PerProcessorStationary { n: 1 << 16 },
        ] {
            let mut sc = paper_scenario(model, 0.5);
            sc.fault_model = model;
            let horizon = 20.0 * sc.platform.mu;
            let a = TraceStream::new(&sc, 9).take_until(horizon);
            let b = TraceStream::new(&sc, 9).take_until(horizon);
            assert_eq!(a, b);
            for w in a.windows(2) {
                assert!(w[0].time() <= w[1].time());
            }
        }
    }

    #[test]
    fn per_proc_exponential_equals_platform_renewal() {
        // Fresh exponential superposition IS Poisson(1/μ): the stream must
        // be bit-identical to the platform-renewal shortcut.
        let mut sc = paper_scenario(FaultModel::PerProcessor { n: 1 << 18 }, 0.7);
        sc.fault_law = Law::Exponential;
        sc.false_pred_law = Law::Exponential;
        let a = TraceStream::new(&sc, 4).take_until(10.0 * sc.platform.mu);
        sc.fault_model = FaultModel::PlatformRenewal;
        let b = TraceStream::new(&sc, 4).take_until(10.0 * sc.platform.mu);
        assert_eq!(a, b);
    }

    #[test]
    fn replay_matches_stream_and_is_reusable() {
        let sc = scenario(0.85, 0.82, 600.0);
        let direct = TraceStream::new(&sc, 21).take_until(100_000.0);
        let mut cache = TraceCache::new(&sc, 21);
        for _ in 0..3 {
            let mut cur = cache.replay();
            for want in &direct {
                assert_eq!(cur.next_event(), *want);
            }
        }
        assert!(cache.len() >= direct.len());
    }

    #[test]
    fn uniform_false_pred_law() {
        let mut sc = scenario(0.7, 0.4, 300.0);
        sc.false_pred_law = Law::Uniform;
        let mu_false = sc.predictor.mu_false(sc.platform.mu);
        let mut ts = TraceStream::new(&sc, 8);
        let evs = ts.take_until(2_000_000.0);
        let fps: Vec<f64> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Prediction(p) if !p.true_positive => {
                    Some(p.window_start)
                }
                _ => None,
            })
            .collect();
        let expected = 2_000_000.0 / mu_false;
        let rel = (fps.len() as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{} vs {expected}", fps.len());
    }

    #[test]
    fn flat_stream_matches_heap_stream() {
        // Event-by-event equality of the fast path and the reference heap
        // stream, across the fault models and a false-prediction mix.
        for (sc, n_events) in [
            (scenario(0.85, 0.82, 600.0), 4000),
            (scenario(0.7, 0.4, 300.0), 4000),
            (scenario(0.0, 0.5, 300.0), 500),
            (paper_scenario(FaultModel::PerProcessor { n: 1 << 16 }, 0.7), 2000),
            (
                paper_scenario(
                    FaultModel::PerProcessorStationary { n: 1 << 16 },
                    0.5,
                ),
                500,
            ),
        ] {
            let mut heap = TraceStream::new(&sc, 11);
            let mut flat = FlatTrace::new(&sc, 11);
            for k in 0..n_events {
                assert_eq!(heap.next_event(), flat.next_event(), "event {k}");
            }
        }
    }

    #[test]
    fn arena_recycled_stream_is_identical() {
        let sc = scenario(0.85, 0.82, 600.0);
        let mut want = Vec::new();
        let mut fresh = FlatTrace::new(&sc, 5);
        for _ in 0..1500 {
            want.push(fresh.next_event());
        }
        let mut arena = TraceArena::new();
        for _ in 0..3 {
            let mut ts = arena.stream(&sc, 5);
            for w in &want {
                assert_eq!(ts.next_event(), *w);
            }
            arena.recycle(ts);
        }
    }

    #[test]
    fn reference_cache_matches_fast_cache() {
        let sc = scenario(0.7, 0.4, 300.0);
        let mut fast = TraceCache::new(&sc, 13);
        let mut reference = TraceCache::reference(&sc, 13);
        let (mut a, mut b) = (fast.replay(), reference.replay());
        for _ in 0..3000 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }

    #[test]
    fn advance_index_is_integer_exact() {
        // Plain in-range skip and the exact pool-boundary miss.
        assert_eq!(advance_index(5, 3.0, 9), Some(8));
        assert_eq!(advance_index(5, 4.0, 9), None);
        assert_eq!(advance_index(0, 0.0, 1), Some(0));
        // Non-finite and absurd skips leave the pool.
        assert_eq!(advance_index(0, f64::INFINITY, 100), None);
        assert_eq!(advance_index(0, 1e300, 1 << 60), None);
        // At pool counts beyond 2^53 the old `i as f64 + skip >= pool as
        // f64` comparison rounded (1<<60 - 1) + 0 up to the pool size and
        // wrongly dropped the last processor.
        assert_eq!(advance_index((1 << 60) - 1, 0.0, 1 << 60), Some((1 << 60) - 1));
        assert_eq!(advance_index(u64::MAX - 1, 0.0, u64::MAX), Some(u64::MAX - 1));
        assert_eq!(advance_index(u64::MAX, 5.0, u64::MAX), None);
    }

    #[test]
    #[should_panic(expected = "n >= 1")]
    fn zero_processor_pool_is_rejected() {
        // Regression: n = 0 used to loop forever in next(), extending the
        // horizon with nothing to materialize.
        PerProcSource::new(0, 0.7, 1e6, 1e4, Rng::new(1), false);
    }

    #[test]
    fn wheel_source_matches_heap_source() {
        // Unit-level wheel-vs-heap bit identity (the integration suite in
        // tests/scale.rs covers the full law × convention × seed matrix).
        for stationary in [false, true] {
            let mut heap =
                PerProcSource::new(1 << 14, 0.7, 6e7, 2e5, Rng::new(5), stationary);
            let mut wheel = PerProcWheel::new(
                1 << 14,
                0.7,
                6e7,
                2e5,
                Rng::new(5),
                stationary,
                WheelBufs::default(),
            );
            for k in 0..20_000 {
                let (a, b) = (heap.next(), wheel.next());
                assert!(a.to_bits() == b.to_bits(), "event {k}: {a} vs {b}");
            }
            let stats = wheel.stats();
            assert_eq!(stats.pops, 20_000);
            assert!(stats.occupancy > 0);
        }
    }

    #[test]
    fn timer_wheel_orders_across_levels_and_far_overflow() {
        // Direct wheel exercise across all three tiers: level 0 (< 256),
        // level 1 (< 65536) and the far-future overflow, with bucket-edge
        // times and inserts interleaved with pops (every insert ≥ the last
        // popped time, as the renewal workload guarantees).
        let mut w = TimerWheel::new(64.0, WheelBufs::default()); // g=1
        assert_eq!(w.span0, 256.0);
        assert_eq!(w.span1, 65536.0);
        let first = [
            0.5, 3.0, 3.0, 7.25, 255.9, 256.0, 300.0, 1000.0, 65535.9, 65536.0,
            1e9, 2e9,
        ];
        for &t in &first {
            w.insert(t);
        }
        let mut expect: Vec<f64> = first.to_vec();
        expect.sort_by(|a, b| a.total_cmp(b));
        for want in expect.drain(..expect.len() - 5) {
            assert_eq!(w.pop_min().unwrap().to_bits(), want.to_bits());
        }
        // Last popped was 300.0; interleave inserts at every tier, one of
        // them into the just-drained active bucket's own range.
        for t in [300.5, 64000.0, 70000.0, 3e9] {
            w.insert(t);
            expect.push(t);
        }
        expect.sort_by(|a, b| a.total_cmp(b));
        for want in expect {
            assert_eq!(w.pop_min().unwrap().to_bits(), want.to_bits());
        }
        assert!(w.pop_min().is_none());
        assert_eq!(w.len, 0);
        assert!(w.stats.overflow_promotions > 0, "far/level-1 path never exercised");
        assert!(w.stats.bucket_scans > 0);
        assert_eq!(w.stats.pops, 16);
    }

    #[test]
    fn sharded_stream_is_deterministic_and_sorted() {
        let sc = paper_scenario(FaultModel::PerProcessorStationary { n: 1 << 16 }, 0.7);
        let horizon = 20.0 * sc.platform.mu;
        let mut a = FlatTrace::sharded(&sc, 11, 4);
        let mut b = FlatTrace::sharded(&sc, 11, 4);
        let mut last = f64::NEG_INFINITY;
        loop {
            let (ea, eb) = (a.next_event(), b.next_event());
            assert_eq!(ea, eb);
            if ea.time() >= horizon {
                break;
            }
            assert!(ea.time() >= last);
            last = ea.time();
        }
        let (stats, merges) = a.wheel_stats().expect("sharded wheel");
        assert!(merges > 0, "no shard merges counted");
        assert!(stats.pops > 0);
        // A different shard count is a different trace definition.
        let e2 = FlatTrace::sharded(&sc, 11, 2).next_event();
        let e4 = FlatTrace::sharded(&sc, 11, 4).next_event();
        assert_ne!(e2, e4);
    }

    #[test]
    fn sharded_rate_matches_unsharded() {
        // Splitting an i.i.d. pool cannot change the platform rate: the
        // stationary superposition stays at 1/μ for any shard count.
        let sc = paper_scenario(FaultModel::PerProcessorStationary { n: 1 << 16 }, 0.7);
        // ~1200 expected faults: sampling σ ≈ 2.9%, so the 10% tolerance
        // sits beyond 3σ.
        let horizon = 150.0 * sc.platform.mu;
        let mut total = 0usize;
        for seed in 0..8 {
            let mut ts = FlatTrace::sharded(&sc, seed, 8);
            loop {
                let ev = ts.next_event();
                if ev.time() >= horizon {
                    break;
                }
                total += matches!(ev, Event::Fault { .. }) as usize;
            }
        }
        let expected = 8.0 * horizon / sc.platform.mu;
        let rel = (total as f64 - expected).abs() / expected;
        assert!(rel < 0.10, "{total} vs {expected}");
    }

    #[test]
    fn single_shard_equals_unsharded_fast_path() {
        let sc = paper_scenario(FaultModel::PerProcessor { n: 1 << 16 }, 0.7);
        let mut plain = FlatTrace::new(&sc, 3);
        let mut one = FlatTrace::sharded(&sc, 3, 1);
        for _ in 0..2000 {
            assert_eq!(plain.next_event(), one.next_event());
        }
    }

    #[test]
    fn measured_rate_helper_agrees_with_stationary_theory() {
        let sc = paper_scenario(FaultModel::PerProcessorStationary { n: 1 << 16 }, 0.7);
        // 6 seeds × 200 MTBFs ≈ 1200 faults: σ ≈ 2.9% ⇒ 10% is > 3σ.
        let horizon = 200.0 * sc.platform.mu;
        let mut acc = 0.0;
        for seed in 0..6 {
            acc += measured_fault_rate(&sc, seed, horizon);
        }
        let rel = (acc / 6.0 * sc.platform.mu - 1.0).abs();
        assert!(rel < 0.10, "mean rate·μ = {}", acc / 6.0 * sc.platform.mu);
    }
}
