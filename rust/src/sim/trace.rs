//! Lazy, time-sorted event streams: faults, true predictions (with their
//! windows), and false predictions.
//!
//! Following §4.1 of the paper: a random fault trace (Exponential or Weibull
//! inter-arrival, mean μ) is generated; each fault is *predicted* with
//! probability r (the recall), and its window is placed by the scenario's
//! predictor model ([`crate::predictor::model::PredictorModel`] — the
//! paper's model places the fault uniformly inside a fixed-length window
//! `[ws, ws + I]`, hence E_I^f = I/2; other registered models bias the
//! placement, mix window sizes, jitter the placement, or attach
//! confidence classes).  The prediction is made available exactly `C_p`
//! seconds before the window starts (§2.2 — earlier predictions are
//! indistinguishable, later ones useless).  A second, independent trace of
//! *false* predictions is generated with inter-arrival mean
//! `μ_P/(1-p) = pμ/(r(1-p))`, from either the same law or a Uniform law
//! (Figures 8–13), window shapes from the same model.  Both traces are
//! merged into one stream sorted by *engine-visible* time (prediction
//! notify time, fault strike time).  The substream generators
//! (`FaultGen`/`FpGen`) are also the implementation of the online
//! `predictor::feed`, so the offline trace and the online coordinator
//! consume one code path.
//!
//! The stream is unbounded and lazy: the simulated makespan is not known in
//! advance, so events are produced on demand with just enough look-ahead
//! (window + C_p) to guarantee global time order.
//!
//! Two interchangeable implementations produce the *same* event sequence
//! (same RNG streams, same total order; `tests/fast_path.rs` proves them
//! bit-identical):
//!
//! * [`TraceStream`] — the seed implementation: a `BinaryHeap` merge that
//!   pays a pop-and-refill per event.  Kept as the reference for golden
//!   tests and baselines (and by the coordinator, which is not hot).
//! * [`FlatTrace`] — the fast path: batched generation into flat,
//!   time-sorted `Vec<Event>` buffers (one horizon's worth of faults and
//!   false predictions per batch, two-pointer merged).  The only heap left
//!   is the one inside the per-processor Weibull superposition, where it is
//!   genuinely needed.  With buffers recycled through a [`TraceArena`],
//!   steady-state simulation performs zero allocations per event.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::config::{FaultModel, PredictorSpec, Scenario};
use crate::predictor::model::PredictorModel;
use crate::sim::distribution::{Distribution, Law};
use crate::sim::rng::Rng;
use crate::util::gamma;

/// A prediction event, visible to the engine at `notify_t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    /// When the predictor announces the window (= window_start - C_p).
    pub notify_t: f64,
    /// Window start t0.
    pub window_start: f64,
    /// Window end t0 + I.
    pub window_end: f64,
    /// True positive (an actual fault lies inside the window)?
    /// The engine must NOT branch on this — it is trace metadata used by
    /// statistics and tests only.
    pub true_positive: bool,
    /// Per-announcement trust weight: multiplies the engine's §3.1 trust
    /// probability q.  1.0 for single-class predictors (the paper's);
    /// confidence-classed predictors discount their low class (see
    /// [`crate::predictor::model::ClassedModel`]).  Unlike
    /// `true_positive`, the engine *may* branch on this — it is part of
    /// what the predictor announces.
    pub weight: f64,
}

/// An event as seen by the simulation engine, in visible-time order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A fault strikes at `t`. `predicted` is trace metadata (stats only).
    Fault { t: f64, predicted: bool },
    /// A prediction window is announced.
    Prediction(Prediction),
}

impl Event {
    /// The time at which the engine learns about this event.
    pub fn time(&self) -> f64 {
        match self {
            Event::Fault { t, .. } => *t,
            Event::Prediction(p) => p.notify_t,
        }
    }

    fn rank(&self) -> u8 {
        // Deterministic tie-break: faults before predictions at equal time.
        match self {
            Event::Fault { .. } => 0,
            Event::Prediction(_) => 1,
        }
    }
}

/// The total event order shared by the heap and flat implementations:
/// visible time, faults before predictions on ties.
fn event_order(a: &Event, b: &Event) -> Ordering {
    a.time()
        .total_cmp(&b.time())
        .then_with(|| a.rank().cmp(&b.rank()))
}

/// Min-heap wrapper with a total order on (time, rank).
#[derive(Clone, Copy, Debug)]
struct HeapEvent(Event);

impl PartialEq for HeapEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapEvent {}
impl PartialOrd for HeapEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest event.
        event_order(&other.0, &self.0)
    }
}

/// Total-ordered f64 wrapper for the per-processor failure heap.
#[derive(Clone, Copy, Debug, PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.total_cmp(&self.0) // reversed: min-heap
    }
}

/// Superposition of `n` independent per-processor Weibull(k, λ_ind)
/// renewal processes — the paper's fault-trace generator
/// (see [`FaultModel::PerProcessor`]).
///
/// Two start conventions:
/// * **fresh** (`stationary = false`, the paper's simulator and our
///   default): every processor starts a new lifetime at t = 0.  With
///   k < 1 the platform sees the superposed infant-mortality transient —
///   an effective fault rate far above 1/μ over a days-long job.  This is
///   what separates the Weibull results from the Exponential ones in the
///   paper's figures and tables.
/// * **stationary** (`stationary = true`, ablation): each processor's
///   first failure follows the *equilibrium* residual-life distribution,
///   whose survival is `S_eq(t) = Q(1/k, (t/λ)^k)` (regularized upper
///   incomplete gamma); the platform rate is exactly 1/μ.
///
/// Processors are i.i.d., so un-failed processors need no individual state:
/// the source keeps (i) a *pool count* of processors whose first failure
/// lies beyond the materialization `horizon`, and (ii) a min-heap of
/// materialized failure times.  Extending the horizon thins the pool with
/// geometric skipping over the conditional failure probability — O(number
/// of failures), never O(n).  Every popped failure pushes that processor's
/// next renewal (a fresh Weibull lifetime from the failure instant).
struct PerProcSource {
    rng: Rng,
    shape: f64,
    /// Per-processor Weibull scale λ_ind = μ_ind / Γ(1 + 1/k).
    lambda: f64,
    stationary: bool,
    pool: u64,
    horizon: f64,
    step: f64,
    heap: BinaryHeap<OrdF64>,
}

impl PerProcSource {
    fn new(
        n: u64,
        shape: f64,
        mu_ind: f64,
        step: f64,
        rng: Rng,
        stationary: bool,
    ) -> Self {
        PerProcSource {
            rng,
            shape,
            lambda: mu_ind / gamma(1.0 + 1.0 / shape),
            stationary,
            pool: n,
            horizon: 0.0,
            step: step.max(1.0),
            heap: BinaryHeap::new(),
        }
    }

    /// (t/λ)^k — the cumulative hazard at t.
    #[inline]
    fn hazard(&self, t: f64) -> f64 {
        (t / self.lambda).powf(self.shape)
    }

    /// Survival function of a pool processor's first failure:
    /// fresh lifetime `exp(-(t/λ)^k)` or equilibrium residual life
    /// `Q(1/k, (t/λ)^k)`.
    #[inline]
    fn pool_survival(&self, t: f64) -> f64 {
        if self.stationary {
            crate::util::gammq(1.0 / self.shape, self.hazard(t))
        } else {
            (-self.hazard(t)).exp()
        }
    }

    /// Invert the pool survival on [h1, h2]: find t with S(t) = target.
    fn invert_survival(&self, h1: f64, h2: f64, target: f64) -> f64 {
        if !self.stationary {
            // Closed form: t = λ (-ln S)^{1/k}.
            let st = target.max(f64::MIN_POSITIVE);
            return self.lambda * (-st.ln()).powf(1.0 / self.shape);
        }
        let (mut lo, mut hi) = (h1, h2);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.pool_survival(mid) > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Materialize all pool (first-)failures in (horizon, horizon + step].
    fn extend(&mut self) {
        let h1 = self.horizon;
        let h2 = self.horizon + self.step;
        let (s1, s2) = (self.pool_survival(h1), self.pool_survival(h2));
        // Conditional first-failure probability in (h1, h2] given none yet.
        let q = if s1 > 0.0 { (s1 - s2) / s1 } else { 1.0 };
        self.horizon = h2;
        if q <= 0.0 || self.pool == 0 {
            return;
        }
        if q >= 1.0 - 1e-15 {
            // Everything fails this window.
            for _ in 0..self.pool {
                let u = self.rng.f64();
                let target = s1 - u * (s1 - s2);
                self.heap.push(OrdF64(self.invert_survival(h1, h2, target)));
            }
            self.pool = 0;
            return;
        }
        // Geometric skipping: next success index jump ~ floor(lnU/ln(1-q)).
        let ln1q = (1.0 - q).ln();
        let mut i: u64 = 0;
        let mut failures: u64 = 0;
        loop {
            let u = self.rng.f64_open();
            let skip = (u.ln() / ln1q).floor();
            if !skip.is_finite() || i as f64 + skip >= self.pool as f64 {
                break;
            }
            i += skip as u64;
            // Processor i fails in (h1, h2]; inverse-CDF its failure time.
            let u2 = self.rng.f64();
            let target = s1 - u2 * (s1 - s2);
            self.heap.push(OrdF64(self.invert_survival(h1, h2, target)));
            failures += 1;
            i += 1;
            if i >= self.pool {
                break;
            }
        }
        self.pool -= failures;
    }

    /// Next platform failure time (monotone non-decreasing).
    fn next(&mut self) -> f64 {
        loop {
            if let Some(&OrdF64(t)) = self.heap.peek() {
                if t <= self.horizon || self.pool == 0 {
                    self.heap.pop();
                    // The failed processor renews fresh from t.
                    let u = self.rng.f64_open();
                    let renewal =
                        t + self.lambda * (-u.ln()).powf(1.0 / self.shape);
                    self.heap.push(OrdF64(renewal));
                    return t;
                }
            }
            self.extend();
        }
    }
}

/// The fault arrival process feeding a trace.
enum FaultSource {
    /// Single renewal process at the platform level.
    Platform { dist: Distribution, rng: Rng, last: f64 },
    /// Per-processor superposition (fresh Weibull processes).
    PerProc(PerProcSource),
}

impl FaultSource {
    /// Build the scenario's fault arrival process.  Shared by the heap
    /// reference stream and the flat fast path — identical wiring (same
    /// RNG stream ids, same model dispatch) is what keeps the two
    /// bit-identical.
    fn for_scenario(scenario: &Scenario, seed: u64) -> FaultSource {
        let mu = scenario.platform.mu;
        match (scenario.fault_model, scenario.fault_law) {
            // A superposition of (fresh or stationary) exponential
            // processes IS a Poisson process of rate n/μ_ind = 1/μ — use
            // the cheap equivalent.  LogNormal has no per-processor
            // superposition implemented (the pool-thinning source is
            // Weibull-specific), so it runs as a platform-level renewal
            // process under every fault model (see DESIGN.md §Fault-model).
            (FaultModel::PlatformRenewal, law)
            | (FaultModel::PerProcessor { .. }, law @ Law::Exponential)
            | (FaultModel::PerProcessor { .. }, law @ Law::Uniform)
            | (FaultModel::PerProcessor { .. }, law @ Law::LogNormal { .. })
            | (FaultModel::PerProcessorStationary { .. }, law @ Law::Exponential)
            | (FaultModel::PerProcessorStationary { .. }, law @ Law::Uniform)
            | (FaultModel::PerProcessorStationary { .. }, law @ Law::LogNormal { .. }) => {
                FaultSource::Platform {
                    dist: Distribution::new(law, mu),
                    rng: Rng::stream(seed, 0xf4017),
                    last: 0.0,
                }
            }
            (FaultModel::PerProcessor { n }, Law::Weibull { shape }) => {
                FaultSource::PerProc(PerProcSource::new(
                    n,
                    shape,
                    mu * n as f64, // μ_ind
                    (scenario.job_size * 0.5).max(50.0 * mu),
                    Rng::stream(seed, 0xf4017),
                    false,
                ))
            }
            (FaultModel::PerProcessorStationary { n }, Law::Weibull { shape }) => {
                FaultSource::PerProc(PerProcSource::new(
                    n,
                    shape,
                    mu * n as f64,
                    (scenario.job_size * 0.5).max(50.0 * mu),
                    Rng::stream(seed, 0xf4017),
                    true,
                ))
            }
        }
    }

    fn next(&mut self) -> f64 {
        match self {
            FaultSource::Platform { dist, rng, last } => {
                *last += dist.sample(rng);
                *last
            }
            FaultSource::PerProc(src) => src.next(),
        }
    }
}

/// Fault-substream event construction: the predictor model's recall coin
/// and window placement, plus the too-late-to-announce reclassification.
/// One shared implementation — used by the heap stream, the flat stream
/// AND the online `predictor::feed` — so every consumer draws the RNG
/// identically (that sharing is what makes the offline trace and the
/// online feed emit bit-identical announcements).
pub(crate) struct FaultGen {
    rng: Rng,
    model: Arc<dyn PredictorModel>,
    cp: f64,
}

impl FaultGen {
    /// Events for the fault striking at `tf`: the fault itself and, when
    /// predicted and announceable, its window.  RNG order is the model's
    /// contract ([`crate::predictor::model`]); the paper model draws the
    /// recall coin then a uniform window offset (E_I^f = I/2), exactly as
    /// the pre-trait generator did.
    pub(crate) fn events(&mut self, tf: f64) -> (Event, Option<Event>) {
        if let Some(w) = self.model.true_window(&mut self.rng, tf) {
            let notify = w.start - self.cp;
            if notify >= 0.0 {
                return (
                    Event::Fault { t: tf, predicted: w.covers },
                    Some(Event::Prediction(Prediction {
                        notify_t: notify,
                        window_start: w.start,
                        window_end: w.start + w.len,
                        true_positive: w.covers,
                        weight: w.weight,
                    })),
                );
            }
            // Prediction would be announced before t = 0: too late to act —
            // reclassify as unpredicted (§2.2).
        }
        (Event::Fault { t: tf, predicted: false }, None)
    }
}

/// False-prediction substream: raw window starts from `dist` (None when the
/// predictor emits no false predictions — p = 1 or r = 0), window shape
/// from the predictor model, announced `C_p` early; windows whose
/// announcement would land before t = 0 are dropped.
pub(crate) struct FpGen {
    dist: Option<Distribution>,
    rng: Rng,
    model: Arc<dyn PredictorModel>,
    cp: f64,
}

impl FpGen {
    /// Advance the raw cursor; returns the announcement event, if any.
    /// The window start IS the raw arrival (models choose only the shape),
    /// so this substream is generated in notify order by construction —
    /// the flat trace's merge relies on that.
    pub(crate) fn next(&mut self, last_raw: &mut f64) -> Option<Event> {
        let Some(dist) = self.dist else {
            *last_raw = f64::INFINITY;
            return None;
        };
        *last_raw += dist.sample(&mut self.rng);
        let (len, weight) = self.model.false_shape(&mut self.rng);
        let ws = *last_raw;
        let notify = ws - self.cp;
        if notify >= 0.0 {
            return Some(Event::Prediction(Prediction {
                notify_t: notify,
                window_start: ws,
                window_end: ws + len,
                true_positive: false,
                weight,
            }));
        }
        None
    }
}

/// The two prediction substream generators, wired identically for the
/// offline trace streams and the online [`crate::predictor::feed`]: same
/// stream ids, same model behaviour, same lead-time and t = 0 handling.
pub(crate) fn pred_gens(
    pred: &PredictorSpec,
    cp: f64,
    mu: f64,
    false_pred_law: Law,
    seed: u64,
) -> (FaultGen, FpGen) {
    let fp_dist = if pred.recall > 0.0 && pred.precision < 1.0 {
        Some(Distribution::new(false_pred_law, pred.mu_false(mu)))
    } else {
        None
    };
    // One behaviour object per trace, shared by both substreams.
    let model: Arc<dyn PredictorModel> =
        Arc::from(crate::predictor::model::instantiate(pred));
    let fault_gen = FaultGen {
        rng: Rng::stream(seed, 0x0fa17),
        model: Arc::clone(&model),
        cp,
    };
    let fp_gen = FpGen { dist: fp_dist, rng: Rng::stream(seed, 0xfa15e), model, cp };
    (fault_gen, fp_gen)
}

/// The three substream generators of a trace, wired identically for every
/// stream implementation ([`TraceStream`] and [`FlatTrace`]).
fn trace_parts(scenario: &Scenario, seed: u64) -> (FaultSource, FaultGen, FpGen) {
    let faults = FaultSource::for_scenario(scenario, seed);
    let (fault_gen, fp_gen) = pred_gens(
        &scenario.predictor,
        scenario.platform.cp,
        scenario.platform.mu,
        scenario.false_pred_law,
        seed,
    );
    (faults, fault_gen, fp_gen)
}

/// Unbounded, lazily generated, time-sorted event stream (heap-merged
/// reference implementation; see [`FlatTrace`] for the fast path).
pub struct TraceStream {
    faults: FaultSource,
    fault_gen: FaultGen,
    fp_gen: FpGen,
    /// Largest gap between a raw arrival and its earliest visible event:
    /// the predictor's longest window plus any placement slack (the lead
    /// time `cp` is added where the bound is applied).  Equals the window
    /// length I for the paper predictor.
    lookback: f64,
    cp: f64,
    last_fault_raw: f64,
    last_fp_raw: f64,
    heap: BinaryHeap<HeapEvent>,
}

impl TraceStream {
    /// Build the stream for a scenario.  `seed` fixes the whole trace: two
    /// strategies given the same (scenario, seed) see the *same* faults and
    /// predictions, as in the paper's per-instance comparisons.
    pub fn new(scenario: &Scenario, seed: u64) -> Self {
        let (faults, fault_gen, fp_gen) = trace_parts(scenario, seed);
        TraceStream {
            faults,
            fault_gen,
            fp_gen,
            lookback: scenario.predictor.max_window()
                + scenario.predictor.placement_slack(),
            cp: scenario.platform.cp,
            last_fault_raw: 0.0,
            last_fp_raw: 0.0,
            heap: BinaryHeap::new(),
        }
    }

    fn gen_fault(&mut self) {
        self.last_fault_raw = self.faults.next();
        let (fault, pred) = self.fault_gen.events(self.last_fault_raw);
        if let Some(p) = pred {
            self.heap.push(HeapEvent(p));
        }
        self.heap.push(HeapEvent(fault));
    }

    fn gen_fp(&mut self) {
        if let Some(ev) = self.fp_gen.next(&mut self.last_fp_raw) {
            self.heap.push(HeapEvent(ev));
        }
    }

    /// Produce the next event in visible-time order (never exhausts).
    pub fn next_event(&mut self) -> Event {
        loop {
            if let Some(HeapEvent(ev)) = self.heap.peek() {
                // A future raw arrival at time t can create an event no
                // earlier than t - lookback - cp; once both cursors are
                // past this horizon, the heap minimum is globally minimal.
                let safe = ev.time() + self.lookback + self.cp;
                if self.last_fault_raw > safe && self.last_fp_raw > safe {
                    return self.heap.pop().unwrap().0;
                }
            }
            if self.last_fault_raw <= self.last_fp_raw {
                self.gen_fault();
            } else {
                self.gen_fp();
            }
        }
    }

    /// Collect all events with visible time < `horizon` (test helper).
    pub fn take_until(&mut self, horizon: f64) -> Vec<Event> {
        let mut out = Vec::new();
        loop {
            let ev = self.next_event();
            if ev.time() >= horizon {
                // Push back so callers could continue (rarely needed).
                self.heap.push(HeapEvent(ev));
                return out;
            }
            out.push(ev);
        }
    }
}

/// Anything that can feed the engine a time-sorted event stream.
pub trait EventSource {
    fn next_event(&mut self) -> Event;
}

impl EventSource for TraceStream {
    fn next_event(&mut self) -> Event {
        TraceStream::next_event(self)
    }
}

impl<S: EventSource + ?Sized> EventSource for &mut S {
    fn next_event(&mut self) -> Event {
        (**self).next_event()
    }
}

/// The reusable flat buffers of a [`FlatTrace`]: pending fault-substream
/// events, pending false predictions, and the merged batch being emitted.
/// Recycled through a [`TraceArena`] so repeated simulations allocate
/// nothing once the buffers reach steady-state capacity.
#[derive(Default)]
pub struct TraceBufs {
    fault: Vec<Event>,
    fp: Vec<Event>,
    merged: Vec<Event>,
}

impl TraceBufs {
    fn clear(&mut self) {
        self.fault.clear();
        self.fp.clear();
        self.merged.clear();
    }
}

/// Flat-buffer fast path: the same event sequence as [`TraceStream`], but
/// generated a horizon batch at a time instead of a heap op per event.
///
/// Each refill advances the emission horizon by one chunk, drains the raw
/// arrival processes far enough (horizon + window + C_p) that every event
/// below the horizon is known, sorts the fault-substream scratch vector
/// (predictions can precede earlier faults' strikes, so it is not generated
/// in order), and two-pointer merges it with the (naturally ordered)
/// false-prediction vector into the emission buffer.  Events beyond the
/// horizon stay in their scratch vectors for the next batch.
pub struct FlatTrace {
    faults: FaultSource,
    fault_gen: FaultGen,
    fp_gen: FpGen,
    /// See [`TraceStream`]: max window + placement slack.
    lookback: f64,
    cp: f64,
    last_fault_raw: f64,
    last_fp_raw: f64,
    /// Events with visible time < `horizon` have been merged already.
    horizon: f64,
    /// Horizon advance per refill (a few dozen platform MTBFs: enough to
    /// amortize the batch bookkeeping, small enough not to overshoot the
    /// makespan by much).
    chunk: f64,
    bufs: TraceBufs,
    pos: usize,
}

impl FlatTrace {
    /// Build the fast stream for a scenario (same seeding contract as
    /// [`TraceStream::new`]).
    pub fn new(scenario: &Scenario, seed: u64) -> Self {
        Self::with_bufs(scenario, seed, TraceBufs::default())
    }

    /// [`FlatTrace::new`] reusing previously allocated buffers (see
    /// [`TraceArena`]).
    pub fn with_bufs(scenario: &Scenario, seed: u64, mut bufs: TraceBufs) -> Self {
        bufs.clear();
        let (faults, fault_gen, fp_gen) = trace_parts(scenario, seed);
        let lookback = scenario.predictor.max_window()
            + scenario.predictor.placement_slack();
        let cp = scenario.platform.cp;
        FlatTrace {
            faults,
            fault_gen,
            fp_gen,
            lookback,
            cp,
            last_fault_raw: 0.0,
            last_fp_raw: 0.0,
            horizon: 0.0,
            chunk: (32.0 * scenario.platform.mu).max(8.0 * (lookback + cp)),
            bufs,
            pos: 0,
        }
    }

    /// Recover the buffers for reuse (see [`TraceArena::recycle`]).
    pub fn into_bufs(self) -> TraceBufs {
        self.bufs
    }

    /// Generate and merge the next non-empty batch of events.
    fn refill(&mut self) {
        loop {
            let h = self.horizon + self.chunk;
            // Any event with visible time < h comes from a raw arrival at
            // or before h + lookback + cp (a fault strikes at its arrival;
            // a window opens at most lookback + cp after its announcement),
            // so draining both processes to there completes the batch.
            let gen_to = h + self.lookback + self.cp;
            while self.last_fault_raw <= gen_to {
                self.last_fault_raw = self.faults.next();
                let (fault, pred) = self.fault_gen.events(self.last_fault_raw);
                self.bufs.fault.push(fault);
                if let Some(p) = pred {
                    self.bufs.fault.push(p);
                }
            }
            while self.last_fp_raw <= gen_to {
                if let Some(ev) = self.fp_gen.next(&mut self.last_fp_raw) {
                    self.bufs.fp.push(ev);
                }
            }
            self.horizon = h;
            // In-place sort (carried tail + new events); the fp vector is
            // generated in notify order and needs none.
            self.bufs.fault.sort_unstable_by(event_order);
            self.bufs.merged.clear();
            self.pos = 0;
            let (mut i, mut j) = (0usize, 0usize);
            loop {
                let take_fault = match (self.bufs.fault.get(i), self.bufs.fp.get(j)) {
                    (None, None) => break,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (Some(a), Some(b)) => event_order(a, b) != Ordering::Greater,
                };
                let ev = if take_fault { self.bufs.fault[i] } else { self.bufs.fp[j] };
                if ev.time() >= h {
                    break; // beyond the horizon: belongs to a later batch
                }
                if take_fault {
                    i += 1;
                } else {
                    j += 1;
                }
                self.bufs.merged.push(ev);
            }
            self.bufs.fault.drain(..i);
            self.bufs.fp.drain(..j);
            if !self.bufs.merged.is_empty() {
                return;
            }
        }
    }
}

impl EventSource for FlatTrace {
    fn next_event(&mut self) -> Event {
        while self.pos == self.bufs.merged.len() {
            self.refill();
        }
        let ev = self.bufs.merged[self.pos];
        self.pos += 1;
        ev
    }
}

/// Recycler for [`TraceBufs`]: hand buffers from finished streams to new
/// ones so back-to-back simulations (a worker thread draining a campaign
/// queue, a harness seed sweep) allocate nothing per instance — and nothing
/// per event.
#[derive(Default)]
pub struct TraceArena {
    spare: Vec<TraceBufs>,
}

impl TraceArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A [`FlatTrace`] backed by recycled buffers when any are available.
    pub fn stream(&mut self, scenario: &Scenario, seed: u64) -> FlatTrace {
        FlatTrace::with_bufs(scenario, seed, self.spare.pop().unwrap_or_default())
    }

    /// Return a finished stream's buffers to the arena.
    pub fn recycle(&mut self, stream: FlatTrace) {
        self.spare.push(stream.into_bufs());
    }
}

/// Which generator backs a [`TraceCache`].
enum CacheSource {
    Fast(FlatTrace),
    Reference(TraceStream),
}

/// Memoized trace: generates events once and replays them for any number
/// of simulations of the SAME (scenario, seed).
///
/// The BestPeriod brute-force search simulates dozens of candidate periods
/// against identical traces, and the campaign runs several strategy
/// variants per fault environment; without caching, trace generation (RNG +
/// heaps + per-processor thinning) is regenerated per candidate and costs
/// a significant fraction of each run.  `TraceCache` pays it once.
pub struct TraceCache {
    source: CacheSource,
    events: Vec<Event>,
}

impl TraceCache {
    /// A cache backed by the flat fast path (the default).
    pub fn new(scenario: &Scenario, seed: u64) -> Self {
        TraceCache {
            source: CacheSource::Fast(FlatTrace::new(scenario, seed)),
            events: Vec::new(),
        }
    }

    /// A cache backed by the heap-merged seed stream — baselines and
    /// golden equivalence tests only.
    pub fn reference(scenario: &Scenario, seed: u64) -> Self {
        TraceCache {
            source: CacheSource::Reference(TraceStream::new(scenario, seed)),
            events: Vec::new(),
        }
    }

    /// A fresh replay cursor over this cache.
    pub fn replay(&mut self) -> Replay<'_> {
        Replay { cache: self, pos: 0 }
    }

    /// Materialize one more event from the backing stream.
    fn extend(&mut self) {
        let ev = match &mut self.source {
            CacheSource::Fast(s) => s.next_event(),
            CacheSource::Reference(s) => s.next_event(),
        };
        self.events.push(ev);
    }

    /// Events materialized so far (diagnostics; also the unit of the
    /// [`crate::campaign::TracePool`] memory budget).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Cursor over a [`TraceCache`]; extends the cache on demand.
pub struct Replay<'a> {
    cache: &'a mut TraceCache,
    pos: usize,
}

impl EventSource for Replay<'_> {
    fn next_event(&mut self) -> Event {
        if self.pos == self.cache.events.len() {
            self.cache.extend();
        }
        let ev = self.cache.events[self.pos];
        self.pos += 1;
        ev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PredictorSpec, Scenario};
    use crate::sim::distribution::Law;

    fn scenario(recall: f64, precision: f64, window: f64) -> Scenario {
        Scenario {
            platform: crate::config::Platform {
                mu: 1000.0,
                c: 100.0,
                cp: 50.0,
                d: 10.0,
                r: 100.0,
            },
            predictor: PredictorSpec::paper(recall, precision, window),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e6,
        }
    }

    #[test]
    fn events_sorted_by_visible_time() {
        let sc = scenario(0.85, 0.82, 600.0);
        let mut ts = TraceStream::new(&sc, 1);
        let evs = ts.take_until(200_000.0);
        assert!(evs.len() > 100);
        for w in evs.windows(2) {
            assert!(w[0].time() <= w[1].time(), "{w:?}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let sc = scenario(0.7, 0.4, 300.0);
        let a = TraceStream::new(&sc, 9).take_until(50_000.0);
        let b = TraceStream::new(&sc, 9).take_until(50_000.0);
        assert_eq!(a, b);
        let c = TraceStream::new(&sc, 10).take_until(50_000.0);
        assert_ne!(a, c);
    }

    #[test]
    fn fault_rate_matches_mu() {
        let sc = scenario(0.85, 0.82, 600.0);
        let horizon = 2_000_000.0;
        let mut ts = TraceStream::new(&sc, 2);
        let faults = ts
            .take_until(horizon)
            .iter()
            .filter(|e| matches!(e, Event::Fault { .. }))
            .count();
        let expected = horizon / sc.platform.mu;
        let rel = (faults as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{faults} vs {expected}");
    }

    #[test]
    fn recall_fraction_of_faults_predicted() {
        let sc = scenario(0.85, 0.82, 600.0);
        let mut ts = TraceStream::new(&sc, 3);
        let evs = ts.take_until(3_000_000.0);
        let (mut pred, mut tot) = (0usize, 0usize);
        for e in &evs {
            if let Event::Fault { predicted, .. } = e {
                tot += 1;
                pred += *predicted as usize;
            }
        }
        let frac = pred as f64 / tot as f64;
        assert!((frac - 0.85).abs() < 0.03, "{frac} over {tot}");
    }

    #[test]
    fn predicted_fault_lies_inside_its_window() {
        let sc = scenario(1.0, 1.0, 600.0); // every fault predicted, no FPs
        let mut ts = TraceStream::new(&sc, 4);
        let evs = ts.take_until(1_000_000.0);
        let mut openings: Vec<Prediction> = Vec::new();
        let mut checked = 0;
        for e in &evs {
            match e {
                Event::Prediction(p) => {
                    assert!(p.true_positive);
                    assert!((p.window_end - p.window_start - 600.0).abs() < 1e-9);
                    assert!((p.window_start - p.notify_t - 50.0).abs() < 1e-9);
                    openings.push(*p);
                }
                Event::Fault { t, predicted: true } => {
                    // The matching window is the one containing t.
                    let hit = openings
                        .iter()
                        .any(|p| *t >= p.window_start && *t <= p.window_end);
                    assert!(hit, "fault at {t} outside every window");
                    checked += 1;
                }
                Event::Fault { predicted: false, .. } => {}
            }
        }
        assert!(checked > 100);
    }

    #[test]
    fn false_prediction_rate() {
        let sc = scenario(0.7, 0.4, 300.0);
        // μ_false = pμ/(r(1-p)) = 0.4*1000/(0.7*0.6) ≈ 952.4
        let mu_false = sc.predictor.mu_false(sc.platform.mu);
        let horizon = 3_000_000.0;
        let mut ts = TraceStream::new(&sc, 5);
        let fps = ts
            .take_until(horizon)
            .iter()
            .filter(
                |e| matches!(e, Event::Prediction(p) if !p.true_positive),
            )
            .count();
        let expected = horizon / mu_false;
        let rel = (fps as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{fps} vs {expected}");
    }

    #[test]
    fn perfect_precision_has_no_false_predictions() {
        let sc = scenario(0.9, 1.0, 300.0);
        let mut ts = TraceStream::new(&sc, 6);
        let fps = ts
            .take_until(500_000.0)
            .iter()
            .filter(
                |e| matches!(e, Event::Prediction(p) if !p.true_positive),
            )
            .count();
        assert_eq!(fps, 0);
    }

    #[test]
    fn zero_recall_means_no_predictions() {
        let sc = scenario(0.0, 0.5, 300.0);
        let mut ts = TraceStream::new(&sc, 7);
        let evs = ts.take_until(500_000.0);
        assert!(evs
            .iter()
            .all(|e| matches!(e, Event::Fault { predicted: false, .. })));
    }

    fn paper_scenario(model: FaultModel, shape: f64) -> Scenario {
        let n = 1u64 << 18;
        let mut sc = Scenario::paper(
            n,
            1.0,
            PredictorSpec::paper_a(600.0),
            Law::Weibull { shape },
            Law::Weibull { shape },
        );
        sc.fault_model = model;
        sc
    }

    fn fault_count(sc: &Scenario, horizon: f64, seed: u64) -> usize {
        TraceStream::new(sc, seed)
            .take_until(horizon)
            .iter()
            .filter(|e| matches!(e, Event::Fault { .. }))
            .count()
    }

    #[test]
    fn stationary_per_proc_rate_is_one_over_mu() {
        let sc = paper_scenario(
            FaultModel::PerProcessorStationary { n: 1 << 18 },
            0.7,
        );
        let horizon = 60.0 * sc.platform.mu;
        let mut total = 0usize;
        for seed in 0..12 {
            total += fault_count(&sc, horizon, seed);
        }
        let expected = 12.0 * horizon / sc.platform.mu;
        let rel = (total as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{total} vs {expected}");
    }

    #[test]
    fn fresh_per_proc_rate_exceeds_one_over_mu() {
        // Infant mortality: the fresh-start transient fault rate is far
        // above the steady state for k < 1 over a job-sized horizon.
        let sc = paper_scenario(FaultModel::PerProcessor { n: 1 << 18 }, 0.7);
        let horizon = 60.0 * sc.platform.mu;
        let count = fault_count(&sc, horizon, 3);
        let steady = horizon / sc.platform.mu;
        assert!(
            count as f64 > 3.0 * steady,
            "fresh rate {count} vs steady {steady}"
        );
        // And k = 0.5 is even more extreme than k = 0.7.
        let sc5 = paper_scenario(FaultModel::PerProcessor { n: 1 << 18 }, 0.5);
        let count5 = fault_count(&sc5, horizon, 3);
        assert!(count5 > count, "{count5} vs {count}");
    }

    #[test]
    fn per_proc_stream_sorted_and_deterministic() {
        for model in [
            FaultModel::PerProcessor { n: 1 << 16 },
            FaultModel::PerProcessorStationary { n: 1 << 16 },
        ] {
            let mut sc = paper_scenario(model, 0.5);
            sc.fault_model = model;
            let horizon = 20.0 * sc.platform.mu;
            let a = TraceStream::new(&sc, 9).take_until(horizon);
            let b = TraceStream::new(&sc, 9).take_until(horizon);
            assert_eq!(a, b);
            for w in a.windows(2) {
                assert!(w[0].time() <= w[1].time());
            }
        }
    }

    #[test]
    fn per_proc_exponential_equals_platform_renewal() {
        // Fresh exponential superposition IS Poisson(1/μ): the stream must
        // be bit-identical to the platform-renewal shortcut.
        let mut sc = paper_scenario(FaultModel::PerProcessor { n: 1 << 18 }, 0.7);
        sc.fault_law = Law::Exponential;
        sc.false_pred_law = Law::Exponential;
        let a = TraceStream::new(&sc, 4).take_until(10.0 * sc.platform.mu);
        sc.fault_model = FaultModel::PlatformRenewal;
        let b = TraceStream::new(&sc, 4).take_until(10.0 * sc.platform.mu);
        assert_eq!(a, b);
    }

    #[test]
    fn replay_matches_stream_and_is_reusable() {
        let sc = scenario(0.85, 0.82, 600.0);
        let direct = TraceStream::new(&sc, 21).take_until(100_000.0);
        let mut cache = TraceCache::new(&sc, 21);
        for _ in 0..3 {
            let mut cur = cache.replay();
            for want in &direct {
                assert_eq!(cur.next_event(), *want);
            }
        }
        assert!(cache.len() >= direct.len());
    }

    #[test]
    fn uniform_false_pred_law() {
        let mut sc = scenario(0.7, 0.4, 300.0);
        sc.false_pred_law = Law::Uniform;
        let mu_false = sc.predictor.mu_false(sc.platform.mu);
        let mut ts = TraceStream::new(&sc, 8);
        let evs = ts.take_until(2_000_000.0);
        let fps: Vec<f64> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Prediction(p) if !p.true_positive => {
                    Some(p.window_start)
                }
                _ => None,
            })
            .collect();
        let expected = 2_000_000.0 / mu_false;
        let rel = (fps.len() as f64 - expected).abs() / expected;
        assert!(rel < 0.05, "{} vs {expected}", fps.len());
    }

    #[test]
    fn flat_stream_matches_heap_stream() {
        // Event-by-event equality of the fast path and the reference heap
        // stream, across the fault models and a false-prediction mix.
        for (sc, n_events) in [
            (scenario(0.85, 0.82, 600.0), 4000),
            (scenario(0.7, 0.4, 300.0), 4000),
            (scenario(0.0, 0.5, 300.0), 500),
            (paper_scenario(FaultModel::PerProcessor { n: 1 << 16 }, 0.7), 2000),
            (
                paper_scenario(
                    FaultModel::PerProcessorStationary { n: 1 << 16 },
                    0.5,
                ),
                500,
            ),
        ] {
            let mut heap = TraceStream::new(&sc, 11);
            let mut flat = FlatTrace::new(&sc, 11);
            for k in 0..n_events {
                assert_eq!(heap.next_event(), flat.next_event(), "event {k}");
            }
        }
    }

    #[test]
    fn arena_recycled_stream_is_identical() {
        let sc = scenario(0.85, 0.82, 600.0);
        let mut want = Vec::new();
        let mut fresh = FlatTrace::new(&sc, 5);
        for _ in 0..1500 {
            want.push(fresh.next_event());
        }
        let mut arena = TraceArena::new();
        for _ in 0..3 {
            let mut ts = arena.stream(&sc, 5);
            for w in &want {
                assert_eq!(ts.next_event(), *w);
            }
            arena.recycle(ts);
        }
    }

    #[test]
    fn reference_cache_matches_fast_cache() {
        let sc = scenario(0.7, 0.4, 300.0);
        let mut fast = TraceCache::new(&sc, 13);
        let mut reference = TraceCache::reference(&sc, 13);
        let (mut a, mut b) = (fast.replay(), reference.replay());
        for _ in 0..3000 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }
}
