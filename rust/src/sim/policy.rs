//! Scheduling policies as behaviour: the [`PolicyLogic`] trait and its
//! implementations.
//!
//! The engine's main loop ([`crate::sim::engine`]) is identical for every
//! strategy the paper studies — regular periods, fault handling, the
//! pre-window proactive checkpoint.  What differs between strategies is a
//! small set of *decisions*:
//!
//! 1. **announcement** — is the engine listening for predictions at all
//!    ([`PolicyLogic::listens`]), and with what probability is an
//!    announcement trusted ([`PolicyLogic::trust`], the paper's q, §3.1)?
//! 2. **in-window behaviour** — what happens between the pre-window
//!    checkpoint at `t0` and the window close at `t0 + I`
//!    ([`PolicyLogic::in_window`])?
//! 3. **period resumption** — once the window is over, does the
//!    interrupted regular period resume, or does a fresh one start
//!    ([`PolicyLogic::resume_period`])?
//!
//! Each decision set is a zero-sized (or tiny `Copy`) type implementing
//! [`PolicyLogic`]; the engine is generic over it and monomorphized, so the
//! per-event hot path pays no dynamic dispatch — `tests/fast_path.rs`
//! pins the four original modes bit-identical to the pre-trait engine.
//!
//! Implementations:
//!
//! | logic                 | `PolicyKind`          | behaviour |
//! |-----------------------|-----------------------|-----------|
//! | [`IgnoreLogic`]       | `IgnorePredictions`   | q = 0: never listens |
//! | [`InstantLogic`]      | `Instant`             | §3.4: straight back to regular mode |
//! | [`NoCkptLogic`]       | `NoCkpt`              | §3.3: work unprotected until `t0 + I` |
//! | [`WithCkptLogic`]     | `WithCkpt`            | §3.2 / Algorithm 1: proactive periods in-window |
//! | [`ExactPredLogic`]    | `ExactPred`           | I → 0 exact-prediction limit: like Instant, but the proactive checkpoint starts a *fresh* period |
//! | [`WindowEndCkptLogic`]| `WindowEndCkpt`       | NoCkptI plus a terminal proactive checkpoint at `t0 + I` |
//! | [`QTrustLogic`]       | `QTrust { q }`        | NoCkptI trusted with probability q (first-class §3.1 randomized trust) |
//!
//! To add a strategy: implement [`PolicyLogic`] here, add a
//! [`crate::strategy::PolicyKind`] variant with a dispatch arm in
//! [`crate::sim::engine`], and register a named entry in
//! [`crate::strategy::registry`] — campaign grids, the harness and the CLI
//! pick it up from the registry with no further edits.

use crate::obs::Recorder;
use crate::sim::engine::{Engine, Seg};
use crate::sim::trace::{EventSource, Prediction};

/// The per-strategy decisions of the two-mode scheduler.
///
/// Implementations must be cheap `Copy` values: the engine copies the
/// logic out of itself before handing itself to [`PolicyLogic::in_window`]
/// mutably.
pub trait PolicyLogic: Copy {
    /// Does the engine listen for prediction announcements at all?
    /// `false` is the paper's q = 0 mode: announcements are counted and
    /// dropped without consuming trust coin-flips.
    fn listens(self) -> bool {
        true
    }

    /// Probability that a heard announcement is trusted (the paper's q,
    /// §3.1).  Composed multiplicatively with the trust probability the
    /// caller passes to the `simulate*` entry points.
    fn trust(self) -> f64 {
        1.0
    }

    /// In-window behaviour, entered at `t0` right after the pre-window
    /// proactive checkpoint committed.  Must leave the engine back in
    /// regular mode: either run to a clean window exit, or delegate fault
    /// recovery to [`Engine::handle_fault`] and return.
    fn in_window<S: EventSource, R: Recorder>(
        self,
        eng: &mut Engine<'_, S, Self, R>,
        p: Prediction,
    );

    /// Decide how the regular period resumes after a served window.
    /// `period_rem` holds the interrupted period's remaining work on
    /// entry; `fresh` is a full period's work (`T_R - C`).  The default
    /// keeps `period_rem` — the paper's semantics: the interrupted period
    /// resumes where it stopped.
    fn resume_period(self, period_rem: &mut f64, fresh: f64) {
        let _ = (period_rem, fresh);
    }
}

/// Work until `end` with no checkpoint protection, recovering from any
/// fault that strikes.  Shared by every "work through the window" policy;
/// returns the segment outcome so callers can tell a clean window exit
/// (`Seg::Completed`) from a fault or early job completion.
fn work_through_window<S: EventSource, L: PolicyLogic, R: Recorder>(
    eng: &mut Engine<'_, S, L, R>,
    end: f64,
) -> Seg {
    match eng.advance(end, true, false) {
        Seg::Fault => {
            eng.handle_fault();
            Seg::Fault
        }
        Seg::Notify(_) => unreachable!("not listening in-window"),
        seg => seg,
    }
}

/// One proactive checkpoint of duration `C_p` starting now; aborted (idle
/// time) if a fault strikes mid-checkpoint.
fn proactive_checkpoint<S: EventSource, L: PolicyLogic, R: Recorder>(
    eng: &mut Engine<'_, S, L, R>,
) -> Seg {
    let cp = eng.scenario().platform.cp;
    let start = eng.now();
    match eng.advance(start + cp, false, false) {
        Seg::Completed => {
            eng.commit_checkpoint(cp, true);
            Seg::Completed
        }
        Seg::Fault => {
            eng.abort_checkpoint(start);
            eng.handle_fault();
            Seg::Fault
        }
        _ => unreachable!("checkpoints do no work and do not listen"),
    }
}

/// q = 0: predictions ignored entirely (Daly / Young / RFO execution mode).
#[derive(Clone, Copy, Debug, Default)]
pub struct IgnoreLogic;

impl PolicyLogic for IgnoreLogic {
    fn listens(self) -> bool {
        false
    }

    fn in_window<S: EventSource, R: Recorder>(
        self,
        _eng: &mut Engine<'_, S, Self, R>,
        _p: Prediction,
    ) {
        unreachable!("q = 0 never trusts a prediction")
    }
}

/// §3.4 Instant: proactive checkpoint before the window, immediate return
/// to the interrupted regular period.
#[derive(Clone, Copy, Debug, Default)]
pub struct InstantLogic;

impl PolicyLogic for InstantLogic {
    fn in_window<S: EventSource, R: Recorder>(
        self,
        _eng: &mut Engine<'_, S, Self, R>,
        _p: Prediction,
    ) {
        // Straight back to regular mode.
    }
}

/// §3.3 NoCkptI: work without checkpointing until the window closes.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoCkptLogic;

impl PolicyLogic for NoCkptLogic {
    fn in_window<S: EventSource, R: Recorder>(
        self,
        eng: &mut Engine<'_, S, Self, R>,
        p: Prediction,
    ) {
        work_through_window(eng, p.window_end);
    }
}

/// §3.2 WithCkptI (Algorithm 1 lines 16–17): while in proactive mode
/// (elapsed < I), work `T_P - C_p` then checkpoint `C_p`.  A started
/// proactive period runs to completion even if it crosses `t0 + I` (the
/// mode check happens at iteration boundaries).
#[derive(Clone, Copy, Debug, Default)]
pub struct WithCkptLogic;

impl PolicyLogic for WithCkptLogic {
    fn in_window<S: EventSource, R: Recorder>(
        self,
        eng: &mut Engine<'_, S, Self, R>,
        p: Prediction,
    ) {
        let cp = eng.scenario().platform.cp;
        let tp = eng.policy().tp;
        while !eng.job_done() && eng.now() < p.window_end {
            let wend = eng.now() + (tp - cp);
            match eng.advance(wend, true, false) {
                Seg::Completed => (),
                Seg::JobDone => return,
                Seg::Fault => {
                    eng.handle_fault();
                    return;
                }
                Seg::Notify(_) => unreachable!("not listening in-window"),
            }
            if let Seg::Fault = proactive_checkpoint(eng) {
                return;
            }
        }
    }
}

/// The I → 0 exact-prediction limit (the companion paper *Checkpointing
/// algorithms and fault prediction* studies exact predictions; this is
/// their natural embedding in the window framework): the scheduler treats
/// the prediction as pinpointing the strike, so after the pre-window
/// proactive checkpoint there is nothing to do in-window — and, unlike
/// Instant, the proactive checkpoint *replaces* the period's checkpoint:
/// a fresh regular period starts at the window exit.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactPredLogic;

impl PolicyLogic for ExactPredLogic {
    fn in_window<S: EventSource, R: Recorder>(
        self,
        _eng: &mut Engine<'_, S, Self, R>,
        _p: Prediction,
    ) {
        // The believed strike instant is the window itself; nothing to do.
    }

    fn resume_period(self, period_rem: &mut f64, fresh: f64) {
        *period_rem = fresh;
    }
}

/// NoCkptI plus a terminal proactive checkpoint at `t0 + I`: the window's
/// unprotected work is secured before regular mode resumes, at the price
/// of one more `C_p` per trusted window.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowEndCkptLogic;

impl PolicyLogic for WindowEndCkptLogic {
    fn in_window<S: EventSource, R: Recorder>(
        self,
        eng: &mut Engine<'_, S, Self, R>,
        p: Prediction,
    ) {
        if !matches!(work_through_window(eng, p.window_end), Seg::Completed) {
            // Fault (already recovered) or the job finished in-window.
            return;
        }
        proactive_checkpoint(eng);
    }
}

/// §3.1 randomized trust as a first-class strategy: NoCkptI's execution
/// mode, but each announcement is trusted only with probability `q`.  The
/// paper proves the optimum is always at q ∈ {0, 1}; this strategy makes
/// the interior of that claim directly simulable from campaign grids
/// (previously only reachable through the `simulate_q` entry point).
#[derive(Clone, Copy, Debug)]
pub struct QTrustLogic {
    /// Trust probability q ∈ [0, 1].
    pub q: f64,
}

impl PolicyLogic for QTrustLogic {
    fn trust(self) -> f64 {
        self.q
    }

    fn in_window<S: EventSource, R: Recorder>(
        self,
        eng: &mut Engine<'_, S, Self, R>,
        p: Prediction,
    ) {
        work_through_window(eng, p.window_end);
    }
}
