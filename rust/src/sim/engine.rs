//! The two-mode (regular / proactive) checkpoint scheduling simulator.
//!
//! This is a faithful discrete-event implementation of the paper's
//! framework (§2) and of Algorithm 1 (WithCkptI), with the Instant and
//! NoCkptI variants and the prediction-ignoring (q = 0) mode:
//!
//! * **Regular mode** — periodic checkpointing with period `T_R`: work
//!   `T_R - C`, checkpoint `C`, repeat.
//! * On a trusted prediction with window `[t0, t0+I]` (announced at
//!   `t0 - C_p`): interrupt the period, take a proactive checkpoint during
//!   `[t0 - C_p, t0]`, then hand control to the policy's in-window
//!   behaviour, and finally resume the regular period as the policy
//!   decides.
//! * A fault loses all work since the last *completed* checkpoint, costs
//!   downtime `D` + recovery `R` (faults during D+R restart it), and drops
//!   the engine back into regular mode with a fresh period.
//! * If a *regular* checkpoint is in progress when a trusted prediction is
//!   announced, there is no time for it to complete before the proactive
//!   action: it is aborted and its elapsed time accounted as idle (the
//!   paper's "no time for the extra checkpoint" case, accounted as idle
//!   time in the waste).
//! * Predictions announced while the engine is not in regular mode
//!   (proactive sequence, downtime) are ignored — the paper's analysis
//!   assumes at most one event per interval; the simulator, like the
//!   paper's, resolves overlaps by ignoring the later prediction.
//!
//! The job completes the instant the cumulative useful work reaches
//! `Time_base` (`job_size`); no terminal checkpoint is required.
//!
//! **Policies are behaviour, not enum tags**: the per-strategy decisions
//! live behind the [`PolicyLogic`] trait (see [`crate::sim::policy`]), the
//! main loop is generic over it, and each [`PolicyKind`] dispatches once —
//! at entry — to a fully monomorphized loop, so the per-event hot path is
//! as fast as the pre-trait hand-matched engine (`tests/fast_path.rs`
//! pins the four original modes bit-identical).

use crate::config::Scenario;
use crate::obs::{NoopRecorder, Recorder};
use crate::sim::policy::{
    ExactPredLogic, IgnoreLogic, InstantLogic, NoCkptLogic, PolicyLogic, QTrustLogic,
    WindowEndCkptLogic, WithCkptLogic,
};
use crate::sim::rng::Rng;
use crate::sim::timeline::{Span, Timeline};
use crate::sim::trace::{Event, EventSource, FlatTrace, Prediction};
use crate::strategy::{Policy, PolicyKind};

/// Statistics of one simulated execution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimOutcome {
    /// Total wall-clock time to complete the job (s).
    pub makespan: f64,
    /// Useful work completed (== scenario.job_size on success).
    pub job_size: f64,
    /// Faults that struck (any kind).
    pub n_faults: u64,
    /// Faults that struck and were covered by a prediction (trace metadata).
    pub n_predicted_faults: u64,
    /// Prediction announcements seen (true + false).
    pub n_preds_seen: u64,
    /// Predictions acted upon (proactive sequence started).
    pub n_preds_trusted: u64,
    /// Predictions ignored because the engine was busy (overlap) — q=1 only.
    pub n_preds_overlapped: u64,
    /// Completed regular checkpoints.
    pub n_reg_ckpts: u64,
    /// Completed proactive checkpoints (pre-window + in-window).
    pub n_pro_ckpts: u64,
    /// Regular checkpoints aborted by a trusted prediction.
    pub n_ckpts_aborted: u64,
    /// Work destroyed by faults (s).
    pub work_lost: f64,
    /// Time spent in completed checkpoints (s).
    pub time_ckpt: f64,
    /// Time spent in downtime + recovery (s).
    pub time_down: f64,
    /// Time wasted in aborted checkpoints (accounted as idle, §3.1).
    pub time_idle: f64,
    /// Trace events consumed.
    pub events: u64,
}

impl SimOutcome {
    /// WASTE = (Time_final - Time_base) / Time_final (§2.1).
    ///
    /// A degenerate run (capped at zero, or an empty outcome) has
    /// `makespan == 0` and wasted nothing: the division is guarded so this
    /// reports 0.0 instead of NaN, which would poison every mean it enters.
    pub fn waste(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (self.makespan - self.job_size) / self.makespan
    }
}

/// Outcome of advancing through one activity segment.
pub enum Seg {
    /// Reached the segment end.
    Completed,
    /// The job's last unit of work completed (work segments only).
    JobDone,
    /// A fault struck (engine time advanced to the strike instant).
    Fault,
    /// A prediction was announced (only when `listen` was set).
    Notify(Prediction),
}

/// The engine state a [`PolicyLogic`] implementation drives through the
/// public methods ([`Engine::advance`], [`Engine::handle_fault`],
/// [`Engine::commit_checkpoint`], [`Engine::abort_checkpoint`]).
pub struct Engine<'a, S: EventSource, L: PolicyLogic, R: Recorder = NoopRecorder> {
    sc: &'a Scenario,
    pol: &'a Policy,
    logic: L,
    /// Telemetry sink ([`crate::obs`]).  The default [`NoopRecorder`]'s
    /// empty inline hooks compile away; any recorder observes *after* the
    /// engine's own accounting and never touches an RNG stream, so
    /// enabling one cannot perturb outcomes.
    rec: R,
    /// Effective probability of trusting each prediction: the caller's q
    /// (the paper's §3.1 knob) times the policy's own trust probability.
    trust_prob: f64,
    /// Dedicated stream for the q coin-flips (keeps traces unchanged).
    rng_q: Rng,
    /// Abandon the run once simulated time exceeds this (waste ≈ 1 regime;
    /// used by the BestPeriod search to skip hopeless candidates cheaply).
    t_cap: f64,
    /// Optional span recorder (see [`crate::sim::timeline`]).
    timeline: Option<Timeline>,
    stream: S,
    next_ev: Event,
    t: f64,
    /// Work secured by the last completed checkpoint.
    saved: f64,
    /// Work done since the last completed checkpoint (lost on fault).
    unsaved: f64,
    /// Work remaining in the current regular period before its checkpoint.
    period_rem: f64,
    done: bool,
    out: SimOutcome,
}

/// The single construction path shared by every `simulate*` entry point:
/// scenario + policy, with trust probability, seed, makespan cap and
/// timeline recording as opt-in knobs.  (Historically each entry point
/// hand-rolled its own engine — `simulate_traced` could take neither a q
/// nor a cap; now every knob composes with every other.)
struct EngineBuilder<'a> {
    sc: &'a Scenario,
    pol: &'a Policy,
    q: f64,
    seed: u64,
    cap: f64,
    record_timeline: bool,
}

impl<'a> EngineBuilder<'a> {
    fn new(sc: &'a Scenario, pol: &'a Policy) -> Self {
        EngineBuilder { sc, pol, q: 1.0, seed: 0, cap: f64::INFINITY, record_timeline: false }
    }

    fn trust(mut self, q: f64) -> Self {
        assert!((0.0..=1.0).contains(&q), "trust probability q = {q}");
        self.q = q;
        self
    }

    fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    fn cap(mut self, cap: f64) -> Self {
        self.cap = cap;
        self
    }

    fn timeline(mut self, record: bool) -> Self {
        self.record_timeline = record;
        self
    }

    /// Dispatch on the policy kind once, then run the fully monomorphized
    /// engine loop for that behaviour.
    fn run<S: EventSource, R: Recorder>(
        self,
        stream: S,
        rec: R,
    ) -> (SimOutcome, Option<Timeline>) {
        match self.pol.kind {
            PolicyKind::IgnorePredictions => self.run_with(IgnoreLogic, stream, rec),
            PolicyKind::Instant => self.run_with(InstantLogic, stream, rec),
            PolicyKind::NoCkpt => self.run_with(NoCkptLogic, stream, rec),
            PolicyKind::WithCkpt => self.run_with(WithCkptLogic, stream, rec),
            PolicyKind::ExactPred => self.run_with(ExactPredLogic, stream, rec),
            PolicyKind::WindowEndCkpt => {
                self.run_with(WindowEndCkptLogic, stream, rec)
            }
            PolicyKind::QTrust { q } => {
                self.run_with(QTrustLogic { q }, stream, rec)
            }
        }
    }

    fn run_with<S: EventSource, L: PolicyLogic, R: Recorder>(
        self,
        logic: L,
        mut stream: S,
        rec: R,
    ) -> (SimOutcome, Option<Timeline>) {
        self.pol.validate(self.sc);
        let next_ev = stream.next_event();
        let mut eng = Engine {
            sc: self.sc,
            pol: self.pol,
            trust_prob: self.q * logic.trust(),
            logic,
            rec,
            rng_q: Rng::stream(self.seed, 0x7125_7),
            t_cap: self.cap,
            timeline: self.record_timeline.then(Timeline::default),
            stream,
            next_ev,
            t: 0.0,
            saved: 0.0,
            unsaved: 0.0,
            period_rem: self.pol.tr - self.sc.platform.c,
            done: false,
            out: SimOutcome::default(),
        };
        eng.run();
        eng.out.makespan = eng.t;
        // Capped runs report the work actually completed so waste() is
        // honest.
        eng.out.job_size =
            if eng.done { self.sc.job_size } else { eng.saved + eng.unsaved };
        (eng.out, eng.timeline)
    }
}

/// Simulate one execution of `policy` under `scenario` with the fault and
/// prediction trace fixed by `seed`.  The same (scenario, seed) pair yields
/// the same trace for every policy, enabling paired comparisons.
pub fn simulate(scenario: &Scenario, policy: &Policy, seed: u64) -> SimOutcome {
    simulate_q(scenario, policy, 1.0, seed)
}

/// [`simulate`] plus a full execution [`Timeline`] (span-by-span record of
/// the scheduler's decisions; see `sim::timeline`).
pub fn simulate_traced(
    scenario: &Scenario,
    policy: &Policy,
    seed: u64,
) -> (SimOutcome, Timeline) {
    simulate_traced_q(scenario, policy, 1.0, seed)
}

/// [`simulate_traced`] with the §3.1 trust probability `q` — the shared
/// engine builder gives the traced path every knob of the untraced one.
pub fn simulate_traced_q(
    scenario: &Scenario,
    policy: &Policy,
    q: f64,
    seed: u64,
) -> (SimOutcome, Timeline) {
    let (out, tl) = EngineBuilder::new(scenario, policy)
        .trust(q)
        .seed(seed)
        .timeline(true)
        .run(FlatTrace::new(scenario, seed), NoopRecorder);
    (out, tl.expect("timeline recording requested"))
}

/// Like [`simulate`], but each prediction is trusted only with probability
/// `q` (§3.1's randomized-trust scheme).  `q = 1` is the paper's q=1
/// strategies; `q = 0` behaves like `PolicyKind::IgnorePredictions`.  The
/// paper proves analytically that the optimum is always at q ∈ {0, 1};
/// `tests/prop.rs` verifies this by simulation.  (Randomized trust is also
/// available as the first-class `QTrust` strategy — see
/// [`crate::strategy::registry`].)
pub fn simulate_q(
    scenario: &Scenario,
    policy: &Policy,
    q: f64,
    seed: u64,
) -> SimOutcome {
    let stream = FlatTrace::new(scenario, seed);
    simulate_from(scenario, policy, q, seed, stream)
}

/// Run the engine against any [`EventSource`] — e.g. a
/// [`crate::sim::trace::Replay`] cursor over a memoized trace, which the
/// BestPeriod search uses to amortize trace generation across candidate
/// periods.  `seed` only seeds the q coin-flips here.
pub fn simulate_from<S: EventSource>(
    scenario: &Scenario,
    policy: &Policy,
    q: f64,
    seed: u64,
    stream: S,
) -> SimOutcome {
    simulate_from_capped(scenario, policy, q, seed, stream, f64::INFINITY)
}

/// [`simulate_from`] with a makespan cap: if simulated time exceeds `cap`
/// before the job completes, the run is abandoned and the outcome reports
/// the work actually completed (`job_size` = completed work, so `waste()`
/// reflects the partial run).  Candidates whose waste is this bad lose any
/// search; capping avoids simulating astronomically long makespans.
pub fn simulate_from_capped<S: EventSource>(
    scenario: &Scenario,
    policy: &Policy,
    q: f64,
    seed: u64,
    stream: S,
    cap: f64,
) -> SimOutcome {
    EngineBuilder::new(scenario, policy)
        .trust(q)
        .seed(seed)
        .cap(cap)
        .run(stream, NoopRecorder)
        .0
}

/// [`simulate_from`] with a telemetry [`Recorder`] attached.  The caller
/// keeps ownership of the recorder (the forwarding `impl Recorder for
/// &mut R` hands the engine a reborrow), so per-simulation counters can
/// be audited against the returned outcome and then merged into
/// campaign-level aggregates.  With [`crate::obs::EventCounters`] the
/// outcome is bit-identical to [`simulate_from`] — recorders observe
/// after the fact and never touch the RNG streams.
pub fn simulate_recorded<S: EventSource, R: Recorder>(
    scenario: &Scenario,
    policy: &Policy,
    q: f64,
    seed: u64,
    stream: S,
    rec: &mut R,
) -> SimOutcome {
    EngineBuilder::new(scenario, policy).trust(q).seed(seed).run(stream, rec).0
}

impl<S: EventSource, L: PolicyLogic, R: Recorder> Engine<'_, S, L, R> {
    /// Current simulated time.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Has the job's last unit of work completed?
    pub fn job_done(&self) -> bool {
        self.done
    }

    /// The scenario being simulated.
    pub fn scenario(&self) -> &Scenario {
        self.sc
    }

    /// The instantiated policy (periods `tr` / `tp`).
    pub fn policy(&self) -> &Policy {
        self.pol
    }

    /// Pop the next trace event.
    fn bump_event(&mut self) {
        self.out.events += 1;
        self.next_ev = self.stream.next_event();
    }

    /// Advance from the current time to `end`, doing useful work iff
    /// `work`.
    ///
    /// Consumes every trace event with visible time < the stopping point:
    /// faults always interrupt; predictions interrupt iff `listen`
    /// (otherwise they are counted and dropped).
    pub fn advance(&mut self, end: f64, work: bool, listen: bool) -> Seg {
        loop {
            // Time at which the job would complete within this segment.
            let t_complete = if work {
                self.t + (self.sc.job_size - self.saved - self.unsaved)
            } else {
                f64::INFINITY
            };
            let te = self.next_ev.time();
            let stop = end.min(t_complete).min(te);
            if work {
                self.unsaved += stop - self.t;
                self.rec.work(stop - self.t);
                if let Some(tl) = self.timeline.as_mut() {
                    tl.push(Span::Work { start: self.t, end: stop });
                }
            }
            self.t = stop;
            if stop == t_complete && t_complete <= end && t_complete <= te {
                self.done = true;
                return Seg::JobDone;
            }
            if te <= end && stop == te {
                // An event fires inside the segment.
                let ev = self.next_ev;
                match ev {
                    Event::Fault { predicted, .. } => {
                        self.bump_event();
                        self.out.n_faults += 1;
                        self.out.n_predicted_faults += predicted as u64;
                        self.rec.fault(self.t, predicted);
                        return Seg::Fault;
                    }
                    Event::Prediction(p) => {
                        self.bump_event();
                        self.out.n_preds_seen += 1;
                        self.rec.prediction_seen();
                        if listen {
                            // §3.1: trust the predictor with probability q,
                            // scaled by the announcement's confidence
                            // weight (1.0 for single-class predictors, so
                            // the paper's streams are untouched).
                            let trust = self.trust_prob * p.weight;
                            if trust >= 1.0 || self.rng_q.bernoulli(trust) {
                                return Seg::Notify(p);
                            }
                            self.rec.prediction_ignored();
                            continue; // coin said ignore this one
                        }
                        if self.logic.listens() {
                            self.out.n_preds_overlapped += 1;
                            self.rec.prediction_overlapped();
                        } else {
                            self.rec.prediction_ignored();
                        }
                        continue; // ignored; keep advancing
                    }
                }
            }
            return Seg::Completed;
        }
    }

    /// Lose unsaved work, then serve downtime + recovery (restarted by any
    /// fault that strikes during them).  Ends in regular mode with a fresh
    /// period.
    pub fn handle_fault(&mut self) {
        if let Some(tl) = self.timeline.as_mut() {
            tl.record_fault(self.t);
        }
        self.out.work_lost += self.unsaved;
        self.rec.rollback(self.unsaved);
        self.unsaved = 0.0;
        loop {
            let start = self.t;
            let end = self.t + self.sc.platform.d + self.sc.platform.r;
            match self.advance(end, false, false) {
                Seg::Completed => {
                    self.out.time_down += self.t - start;
                    self.rec.downtime(self.t - start);
                    if let Some(tl) = self.timeline.as_mut() {
                        tl.push(Span::Down { start, end: self.t });
                    }
                    break;
                }
                Seg::Fault => {
                    self.out.time_down += self.t - start;
                    self.rec.downtime(self.t - start);
                    if let Some(tl) = self.timeline.as_mut() {
                        tl.push(Span::Down { start, end: self.t });
                        tl.record_fault(self.t);
                    }
                    continue; // restart D + R from the new strike
                }
                _ => unreachable!("no work, no listen during downtime"),
            }
        }
        self.period_rem = self.pol.tr - self.sc.platform.c;
    }

    /// A completed checkpoint secures all work done so far.
    pub fn commit_checkpoint(&mut self, duration: f64, proactive: bool) {
        if let Some(tl) = self.timeline.as_mut() {
            tl.push(Span::Ckpt {
                start: self.t - duration,
                end: self.t,
                proactive,
            });
        }
        self.saved += self.unsaved;
        self.unsaved = 0.0;
        self.out.time_ckpt += duration;
        if proactive {
            self.out.n_pro_ckpts += 1;
        } else {
            self.out.n_reg_ckpts += 1;
        }
        self.rec.ckpt_committed(duration, proactive);
    }

    /// Account a checkpoint destroyed or abandoned mid-write: its elapsed
    /// time since `start` becomes idle time (the paper's §3.1 accounting).
    pub fn abort_checkpoint(&mut self, start: f64) {
        self.out.time_idle += self.t - start;
        self.rec.ckpt_aborted(self.t - start);
        if let Some(tl) = self.timeline.as_mut() {
            tl.push(Span::Idle { start, end: self.t });
        }
    }

    /// Serve a trusted prediction: proactive checkpoint before the window
    /// (common to every policy), then the policy's in-window behaviour,
    /// then the policy's period-resumption decision.  Returns with the
    /// engine back in regular mode (or `done`).
    fn handle_prediction(&mut self, p: Prediction) {
        self.out.n_preds_trusted += 1;
        self.rec.prediction_trusted();
        let cp = self.sc.platform.cp;

        // 1. Proactive checkpoint during [t0 - Cp, t0].  (We are at t0 - Cp:
        //    the notification time.)
        let ck_start = self.t;
        match self.advance(p.window_start, false, false) {
            Seg::Completed => self.commit_checkpoint(cp, true),
            Seg::Fault => {
                // The checkpoint is destroyed; its partial time is idle and
                // the prediction is stale.
                self.abort_checkpoint(ck_start);
                self.handle_fault();
                return;
            }
            _ => unreachable!(),
        }

        // 2. In-window behaviour.
        let logic = self.logic;
        logic.in_window(self, p);

        // 3. Period resumption (default: resume the interrupted period).
        let fresh = self.pol.tr - self.sc.platform.c;
        let mut rem = self.period_rem;
        logic.resume_period(&mut rem, fresh);
        self.period_rem = rem;
    }

    /// Main loop: regular mode until the job completes.
    fn run(&mut self) {
        let c = self.sc.platform.c;
        let listen = self.logic.listens();
        while !self.done {
            if self.t >= self.t_cap {
                return; // abandoned: hopeless-candidate cutoff
            }
            if self.period_rem > 1e-9 {
                // Work phase of the regular period.
                let t0 = self.t;
                let end = self.t + self.period_rem;
                let seg = self.advance(end, true, listen);
                self.period_rem -= self.t - t0;
                match seg {
                    Seg::Completed => self.period_rem = 0.0,
                    Seg::JobDone => return,
                    Seg::Fault => self.handle_fault(),
                    Seg::Notify(p) => self.handle_prediction(p),
                }
            } else {
                // Checkpoint phase of the regular period.
                let start = self.t;
                let end = self.t + c;
                match self.advance(end, false, listen) {
                    Seg::Completed => {
                        self.commit_checkpoint(c, false);
                        self.period_rem = self.pol.tr - c;
                    }
                    Seg::Fault => {
                        // Partial (destroyed) checkpoint time is idle.
                        self.abort_checkpoint(start);
                        self.handle_fault();
                    }
                    Seg::Notify(p) => {
                        // No time to finish the regular checkpoint before
                        // the proactive action: abort it (idle time).
                        self.out.n_ckpts_aborted += 1;
                        self.abort_checkpoint(start);
                        self.handle_prediction(p);
                        // period_rem stays 0 unless the policy's
                        // resumption decision says otherwise: by default
                        // the checkpoint is retaken after the window.
                    }
                    Seg::JobDone => unreachable!("checkpoint does no work"),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec, Scenario};
    use crate::sim::distribution::Law;

    fn base_scenario() -> Scenario {
        Scenario {
            platform: Platform { mu: 50_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1.0e6,
        }
    }

    fn policy(kind: PolicyKind, tr: f64, tp: f64) -> Policy {
        Policy { kind, tr, tp }
    }

    #[test]
    fn fault_free_waste_equals_c_over_t() {
        // With no faults and no predictions the waste is exactly C/T_R
        // (§2.1), up to the truncated last period.
        let mut sc = base_scenario();
        sc.platform.mu = 1e15; // effectively fault-free
        sc.predictor.recall = 0.0;
        let pol = policy(PolicyKind::IgnorePredictions, 3600.0, 600.0);
        let out = simulate(&sc, &pol, 1);
        assert_eq!(out.n_faults, 0);
        // n full periods of work 3000 + final partial work segment
        let expected_ckpts = (sc.job_size / 3000.0).ceil() as u64 - 1;
        assert_eq!(out.n_reg_ckpts, expected_ckpts);
        let waste = out.waste();
        let ideal = 600.0 / 3600.0;
        assert!((waste - ideal).abs() < 1e-3, "waste {waste} vs {ideal}");
    }

    #[test]
    fn work_conservation() {
        let sc = base_scenario();
        let pol = policy(PolicyKind::WithCkpt, 8000.0, 1000.0);
        let out = simulate(&sc, &pol, 7);
        // Makespan == job + checkpoints + downtime + idle + lost work.
        let accounted = sc.job_size
            + out.time_ckpt
            + out.time_down
            + out.time_idle
            + out.work_lost;
        assert!(
            (out.makespan - accounted).abs() < 1e-6 * out.makespan,
            "makespan {} vs accounted {accounted}",
            out.makespan
        );
    }

    #[test]
    fn waste_in_unit_interval_and_makespan_exceeds_job() {
        let sc = base_scenario();
        for (kind, tp) in [
            (PolicyKind::IgnorePredictions, 600.0),
            (PolicyKind::Instant, 600.0),
            (PolicyKind::NoCkpt, 600.0),
            (PolicyKind::WithCkpt, 700.0),
        ] {
            let pol = policy(kind, 6000.0, tp);
            let out = simulate(&sc, &pol, 3);
            assert!(out.makespan >= sc.job_size);
            assert!((0.0..1.0).contains(&out.waste()), "{:?}", out.waste());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let sc = base_scenario();
        let pol = policy(PolicyKind::NoCkpt, 5000.0, 600.0);
        let a = simulate(&sc, &pol, 11);
        let b = simulate(&sc, &pol, 11);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.n_faults, b.n_faults);
    }

    #[test]
    fn prediction_aware_beats_ignoring_with_good_predictor() {
        // Accurate predictor, short window, many faults: trusting must win.
        let mut sc = base_scenario();
        sc.platform.mu = 20_000.0;
        sc.predictor = PredictorSpec::paper(0.95, 0.95, 300.0);
        sc.job_size = 5e6;
        let tr = crate::model::optimal::rfo_period(&sc.platform);
        let ign = simulate(&sc, &policy(PolicyKind::IgnorePredictions, tr, 600.0), 5);
        let tr1 = crate::model::optimal::tr_extr_instant(&sc);
        let inst = simulate(&sc, &policy(PolicyKind::Instant, tr1, 600.0), 5);
        assert!(
            inst.waste() < ign.waste(),
            "instant {} vs ignore {}",
            inst.waste(),
            ign.waste()
        );
    }

    #[test]
    fn more_faults_mean_more_waste() {
        let mut sc = base_scenario();
        sc.predictor.recall = 0.0;
        let pol = policy(PolicyKind::IgnorePredictions, 6000.0, 600.0);
        sc.platform.mu = 200_000.0;
        let low = simulate(&sc, &pol, 2);
        sc.platform.mu = 20_000.0;
        let high = simulate(&sc, &pol, 2);
        assert!(high.waste() > low.waste());
        assert!(high.n_faults > low.n_faults);
    }

    #[test]
    fn downtime_restarts_on_fault_during_recovery() {
        // With a tiny MTBF and huge D+R, faults pile up during recovery;
        // the engine must still terminate and account all time.
        let mut sc = base_scenario();
        sc.platform.mu = 3000.0;
        sc.platform.d = 200.0;
        sc.platform.r = 800.0;
        sc.predictor.recall = 0.0;
        sc.job_size = 50_000.0;
        let pol = policy(PolicyKind::IgnorePredictions, 2500.0, 600.0);
        let out = simulate(&sc, &pol, 13);
        assert!(out.makespan.is_finite());
        let accounted = sc.job_size + out.time_ckpt + out.time_down
            + out.time_idle + out.work_lost;
        assert!((out.makespan - accounted).abs() < 1e-6 * out.makespan);
    }

    #[test]
    fn proactive_checkpoints_taken_withckpt() {
        let mut sc = base_scenario();
        sc.predictor.window = 3000.0;
        sc.platform.cp = 60.0;
        let pol = policy(PolicyKind::WithCkpt, 8000.0, 400.0);
        let out = simulate(&sc, &pol, 4);
        assert!(out.n_pro_ckpts > 0);
        assert!(out.n_preds_trusted > 0);
    }

    #[test]
    fn instant_takes_only_prewindow_checkpoints() {
        let mut sc = base_scenario();
        sc.predictor.window = 3000.0;
        let pol = policy(PolicyKind::Instant, 8000.0, 700.0);
        let out = simulate(&sc, &pol, 4);
        // Every trusted prediction takes exactly one proactive checkpoint
        // (the pre-window one), unless destroyed by a fault mid-checkpoint.
        assert!(out.n_pro_ckpts <= out.n_preds_trusted);
        assert!(out.n_pro_ckpts + 5 >= out.n_preds_trusted);
    }

    #[test]
    fn ignore_mode_never_trusts() {
        let sc = base_scenario();
        let pol = policy(PolicyKind::IgnorePredictions, 6000.0, 600.0);
        let out = simulate(&sc, &pol, 6);
        assert_eq!(out.n_preds_trusted, 0);
        assert_eq!(out.n_pro_ckpts, 0);
        assert!(out.n_preds_seen > 0);
    }

    #[test]
    fn timeline_tiles_makespan_for_all_policies() {
        let sc = base_scenario();
        for (kind, tp) in [
            (PolicyKind::IgnorePredictions, 700.0),
            (PolicyKind::Instant, 700.0),
            (PolicyKind::NoCkpt, 700.0),
            (PolicyKind::WithCkpt, 700.0),
        ] {
            let pol = policy(kind, 6000.0, tp);
            let (out, tl) = crate::sim::engine::simulate_traced(&sc, &pol, 5);
            let totals = tl.validate(out.makespan).expect("tiling");
            // Per-kind span totals must equal the outcome's accounting.
            assert!((totals[0] - (out.makespan - out.time_ckpt
                - out.time_down - out.time_idle)).abs() < 1e-6 * out.makespan);
            assert!((totals[1] - out.time_ckpt).abs() < 1e-6, "{kind:?}");
            assert!((totals[2] - out.time_down).abs() < 1e-6);
            assert!((totals[3] - out.time_idle).abs() < 1e-6);
            assert_eq!(tl.faults.len() as u64, out.n_faults);
        }
    }

    #[test]
    fn timeline_fault_free_alternates_work_and_ckpt() {
        let mut sc = base_scenario();
        sc.platform.mu = 1e15;
        sc.predictor.recall = 0.0;
        sc.job_size = 15_000.0;
        let pol = policy(PolicyKind::IgnorePredictions, 3600.0, 600.0);
        let (_, tl) = crate::sim::engine::simulate_traced(&sc, &pol, 1);
        use crate::sim::timeline::Span;
        for (i, span) in tl.spans.iter().enumerate() {
            if i % 2 == 0 {
                assert!(matches!(span, Span::Work { .. }), "{i}: {span:?}");
            } else {
                assert!(
                    matches!(span, Span::Ckpt { proactive: false, .. }),
                    "{i}: {span:?}"
                );
            }
        }
    }

    #[test]
    fn waste_is_zero_not_nan_for_degenerate_runs() {
        // A run capped at t = 0 completes no work in no time; its waste is
        // 0, not 0/0 (regression: NaN here poisoned search means).
        let sc = base_scenario();
        let pol = policy(PolicyKind::IgnorePredictions, 6000.0, 600.0);
        let out = simulate_from_capped(
            &sc,
            &pol,
            1.0,
            1,
            crate::sim::trace::FlatTrace::new(&sc, 1),
            0.0,
        );
        assert_eq!(out.makespan, 0.0);
        assert_eq!(out.job_size, 0.0);
        assert_eq!(out.waste(), 0.0);
        assert_eq!(SimOutcome::default().waste(), 0.0);
    }

    #[test]
    fn tiny_job_completes_before_first_checkpoint() {
        let mut sc = base_scenario();
        sc.platform.mu = 1e15;
        sc.predictor.recall = 0.0;
        sc.job_size = 100.0;
        let pol = policy(PolicyKind::IgnorePredictions, 3600.0, 600.0);
        let out = simulate(&sc, &pol, 8);
        assert_eq!(out.makespan, 100.0);
        assert_eq!(out.n_reg_ckpts, 0);
        assert_eq!(out.waste(), 0.0);
    }

    #[test]
    fn traced_q_matches_untraced_q() {
        // The builder dedup: the traced path takes the same q (and cap)
        // knobs as the untraced one and produces the same outcome.
        let sc = base_scenario();
        let pol = policy(PolicyKind::NoCkpt, 6000.0, 700.0);
        for q in [0.0, 0.4, 1.0] {
            let plain = simulate_q(&sc, &pol, q, 21);
            let (traced, tl) = simulate_traced_q(&sc, &pol, q, 21);
            assert_eq!(plain, traced, "q = {q}");
            tl.validate(traced.makespan).expect("tiling");
        }
    }
}
