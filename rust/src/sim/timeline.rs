//! Execution timelines: a span-by-span record of what the scheduler did.
//!
//! `simulate_traced` returns, besides the [`crate::sim::engine::SimOutcome`],
//! the exact sequence of activity spans (work, regular/proactive
//! checkpoints, downtime+recovery, idle).  This is how we *verify* the
//! Algorithm 1 semantics beyond aggregate counters — the spans must tile
//! the makespan exactly — and it powers `ckptwin inspect`'s ASCII strip.

/// One contiguous activity span.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Span {
    /// Useful work.
    Work { start: f64, end: f64 },
    /// A completed checkpoint (`proactive` distinguishes C vs C_p).
    Ckpt { start: f64, end: f64, proactive: bool },
    /// Downtime + recovery after a fault.
    Down { start: f64, end: f64 },
    /// Idle (aborted checkpoints, §3.1's "accounted as idle time").
    Idle { start: f64, end: f64 },
}

impl Span {
    pub fn start(&self) -> f64 {
        match *self {
            Span::Work { start, .. }
            | Span::Ckpt { start, .. }
            | Span::Down { start, .. }
            | Span::Idle { start, .. } => start,
        }
    }

    pub fn end(&self) -> f64 {
        match *self {
            Span::Work { end, .. }
            | Span::Ckpt { end, .. }
            | Span::Down { end, .. }
            | Span::Idle { end, .. } => end,
        }
    }

    pub fn duration(&self) -> f64 {
        self.end() - self.start()
    }

    fn glyph(&self) -> char {
        match self {
            Span::Work { .. } => '=',
            Span::Ckpt { proactive: false, .. } => 'C',
            Span::Ckpt { proactive: true, .. } => 'P',
            Span::Down { .. } => 'x',
            Span::Idle { .. } => '.',
        }
    }
}

/// The ordered span record of one execution.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub spans: Vec<Span>,
    /// Fault strike instants (for annotation; downtime spans follow them).
    pub faults: Vec<f64>,
}

impl Timeline {
    /// Append a span, coalescing consecutive work spans.
    pub fn push(&mut self, span: Span) {
        if span.duration() <= 0.0 {
            return;
        }
        if let (Some(Span::Work { end, .. }), Span::Work { start, end: new_end }) =
            (self.spans.last_mut(), span)
        {
            if (*end - start).abs() < 1e-9 {
                *end = new_end;
                return;
            }
        }
        self.spans.push(span);
    }

    pub fn record_fault(&mut self, t: f64) {
        self.faults.push(t);
    }

    /// Verify the spans tile [0, makespan] with no gaps or overlaps;
    /// returns the total per-kind durations (work, ckpt, down, idle).
    pub fn validate(&self, makespan: f64) -> Result<[f64; 4], String> {
        let mut cursor = 0.0;
        let mut totals = [0.0f64; 4];
        for (i, span) in self.spans.iter().enumerate() {
            if (span.start() - cursor).abs() > 1e-6 * makespan.max(1.0) {
                return Err(format!(
                    "span {i} starts at {} but previous ended at {cursor}",
                    span.start()
                ));
            }
            if span.end() < span.start() {
                return Err(format!("span {i} has negative duration"));
            }
            let idx = match span {
                Span::Work { .. } => 0,
                Span::Ckpt { .. } => 1,
                Span::Down { .. } => 2,
                Span::Idle { .. } => 3,
            };
            totals[idx] += span.duration();
            cursor = span.end();
        }
        if (cursor - makespan).abs() > 1e-6 * makespan.max(1.0) {
            return Err(format!(
                "spans end at {cursor} but makespan is {makespan}"
            ));
        }
        Ok(totals)
    }

    /// Additive per-kind totals with the checkpoint time split into its
    /// regular and proactive components:
    /// `[work, ckpt_reg, ckpt_pro, down, idle]`.  Unlike
    /// [`Timeline::validate`] this does no tiling check — it is the
    /// span-level counterpart of [`crate::obs::EventCounters`]'s time
    /// decomposition (`tests/metrics.rs` cross-checks the two).
    pub fn totals_split(&self) -> [f64; 5] {
        let mut totals = [0.0f64; 5];
        for span in &self.spans {
            let idx = match span {
                Span::Work { .. } => 0,
                Span::Ckpt { proactive: false, .. } => 1,
                Span::Ckpt { proactive: true, .. } => 2,
                Span::Down { .. } => 3,
                Span::Idle { .. } => 4,
            };
            totals[idx] += span.duration();
        }
        totals
    }

    /// Render an ASCII strip of `width` characters covering the makespan.
    pub fn render(&self, width: usize) -> String {
        let width = width.max(10);
        let makespan = self.spans.last().map(|s| s.end()).unwrap_or(0.0);
        if makespan <= 0.0 {
            return "(empty timeline)".to_string();
        }
        let mut strip = vec![' '; width];
        for span in &self.spans {
            let a = (span.start() / makespan * width as f64) as usize;
            let b = ((span.end() / makespan * width as f64).ceil() as usize)
                .min(width)
                .max(a + 1);
            for cell in strip.iter_mut().take(b).skip(a) {
                *cell = span.glyph();
            }
        }
        // Overlay fault markers.
        for &tf in &self.faults {
            let i = ((tf / makespan * width as f64) as usize).min(width - 1);
            strip[i] = 'X';
        }
        let mut out: String = strip.into_iter().collect();
        out.push_str(
            "\n  = work   C reg-ckpt   P pro-ckpt   X fault   x down+rec   . idle",
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_coalesces_adjacent_work() {
        let mut tl = Timeline::default();
        tl.push(Span::Work { start: 0.0, end: 5.0 });
        tl.push(Span::Work { start: 5.0, end: 9.0 });
        tl.push(Span::Ckpt { start: 9.0, end: 10.0, proactive: false });
        tl.push(Span::Work { start: 10.0, end: 12.0 });
        assert_eq!(tl.spans.len(), 3);
        assert_eq!(tl.spans[0], Span::Work { start: 0.0, end: 9.0 });
    }

    #[test]
    fn validate_detects_gap_and_overlap() {
        let mut tl = Timeline::default();
        tl.push(Span::Work { start: 0.0, end: 5.0 });
        tl.push(Span::Ckpt { start: 6.0, end: 7.0, proactive: false });
        assert!(tl.validate(7.0).is_err());
        let mut tl2 = Timeline::default();
        tl2.push(Span::Work { start: 0.0, end: 5.0 });
        tl2.push(Span::Ckpt { start: 5.0, end: 7.0, proactive: false });
        let totals = tl2.validate(7.0).unwrap();
        assert_eq!(totals[0], 5.0);
        assert_eq!(totals[1], 2.0);
    }

    #[test]
    fn totals_split_separates_proactive_from_regular() {
        let mut tl = Timeline::default();
        tl.push(Span::Work { start: 0.0, end: 5.0 });
        tl.push(Span::Ckpt { start: 5.0, end: 6.0, proactive: false });
        tl.push(Span::Ckpt { start: 6.0, end: 8.0, proactive: true });
        tl.push(Span::Down { start: 8.0, end: 11.0 });
        tl.push(Span::Idle { start: 11.0, end: 11.5 });
        let t = tl.totals_split();
        assert_eq!(t, [5.0, 1.0, 2.0, 3.0, 0.5]);
        // Consistent with validate()'s coarse totals.
        let coarse = tl.validate(11.5).unwrap();
        assert_eq!(coarse, [t[0], t[1] + t[2], t[3], t[4]]);
    }

    #[test]
    fn render_strip() {
        let mut tl = Timeline::default();
        tl.push(Span::Work { start: 0.0, end: 80.0 });
        tl.push(Span::Ckpt { start: 80.0, end: 100.0, proactive: true });
        tl.record_fault(50.0);
        let s = tl.render(50);
        assert!(s.contains('='));
        assert!(s.contains('P'));
        assert!(s.contains('X'));
    }
}
