//! `ckptwin` — CLI launcher for the reproduction.
//!
//! Subcommands:
//! * `simulate`    — run the 9-heuristic comparison on one scenario
//! * `analytic`    — closed-form wastes and optimal periods for a scenario
//! * `figure`      — regenerate a paper figure (`--id 2..21`) into results/
//! * `table`       — regenerate Table 4 or 5 (`--id 4|5`)
//! * `best-period` — closed-form vs brute-force vs PJRT-grid period search
//! * `e2e`         — train the transformer under fault injection with
//!                   proactive checkpointing (the real-system driver)
//! * `sweep`       — evaluate the Table-6 literature predictors
//! * `config`      — run a scenario described by a TOML file
//! * `campaign`    — declarative scenario-grid sweeps on the campaign
//!                   engine: `campaign run` cartesian-expands the axes
//!                   (`--procs`, `--cp-ratios`, `--laws`, `--predictors`,
//!                   `--windows`, `--strategies`, `--scale`) into cells,
//!                   executes them on a work-stealing pool, and streams
//!                   per-cell results into a JSONL store keyed by stable
//!                   scenario hashes; `campaign resume` recomputes only the
//!                   cells missing from an interrupted store; `campaign
//!                   report` pretty-prints a store.
//! * `validate`    — conformance sweeps on the campaign scheduler: per-cell
//!                   simulated waste (Welford CIs) vs the closed-form model
//!                   at the analytic optimum and at off-optimal periods,
//!                   with validity-domain classification, a per-strategy
//!                   deviation table, a resumable JSONL conformance store
//!                   and a machine-readable `CONFORMANCE.json`; non-zero
//!                   exit on any unexplained failure (the CI gate)
//! * `metrics`     — telemetry snapshot + waste-accounting audit: runs a
//!                   metered campaign (cells/sec, events/sec, trace-pool
//!                   hit-rate), re-simulates every cell with the
//!                   `EventCounters` recorder and checks that the
//!                   counter-derived time decomposition tiles each
//!                   makespan and reconciles with `SimOutcome::waste()`,
//!                   compares campaign-aggregated decompositions
//!                   term-by-term against the closed-form waste terms,
//!                   times a short coordinator run's decision latency,
//!                   and writes everything to `METRICS.json` (schema
//!                   `ckptwin-metrics/1`); non-zero exit on any audit
//!                   violation (the CI gate)
//! * `chaos`       — crash–resume equivalence gate: golden runs vs runs
//!                   crashed (torn writes, transient IO, killed coordinator
//!                   passes) and resumed, compared record-for-record and
//!                   fingerprint-for-fingerprint; writes `CHAOS.json` and
//!                   exits non-zero on any divergence (the CI gate).  The
//!                   global `--inject "site:p=0.01,seed=42"` flag arms the
//!                   same fail points under any other subcommand.
//! * `strategies`  — list the strategy registry (names, aliases,
//!                   parameters); any registered name — including the
//!                   parameterized `qtrust(q=…)` and the BestPeriod
//!                   twins — is valid wherever a strategy is named
//! * `predictors`  — list the predictor registry; any registered name —
//!                   the paper's `a`/`b` or a parameterized model like
//!                   `biased(beta=2)` — is valid wherever a predictor is
//!                   named (`--predictor`, `--predictors`, config files)
//! * `lint`        — check declarative `.ckpt` scenario suites without
//!                   running them: unknown sections/keys/registry ids
//!                   (with nearest-match suggestions), out-of-range
//!                   params, and validity-domain warnings
//! * `explain`     — why one conformance cell passed / failed / was
//!                   classified: the regime guard that fired with its
//!                   measured value, or the 5-term priced tolerance
//!                   broken out term by term
//! * `replay`      — re-run stored campaign/conformance cells from their
//!                   keys and diff field-for-field against the store
//!                   (`--verify` is the CI bit-identity gate); the legacy
//!                   `--log` form replays a recorded failure log
//!
//! Run `ckptwin help` for per-command options.

use anyhow::{anyhow, Result};

use ckptwin::cli::Args;
use ckptwin::config::{FaultModel, PredictorSpec, Scenario};
use ckptwin::harness::{self, figures, tables};
use ckptwin::model::{optimal, waste};
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::best_period;
use ckptwin::util::SECONDS_PER_DAY;

const HELP: &str = "\
ckptwin — Checkpointing strategies with prediction windows (2013), full repro

USAGE: ckptwin <command> [options]

COMMANDS
  simulate     --procs 65536 --cp-ratio 1.0 --predictor a|b|biased(beta=2)|...
               --window 600
               --law exponential|weibull0.7|weibull0.5 [--fp-law uniform]
               [--instances 100] [--best-period-seeds 0]
  analytic     same scenario options; prints Eqs. 3/4/10/14 optima
  figure       --id 2..21 [--instances N] [--best-period-seeds N] [--plot]
  table        --id 4|5 [--instances N]
  best-period  scenario options; compares closed-form, brute-force (racing
               with --batch model seeding by default; --scalar for the
               per-candidate reference, --no-model to disable), the batched
               f64 grid argmin and the PJRT waste-grid search [--grid 256]
  export-grid  write the golden waste-grid JSON for the python kernel
               cross-check [--out python/tests/golden_waste_grid.json]
               [--grid 48]
  e2e          [--steps 400] [--mtbf 4000] [--strategy withckpt|nockpt|
               instant|rfo] [--ckpt-dir DIR] [--seed 42]
  sweep        [--procs 65536] [--instances 50]  (Table-6 predictors)
  ablation     [--procs 262144] [--instances 20]  fault-model + trust-q
               ablations behind DESIGN.md's design choices
  inspect      scenario options + [--strategy withckpt] [--seed 0]
               [--width 100]: ASCII execution timeline of one run
  replay       <store.jsonl> <cell-hash>|--all [--verify]  re-run stored
               campaign/conformance cells from their keys and diff the
               fresh records field-for-field against the store;
               --verify exits non-zero on any divergence.
               Legacy form: --log faults.txt [scenario options] runs all
               heuristics against a recorded failure log; --export N
               writes a synthetic log instead
  explain      <cell-key> | <store.jsonl> <cell-hash>  [--instances 40]
               why a conformance cell passed / failed / classified: the
               guard that fired with its measured value, or the 5-term
               priced tolerance broken out term by term (campaign cell
               keys are explained at multiplier 1.0, platform renewal)
  lint         <file.ckpt> [...]  check scenario files without running
               them: unknown sections/keys/registry ids (with nearest-
               match suggestions), out-of-range params, compile errors;
               warns how many cells would classify inapplicable.
               Non-zero exit on any error
  config       <file.toml> [--instances N]
  campaign     run|resume|report [--out results/campaign.jsonl] [--force]
               [--grid paper|smoke] [--scenario file.ckpt] [--instances N]
               [--threads N]
               [--block N] [--scale F] [--uniform-fp] [--heartbeat]
               [--procs 65536,131072,...] [--cp-ratios 1.0,0.1]
               [--laws exponential,weibull0.7,lognormal1.2]
               [--predictors a,b,biased(beta=2),...] [--windows 300,600,...]
               [--strategies daly,rfo,nockpt,exactpred,qtrust(q=0.5),...]
               [--shards 1,4,...]  (platform-shards axis: split each
               per-processor platform into S merged sub-sources)
               run executes the grid and streams per-cell JSONL results
               (refusing to clobber a non-empty store without --force);
               resume skips cells already in the store; report prints it
  validate     conformance sweep: simulated waste vs the closed-form model
               (Eqs. 3/4/10/14) per (strategy, law, predictor) cell, at the
               analytic optimum and at off-optimal periods; CI-aware
               tolerance verdicts, validity-domain classification, per-
               strategy table + CONFORMANCE.json; exits non-zero on any
               unexplained failure.  [--smoke | --grid default|smoke]
               [--scenario file.ckpt]
               [--instances N] [--threads N] [--multipliers 0.75,1,1.5]
               [--out results/conformance.jsonl] [--resume]
               [--json CONFORMANCE.json] + the campaign axis overrides
               (--procs, --laws, --predictors, --windows, --strategies,
               --cp-ratios, --scale, --shards)
               --scale-check runs the platform-rate scale guard instead:
               measured superposed fault rate vs the 1/mu approximation at
               N = 10^4..10^6 (stationary must conform, fresh Weibull k<1
               must flag platform_rate_nonconforming)
               [--seeds 6] [--horizon-mtbfs 150]
  metrics      telemetry snapshot + waste-accounting audit: metered
               campaign throughput (cells/s, events/s, pool hit-rate),
               per-simulation counter-vs-outcome audit (decomposed times
               must tile the makespan), campaign-aggregate decomposition
               vs the closed-form waste terms, coordinator decision-
               latency histogram; writes METRICS.json and exits non-zero
               on any audit violation.  [--grid smoke|paper]
               [--instances N] [--threads N] [--json METRICS.json]
               [--heartbeat] [--steps 240] [--mtbf 3000] [--seed 42]
               + the campaign axis overrides (--procs, --laws, ...)
  chaos        crash–resume equivalence gate: randomized kill/resume
               cycles over the campaign store (torn partial-line writes,
               transient IO), the conformance store, and the coordinator
               (killed passes resumed from its self-snapshot); each
               survivor must match its golden run record for record /
               fingerprint for fingerprint.  Writes CHAOS.json; non-zero
               exit on any divergence or unquarantined corruption.
               [--smoke (25 cycles) | --cycles 100] [--seed 42]
               [--dir results/chaos-scratch] [--json CHAOS.json]
  strategies   list the strategy registry: names, aliases, parameters
               (any registered name is valid wherever a strategy is named)
  predictors   list the predictor registry: names, aliases, parameters
               (any registered name is valid wherever a predictor is
               named: --predictor, --predictors, [predictor] model in
               config files; e.g. a, b, paper(r=0.9;p=0.7),
               biased(beta=2), mixedwin(i1=300;i2=1200;w=0.5),
               jitter(sigma=120), classed(p_hi=0.95;p_lo=0.6;frac=0.5))
  help         this text

GLOBAL
  --inject \"site:key=val,...[;site:...]\"  arm deterministic fail points
               for the whole process: sites store.append, jsonl.tail,
               sched.worker, pool.insert, coord.pass, snapshot.write;
               keys p= (per-hit probability), nth= (fire on the nth hit),
               seed=, mode=transient|torn|panic|kill (default kill).
               e.g. --inject \"store.append:p=0.01,seed=42,mode=transient\"
";

fn scenario_from_args(args: &Args) -> Result<Scenario> {
    let procs: u64 = args.get_or("procs", 1 << 16);
    let cp_ratio: f64 = args.get_or("cp-ratio", 1.0);
    let window: f64 = args.get_or("window", 600.0);
    // Any registry predictor is valid: a|b, or a parameterized model like
    // biased(beta=2).  A typo or out-of-range parameter is an error —
    // silently falling back to predictor A would make a sweep over model
    // parameters report identical predictor-A numbers without warning.
    let predictor = ckptwin::predictor::registry::PredictorId::parse(
        args.get_str("predictor").unwrap_or("a"),
    )
    .map_err(|e| anyhow!(e))?
    .spec(window);
    let law = args
        .get_str("law")
        .and_then(Law::parse)
        .unwrap_or(Law::Exponential);
    let fp_law = args.get_str("fp-law").and_then(Law::parse).unwrap_or(law);
    Ok(Scenario::paper(procs, cp_ratio, predictor, law, fp_law))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let sc = scenario_from_args(args)?;
    let n = args.get_or("instances", harness::default_instances());
    let bp = args.get_or("best-period-seeds", 0usize);
    println!(
        "scenario: mu={:.0}s C={} Cp={} D={} R={} | p={} r={} I={} | {} faults, {} FPs | job {:.1} days | {n} instances",
        sc.platform.mu, sc.platform.c, sc.platform.cp, sc.platform.d,
        sc.platform.r, sc.predictor.precision, sc.predictor.recall,
        sc.predictor.window, sc.fault_law.label(), sc.false_pred_law.label(),
        sc.job_size / SECONDS_PER_DAY,
    );
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "heuristic", "waste", "±ci95", "analytic", "makespan(d)", "T_R"
    );
    for r in harness::evaluate_heuristics(&sc, n, bp) {
        println!(
            "{:<22} {:>10.4} {:>10.4} {:>10.4} {:>12.2} {:>10.0}",
            r.name,
            r.waste,
            r.waste_ci,
            r.analytic_waste,
            r.makespan / SECONDS_PER_DAY,
            r.tr
        );
    }
    Ok(())
}

fn cmd_analytic(args: &Args) -> Result<()> {
    let sc = scenario_from_args(args)?;
    let pf = &sc.platform;
    println!("closed-form periods (s):");
    println!("  Young      T = {:>10.1}", optimal::young_period(pf));
    println!("  Daly       T = {:>10.1}", optimal::daly_period(pf));
    println!("  RFO        T = {:>10.1}", optimal::rfo_period(pf));
    println!("  Instant    T_R^extr = {:>10.1}", optimal::tr_extr_instant(&sc));
    println!("  NoCkptI    T_R^extr = {:>10.1}", optimal::tr_extr_window(&sc));
    println!("  WithCkptI  T_R^extr = {:>10.1}  T_P^extr = {:.1}",
        optimal::tr_extr_window(&sc), optimal::tp_extr(&sc));
    println!("\nwaste at the optimum:");
    let tr0 = optimal::rfo_period(pf);
    println!("  RFO (Eq.3)        {:.4}", waste::q0(&sc, tr0));
    println!("  Instant (Eq.14)   {:.4}", waste::instant(&sc, optimal::tr_extr_instant(&sc)));
    println!("  NoCkptI (Eq.10)   {:.4}", waste::nockpt(&sc, optimal::tr_extr_window(&sc)));
    println!(
        "  WithCkptI (Eq.4)  {:.4}",
        waste::withckpt(&sc, optimal::tr_extr_window(&sc), optimal::tp_extr(&sc))
    );
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id: u8 = args
        .get("id")
        .ok_or_else(|| anyhow!("--id 2..21 required"))?;
    let n = args.get_or("instances", harness::default_instances());
    let bp = args.get_or("best-period-seeds", 10usize);
    let rows = match id {
        2..=13 => {
            let spec = figures::waste_vs_n_specs()
                .into_iter()
                .find(|s| s.id == id)
                .unwrap();
            figures::run_waste_vs_n(&spec, n, bp)?
        }
        14..=17 => {
            let spec = figures::waste_vs_tr_specs()
                .into_iter()
                .find(|s| s.id == id)
                .unwrap();
            figures::run_waste_vs_tr(&spec, n, args.get_or("grid", 24usize))?
        }
        18..=21 => {
            let spec = figures::waste_vs_i_specs()
                .into_iter()
                .find(|s| s.id == id)
                .unwrap();
            figures::run_waste_vs_i(&spec, n, bp)?
        }
        _ => return Err(anyhow!("figure id must be 2..21")),
    };
    println!("wrote results/fig{id}.csv ({} rows)", rows.len());
    if args.has("plot") {
        print_figure_plot(id, &rows);
    }
    Ok(())
}

/// Quick terminal plot of a figure's exponential-law panel.
fn print_figure_plot(id: u8, rows: &[String]) {
    use ckptwin::harness::plot::{render, Series};
    use std::collections::BTreeMap;
    let mut by_heuristic: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    for row in rows {
        let cols: Vec<&str> = row.split(',').collect();
        if cols.len() < 8 || cols[1] != "exponential" {
            continue;
        }
        let (window, procs, name) = (cols[2], cols[3], cols[4]);
        if name.contains("BestPeriod") || name.ends_with("-period") {
            continue;
        }
        let x: f64 = if (14..=17).contains(&id) {
            cols[5].parse().unwrap_or(f64::NAN) // T_R sweep
        } else if (18..=21).contains(&id) {
            window.parse().unwrap_or(f64::NAN)
        } else {
            procs.parse().unwrap_or(f64::NAN)
        };
        let y: f64 = cols[6].parse().unwrap_or(f64::NAN);
        if x.is_finite() && y.is_finite() {
            by_heuristic.entry(name.to_string()).or_default().push((x, y));
        }
    }
    let series: Vec<Series> = by_heuristic
        .into_iter()
        .map(|(name, points)| Series { name, points })
        .collect();
    println!(
        "{}",
        render(
            &format!("figure {id} (exponential panel, waste vs x)"),
            &series,
            72,
            18
        )
    );
}

fn cmd_table(args: &Args) -> Result<()> {
    let id: u8 = args.get_or("id", 4);
    let n = args.get_or("instances", harness::default_instances());
    let shape = match id {
        4 => 0.7,
        5 => 0.5,
        _ => return Err(anyhow!("table id must be 4 or 5")),
    };
    let table = tables::run_table(id, shape, n)?;
    println!("{}", tables::render(&table));
    println!("wrote results/table{id}.csv");
    Ok(())
}

fn cmd_best_period(args: &Args) -> Result<()> {
    use ckptwin::sim::trace::TraceCache;
    use ckptwin::strategy::best_period::{ModelSide, SearchConfig};
    use ckptwin::strategy::PolicyKind;
    let sc = scenario_from_args(args)?;
    let grid_n: usize = args.get_or("grid", 256);
    let seeds: Vec<u64> = (0..args.get_or("instances", 20u64)).collect();

    // Model side of the racing search: batched closed-form seeding
    // (default), per-candidate scalar seeding (the reference the batched
    // path must agree with), or no model pruning at all.
    let side = match (args.has("batch"), args.has("scalar"), args.has("no-model")) {
        (true, true, _) | (true, _, true) | (_, true, true) => {
            return Err(anyhow!("--batch, --scalar and --no-model are mutually exclusive"))
        }
        (_, true, _) => ModelSide::Scalar,
        (_, _, true) => ModelSide::Off,
        _ => ModelSide::Batched,
    };

    // Closed form.
    println!("closed-form:   RFO={:.0}  Instant={:.0}  window={:.0}",
        optimal::rfo_period(&sc.platform),
        optimal::tr_extr_instant(&sc),
        optimal::tr_extr_window(&sc));

    // Brute force over simulations, model-seeded per --batch/--scalar.
    let tp = ckptwin::strategy::registry::default_tp(&sc);
    let cfg = SearchConfig::adaptive(24, 8).with_model(side);
    for (name, kind) in [
        ("NoPred", PolicyKind::IgnorePredictions),
        ("Instant", PolicyKind::Instant),
        ("NoCkptI", PolicyKind::NoCkpt),
        ("WithCkptI", PolicyKind::WithCkpt),
    ] {
        let mut caches: Vec<TraceCache> =
            seeds.iter().map(|&s| TraceCache::new(&sc, s)).collect();
        let bp = best_period::search_with(&sc, kind, tp, &seeds, &cfg, &mut caches);
        println!(
            "brute-force:   {name:<10} T_R*={:.0}  waste={:.4} ({} sims, {side:?} model)",
            bp.tr, bp.waste, bp.evals
        );
    }

    // Batched model surfaces: the f64 grid argmin (bit-identical to the
    // scalar closed forms) on the same grid the PJRT artifact would use.
    let lo = 1.05 * sc.platform.c;
    let hi = 60.0 * optimal::rfo_period(&sc.platform);
    let grid: Vec<f64> = (0..grid_n)
        .map(|k| lo * (hi / lo).powf(k as f64 / (grid_n - 1) as f64))
        .collect();
    let names = ["Q0", "Instant", "NoCkptI", "WithCkptI"];
    let batch_best = ckptwin::model::batch::best_periods_clipped(&sc, &grid);
    for (i, (tr, w)) in batch_best.iter().enumerate() {
        println!(
            "model-batch:   {:<10} T_R*={tr:.0}  analytic waste={w:.4}",
            names[i]
        );
    }

    // PJRT waste-grid artifact (f32 kernel argmin on the same grid), plus
    // the kernel-vs-model cross-check gate.
    match ckptwin::runtime::Runtime::discover() {
        Ok(rt) => {
            let best = rt.best_periods(&sc, &grid)?;
            for (i, (tr, w)) in best.iter().enumerate() {
                println!(
                    "pjrt-grid:     {:<10} T_R*={tr:.0}  analytic waste={w:.4}",
                    names[i]
                );
            }
            let chk = ckptwin::runtime::waste_grid::crosscheck_waste_grid(
                &rt,
                std::slice::from_ref(&sc),
                &grid,
            )?;
            println!(
                "crosscheck:    {} — {} cells, max |kernel−model| = {:.2e}",
                if chk.passed() { "PASS" } else { "FAIL" },
                chk.cells,
                chk.max_abs_err,
            );
            if !chk.passed() {
                return Err(anyhow!(
                    "{} of {} kernel cells beyond the priced f32 tolerance",
                    chk.failures,
                    chk.cells
                ));
            }
        }
        Err(e) => println!("pjrt-grid:     skipped ({e})"),
    }
    Ok(())
}

/// Emit the golden waste-grid JSON consumed by the python kernel
/// cross-check (`python/tests/test_golden_grid.py`): f64 clipped surfaces
/// from the batched model — bit-identical to scalar `waste_clipped` — over
/// a deterministic scenario battery and linear period grid mirroring
/// `tests/runtime_roundtrip.rs`.  Parameter rows use the layout documented
/// in `python/compile/kernels/ref.py`.
fn cmd_export_grid(args: &Args) -> Result<()> {
    use ckptwin::jsonio::Value;
    use ckptwin::obs::report;
    use ckptwin::runtime::waste_grid::{
        scenario_row_checked, CROSSCHECK_ABS_TOL, CROSSCHECK_REL_TOL,
    };

    let grid_n: usize = args.get_or("grid", 48);
    let out_path = std::path::PathBuf::from(
        args.get_str("out").unwrap_or("python/tests/golden_waste_grid.json"),
    );

    let mut scenarios = Vec::new();
    for procs in [1u64 << 16, 1 << 18] {
        for cp_ratio in [1.0, 0.1] {
            for window in [300.0, 1200.0] {
                for pred in [
                    PredictorSpec::paper_a(window),
                    PredictorSpec::paper_b(window),
                ] {
                    scenarios.push(Scenario::paper(
                        procs,
                        cp_ratio,
                        pred,
                        Law::Exponential,
                        Law::Exponential,
                    ));
                }
            }
        }
    }
    let grid: Vec<f64> = (0..grid_n).map(|k| 650.0 + 900.0 * k as f64).collect();
    let (surfaces, stats) =
        ckptwin::model::batch::clipped_surfaces(&scenarios, &grid, 0);

    let mut param_rows = Vec::with_capacity(scenarios.len());
    for sc in &scenarios {
        // Exported in f64 (the python side narrows to f32 itself), but
        // checked representable here so the comparison is meaningful.
        scenario_row_checked(sc)
            .map_err(|e| anyhow!("scenario not exportable: {e}"))?;
        param_rows.push(Value::Arr(vec![
            Value::Num(sc.platform.mu),
            Value::Num(sc.platform.c),
            Value::Num(sc.platform.cp),
            Value::Num(sc.platform.d),
            Value::Num(sc.platform.r),
            Value::Num(sc.predictor.precision),
            Value::Num(sc.predictor.recall),
            Value::Num(sc.predictor.window),
            Value::Num(sc.e_if()),
            Value::Num(0.0),
        ]));
    }
    let surf_json: Vec<Value> = surfaces
        .iter()
        .map(|s| {
            Value::Arr(
                s.iter()
                    .map(|row| {
                        Value::Arr(row.iter().map(|&w| Value::Num(w)).collect())
                    })
                    .collect(),
            )
        })
        .collect();

    let doc = json_obj(vec![
        ("schema", Value::Str("ckptwin-golden-grid/1".into())),
        (
            "strategies",
            Value::Arr(
                ["q0", "instant", "nockpt", "withckpt"]
                    .iter()
                    .map(|s| Value::Str(s.to_string()))
                    .collect(),
            ),
        ),
        (
            "tolerance",
            json_obj(vec![
                ("abs", Value::Num(CROSSCHECK_ABS_TOL)),
                ("rel", Value::Num(CROSSCHECK_REL_TOL)),
            ]),
        ),
        ("tr", Value::Arr(grid.iter().map(|&t| Value::Num(t)).collect())),
        ("params", Value::Arr(param_rows)),
        ("surfaces", Value::Arr(surf_json)),
    ]);
    let bytes = report::write_json(&out_path, &doc)?;
    println!(
        "wrote {} — {} scenarios × 4 strategies × {} periods ({} cells, {bytes} bytes)",
        out_path.display(),
        scenarios.len(),
        grid.len(),
        stats.cells,
    );
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    use ckptwin::config::Platform;
    use ckptwin::coordinator::{self, workload::PjrtWorkload, CoordinatorConfig};
    use ckptwin::strategy::{Policy, PolicyKind};

    let rt = ckptwin::runtime::Runtime::discover()?;
    println!(
        "runtime: platform={} params={}",
        rt.platform_name(),
        rt.manifest.param_count
    );
    let steps: u64 = args.get_or("steps", 400);
    let mtbf: f64 = args.get_or("mtbf", 4000.0);
    // Any registered strategy name maps to its engine mode ("rfo" and
    // friends run as their execution mode with the e2e platform's periods).
    let kind = ckptwin::strategy::StrategyId::parse(
        args.get_str("strategy").unwrap_or("withckpt"),
    )
    .map_err(|e| anyhow!(e))?
    .kind();
    let scenario = Scenario {
        platform: Platform { mu: mtbf, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
        predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
        fault_law: Law::Exponential,
        false_pred_law: Law::Exponential,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 0.0,
    };
    let tr = match kind {
        PolicyKind::IgnorePredictions => optimal::rfo_period(&scenario.platform),
        PolicyKind::Instant | PolicyKind::ExactPred => {
            optimal::tr_extr_instant(&scenario)
        }
        _ => optimal::tr_extr_window(&scenario),
    };
    let tp = ckptwin::strategy::registry::default_tp(&scenario);
    let cfg = CoordinatorConfig {
        scenario,
        policy: Policy { kind, tr, tp },
        seconds_per_step: 30.0,
        total_steps: steps,
        ckpt_dir: args
            .get_str("ckpt-dir")
            .unwrap_or("results/e2e-ckpts")
            .into(),
        seed: args.get_or("seed", 42),
        log_every: 10,
        selfckpt: None,
    };
    println!(
        "e2e: {} steps, policy {:?} T_R={tr:.0} T_P={tp:.0}, MTBF {mtbf}s",
        steps, kind
    );
    let mut workload = PjrtWorkload::new(&rt, cfg.seed, 0.1)?;
    let rep = coordinator::run(&cfg, &mut workload)?;
    println!(
        "done: makespan {:.0}s sim, waste {:.4} (model predicted {:.4})",
        rep.sim_makespan, rep.sim_waste, rep.predicted_waste
    );
    println!(
        "faults {} | reg ckpts {} | pro ckpts {} | preds trusted {} | steps exec {} (lost {})",
        rep.n_faults, rep.n_reg_ckpts, rep.n_pro_ckpts, rep.n_preds_trusted,
        rep.steps_executed, rep.steps_lost
    );
    println!("loss curve ({} samples):", rep.losses.len());
    for (step, loss) in &rep.losses {
        if step % 50 == 0 || *step == steps {
            println!("  step {step:>6}  loss {loss:.4}");
        }
    }
    println!("wall time {:.1}s ({:.1} steps/s)",
        rep.wall_seconds, rep.steps_executed as f64 / rep.wall_seconds);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let procs: u64 = args.get_or("procs", 1 << 16);
    let n = args.get_or("instances", 50usize);
    println!(
        "{:<18} {:>6} {:>6} {:>8} {:>10} {:>10} {:>10}",
        "predictor", "p", "r", "I", "Daly", "RFO", "best-aware"
    );
    for (name, spec) in ckptwin::predictor::table6_presets() {
        let sc = Scenario::paper(procs, 1.0, spec, Law::Exponential, Law::Exponential);
        let res = harness::evaluate_heuristics(&sc, n, 0);
        let get = |nm: &str| {
            res.iter().find(|r| r.name == nm).map(|r| r.waste).unwrap_or(f64::NAN)
        };
        let aware = ["Instant", "NoCkptI", "WithCkptI"]
            .iter()
            .map(|nm| get(nm))
            .fold(f64::INFINITY, f64::min);
        println!(
            "{:<18} {:>6.2} {:>6.2} {:>8.0} {:>10.4} {:>10.4} {:>10.4}",
            name, spec.precision, spec.recall, spec.window,
            get("Daly"), get("RFO"), aware
        );
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    use ckptwin::sim::engine::simulate_q;
    use ckptwin::strategy::{registry, Policy, PolicyKind, StrategyId};
    let procs: u64 = args.get_or("procs", 1 << 18);
    let n: usize = args.get_or("instances", 20);
    let window: f64 = args.get_or("window", 600.0);
    let law = Law::Weibull { shape: args.get_or("shape", 0.7) };

    // --- Ablation 1: fault-trace model -----------------------------------
    println!("ablation 1 — fault-trace model (Weibull {}, N=2^{}, I={window}):",
        args.get_or("shape", 0.7), procs.trailing_zeros());
    println!("{:<28} {:>10} {:>10} {:>10}", "model", "Daly", "RFO", "NoCkptI");
    for (name, model) in [
        ("platform-renewal", FaultModel::PlatformRenewal),
        ("per-proc stationary", FaultModel::PerProcessorStationary { n: procs }),
        ("per-proc fresh (paper)", FaultModel::PerProcessor { n: procs }),
    ] {
        let mut sc = Scenario::paper(
            procs, 1.0, PredictorSpec::paper_a(window), law, law,
        );
        sc.fault_model = model;
        let w = |strat: StrategyId| {
            let pol = strat.policy(&sc);
            harness::run_instances(&sc, &pol, n).0.mean()
        };
        println!(
            "{:<28} {:>10.4} {:>10.4} {:>10.4}",
            name,
            w(registry::get("Daly").unwrap()),
            w(registry::get("RFO").unwrap()),
            w(registry::get("NoCkptI").unwrap())
        );
    }

    // --- Ablation 2: trust probability q (paper: optimum at 0 or 1) ------
    println!("\nablation 2 — randomized trust q (§3.1; optimum must be extreme):");
    let sc = Scenario::paper(
        procs, 1.0, PredictorSpec::paper_a(window), law, law,
    );
    let tr = optimal::tr_extr_window(&sc);
    let tp = registry::default_tp(&sc);
    let pol = Policy { kind: PolicyKind::NoCkpt, tr, tp };
    print!("{:>8}", "q");
    for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
        print!(" {q:>9.2}");
    }
    print!("\n{:>8}", "waste");
    for q in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
        let mean: f64 = (0..n as u64)
            .map(|s| simulate_q(&sc, &pol, q, s).waste())
            .sum::<f64>()
            / n as f64;
        print!(" {mean:>9.4}");
    }
    println!();

    // --- Ablation 3: proactive checkpoint cost C_p ------------------------
    println!("\nablation 3 — C_p sensitivity (WithCkptI vs NoCkptI, I=3000):");
    println!("{:<10} {:>12} {:>12}", "Cp/C", "NoCkptI", "WithCkptI");
    for ratio in [0.1, 0.5, 1.0, 2.0] {
        let sc = Scenario::paper(
            procs, ratio, PredictorSpec::paper_a(3000.0), law, law,
        );
        let nockpt = registry::get("NoCkptI").unwrap().policy(&sc);
        let withckpt = registry::get("WithCkptI").unwrap().policy(&sc);
        let wn = harness::run_instances(&sc, &nockpt, n).0.mean();
        let ww = harness::run_instances(&sc, &withckpt, n).0.mean();
        println!("{ratio:<10} {wn:>12.4} {ww:>12.4}");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    use ckptwin::sim::engine::simulate_traced;
    use ckptwin::strategy::StrategyId;
    let sc = scenario_from_args(args)?;
    let strat =
        StrategyId::parse(args.get_str("strategy").unwrap_or("withckpt"))
            .map_err(|e| anyhow!(e))?;
    let pol = strat.policy(&sc);
    let seed = args.get_or("seed", 0u64);
    let width = args.get_or("width", 100usize);
    let (out, tl) = simulate_traced(&sc, &pol, seed);
    tl.validate(out.makespan).map_err(|e| anyhow!("timeline: {e}"))?;
    println!(
        "{} @ T_R={:.0} T_P={:.0}, seed {seed}: makespan {:.0}s, waste {:.4}",
        strat, pol.tr, pol.tp, out.makespan, out.waste()
    );
    println!(
        "faults {} ({} predicted) | reg ckpts {} | pro ckpts {} | preds seen {} trusted {}",
        out.n_faults, out.n_predicted_faults, out.n_reg_ckpts,
        out.n_pro_ckpts, out.n_preds_seen, out.n_preds_trusted
    );
    println!("{}", tl.render(width));
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    use ckptwin::sim::tracefile;
    use ckptwin::strategy::registry;
    // Store form: `replay <store.jsonl> <cell-hash>|--all [--verify]`.
    // The legacy failure-log form keeps its `--log`/`--export` options.
    if !args.positional.is_empty() && !args.has("log") && !args.has("export") {
        return cmd_replay_store(args);
    }
    let sc = scenario_from_args(args)?;
    if let Some(n) = args.get::<usize>("export") {
        // Generate a synthetic failure log from the scenario's fault law.
        let mut ts = ckptwin::sim::trace::TraceStream::new(&sc, args.get_or("seed", 0));
        let mut faults = Vec::with_capacity(n);
        while faults.len() < n {
            if let ckptwin::sim::trace::Event::Fault { t, .. } = ts.next_event() {
                faults.push(t);
            }
        }
        let path = std::path::PathBuf::from(
            args.get_str("log").unwrap_or("results/faults.log"),
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        tracefile::write_failure_log(&path, &faults)?;
        println!("wrote {} faults to {}", faults.len(), path.display());
        return Ok(());
    }
    let log_path = args
        .get_str("log")
        .ok_or_else(|| anyhow!("--log <file> required (or --export N)"))?;
    let faults = tracefile::read_failure_log(std::path::Path::new(log_path))?;
    println!(
        "replaying {} recorded faults through all heuristics:",
        faults.len()
    );
    println!("{:<12} {:>10} {:>12} {:>8}", "heuristic", "waste", "makespan(d)", "faults");
    for strat in registry::paper_set() {
        let pol = strat.policy(&sc);
        let out = tracefile::replay(&sc, &pol, &faults, args.get_or("seed", 0));
        let name = strat.to_string();
        println!(
            "{name:<12} {:>10.4} {:>12.2} {:>8}",
            out.waste(),
            out.makespan / SECONDS_PER_DAY,
            out.n_faults
        );
    }
    Ok(())
}

/// `replay <store.jsonl> <cell-hash>|--all [--verify]` — re-run stored
/// cells from their keys and diff field-for-field against the store.
fn cmd_replay_store(args: &Args) -> Result<()> {
    use ckptwin::campaign::Store;
    use ckptwin::obs::MetricsRegistry;
    use ckptwin::scenario::replay::{self, FieldDiff, StoreKind};
    use ckptwin::validate::ConformanceStore;

    let path_raw = args.positional.first().expect("dispatch checked positional");
    let path = std::path::Path::new(path_raw);
    let target_hash = match args.positional.get(1) {
        Some(h) => Some(
            u64::from_str_radix(h.trim_start_matches("0x"), 16)
                .map_err(|_| anyhow!("bad cell hash '{h}' (16-digit hex, as printed by reports)"))?,
        ),
        None => None,
    };
    if target_hash.is_none() && !args.has("all") {
        return Err(anyhow!(
            "usage: ckptwin replay <store.jsonl> <cell-hash>|--all [--verify]"
        ));
    }
    let verify = args.has("verify");
    let kind = replay::sniff_store_kind(path)?;
    let mut reg = MetricsRegistry::new();
    let mut divergent = 0usize;
    let mut replayed = 0usize;
    let mut report = |key: &str, hash: u64, diffs: &[FieldDiff]| {
        if diffs.is_empty() {
            println!("{hash:016x} identical  {key}");
        } else {
            println!("{hash:016x} DIVERGED ({} fields)  {key}", diffs.len());
            for d in diffs {
                println!("    {:<14} stored={}  fresh={}", d.field, d.stored, d.fresh);
            }
        }
    };
    match kind {
        StoreKind::Campaign => {
            let store = Store::open(path)?;
            for rec in store.records() {
                if target_hash.is_some_and(|h| rec.hash != h) {
                    continue;
                }
                let fresh = replay::replay_campaign(rec)?;
                let diffs = replay::diff_campaign(rec, &fresh);
                replayed += 1;
                divergent += usize::from(!diffs.is_empty());
                report(&rec.key, rec.hash, &diffs);
            }
        }
        StoreKind::Conformance => {
            let store = ConformanceStore::open(path)?;
            for rec in store.records() {
                if target_hash.is_some_and(|h| rec.hash != h) {
                    continue;
                }
                let fresh = replay::replay_conformance(rec)?;
                let diffs = replay::diff_conformance(rec, &fresh);
                replayed += 1;
                divergent += usize::from(!diffs.is_empty());
                report(&rec.key, rec.hash, &diffs);
            }
        }
    }
    if replayed == 0 {
        return Err(anyhow!(
            "no record {:016x} in {}",
            target_hash.unwrap_or_default(),
            path.display()
        ));
    }
    reg.add("replay.cells", replayed as u64);
    reg.add("replay.divergent", divergent as u64);
    println!(
        "replayed {replayed} {} cell(s) from {}: {divergent} divergent",
        match kind {
            StoreKind::Campaign => "campaign",
            StoreKind::Conformance => "conformance",
        },
        path.display()
    );
    if verify && divergent > 0 {
        return Err(anyhow!("replay --verify: {divergent} cell(s) diverged from the store"));
    }
    Ok(())
}

/// `explain <cell-key> | <store.jsonl> <cell-hash>` — why a conformance
/// cell passed, failed, or was classified inapplicable.
fn cmd_explain(args: &Args) -> Result<()> {
    use ckptwin::campaign::Store;
    use ckptwin::scenario::{explain, replay};
    use ckptwin::validate::{ConformanceStore, TolerancePolicy, ValCell};

    let first = args.positional.first().ok_or_else(|| {
        anyhow!("usage: ckptwin explain <cell-key> | <store.jsonl> <cell-hash> [--instances 40]")
    })?;
    // A campaign cell key (no fm=/m= suffix) is explained at the
    // conformance baseline: multiplier 1.0, platform-renewal faults.
    let wrap = |cell: ckptwin::campaign::Cell| {
        ValCell::new(cell, 1.0, FaultModel::PlatformRenewal)
    };
    let vc = if first.contains(';') {
        if first.contains(";fm=") {
            replay::parse_val_cell_key(first)?
        } else {
            wrap(replay::parse_cell_key(first)?)
        }
    } else {
        let hash_raw = args
            .positional
            .get(1)
            .ok_or_else(|| anyhow!("usage: ckptwin explain <store.jsonl> <cell-hash>"))?;
        let hash = u64::from_str_radix(hash_raw.trim_start_matches("0x"), 16)
            .map_err(|_| anyhow!("bad cell hash '{hash_raw}' (16-digit hex)"))?;
        let path = std::path::Path::new(first.as_str());
        match replay::sniff_store_kind(path)? {
            replay::StoreKind::Conformance => {
                let store = ConformanceStore::open(path)?;
                let rec = store
                    .get(hash)
                    .ok_or_else(|| anyhow!("no record {hash:016x} in {first}"))?;
                replay::parse_val_cell_key(&rec.key)?
            }
            replay::StoreKind::Campaign => {
                let store = Store::open(path)?;
                let rec = store
                    .get(hash)
                    .ok_or_else(|| anyhow!("no record {hash:016x} in {first}"))?;
                wrap(replay::parse_cell_key(&rec.key)?)
            }
        }
    };
    let instances = args.get_or("instances", 40usize);
    let ex = explain::explain_cell(&vc, instances, &TolerancePolicy::default());
    print!("{}", ex.render());
    Ok(())
}

/// `lint <file.ckpt> [...]` — check scenario files without running them.
fn cmd_lint(args: &Args) -> Result<()> {
    use ckptwin::obs::MetricsRegistry;
    use ckptwin::scenario::lint_str;

    if args.positional.is_empty() {
        return Err(anyhow!("usage: ckptwin lint <file.ckpt> [...]"));
    }
    let mut reg = MetricsRegistry::new();
    for path in &args.positional {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        let rep = lint_str(&text);
        reg.inc("lint.files");
        reg.add("lint.errors", rep.errors.len() as u64);
        reg.add("lint.warnings", rep.warnings.len() as u64);
        for d in &rep.errors {
            println!("{path}: error: {d}");
        }
        for d in &rep.warnings {
            println!("{path}: warning: {d}");
        }
        if rep.ok() {
            println!(
                "{path}: ok — suite '{}' compiles to {} cells ({} warning(s))",
                rep.name.as_deref().unwrap_or("?"),
                rep.cells,
                rep.warnings.len()
            );
        }
    }
    let errors = reg.counter("lint.errors");
    println!(
        "linted {} file(s): {errors} error(s), {} warning(s)",
        reg.counter("lint.files"),
        reg.counter("lint.warnings")
    );
    if errors > 0 {
        return Err(anyhow!("{errors} lint error(s)"));
    }
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: ckptwin config <file.toml>"))?;
    let sc = ckptwin::config::scenario_from_file(std::path::Path::new(path))
        .map_err(|e| anyhow!("{e}"))?;
    let n = args.get_or("instances", harness::default_instances());
    println!(
        "{:<22} {:>10} {:>10} {:>12}",
        "heuristic", "waste", "analytic", "makespan(d)"
    );
    for r in harness::evaluate_heuristics(&sc, n, 0) {
        println!(
            "{:<22} {:>10.4} {:>10.4} {:>12.2}",
            r.name,
            r.waste,
            r.analytic_waste,
            r.makespan / SECONDS_PER_DAY
        );
    }
    Ok(())
}

/// Load and compile a `--scenario file.ckpt`, requiring the given suite
/// kind (a campaign file fed to `validate` — or vice versa — is an
/// error, not a silent reinterpretation).
fn suite_from_args(
    args: &Args,
    want: ckptwin::scenario::SuiteKind,
) -> Result<Option<ckptwin::scenario::CompiledSuite>> {
    let Some(path) = args.get_str("scenario") else {
        return Ok(None);
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading scenario {path}: {e}"))?;
    let suite = ckptwin::scenario::compile::compile_str(&text)
        .map_err(|e| anyhow!("{path}: {e}"))?;
    if suite.kind != want {
        return Err(anyhow!(
            "{path} is a {} suite; this subcommand runs {} suites",
            suite.kind.label(),
            want.label()
        ));
    }
    Ok(Some(suite))
}

/// Build the campaign grid from a `--scenario` file or a `--grid` preset
/// plus CLI axis overrides.
fn grid_from_args(args: &Args, extra_allowed: &[&str]) -> Result<ckptwin::campaign::Grid> {
    use ckptwin::campaign::Grid;
    let mut grid = match suite_from_args(args, ckptwin::scenario::SuiteKind::Campaign)? {
        Some(suite) => suite.grid,
        None => match args.get_str("grid").unwrap_or("paper") {
            "paper" => Grid::paper(),
            "smoke" => Grid::smoke(),
            other => return Err(anyhow!("unknown grid preset '{other}' (paper|smoke)")),
        },
    };
    apply_grid_overrides(&mut grid, args, extra_allowed)?;
    Ok(grid)
}

fn parse_list<T, E: std::fmt::Display>(
    raw: &str,
    what: &str,
    parse: impl Fn(&str) -> Result<T, E>,
) -> Result<Vec<T>> {
    raw.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| parse(t).map_err(|e| anyhow!("bad {what} '{t}': {e}")))
        .collect()
}

/// Apply the shared CLI axis overrides (`--procs`, `--laws`, …) to a grid
/// preset; used by `campaign`, `validate` and `metrics`.  Every present
/// option key must be a grid axis or in `extra_allowed` (the
/// subcommand's own options) — unknown keys error with a nearest-match
/// suggestion instead of being silently ignored
/// (`campaign::overrides::check_keys`).
fn apply_grid_overrides(
    grid: &mut ckptwin::campaign::Grid,
    args: &Args,
    extra_allowed: &[&str],
) -> Result<()> {
    use ckptwin::campaign::overrides;
    overrides::check_keys(args.keys(), extra_allowed).map_err(|e| anyhow!(e))?;
    for &key in overrides::AXIS_KEYS {
        if key == "uniform-fp" {
            // A bare `--uniform-fp` flag means true; `--uniform-fp=false`
            // can switch a scenario-file default back off.
            if args.has(key) {
                overrides::apply_override(grid, key, args.get_str(key).unwrap_or("true"))
                    .map_err(|e| anyhow!(e))?;
            }
        } else if let Some(raw) = args.get_str(key) {
            overrides::apply_override(grid, key, raw).map_err(|e| anyhow!(e))?;
        }
    }
    if grid.is_empty() {
        return Err(anyhow!("grid has an empty axis — nothing to run"));
    }
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    use ckptwin::campaign::{self, CampaignOptions, Store};
    // The mode is mandatory: defaulting to "run" would let a forgotten
    // word (or a flag that swallowed the mode token) silently truncate a
    // completed store.
    let mode = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("usage: ckptwin campaign run|resume|report [options]"))?;
    let out = args.get_str("out").unwrap_or("results/campaign.jsonl");

    if mode == "report" {
        // Read-only: don't let Store::open create an empty file at a
        // mistyped path and report "0 cells".
        if !std::path::Path::new(out).exists() {
            return Err(anyhow!("no campaign store at {out}"));
        }
        let store = Store::open(std::path::Path::new(out))?;
        println!(
            "campaign store {} — {} cells{}",
            out,
            store.len(),
            if store.skipped_lines > 0 {
                format!(" ({} torn lines ignored)", store.skipped_lines)
            } else {
                String::new()
            }
        );
        println!(
            "{:<16} {:>6} {:>10} {:>10} {:>10} {:>12}  {}",
            "hash", "inst", "waste", "±ci95", "T_R", "makespan(d)", "key"
        );
        for rec in store.records() {
            println!(
                "{:016x} {:>6} {:>10.4} {:>10.4} {:>10.0} {:>12.2}  {}",
                rec.hash,
                rec.instances,
                rec.waste_mean,
                rec.waste_ci95,
                rec.tr,
                rec.makespan_mean / SECONDS_PER_DAY,
                rec.key
            );
        }
        return Ok(());
    }
    if mode != "run" && mode != "resume" {
        return Err(anyhow!("usage: ckptwin campaign run|resume|report [options]"));
    }

    // Non-axis options `campaign run|resume` accepts; anything else on
    // the command line is a typo'd axis and errors (overrides::check_keys).
    const CAMPAIGN_KEYS: &[&str] = &[
        "out", "force", "grid", "scenario", "instances", "threads", "block",
        "heartbeat", "inject",
    ];
    let grid = grid_from_args(args, CAMPAIGN_KEYS)?;
    let cells = grid.expand();
    let mut store = if mode == "run" {
        if args.has("force") {
            Store::create_force(std::path::Path::new(out))?
        } else {
            Store::create(std::path::Path::new(out))?
        }
    } else {
        // Resume is read-modify: a mistyped path must not silently start
        // an empty store and recompute the whole grid into the wrong file.
        if !std::path::Path::new(out).exists() {
            return Err(anyhow!(
                "no campaign store at {out} to resume (use 'campaign run' to start one)"
            ));
        }
        Store::open(std::path::Path::new(out))?
    };
    let opt = CampaignOptions {
        instances: args.get_or("instances", harness::default_instances()),
        block: args.get_or("block", 0usize),
        threads: args.get_or("threads", 0usize),
    };
    println!(
        "campaign {mode}: {} cells ({} already complete in store), {} instances/cell",
        cells.len(),
        cells
            .iter()
            .filter(|c| campaign::cell_complete(&store, c, opt.instances))
            .count(),
        opt.instances,
    );
    let (outcomes, skipped, m) = campaign::run_cells_metered(
        &cells,
        &opt,
        Some(&mut store),
        args.has("heartbeat"),
    )?;
    println!(
        "done: {} cells computed, {} skipped, {:.1}s ({:.1} cells/s, {:.0} events/s, pool hit-rate {:.2})",
        outcomes.len(),
        skipped,
        m.elapsed_secs,
        m.cells_per_sec(),
        m.events_per_sec(),
        m.pool_hit_rate(),
    );
    println!("store: {} ({} cells total)", out, store.len());
    Ok(())
}

/// Conformance sweep: model vs simulation over a grid, with statistical
/// verdicts per cell, a per-strategy table, a resumable JSONL store and
/// the machine-readable CONFORMANCE.json artifact.  Exits non-zero when
/// any applicable cell exceeds its declared tolerance — the CI gate.
fn cmd_validate(args: &Args) -> Result<()> {
    use ckptwin::validate::{self, ConformanceStore, SweepOptions, Verdict};

    if args.has("scale-check") {
        return cmd_validate_scale(args);
    }
    // Non-axis options `validate` accepts; anything else is a typo'd
    // axis and errors (overrides::check_keys).
    const VALIDATE_KEYS: &[&str] = &[
        "smoke", "grid", "scenario", "multipliers", "out", "resume", "json",
        "instances", "threads", "scale-check", "inject",
    ];
    let smoke = args.has("smoke") || args.get_str("grid") == Some("smoke");
    let suite = suite_from_args(args, ckptwin::scenario::SuiteKind::Conformance)?;
    let (mut grid, suite_multipliers) = match suite {
        Some(suite) => (suite.grid, Some(suite.multipliers)),
        None => {
            let grid = match args.get_str("grid").unwrap_or(if smoke {
                "smoke"
            } else {
                "default"
            }) {
                "default" => validate::default_grid(),
                "smoke" => validate::smoke_grid(),
                other => {
                    return Err(anyhow!("unknown grid preset '{other}' (default|smoke)"))
                }
            };
            (grid, None)
        }
    };
    apply_grid_overrides(&mut grid, args, VALIDATE_KEYS)?;
    let mut multipliers: Vec<f64> = match args.get_str("multipliers") {
        Some(raw) => parse_list(raw, "multiplier", str::parse::<f64>)?,
        None => match suite_multipliers {
            Some(ms) => ms,
            None if smoke => vec![1.0],
            None => validate::DEFAULT_MULTIPLIERS.to_vec(),
        },
    };
    if let Some(bad) = multipliers.iter().find(|m| !m.is_finite() || **m <= 0.0) {
        return Err(anyhow!("multiplier {bad} must be a positive number"));
    }
    // Dedup repeated values: a duplicate would double-count its cells in
    // the report (the sweep itself dedups by hash).
    let mut seen = Vec::new();
    multipliers.retain(|m| {
        let fresh = !seen.contains(&m.to_bits());
        seen.push(m.to_bits());
        fresh
    });
    if multipliers.is_empty() {
        return Err(anyhow!("empty multiplier list"));
    }
    let cells = validate::expand_cells(&grid, &multipliers);

    let out = args.get_str("out").unwrap_or("results/conformance.jsonl");
    let mut store = if args.has("resume") {
        if !std::path::Path::new(out).exists() {
            return Err(anyhow!("no conformance store at {out} to resume"));
        }
        ConformanceStore::open(std::path::Path::new(out))?
    } else {
        ConformanceStore::create(std::path::Path::new(out))?
    };
    let opt = SweepOptions {
        instances: args.get_or("instances", if smoke { 40 } else { 100 }),
        threads: args.get_or("threads", 0usize),
        ..Default::default()
    };
    println!(
        "conformance sweep: {} cells ({} grid points × {} strategies × {} multipliers), {} instances/cell",
        cells.len(),
        grid.len() / grid.strategies.len(),
        grid.strategies.len(),
        multipliers.len(),
        opt.instances,
    );
    let t0 = std::time::Instant::now();
    let (_fresh, skipped) = validate::run_sweep(&cells, &opt, Some(&mut store))?;
    let dt = t0.elapsed().as_secs_f64();

    // Report over the full requested cell set, resumed records included;
    // duplicate-hash cells (repeated axis values) count once, like the
    // sweep itself.
    let mut reported = std::collections::BTreeSet::new();
    let reports: Vec<_> = cells
        .iter()
        .filter(|vc| reported.insert(vc.hash))
        .filter_map(|vc| store.get(vc.hash))
        .filter_map(ckptwin::validate::CellReport::from_record)
        .collect();
    let summaries = validate::summarize(&reports);
    print!("{}", validate::render_table(&summaries));
    let failures = validate::render_failures(&reports);
    if !failures.is_empty() {
        print!("{failures}");
    }
    let json_path = std::path::PathBuf::from(
        args.get_str("json").unwrap_or("CONFORMANCE.json"),
    );
    let bytes = validate::write_json(&json_path, &reports, &summaries)?;
    let n_fail = reports
        .iter()
        .filter(|r| matches!(r.verdict, Verdict::Fail))
        .count();
    println!(
        "done in {dt:.1}s ({skipped} cells resumed); store {out}; wrote {} ({bytes} bytes)",
        json_path.display()
    );
    if n_fail > 0 {
        return Err(anyhow!(
            "{n_fail} cells exceeded their conformance tolerance (see {})",
            json_path.display()
        ));
    }
    println!("all applicable cells within tolerance — zero unexplained failures");
    Ok(())
}

/// `ckptwin validate --scale-check`: the platform-rate scale guard
/// ([`ckptwin::validate::domain::platform_rate_check`]) swept over
/// N = 10^4..10^6.  At every N the measured superposed fault rate of the
/// stationary (and exponential) per-processor traces must match the `1/μ`
/// the closed forms assume, while fresh Weibull k < 1 traces must land in
/// the named `platform_rate_nonconforming` regime (their infant-mortality
/// transient runs hot of 1/μ over job-sized horizons).  Exits non-zero
/// when any row disagrees with its expected regime.
fn cmd_validate_scale(args: &Args) -> Result<()> {
    use ckptwin::validate::domain::{self, PLATFORM_RATE_TOL};

    // Defaults put the conforming rows' sampling noise well inside the
    // tolerance: 6 seeds × 150 MTBFs ≈ 900 faults per row ⇒ σ ≈ 3.3%,
    // three σ under PLATFORM_RATE_TOL.
    let seeds: u64 = args.get_or("seeds", 6u64);
    let horizon: f64 = args.get_or("horizon-mtbfs", 150.0);
    println!(
        "platform-rate scale conformance: tol {PLATFORM_RATE_TOL}, {seeds} seeds, \
         horizon {horizon} platform MTBFs"
    );
    println!(
        "{:>9} {:<22} {:>12} {:>12} {:>9}  verdict",
        "procs", "trace", "measured", "nominal", "rel_err"
    );
    let mut failures = 0usize;
    for n in [10_000u64, 100_000, 1_000_000] {
        let rows: [(&str, Law, FaultModel, bool); 3] = [
            (
                "exponential fresh",
                Law::Exponential,
                FaultModel::PerProcessor { n },
                true,
            ),
            (
                "weibull0.7 stationary",
                Law::Weibull { shape: 0.7 },
                FaultModel::PerProcessorStationary { n },
                true,
            ),
            (
                "weibull0.7 fresh",
                Law::Weibull { shape: 0.7 },
                FaultModel::PerProcessor { n },
                false,
            ),
        ];
        for (name, law, fm, must_conform) in rows {
            let mut sc =
                Scenario::paper(n, 1.0, PredictorSpec::paper_a(600.0), law, law);
            sc.fault_model = fm;
            let chk = domain::platform_rate_check(&sc, seeds, horizon, PLATFORM_RATE_TOL);
            let ok = chk.verdict.is_none() == must_conform;
            if !ok {
                failures += 1;
            }
            println!(
                "{:>9} {:<22} {:>12.5e} {:>12.5e} {:>9.4}  {}{}",
                n,
                name,
                chk.measured_rate,
                chk.nominal_rate,
                chk.rel_err,
                match chk.verdict {
                    None => "conforms",
                    Some(v) => v.label(),
                },
                if ok { "" } else { "  <-- unexpected" },
            );
        }
    }
    if failures > 0 {
        return Err(anyhow!(
            "{failures} scale rows disagreed with their expected regime"
        ));
    }
    println!(
        "scale conformance holds: stationary/exponential traces match 1/mu, \
         fresh Weibull k<1 flags platform_rate_nonconforming"
    );
    Ok(())
}

/// Assemble a JSON object from `(key, value)` pairs — the `METRICS.json`
/// section builder (`cmd_metrics`).
fn json_obj(pairs: Vec<(&str, ckptwin::jsonio::Value)>) -> ckptwin::jsonio::Value {
    let map = pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    ckptwin::jsonio::Value::Obj(map)
}

/// Telemetry snapshot + waste-accounting audit (`ckptwin metrics`).
///
/// Four phases, one artifact:
///
/// 1. **campaign** — the grid runs on the metered scheduler; cells/sec,
///    events/sec and trace-pool efficacy land in the registry.
/// 2. **audit** — every cell re-simulates with the [`EventCounters`]
///    recorder attached.  Per simulation, the recorded run must equal the
///    plain run bit-for-bit (recorders are pure observers) and the
///    counter-derived time decomposition must tile the makespan and
///    reconcile with `SimOutcome::waste()` (`EventCounters::audit`).  Per
///    cell, where a closed form applies, the aggregated decomposition is
///    compared term-by-term (regular ckpt / proactive ckpt / down /
///    re-exec) against the model's waste terms at the cell's conformance
///    tolerance.
/// 3. **batch** — the batched closed-form evaluator
///    ([`ckptwin::model::batch`]) sweeps full waste surfaces over the
///    grid's unique scenarios; block/cell throughput and the guard-skip
///    rate land in the registry.
/// 4. **coordinator** — a short synthetic-workload run samples per-pass
///    decision latency into a log2 histogram.
///
/// Everything is assembled into `METRICS.json` (schema
/// `ckptwin-metrics/1`); any audit violation exits non-zero — the CI gate.
fn cmd_metrics(args: &Args) -> Result<()> {
    use ckptwin::campaign::{self, CampaignOptions, Grid};
    use ckptwin::jsonio::Value;
    use ckptwin::obs::{report, EventCounters, MetricsRegistry};
    use ckptwin::sim::engine::{simulate_q, simulate_recorded};
    use ckptwin::sim::trace::FlatTrace;
    use ckptwin::stats::Welford;
    use ckptwin::validate::{domain, TolerancePolicy};

    let obj = json_obj;

    // The default grid is the conformance smoke grid: every registered
    // strategy with a default period rule (the BestPeriod twins search,
    // which the audit doesn't need), both C_p ratios, two laws, two
    // windows — a census of the engine's execution modes.
    let mut grid = match args.get_str("grid").unwrap_or("smoke") {
        "smoke" => ckptwin::validate::smoke_grid(),
        "paper" => Grid::paper(),
        other => return Err(anyhow!("unknown grid preset '{other}' (smoke|paper)")),
    };
    // Non-axis options `metrics` accepts (overrides::check_keys).
    const METRICS_KEYS: &[&str] = &[
        "grid", "instances", "threads", "block", "json", "heartbeat", "steps",
        "mtbf", "seed", "ckpt-dir", "inject",
    ];
    apply_grid_overrides(&mut grid, args, METRICS_KEYS)?;
    let cells = grid.expand();
    let instances = args.get_or("instances", harness::default_instances()).max(1);
    let opt = CampaignOptions {
        instances,
        block: args.get_or("block", 0usize),
        threads: args.get_or("threads", 0usize),
    };
    let mut reg = MetricsRegistry::new();

    // --- phase 1: metered campaign (throughput telemetry) ----------------
    println!("metrics: campaign phase — {} cells, {} instances/cell", cells.len(), instances);
    let (_outcomes, _skipped, m) =
        campaign::run_cells_metered(&cells, &opt, None, args.has("heartbeat"))?;
    reg.add("campaign.cells", m.cells as u64);
    reg.add("campaign.instances", m.instances);
    reg.add("campaign.sim_events", m.sim_events);
    reg.add("campaign.pool_hits", m.pool_hits);
    reg.add("campaign.pool_misses", m.pool_misses);
    reg.add("campaign.pool_evictions", m.pool_evictions);
    // Scale-out health: timer-wheel work per generated fault event and
    // shard-merge traffic (zero on platform-renewal grids, whose traces
    // never run a wheel).
    reg.add("campaign.wheel_pops", m.wheel_pops);
    reg.add("campaign.wheel_bucket_scans", m.wheel_bucket_scans);
    reg.add("campaign.wheel_overflow_promotions", m.wheel_overflow_promotions);
    reg.add("campaign.shard_merges", m.shard_merges);
    reg.set_gauge("campaign.elapsed_secs", m.elapsed_secs);
    reg.set_gauge("campaign.cells_per_sec", m.cells_per_sec());
    reg.set_gauge("campaign.events_per_sec", m.events_per_sec());
    reg.set_gauge("campaign.pool_hit_rate", m.pool_hit_rate());
    println!(
        "  {} cells, {} sims, {} events in {:.2}s — {:.1} cells/s, {:.0} events/s, pool hit-rate {:.2}",
        m.cells,
        m.instances,
        m.sim_events,
        m.elapsed_secs,
        m.cells_per_sec(),
        m.events_per_sec(),
        m.pool_hit_rate(),
    );
    let campaign_section = obj(vec![
        ("cells", Value::Num(m.cells as f64)),
        ("instances", Value::Num(m.instances as f64)),
        ("sim_events", Value::Num(m.sim_events as f64)),
        ("elapsed_secs", Value::Num(m.elapsed_secs)),
        ("cells_per_sec", Value::Num(m.cells_per_sec())),
        ("events_per_sec", Value::Num(m.events_per_sec())),
        (
            "pool",
            obj(vec![
                ("hits", Value::Num(m.pool_hits as f64)),
                ("misses", Value::Num(m.pool_misses as f64)),
                ("evictions", Value::Num(m.pool_evictions as f64)),
                ("hit_rate", Value::Num(m.pool_hit_rate())),
            ]),
        ),
        (
            "wheel",
            obj(vec![
                ("pops", Value::Num(m.wheel_pops as f64)),
                ("bucket_scans", Value::Num(m.wheel_bucket_scans as f64)),
                (
                    "overflow_promotions",
                    Value::Num(m.wheel_overflow_promotions as f64),
                ),
                ("shard_merges", Value::Num(m.shard_merges as f64)),
            ]),
        ),
    ]);

    // --- phase 2: waste-accounting audit ---------------------------------
    println!("metrics: audit phase — recorder census over every cell");
    let tolpol = TolerancePolicy::default();
    let mut total = EventCounters::default();
    let mut audit_sims: u64 = 0;
    let mut violations: Vec<String> = Vec::new();
    let mut term_rows: Vec<Value> = Vec::new();
    let mut term_failures = 0usize;
    let mut sum_makespan = 0.0f64;
    let mut sum_job = 0.0f64;
    let mut seen = std::collections::BTreeSet::new();
    for cell in &cells {
        if !seen.insert(cell.hash) {
            continue;
        }
        let sc = cell.scenario();
        let pol = cell.strategy.policy(&sc);
        let mut cc = EventCounters::default();
        let mut waste = Welford::new();
        let mut cell_makespan = 0.0f64;
        for i in 0..instances as u64 {
            let seed = cell.instance_seed(i);
            let plain = simulate_q(&sc, &pol, 1.0, seed);
            let mut c = EventCounters::default();
            let out = simulate_recorded(&sc, &pol, 1.0, seed, FlatTrace::new(&sc, seed), &mut c);
            audit_sims += 1;
            if out != plain {
                violations.push(format!(
                    "{}: seed {seed}: recorded run diverged from plain run",
                    cell.key()
                ));
            }
            if let Err(e) = c.audit(&out) {
                violations.push(format!("{}: seed {seed}: {e}", cell.key()));
            }
            reg.observe("audit.faults_per_sim", out.n_faults);
            reg.observe("audit.events_per_sim", out.events);
            waste.push(out.waste());
            cell_makespan += out.makespan;
            sum_job += out.job_size;
            cc.merge(&c);
        }
        sum_makespan += cell_makespan;
        total.merge(&cc);
        // Term-by-term model comparison, where a closed form applies at
        // this cell (same classification the conformance sweep uses).
        let kind = cell.strategy.kind();
        let gs = match kind.grid_strategy() {
            Some(gs) => gs,
            None => continue,
        };
        let model_total = match domain::classify(&sc, kind, pol.tr, pol.tp, &tolpol) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let terms = waste::waste_terms(&sc, gs, pol.tr, pol.tp);
        let tol = domain::tolerance(&tolpol, &sc, kind, pol.tr, waste.ci95());
        let sim = [
            cc.time_ckpt_reg / cell_makespan,
            cc.time_ckpt_pro / cell_makespan,
            cc.time_down / cell_makespan,
            cc.time_reexec / cell_makespan,
        ];
        let model = [terms.ckpt_reg, terms.ckpt_pro, terms.down, terms.reexec];
        let mut dev = (waste.mean() - model_total).abs();
        for (s, mv) in sim.iter().zip(&model) {
            dev = dev.max((s - mv).abs());
        }
        let within = dev <= tol;
        if !within {
            term_failures += 1;
        }
        term_rows.push(obj(vec![
            ("key", Value::Str(cell.key())),
            ("strategy", Value::Str(cell.strategy.to_string())),
            ("law", Value::Str(cell.fault_law.label())),
            ("tr", Value::Num(pol.tr)),
            (
                "model",
                obj(vec![
                    ("ckpt_reg", Value::Num(terms.ckpt_reg)),
                    ("ckpt_pro", Value::Num(terms.ckpt_pro)),
                    ("down", Value::Num(terms.down)),
                    ("reexec", Value::Num(terms.reexec)),
                    ("total", Value::Num(model_total)),
                ]),
            ),
            (
                "sim",
                obj(vec![
                    ("ckpt_reg", Value::Num(sim[0])),
                    ("ckpt_pro", Value::Num(sim[1])),
                    ("down", Value::Num(sim[2])),
                    ("reexec", Value::Num(sim[3])),
                    ("waste", Value::Num(waste.mean())),
                ]),
            ),
            ("deviation_max", Value::Num(dev)),
            ("tolerance", Value::Num(tol)),
            ("within_tolerance", Value::Bool(within)),
        ]));
    }
    // Campaign-level reconciliation: aggregated counters must reproduce
    // the aggregate waste exactly (follows from the per-sim identities;
    // asserted independently so a merge bug can't hide).
    let agg_waste_sim = (sum_makespan - sum_job) / sum_makespan;
    let mut overhead = total.time_ckpt_reg + total.time_ckpt_pro + total.time_down;
    overhead += total.time_idle + total.time_reexec;
    let agg_waste_counters = overhead / sum_makespan;
    if (agg_waste_sim - agg_waste_counters).abs() > 1e-6 {
        violations.push(format!(
            "campaign aggregate: counter-derived waste {agg_waste_counters} \
             != simulated waste {agg_waste_sim}"
        ));
    }
    reg.add("audit.sims", audit_sims);
    reg.add("audit.violations", violations.len() as u64);
    reg.add("audit.model_term_failures", term_failures as u64);
    println!(
        "  {} sims audited: {} identity violations, {}/{} model-term cells within tolerance",
        audit_sims,
        violations.len(),
        term_rows.len() - term_failures,
        term_rows.len(),
    );
    let examples: Vec<Value> = violations.iter().take(5).map(|s| Value::Str(s.clone())).collect();
    let audit_section = obj(vec![
        ("sims", Value::Num(audit_sims as f64)),
        ("violations", Value::Num(violations.len() as f64)),
        ("violation_examples", Value::Arr(examples)),
        ("aggregate_waste_sim", Value::Num(agg_waste_sim)),
        ("aggregate_waste_counters", Value::Num(agg_waste_counters)),
        ("counters", report::counters_json(&total)),
        ("model_terms", Value::Arr(term_rows)),
        ("model_term_failures", Value::Num(term_failures as f64)),
    ]);

    // --- phase 3: batched closed-form evaluator --------------------------
    println!("metrics: batch phase — waste surfaces over the grid's scenarios");
    let batch_section = {
        use ckptwin::model::batch;
        let mut items: Vec<(Scenario, f64)> = Vec::new();
        let mut seen_sc = std::collections::BTreeSet::new();
        for cell in &cells {
            if !seen_sc.insert(cell.hash) {
                continue;
            }
            let sc = cell.scenario();
            let tp = ckptwin::strategy::registry::default_tp(&sc);
            items.push((sc, tp));
        }
        let lo = 1.05
            * items
                .iter()
                .map(|(sc, _)| sc.platform.c)
                .fold(f64::MIN, f64::max);
        let hi = 60.0
            * items
                .iter()
                .map(|(sc, _)| optimal::rfo_period(&sc.platform))
                .fold(f64::MIN, f64::max);
        let pts = 256usize;
        let grid: Vec<f64> = (0..pts)
            .map(|k| lo * (hi / lo).powf(k as f64 / (pts - 1) as f64))
            .collect();
        let (_surfaces, bst) = batch::waste_surfaces(&items, &grid, opt.threads);
        reg.add("model.batch_blocks", bst.blocks);
        reg.add("model.batch_cells", bst.cells);
        reg.add("model.batch_guard_skips", bst.guard_skipped);
        reg.set_gauge("model.batch_cells_per_s", bst.cells_per_sec());
        reg.set_gauge("model.batch_guard_skip_rate", bst.guard_skip_rate());
        println!(
            "  {} scenarios × 4 strategies × {} periods: {} blocks, {} cells \
             in {:.3}s — {:.3e} cells/s, guard-skip rate {:.3}",
            items.len(),
            grid.len(),
            bst.blocks,
            bst.cells,
            bst.elapsed_secs,
            bst.cells_per_sec(),
            bst.guard_skip_rate(),
        );
        obj(vec![
            ("scenarios", Value::Num(items.len() as f64)),
            ("grid_points", Value::Num(grid.len() as f64)),
            ("blocks", Value::Num(bst.blocks as f64)),
            ("cells", Value::Num(bst.cells as f64)),
            ("guard_skipped", Value::Num(bst.guard_skipped as f64)),
            ("elapsed_secs", Value::Num(bst.elapsed_secs)),
            ("cells_per_sec", Value::Num(bst.cells_per_sec())),
            ("guard_skip_rate", Value::Num(bst.guard_skip_rate())),
        ])
    };

    // --- phase 4: coordinator decision latency ---------------------------
    println!("metrics: coordinator phase — synthetic workload");
    let coordinator_section = {
        use ckptwin::config::Platform;
        use ckptwin::coordinator::{self, workload::SyntheticWorkload, CoordinatorConfig};
        use ckptwin::strategy::{Policy, PolicyKind};
        let steps: u64 = args.get_or("steps", 240);
        let mtbf: f64 = args.get_or("mtbf", 3000.0);
        let scenario = Scenario {
            platform: Platform { mu: mtbf, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 0.0,
        };
        let tr = optimal::tr_extr_window(&scenario);
        let tp = ckptwin::strategy::registry::default_tp(&scenario);
        let cfg = CoordinatorConfig {
            scenario,
            policy: Policy { kind: PolicyKind::WithCkpt, tr, tp },
            seconds_per_step: 30.0,
            total_steps: steps,
            ckpt_dir: args.get_str("ckpt-dir").unwrap_or("results/metrics-ckpts").into(),
            seed: args.get_or("seed", 42),
            log_every: 0,
            selfckpt: None,
        };
        let mut wl = SyntheticWorkload::new(64);
        let rep = coordinator::run(&cfg, &mut wl)?;
        let d = &rep.decision_ns;
        if !d.is_empty() {
            reg.set_gauge("coordinator.decision_p50_ns", d.quantile(0.5) as f64);
            reg.set_gauge("coordinator.decision_p99_ns", d.quantile(0.99) as f64);
        }
        reg.add("coordinator.steps_executed", rep.steps_executed);
        reg.add("coordinator.n_faults", rep.n_faults);
        println!(
            "  {} steps ({} lost), {} faults; decision latency p50 {}ns p99 {}ns over {} passes",
            rep.steps_executed,
            rep.steps_lost,
            rep.n_faults,
            d.quantile(0.5),
            d.quantile(0.99),
            d.count(),
        );
        obj(vec![
            ("steps_executed", Value::Num(rep.steps_executed as f64)),
            ("steps_lost", Value::Num(rep.steps_lost as f64)),
            ("n_faults", Value::Num(rep.n_faults as f64)),
            ("sim_makespan", Value::Num(rep.sim_makespan)),
            ("sim_waste", Value::Num(rep.sim_waste)),
            ("decision_ns", report::hist_json(d)),
        ])
    };

    // --- artifact + gate --------------------------------------------------
    let doc = report::metrics_json(
        &reg,
        &[
            ("campaign", campaign_section),
            ("audit", audit_section),
            ("batch", batch_section),
            ("coordinator", coordinator_section),
        ],
    );
    let json_path = std::path::PathBuf::from(args.get_str("json").unwrap_or("METRICS.json"));
    let bytes = report::write_json(&json_path, &doc)?;
    println!("wrote {} ({bytes} bytes, schema {})", json_path.display(), report::SCHEMA);
    if !violations.is_empty() {
        for v in violations.iter().take(5) {
            eprintln!("audit violation: {v}");
        }
        return Err(anyhow!(
            "{} waste-accounting audit violations (see {})",
            violations.len(),
            json_path.display()
        ));
    }
    if term_failures > 0 {
        return Err(anyhow!(
            "{term_failures} cells' aggregated decomposition exceeded the \
             closed-form term tolerance (see {})",
            json_path.display()
        ));
    }
    println!(
        "audit clean: every decomposition tiles its makespan and reconciles \
         with waste(); all model terms within tolerance"
    );
    Ok(())
}

/// List the strategy registry: every name the campaign grids, harness and
/// this CLI accept, with aliases, parameters and a one-line description.
fn cmd_strategies(_args: &Args) -> Result<()> {
    use ckptwin::strategy::registry;
    println!(
        "{:<24} {:<18} {:<28} {}",
        "name", "parameters", "aliases", "description"
    );
    for def in registry::catalog() {
        let params: String = def
            .params
            .iter()
            .map(|p| format!("{}={} [{},{}]", p.key, p.default, p.min, p.max))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "{:<24} {:<18} {:<28} {}",
            def.name,
            if params.is_empty() { "-".to_string() } else { params },
            def.aliases.join(","),
            def.summary
        );
    }
    println!(
        "\nuse anywhere a strategy is named, e.g. \
         `campaign run --strategies instant,exactpred,qtrust(q=0.25)`"
    );
    Ok(())
}

/// List the predictor registry: every name the campaign/validate grids and
/// `--predictor(s)` accept, with aliases, parameters and a description.
fn cmd_predictors(_args: &Args) -> Result<()> {
    use ckptwin::predictor::registry;
    println!(
        "{:<12} {:<44} {:<24} {}",
        "name", "parameters", "aliases", "description"
    );
    for def in registry::catalog() {
        let params: String = def
            .params
            .iter()
            .map(|p| format!("{}={}", p.key, p.default))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "{:<12} {:<44} {:<24} {}",
            def.name,
            if params.is_empty() { "-".to_string() } else { params },
            def.aliases.join(","),
            def.summary
        );
    }
    println!(
        "\nuse anywhere a predictor is named, e.g. `campaign run \
         --predictors a,biased(beta=2),mixedwin(i1=300;i2=1200;w=0.5)`;\n\
         non-paper models classify out-of-domain conformance cells by name \
         (see `ckptwin validate`)"
    );
    Ok(())
}

/// `ckptwin chaos` — the crash–resume equivalence gate.
///
/// Runs randomized kill/resume cycles over the campaign store, the
/// conformance store, and the coordinator (see `resilience::chaos`),
/// writes `CHAOS.json`, and exits non-zero on any divergence.  `--smoke`
/// is the 25-cycle CI variant; the full gate defaults to 100 cycles.
fn cmd_chaos(args: &Args) -> Result<()> {
    use ckptwin::resilience::chaos::{self, ChaosOptions};
    let cycles: u64 = args.get_or("cycles", if args.has("smoke") { 25 } else { 100 });
    let seed: u64 = args.get_or("seed", 42);
    let dir = std::path::PathBuf::from(args.get_str("dir").unwrap_or("results/chaos-scratch"));
    println!(
        "chaos: {cycles} kill/resume cycles (seed {seed}) over \
         campaign store, conformance store, coordinator"
    );
    let t0 = std::time::Instant::now();
    let rep = chaos::run_chaos(&ChaosOptions { cycles, seed, dir })?;
    let json_path = std::path::PathBuf::from(args.get_str("json").unwrap_or("CHAOS.json"));
    let bytes = chaos::write_chaos_json(&json_path, &rep)?;
    println!(
        "chaos: {} cycles in {:.1}s — {} crashes injected, {} resumes, \
         {} torn tails repaired, {} records quarantined, {} transient retries",
        rep.cycles_run,
        t0.elapsed().as_secs_f64(),
        rep.crashes_injected,
        rep.resumes,
        rep.torn_tails_repaired,
        rep.records_quarantined,
        rep.transient_retries,
    );
    println!("wrote {} ({bytes} bytes, schema {})", json_path.display(), chaos::SCHEMA);
    if !rep.ok() {
        for d in &rep.divergences {
            eprintln!("chaos divergence: {d}");
        }
        return Err(anyhow!(
            "{} crash–resume divergence(s); see {}",
            rep.divergences.len(),
            json_path.display()
        ));
    }
    println!("chaos gate clean: every crashed run resumed to an identical result");
    Ok(())
}

fn main() {
    let args = Args::from_env();
    // Global fault injection: armed once here and held for the whole
    // process so every subcommand sees the same plan.  `chaos` arms its
    // own per-cycle plans on this thread and would deadlock against an
    // outer guard, so the combination is rejected.
    let mut _inject_guard = None;
    if let Some(spec) = args.get_str("inject") {
        if args.subcommand.as_deref() == Some("chaos") {
            eprintln!("error: `chaos` arms its own fail points; drop --inject");
            std::process::exit(1);
        }
        match ckptwin::resilience::failpoint::Plan::parse(spec) {
            Ok(plan) => _inject_guard = Some(ckptwin::resilience::failpoint::arm(plan)),
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("analytic") => cmd_analytic(&args),
        Some("figure") => cmd_figure(&args),
        Some("table") => cmd_table(&args),
        Some("best-period") => cmd_best_period(&args),
        Some("export-grid") => cmd_export_grid(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("replay") => cmd_replay(&args),
        Some("config") => cmd_config(&args),
        Some("campaign") => cmd_campaign(&args),
        Some("validate") => cmd_validate(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("strategies") => cmd_strategies(&args),
        Some("predictors") => cmd_predictors(&args),
        Some("explain") => cmd_explain(&args),
        Some("lint") => cmd_lint(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown command '{other}'\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
