//! Data-driven strategy registry: stable string names + parameter maps.
//!
//! The strategy axis is **open**: every strategy the stack can run —
//! campaign grids, the figure/table harness, the `ckptwin` CLI — is a row
//! in this registry, addressed by a [`StrategyId`] (a registered name plus
//! a fully materialized parameter map).  Adding a strategy means adding a
//! [`crate::sim::policy::PolicyLogic`] implementation (behaviour), a
//! [`PolicyKind`] dispatch arm, and one registry row here; no campaign,
//! harness or CLI edits.
//!
//! Identifier grammar (round-trips through [`StrategyId`]'s `FromStr` /
//! `Display` pair):
//!
//! ```text
//!   Daly                      a parameterless strategy (canonical name)
//!   nockpt                    aliases parse case-insensitively
//!   QTrust(q=0.25)            parameters as key=value, ';' separated
//!   BestPeriod-NoCkptI(seeds=16)
//! ```
//!
//! Display always emits the canonical form — registered name casing, every
//! parameter present (defaults materialized) — so the string is also the
//! stable identity the campaign store keys on: the parameterless names are
//! byte-identical to the pre-registry `Strategy` enum labels, keeping
//! existing JSONL stores resumable.
//!
//! Registered strategies:
//!
//! | name | mode | period T_R | analytic model |
//! |------|------|-----------|----------------|
//! | `Daly`, `Young`, `RFO` | q = 0 | closed forms | Eq. (3) |
//! | `Instant` | Instant | `T_R^extr` (§3.4) | Eq. (14) |
//! | `NoCkptI` | NoCkpt | `T_R^extr` (Eq. 6) | Eq. (10) |
//! | `WithCkptI` | WithCkpt | `T_R^extr` (Eq. 6) | Eq. (4) |
//! | `ExactPred` | ExactPred | `T_R^extr` (§3.4) | — (I → 0 limit of Eq. 14) |
//! | `WindowEndCkpt` | WindowEndCkpt | `T_R^extr` (Eq. 6) | — |
//! | `QTrust(q=…)` | QTrust | `T_R^extr` (Eq. 6) | — (paper: optimum at q ∈ {0,1}) |
//! | `BestPeriod-*(seeds=…)` | as base | brute-force search (§4.1) | — |

use std::fmt;
use std::str::FromStr;

use crate::config::Scenario;
use crate::model::optimal;
use crate::model::waste::GridStrategy;
use crate::strategy::{best_period, Policy, PolicyKind};

/// A parameter accepted by a registered strategy.
#[derive(Clone, Copy, Debug)]
pub struct ParamDef {
    /// Parameter key as written in identifiers (`q`, `seeds`).
    pub key: &'static str,
    /// Value used when the identifier omits the parameter.
    pub default: f64,
    /// Inclusive validity range.
    pub min: f64,
    /// Inclusive validity range.
    pub max: f64,
}

/// One registry row: everything the stack needs to name, parse, describe
/// and instantiate a strategy.
pub struct StrategyDef {
    /// Canonical display name (the paper's figure labels where they exist).
    pub name: &'static str,
    /// Lowercase aliases accepted by the parser.
    pub aliases: &'static [&'static str],
    /// One-line description for `ckptwin strategies`.
    pub summary: &'static str,
    /// Accepted parameters (empty for the parameterless strategies).
    pub params: &'static [ParamDef],
    kind: fn(&StrategyId) -> PolicyKind,
    /// Analytic regular period before the job-size clamp.
    period: fn(&StrategyId, &Scenario) -> f64,
}

const P_Q: ParamDef = ParamDef { key: "q", default: 0.5, min: 0.0, max: 1.0 };
const P_SEEDS: ParamDef =
    ParamDef { key: "seeds", default: 10.0, min: 1.0, max: 100_000.0 };

fn kind_ignore(_: &StrategyId) -> PolicyKind {
    PolicyKind::IgnorePredictions
}
fn kind_instant(_: &StrategyId) -> PolicyKind {
    PolicyKind::Instant
}
fn kind_nockpt(_: &StrategyId) -> PolicyKind {
    PolicyKind::NoCkpt
}
fn kind_withckpt(_: &StrategyId) -> PolicyKind {
    PolicyKind::WithCkpt
}
fn kind_exactpred(_: &StrategyId) -> PolicyKind {
    PolicyKind::ExactPred
}
fn kind_windowend(_: &StrategyId) -> PolicyKind {
    PolicyKind::WindowEndCkpt
}
fn kind_qtrust(id: &StrategyId) -> PolicyKind {
    PolicyKind::QTrust { q: id.param("q") }
}

fn period_daly(_: &StrategyId, sc: &Scenario) -> f64 {
    optimal::daly_period(&sc.platform)
}
fn period_young(_: &StrategyId, sc: &Scenario) -> f64 {
    optimal::young_period(&sc.platform)
}
fn period_rfo(_: &StrategyId, sc: &Scenario) -> f64 {
    optimal::rfo_period(&sc.platform)
}
fn period_instant(_: &StrategyId, sc: &Scenario) -> f64 {
    optimal::tr_extr_instant(sc)
}
fn period_window(_: &StrategyId, sc: &Scenario) -> f64 {
    optimal::tr_extr_window(sc)
}

/// BestPeriod twins: `T_R` found by the adaptive brute-force search (§4.1)
/// over `seeds` dedicated instance streams (disjoint from the evaluation
/// seeds, like the harness's twin runner).
///
/// Each instantiation generates its own search traces; sibling twin cells
/// at one scenario point do not share them (the campaign memoizes the
/// policy per cell, so the cost is per (cell, campaign), not per block —
/// the figure harness's `best_period_results_seeded` remains the
/// cache-sharing path for running all four twins on one scenario).
fn period_best_period(id: &StrategyId, sc: &Scenario) -> f64 {
    let n = id.param("seeds") as u64;
    let seeds: Vec<u64> = (1000..1000 + n).collect();
    let tp = default_tp(sc);
    best_period::search(sc, id.kind(), tp, &seeds, 24, 8).tr
}

/// The proactive period every instantiation uses: `T_P^extr`, kept a hair
/// above `C_p` so Algorithm 1's inner loop always fits one checkpoint.
pub fn default_tp(sc: &Scenario) -> f64 {
    optimal::tp_extr(sc).max(sc.platform.cp * 1.1)
}

/// The registry itself.  Order is presentation order (`ckptwin
/// strategies`); lookups are by name/alias, never by index.
static DEFS: &[StrategyDef] = &[
    StrategyDef {
        name: "Daly",
        aliases: &["daly"],
        summary: "periodic, predictions ignored; Daly's period (baseline)",
        params: &[],
        kind: kind_ignore,
        period: period_daly,
    },
    StrategyDef {
        name: "Young",
        aliases: &["young"],
        summary: "periodic, predictions ignored; Young's first-order period",
        params: &[],
        kind: kind_ignore,
        period: period_young,
    },
    StrategyDef {
        name: "RFO",
        aliases: &["rfo"],
        summary: "periodic, predictions ignored; RFO period (Eq. 3 optimum)",
        params: &[],
        kind: kind_ignore,
        period: period_rfo,
    },
    StrategyDef {
        name: "Instant",
        aliases: &["instant"],
        summary: "pre-window proactive checkpoint, immediate return (S3.4)",
        params: &[],
        kind: kind_instant,
        period: period_instant,
    },
    StrategyDef {
        name: "NoCkptI",
        aliases: &["nockpt", "nockpti"],
        summary: "work unprotected inside the window (S3.3)",
        params: &[],
        kind: kind_nockpt,
        period: period_window,
    },
    StrategyDef {
        name: "WithCkptI",
        aliases: &["withckpt", "withckpti"],
        summary: "proactive periods T_P in-window (S3.2, Algorithm 1)",
        params: &[],
        kind: kind_withckpt,
        period: period_window,
    },
    StrategyDef {
        name: "ExactPred",
        aliases: &["exactpred", "exact-pred", "exact"],
        summary: "I -> 0 exact limit: Instant + fresh period after the ckpt",
        params: &[],
        kind: kind_exactpred,
        period: period_instant,
    },
    StrategyDef {
        name: "WindowEndCkpt",
        aliases: &["windowendckpt", "window-end-ckpt", "wec"],
        summary: "NoCkptI plus a terminal proactive checkpoint at t0 + I",
        params: &[],
        kind: kind_windowend,
        period: period_window,
    },
    StrategyDef {
        name: "QTrust",
        aliases: &["qtrust", "q-trust"],
        summary: "NoCkptI trusted with probability q (S3.1 randomized trust)",
        params: &[P_Q],
        kind: kind_qtrust,
        period: period_window,
    },
    StrategyDef {
        name: "BestPeriod-NoPred",
        aliases: &["bestperiod-nopred", "bp-nopred"],
        summary: "q = 0 mode, T_R by brute-force search (S4.1)",
        params: &[P_SEEDS],
        kind: kind_ignore,
        period: period_best_period,
    },
    StrategyDef {
        name: "BestPeriod-Instant",
        aliases: &["bestperiod-instant", "bp-instant"],
        summary: "Instant mode, T_R by brute-force search (S4.1)",
        params: &[P_SEEDS],
        kind: kind_instant,
        period: period_best_period,
    },
    StrategyDef {
        name: "BestPeriod-NoCkptI",
        aliases: &["bestperiod-nockpt", "bestperiod-nockpti", "bp-nockpti"],
        summary: "NoCkptI mode, T_R by brute-force search (S4.1)",
        params: &[P_SEEDS],
        kind: kind_nockpt,
        period: period_best_period,
    },
    StrategyDef {
        name: "BestPeriod-WithCkptI",
        aliases: &["bestperiod-withckpt", "bestperiod-withckpti", "bp-withckpti"],
        summary: "WithCkptI mode, T_R by brute-force search (S4.1)",
        params: &[P_SEEDS],
        kind: kind_withckpt,
        period: period_best_period,
    },
];

fn find_def(token: &str) -> Option<&'static StrategyDef> {
    let lower = token.to_ascii_lowercase();
    DEFS.iter().find(|d| {
        d.name.eq_ignore_ascii_case(token) || d.aliases.contains(&lower.as_str())
    })
}

/// A parsed strategy identifier: registered name + fully materialized
/// parameter values (defaults filled in at parse time, so two identifiers
/// naming the same strategy compare and display identically).
#[derive(Clone, Debug, PartialEq)]
pub struct StrategyId {
    name: &'static str,
    /// `(key, value)` in the registry's declaration order.
    params: Vec<(&'static str, f64)>,
}

impl StrategyId {
    /// The strategy registered under `name` (canonical name or alias,
    /// case-insensitive), with default parameters.
    pub fn with_defaults(def: &'static StrategyDef) -> StrategyId {
        StrategyId {
            name: def.name,
            params: def.params.iter().map(|p| (p.key, p.default)).collect(),
        }
    }

    /// Parse an identifier: `name` or `name(k=v;k2=v2)` (',' also accepted
    /// as a parameter separator).  See the module docs for the grammar.
    pub fn parse(s: &str) -> Result<StrategyId, String> {
        let s = s.trim();
        let (base, args) = match s.split_once('(') {
            None => (s, None),
            Some((base, rest)) => {
                let inner = rest.strip_suffix(')').ok_or_else(|| {
                    format!("strategy '{s}': missing closing ')'")
                })?;
                (base.trim(), Some(inner))
            }
        };
        let def = find_def(base).ok_or_else(|| {
            format!(
                "unknown strategy '{base}' (known: {})",
                DEFS.iter().map(|d| d.name).collect::<Vec<_>>().join(", ")
            )
        })?;
        let mut id = StrategyId::with_defaults(def);
        if let Some(args) = args {
            for kv in args.split([';', ',']).map(str::trim).filter(|t| !t.is_empty()) {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    format!("{}: expected key=value, got '{kv}'", def.name)
                })?;
                let v: f64 = v.trim().parse().map_err(|_| {
                    format!("{}: parameter '{kv}' is not a number", def.name)
                })?;
                id.set_param(def, k.trim(), v)?;
            }
        }
        Ok(id)
    }

    fn set_param(
        &mut self,
        def: &'static StrategyDef,
        key: &str,
        val: f64,
    ) -> Result<(), String> {
        let pd = def
            .params
            .iter()
            .find(|p| p.key.eq_ignore_ascii_case(key))
            .ok_or_else(|| {
                format!("{}: unknown parameter '{key}'", def.name)
            })?;
        if !val.is_finite() || !(pd.min..=pd.max).contains(&val) {
            return Err(format!(
                "{}: {} = {val} outside [{}, {}]",
                def.name, pd.key, pd.min, pd.max
            ));
        }
        for slot in &mut self.params {
            if slot.0 == pd.key {
                slot.1 = val;
            }
        }
        Ok(())
    }

    /// A copy with `key` set to `val` (validated against the registry).
    pub fn with_param(mut self, key: &str, val: f64) -> Result<StrategyId, String> {
        let def = self.def();
        self.set_param(def, key, val)?;
        Ok(self)
    }

    fn def(&self) -> &'static StrategyDef {
        DEFS.iter()
            .find(|d| d.name == self.name)
            .expect("StrategyId only constructed from registry rows")
    }

    /// Canonical registered name (`"Daly"`, `"QTrust"`, …).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The value of a declared parameter.  Panics on undeclared keys —
    /// construction guarantees every declared parameter is present.
    pub fn param(&self, key: &str) -> f64 {
        self.params
            .iter()
            .find(|(k, _)| *k == key)
            .unwrap_or_else(|| panic!("{}: no parameter '{key}'", self.name))
            .1
    }

    /// One-line description (for `ckptwin strategies`).
    pub fn summary(&self) -> &'static str {
        self.def().summary
    }

    /// The engine execution mode this strategy runs in.
    pub fn kind(&self) -> PolicyKind {
        (self.def().kind)(self)
    }

    /// The analytic waste model paired with this strategy, where the paper
    /// derives one.
    pub fn grid_strategy(&self) -> Option<GridStrategy> {
        self.kind().grid_strategy()
    }

    /// Instantiate the policy for a scenario: the strategy's period rule
    /// (closed form, or brute-force search for the BestPeriod twins), with
    /// `T_P = T_P^extr` and the period clamped to the job itself.
    pub fn policy(&self, sc: &Scenario) -> Policy {
        let tp = default_tp(sc);
        let tr = (self.def().period)(self, sc);
        // Periods never exceed the job itself.
        let tr = tr.min(sc.job_size.max(1.2 * sc.platform.c));
        Policy { kind: self.kind(), tr, tp }
    }
}

impl fmt::Display for StrategyId {
    /// Canonical form: registered name, every parameter materialized.
    /// This string is the campaign store identity — parameterless names
    /// are byte-identical to the pre-registry enum labels.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name)?;
        if !self.params.is_empty() {
            f.write_str("(")?;
            for (i, (k, v)) in self.params.iter().enumerate() {
                if i > 0 {
                    f.write_str(";")?;
                }
                write!(f, "{k}={v}")?;
            }
            f.write_str(")")?;
        }
        Ok(())
    }
}

impl FromStr for StrategyId {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        StrategyId::parse(s)
    }
}

/// Look up a strategy by canonical name or alias, with default parameters.
pub fn get(name: &str) -> Option<StrategyId> {
    find_def(name).map(StrategyId::with_defaults)
}

/// The five heuristics compared in the paper's simulations (§4.1);
/// Young is implemented as an extra but not plotted by the paper.
pub fn paper_set() -> Vec<StrategyId> {
    ["Daly", "RFO", "Instant", "NoCkptI", "WithCkptI"]
        .iter()
        .map(|n| get(n).expect("paper strategies are registered"))
        .collect()
}

/// Every registered strategy with default parameters, in registry order.
/// The generic invariant suite iterates this, so new registrations get
/// coverage for free.
pub fn all_defaults() -> Vec<StrategyId> {
    DEFS.iter().map(StrategyId::with_defaults).collect()
}

/// The registry rows themselves (for `ckptwin strategies` and docs).
pub fn catalog() -> impl Iterator<Item = &'static StrategyDef> {
    DEFS.iter()
}

/// Parse a comma-separated strategy list, paren-aware: commas inside a
/// `name(k=v,…)` parameter list do not split entries (`;` works too and
/// needs no care).  Used by the CLI's `--strategies` axis; the splitter
/// ([`crate::util::split_top_level`]) is shared with the predictor
/// registry's `--predictors` parser.
pub fn parse_strategy_list(raw: &str) -> Result<Vec<StrategyId>, String> {
    let mut out = Vec::new();
    for tok in crate::util::split_top_level(raw) {
        let tok = tok.trim();
        if !tok.is_empty() {
            out.push(StrategyId::parse(tok)?);
        }
    }
    if out.is_empty() {
        return Err("empty strategy list".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorSpec;
    use crate::sim::distribution::Law;

    fn sc() -> Scenario {
        Scenario::paper(
            1 << 16,
            1.0,
            PredictorSpec::paper_a(600.0),
            Law::Exponential,
            Law::Exponential,
        )
    }

    #[test]
    fn display_round_trips_for_every_registered_strategy() {
        for id in all_defaults() {
            let label = id.to_string();
            let back: StrategyId = label.parse().unwrap_or_else(|e| {
                panic!("'{label}' failed to re-parse: {e}")
            });
            assert_eq!(back, id, "round trip of '{label}'");
            assert_eq!(back.to_string(), label);
        }
    }

    #[test]
    fn non_default_params_round_trip() {
        for raw in ["QTrust(q=0.25)", "BestPeriod-NoCkptI(seeds=16)"] {
            let id = StrategyId::parse(raw).unwrap();
            assert_eq!(id.to_string(), raw);
            assert_eq!(StrategyId::parse(&id.to_string()).unwrap(), id);
        }
        // ',' is accepted as a parameter separator on input.
        assert_eq!(
            StrategyId::parse("qtrust(q=0.25)").unwrap(),
            StrategyId::parse("QTrust(q=0.25,)").unwrap()
        );
    }

    #[test]
    fn legacy_names_and_aliases_parse() {
        // The pre-registry grid parser's vocabulary must keep working.
        for (alias, canonical) in [
            ("daly", "Daly"),
            ("young", "Young"),
            ("rfo", "RFO"),
            ("instant", "Instant"),
            ("nockpt", "NoCkptI"),
            ("nockpti", "NoCkptI"),
            ("withckpt", "WithCkptI"),
            ("withckpti", "WithCkptI"),
            ("exactpred", "ExactPred"),
            ("wec", "WindowEndCkpt"),
        ] {
            assert_eq!(StrategyId::parse(alias).unwrap().name(), canonical);
        }
        assert!(StrategyId::parse("nope").is_err());
    }

    #[test]
    fn legacy_display_names_unchanged() {
        // These exact strings appear in store keys and CSV rows; changing
        // one silently orphans every existing campaign store.
        let expected =
            ["Daly", "Young", "RFO", "Instant", "NoCkptI", "WithCkptI"];
        for name in expected {
            assert_eq!(get(name).unwrap().to_string(), name);
        }
    }

    #[test]
    fn parameter_validation() {
        assert!(StrategyId::parse("QTrust(q=1.5)").is_err());
        assert!(StrategyId::parse("QTrust(q=nan)").is_err());
        assert!(StrategyId::parse("QTrust(frob=1)").is_err());
        assert!(StrategyId::parse("QTrust(q=0.5").is_err()); // missing ')'
        assert!(StrategyId::parse("Daly(q=0.5)").is_err()); // no params
        assert!(StrategyId::parse("BestPeriod-NoPred(seeds=0)").is_err());
        let q = StrategyId::parse("QTrust").unwrap();
        assert_eq!(q.param("q"), 0.5); // default materialized
    }

    #[test]
    fn kinds_and_policies() {
        let s = sc();
        let q = StrategyId::parse("qtrust(q=0.3)").unwrap();
        assert_eq!(q.kind(), PolicyKind::QTrust { q: 0.3 });
        let pol = q.policy(&s);
        pol.validate(&s);
        assert_eq!(
            get("ExactPred").unwrap().policy(&s).tr,
            get("Instant").unwrap().policy(&s).tr,
            "ExactPred shares Instant's closed-form period"
        );
        assert_eq!(
            get("WindowEndCkpt").unwrap().policy(&s).tr,
            get("NoCkptI").unwrap().policy(&s).tr,
        );
    }

    #[test]
    fn paper_set_shape() {
        let set = paper_set();
        assert_eq!(set.len(), 5);
        assert_eq!(set[0].name(), "Daly");
        assert_eq!(set[4].name(), "WithCkptI");
    }

    #[test]
    fn strategy_list_parsing_is_paren_aware() {
        let ids = parse_strategy_list(
            "instant, qtrust(q=0.25,) ,QTrust(q=0.75;)",
        )
        .unwrap();
        assert_eq!(ids.len(), 3);
        assert_eq!(ids[1].param("q"), 0.25);
        assert_eq!(ids[2].param("q"), 0.75);
        assert!(parse_strategy_list("").is_err());
        assert!(parse_strategy_list("daly,,rfo").is_ok());
        assert!(parse_strategy_list("daly,bogus").is_err());
    }

    #[test]
    fn best_period_twin_instantiates_via_search() {
        let mut s = sc();
        s.job_size *= 0.02; // keep the search cheap
        let id = StrategyId::parse("BestPeriod-NoPred(seeds=2)").unwrap();
        let pol = id.policy(&s);
        pol.validate(&s);
        assert_eq!(pol.kind, PolicyKind::IgnorePredictions);
        assert!(pol.tr > s.platform.c);
    }
}
