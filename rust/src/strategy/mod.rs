//! Checkpointing strategies: the paper's nine heuristics.
//!
//! * Prediction-ignoring (q = 0): **Daly**, **Young**, **RFO** — periodic
//!   checkpointing with the respective closed-form periods.
//! * Prediction-aware (q = 1): **Instant**, **NoCkptI**, **WithCkptI** —
//!   two-mode scheduling with the closed-form `T_R^extr` / `T_P^extr`.
//! * [`best_period`] — the BestPeriod counterparts: same execution modes,
//!   but `T_R` found by brute-force numerical search over simulations
//!   (§4.1), the paper's yardstick for "how good are the formulas?".

pub mod best_period;

use crate::config::Scenario;
use crate::model::optimal;

/// Execution mode of the engine (how predictions are handled).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// q = 0: predictions ignored entirely.
    IgnorePredictions,
    /// Proactive checkpoint before the window, immediate return (§3.4).
    Instant,
    /// Proactive checkpoint, then work without checkpointing in-window (§3.3).
    NoCkpt,
    /// Proactive checkpoint + periodic proactive checkpoints in-window (§3.2).
    WithCkpt,
}

impl PolicyKind {
    /// The analytic waste-model strategy this execution mode maps to
    /// (Eqs. 3/14/10/4) — the single source of truth for every consumer
    /// that pairs a simulated mode with its closed-form prediction.
    pub fn grid_strategy(&self) -> crate::model::waste::GridStrategy {
        use crate::model::waste::GridStrategy;
        match self {
            PolicyKind::IgnorePredictions => GridStrategy::Q0,
            PolicyKind::Instant => GridStrategy::Instant,
            PolicyKind::NoCkpt => GridStrategy::NoCkpt,
            PolicyKind::WithCkpt => GridStrategy::WithCkpt,
        }
    }
}

/// A fully instantiated policy: mode + concrete periods.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub kind: PolicyKind,
    /// Regular-mode period `T_R` (work `T_R - C`, then checkpoint `C`).
    pub tr: f64,
    /// Proactive-mode period `T_P` (WithCkpt only; work `T_P - C_p`, then
    /// checkpoint `C_p`).
    pub tp: f64,
}

impl Policy {
    /// Engine preconditions; violations are programming errors.
    pub fn validate(&self, sc: &Scenario) {
        assert!(
            self.tr > sc.platform.c,
            "T_R = {} must exceed C = {}",
            self.tr,
            sc.platform.c
        );
        if matches!(self.kind, PolicyKind::WithCkpt) {
            assert!(
                self.tp > sc.platform.cp,
                "T_P = {} must exceed C_p = {}",
                self.tp,
                sc.platform.cp
            );
        }
        assert!(self.tr.is_finite() && self.tp.is_finite());
    }
}

/// The paper's named heuristics (analytic periods).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Daly's periodic policy — the paper's reference baseline.
    Daly,
    /// Young's first-order periodic policy.
    Young,
    /// Refined First-Order periodic policy (q = 0 optimum, Eq. 3).
    Rfo,
    /// Instant (q = 1).
    Instant,
    /// NoCkptI (q = 1).
    NoCkptI,
    /// WithCkptI (q = 1), T_P = T_P^extr.
    WithCkptI,
}

impl Strategy {
    /// Display name matching the paper's figures.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Daly => "Daly",
            Strategy::Young => "Young",
            Strategy::Rfo => "RFO",
            Strategy::Instant => "Instant",
            Strategy::NoCkptI => "NoCkptI",
            Strategy::WithCkptI => "WithCkptI",
        }
    }

    /// The five heuristics compared in the paper's simulations (§4.1);
    /// Young is implemented as an extra but not plotted by the paper.
    pub fn paper_set() -> [Strategy; 5] {
        [
            Strategy::Daly,
            Strategy::Rfo,
            Strategy::Instant,
            Strategy::NoCkptI,
            Strategy::WithCkptI,
        ]
    }

    /// The engine mode this strategy runs in.
    pub fn kind(&self) -> PolicyKind {
        match self {
            Strategy::Daly | Strategy::Young | Strategy::Rfo => {
                PolicyKind::IgnorePredictions
            }
            Strategy::Instant => PolicyKind::Instant,
            Strategy::NoCkptI => PolicyKind::NoCkpt,
            Strategy::WithCkptI => PolicyKind::WithCkpt,
        }
    }

    /// Instantiate the analytic policy for a scenario.
    pub fn policy(&self, sc: &Scenario) -> Policy {
        let tp = optimal::tp_extr(sc).max(sc.platform.cp * 1.1);
        let tr = match self {
            Strategy::Daly => optimal::daly_period(&sc.platform),
            Strategy::Young => optimal::young_period(&sc.platform),
            Strategy::Rfo => optimal::rfo_period(&sc.platform),
            Strategy::Instant => optimal::tr_extr_instant(sc),
            Strategy::NoCkptI | Strategy::WithCkptI => {
                optimal::tr_extr_window(sc)
            }
        };
        // Periods never exceed the job itself.
        let tr = tr.min(sc.job_size.max(1.2 * sc.platform.c));
        Policy { kind: self.kind(), tr, tp }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;

    fn sc() -> Scenario {
        Scenario {
            platform: Platform { mu: 60_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec { recall: 0.85, precision: 0.82, window: 600.0 },
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    #[test]
    fn policies_valid_for_paper_scenarios() {
        for n in [1u64 << 16, 1 << 17, 1 << 18, 1 << 19] {
            for cp_ratio in [1.0, 0.1, 2.0] {
                for pred in [
                    PredictorSpec::paper_a(300.0),
                    PredictorSpec::paper_b(3000.0),
                ] {
                    let s = Scenario::paper(
                        n, cp_ratio, pred, Law::Exponential, Law::Exponential,
                    );
                    for strat in Strategy::paper_set() {
                        let pol = strat.policy(&s);
                        pol.validate(&s); // must not panic
                    }
                }
            }
        }
    }

    #[test]
    fn q0_strategies_ignore_predictions() {
        for s in [Strategy::Daly, Strategy::Young, Strategy::Rfo] {
            assert_eq!(s.kind(), PolicyKind::IgnorePredictions);
        }
    }

    #[test]
    fn period_ordering_young_daly() {
        let s = sc();
        assert!(Strategy::Daly.policy(&s).tr > Strategy::Young.policy(&s).tr);
    }

    #[test]
    #[should_panic(expected = "must exceed C")]
    fn invalid_policy_panics() {
        let s = sc();
        Policy { kind: PolicyKind::Instant, tr: 100.0, tp: 700.0 }.validate(&s);
    }
}
