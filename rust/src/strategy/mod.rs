//! Checkpointing strategies: execution modes, instantiated policies, and
//! the data-driven strategy [`registry`].
//!
//! * [`PolicyKind`] — the engine execution modes (how predictions are
//!   handled); each dispatches to a [`crate::sim::policy::PolicyLogic`]
//!   implementation.
//! * [`Policy`] — a fully instantiated policy: mode + concrete periods.
//! * [`registry`] / [`StrategyId`] — the open strategy axis: stable string
//!   names + parameter maps, instantiating policies and mapping to
//!   analytic waste models where one exists.  The paper's named heuristics
//!   (Daly, Young, RFO, Instant, NoCkptI, WithCkptI), their BestPeriod
//!   twins, and the prediction-handling extensions (ExactPred,
//!   WindowEndCkpt, QTrust) are all registry entries.
//! * [`best_period`] — the BestPeriod brute-force numerical search over
//!   simulations (§4.1), the paper's yardstick for "how good are the
//!   formulas?".

pub mod best_period;
pub mod registry;

pub use registry::StrategyId;

use crate::config::Scenario;

/// Execution mode of the engine (how predictions are handled).  Each
/// variant is dispatched — once, at simulation entry — to its
/// [`crate::sim::policy::PolicyLogic`] implementation; the engine's main
/// loop is monomorphized over that behaviour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PolicyKind {
    /// q = 0: predictions ignored entirely.
    IgnorePredictions,
    /// Proactive checkpoint before the window, immediate return (§3.4).
    Instant,
    /// Proactive checkpoint, then work without checkpointing in-window (§3.3).
    NoCkpt,
    /// Proactive checkpoint + periodic proactive checkpoints in-window (§3.2).
    WithCkpt,
    /// The I → 0 exact-prediction limit: like [`PolicyKind::Instant`], but
    /// the proactive checkpoint replaces the period's checkpoint (fresh
    /// period at the window exit).
    ExactPred,
    /// [`PolicyKind::NoCkpt`] plus a terminal proactive checkpoint at
    /// `t0 + I` securing the window's work.
    WindowEndCkpt,
    /// [`PolicyKind::NoCkpt`] with §3.1 randomized trust: each
    /// announcement is trusted with probability `q`.
    QTrust {
        /// Trust probability q ∈ [0, 1].
        q: f64,
    },
}

impl PolicyKind {
    /// The analytic waste-model strategy this execution mode maps to
    /// (Eqs. 3/14/10/4) — the single source of truth for every consumer
    /// that pairs a simulated mode with its closed-form prediction.
    /// `None` for modes the paper derives no closed form for (the
    /// harness reports NaN in the analytic column there).
    pub fn grid_strategy(&self) -> Option<crate::model::waste::GridStrategy> {
        use crate::model::waste::GridStrategy;
        match self {
            PolicyKind::IgnorePredictions => Some(GridStrategy::Q0),
            PolicyKind::Instant => Some(GridStrategy::Instant),
            PolicyKind::NoCkpt => Some(GridStrategy::NoCkpt),
            PolicyKind::WithCkpt => Some(GridStrategy::WithCkpt),
            PolicyKind::ExactPred
            | PolicyKind::WindowEndCkpt
            | PolicyKind::QTrust { .. } => None,
        }
    }
}

/// A fully instantiated policy: mode + concrete periods.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub kind: PolicyKind,
    /// Regular-mode period `T_R` (work `T_R - C`, then checkpoint `C`).
    pub tr: f64,
    /// Proactive-mode period `T_P` (WithCkpt only; work `T_P - C_p`, then
    /// checkpoint `C_p`).
    pub tp: f64,
}

impl Policy {
    /// Engine preconditions; violations are programming errors.
    pub fn validate(&self, sc: &Scenario) {
        assert!(
            self.tr > sc.platform.c,
            "T_R = {} must exceed C = {}",
            self.tr,
            sc.platform.c
        );
        if matches!(self.kind, PolicyKind::WithCkpt) {
            assert!(
                self.tp > sc.platform.cp,
                "T_P = {} must exceed C_p = {}",
                self.tp,
                sc.platform.cp
            );
        }
        assert!(self.tr.is_finite() && self.tp.is_finite());
        if let PolicyKind::QTrust { q } = self.kind {
            assert!((0.0..=1.0).contains(&q), "QTrust q = {q} out of [0, 1]");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;

    fn sc() -> Scenario {
        Scenario {
            platform: Platform { mu: 60_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 1e7,
        }
    }

    #[test]
    fn policies_valid_for_paper_scenarios() {
        for n in [1u64 << 16, 1 << 17, 1 << 18, 1 << 19] {
            for cp_ratio in [1.0, 0.1, 2.0] {
                for pred in [
                    PredictorSpec::paper_a(300.0),
                    PredictorSpec::paper_b(3000.0),
                ] {
                    let s = Scenario::paper(
                        n, cp_ratio, pred, Law::Exponential, Law::Exponential,
                    );
                    for strat in registry::paper_set() {
                        let pol = strat.policy(&s);
                        pol.validate(&s); // must not panic
                    }
                }
            }
        }
    }

    #[test]
    fn q0_strategies_ignore_predictions() {
        for name in ["Daly", "Young", "RFO"] {
            let id = registry::get(name).unwrap();
            assert_eq!(id.kind(), PolicyKind::IgnorePredictions);
        }
    }

    #[test]
    fn period_ordering_young_daly() {
        let s = sc();
        let daly = registry::get("Daly").unwrap().policy(&s).tr;
        let young = registry::get("Young").unwrap().policy(&s).tr;
        assert!(daly > young);
    }

    #[test]
    #[should_panic(expected = "must exceed C")]
    fn invalid_policy_panics() {
        let s = sc();
        Policy { kind: PolicyKind::Instant, tr: 100.0, tp: 700.0 }.validate(&s);
    }

    #[test]
    fn grid_strategy_mapping_covers_paper_modes_only() {
        use crate::model::waste::GridStrategy;
        assert_eq!(
            PolicyKind::IgnorePredictions.grid_strategy(),
            Some(GridStrategy::Q0)
        );
        assert_eq!(
            PolicyKind::WithCkpt.grid_strategy(),
            Some(GridStrategy::WithCkpt)
        );
        assert_eq!(PolicyKind::ExactPred.grid_strategy(), None);
        assert_eq!(PolicyKind::QTrust { q: 0.5 }.grid_strategy(), None);
        assert_eq!(PolicyKind::WindowEndCkpt.grid_strategy(), None);
    }
}
