//! BestPeriod: brute-force numerical search for the best regular period.
//!
//! The paper compares every heuristic against a "BestPeriod" twin that runs
//! the same execution mode but with `T_R` chosen by brute force over
//! simulations (§4.1).  This is the yardstick that shows the closed-form
//! periods of the prediction-aware strategies are near-optimal, while
//! Daly's (and to a lesser extent RFO's) can be far off under Weibull laws.
//!
//! The search is a two-stage grid: a coarse geometric sweep over
//! `[1.05 C, min(job, 40 T_ref)]`, then a linear refinement around the
//! best coarse point.  Every candidate is scored by the mean waste over the
//! given instance seeds (the same seeds for every candidate — paired
//! comparison).  The expensive variant of this search is exactly what the
//! `waste_grid` PJRT artifact accelerates on the *analytic* side
//! (`runtime::waste_grid`); the simulation side is parallelized in the
//! harness.

use crate::config::Scenario;
use crate::sim::engine::{simulate, simulate_from_capped};
use crate::sim::trace::TraceCache;
use crate::strategy::{Policy, PolicyKind};

/// Result of a brute-force period search.
#[derive(Clone, Copy, Debug)]
pub struct BestPeriod {
    /// The winning regular period.
    pub tr: f64,
    /// Mean waste achieved at `tr` over the search seeds.
    pub waste: f64,
    /// Number of simulations executed by the search.
    pub evals: u64,
}

/// Mean simulated waste of `kind` at period `tr` over `seeds`.
pub fn mean_waste(sc: &Scenario, kind: PolicyKind, tr: f64, tp: f64, seeds: &[u64]) -> f64 {
    let pol = Policy { kind, tr, tp };
    let sum: f64 = seeds
        .iter()
        .map(|&s| simulate(sc, &pol, s).waste())
        .sum();
    sum / seeds.len() as f64
}

/// [`mean_waste`] over memoized traces: identical results, but trace
/// generation is paid once per seed instead of once per (seed, candidate).
pub fn mean_waste_cached(
    sc: &Scenario,
    kind: PolicyKind,
    tr: f64,
    tp: f64,
    seeds: &[u64],
    caches: &mut [TraceCache],
) -> f64 {
    let pol = Policy { kind, tr, tp };
    // Hopeless-candidate cutoff: a candidate whose makespan exceeds
    // 50x the job (waste >= 0.98) cannot win any search; abandoning it
    // early keeps the brute force tractable in the heavy-tailed regimes.
    let cap = 50.0 * sc.job_size + 100.0 * sc.platform.mu;
    let sum: f64 = seeds
        .iter()
        .zip(caches.iter_mut())
        .map(|(&s, cache)| {
            simulate_from_capped(sc, &pol, 1.0, s, cache.replay(), cap)
                .waste()
        })
        .sum();
    sum / seeds.len() as f64
}

/// Brute-force search for the best `T_R` (the proactive period `tp` is kept
/// fixed at its analytic optimum, as in the paper).
pub fn search(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    seeds: &[u64],
    coarse: usize,
    refine: usize,
) -> BestPeriod {
    assert!(!seeds.is_empty());
    let c = sc.platform.c;
    let lo = 1.05 * c;
    // Upper bound: well past any sensible period, but capped by the job
    // itself (a period larger than the job == "never checkpoint").
    let t_ref = crate::model::optimal::rfo_period(&sc.platform);
    let hi = (40.0 * t_ref).min(sc.job_size).max(2.0 * lo);

    // Memoize the per-seed traces: every candidate replays the same one.
    let mut caches: Vec<TraceCache> =
        seeds.iter().map(|&s| TraceCache::new(sc, s)).collect();

    let mut evals = 0u64;
    let mut best = (f64::INFINITY, lo);
    let ratio = (hi / lo).powf(1.0 / (coarse.max(2) - 1) as f64);
    let mut candidates: Vec<f64> =
        (0..coarse).map(|k| lo * ratio.powi(k as i32)).collect();
    // Always include the analytic reference period in the sweep.
    candidates.push(t_ref.min(hi).max(lo));

    for &tr in &candidates {
        let w = mean_waste_cached(sc, kind, tr, tp, seeds, &mut caches);
        evals += seeds.len() as u64;
        if w < best.0 {
            best = (w, tr);
        }
    }

    // Linear refinement around the best coarse point.
    let (mut bw, mut btr) = best;
    let span = btr * (ratio - 1.0);
    let lo2 = (btr - span).max(lo);
    let hi2 = (btr + span).min(hi);
    for k in 0..refine {
        let tr = lo2 + (hi2 - lo2) * (k as f64 + 0.5) / refine as f64;
        let w = mean_waste_cached(sc, kind, tr, tp, seeds, &mut caches);
        evals += seeds.len() as u64;
        if w < bw {
            bw = w;
            btr = tr;
        }
    }
    BestPeriod { tr: btr, waste: bw, evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;
    use crate::strategy::Strategy;

    fn sc() -> Scenario {
        Scenario {
            platform: Platform { mu: 30_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec { recall: 0.85, precision: 0.82, window: 600.0 },
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 2e6,
        }
    }

    #[test]
    fn best_period_no_worse_than_formula() {
        let s = sc();
        let seeds: Vec<u64> = (0..8).collect();
        for strat in [Strategy::Rfo, Strategy::Instant, Strategy::NoCkptI] {
            let pol = strat.policy(&s);
            let w_formula =
                mean_waste(&s, pol.kind, pol.tr, pol.tp, &seeds);
            let bp = search(&s, pol.kind, pol.tp, &seeds, 24, 8);
            assert!(
                bp.waste <= w_formula + 1e-9,
                "{}: search {} vs formula {}",
                strat.name(),
                bp.waste,
                w_formula
            );
        }
    }

    #[test]
    fn search_counts_evals() {
        let s = sc();
        let seeds: Vec<u64> = (0..2).collect();
        let bp = search(&s, PolicyKind::IgnorePredictions, 700.0, &seeds, 10, 4);
        assert_eq!(bp.evals, ((10 + 1 + 4) * 2) as u64);
        assert!(bp.tr > s.platform.c);
    }
}
