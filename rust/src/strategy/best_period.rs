//! BestPeriod: brute-force numerical search for the best regular period.
//!
//! The paper compares every heuristic against a "BestPeriod" twin that runs
//! the same execution mode but with `T_R` chosen by brute force over
//! simulations (§4.1).  This is the yardstick that shows the closed-form
//! periods of the prediction-aware strategies are near-optimal, while
//! Daly's (and to a lesser extent RFO's) can be far off under Weibull laws.
//!
//! The candidate set is a two-stage grid: a coarse geometric sweep over
//! `[1.05 C, min(job, 40 T_ref)]` (plus the analytic reference period,
//! deduplicated against the grid), then a linear refinement around the best
//! coarse point.  Every candidate is scored by the mean waste over the
//! given instance seeds — the same seeds, replaying the same memoized
//! traces, for every candidate (paired comparison).
//!
//! Two sweep modes:
//!
//! * **exhaustive** ([`search_exhaustive`], or `exact` in
//!   [`SearchConfig`]): every candidate is scored on every seed — the
//!   pre-adaptive reference behavior, with deterministic eval counts.
//! * **adaptive** ([`search`], the default): successive-halving style
//!   racing.  All candidates are scored on a small seed prefix first;
//!   candidates whose mean waste is *statistically dominated* (paired mean
//!   difference to the current leader exceeding three paired standard
//!   errors plus a small slack) are eliminated; the seed budget doubles
//!   and only survivors continue.  Once every survivor is provably within
//!   the tolerance of the leader, the race stops early.  A paired test
//!   (`adaptive_search_within_tolerance_of_exhaustive`) pins the result
//!   quality to the exhaustive sweep.

//!
//! Adaptive mode additionally **seeds the race with the closed-form
//! model** ([`ModelSide`]): each candidate batch is evaluated through
//! [`crate::model::batch`] first, candidates are reordered by model waste
//! (so the likely winner leads and elimination bites early), and
//! candidates whose model waste exceeds the model minimum by more than
//! [`SearchConfig::prune_margin`] are dropped before the first
//! simulation.  Candidates the model cannot vouch for (classified
//! [`crate::model::waste::Inapplicability`]) are never pruned — they run
//! after the model-ranked ones in their original order.  The batched and
//! scalar model sides are bit-identical (the `model::batch` contract), so
//! `--batch` vs `--scalar` produce the same winner and the same
//! elimination trace ([`RaceLog`]); exhaustive mode never consults the
//! model ([`ModelSide::Off`]), keeping its eval counts deterministic.

use crate::config::Scenario;
use crate::model::batch::BatchEvaluator;
use crate::model::waste::{waste_checked, Applicability};
use crate::sim::engine::{simulate, simulate_from_capped};
use crate::sim::trace::TraceCache;
use crate::strategy::{Policy, PolicyKind};

/// Result of a brute-force period search.
#[derive(Clone, Copy, Debug)]
pub struct BestPeriod {
    /// The winning regular period.
    pub tr: f64,
    /// Mean waste achieved at `tr` over the seeds the search spent on it
    /// (all of them in exhaustive mode; possibly a prefix when the
    /// adaptive race stopped early).
    pub waste: f64,
    /// Number of simulations executed by the search.
    pub evals: u64,
}

/// Which closed-form implementation seeds the adaptive race's candidate
/// batches (ordering + pruning).  Batched and Scalar are bit-identical
/// (the `model::batch` contract, pinned in `tests/batch_model.rs`), so
/// they yield the same winner and elimination trace; Scalar exists as the
/// `ckptwin best-period --scalar` escape hatch and as the cross-check's
/// reference side.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSide {
    /// Whole-batch evaluation via [`crate::model::batch`] (the default).
    Batched,
    /// Per-candidate [`waste_checked`] calls (escape hatch / reference).
    Scalar,
    /// No model seeding at all — candidates race in grid order
    /// (exhaustive mode, and the pre-batch adaptive behavior).
    Off,
}

/// Sweep shape and mode of a [`search_with`] call.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// Points of the coarse geometric sweep.
    pub coarse: usize,
    /// Points of the linear refinement around the coarse winner.
    pub refine: usize,
    /// Exhaustive mode: score every candidate on every seed.
    pub exact: bool,
    /// Adaptive mode's waste tolerance: elimination slack and early-stop
    /// threshold both derive from it (ignored when `exact`).
    pub tolerance: f64,
    /// Model side seeding the adaptive race (ignored when `exact`).
    pub model: ModelSide,
    /// Adaptive pruning margin, in absolute waste: candidates whose model
    /// waste exceeds the model minimum by more than this are dropped
    /// before any simulation.  Far above the model-vs-simulation deviation
    /// of any conforming scenario (conformance tolerances are ~0.02-0.05),
    /// so the simulated winner is never at risk; inapplicable candidates
    /// are exempt (the model cannot vouch against them).
    pub prune_margin: f64,
}

impl SearchConfig {
    /// The racing configuration used by default (tolerance 0.01 waste,
    /// batched model seeding with a 0.25-waste pruning margin).
    pub fn adaptive(coarse: usize, refine: usize) -> Self {
        SearchConfig {
            coarse,
            refine,
            exact: false,
            tolerance: 0.01,
            model: ModelSide::Batched,
            prune_margin: 0.25,
        }
    }

    /// The pre-adaptive full sweep (no model seeding: deterministic eval
    /// counts, grid-order sweep).
    pub fn exhaustive(coarse: usize, refine: usize) -> Self {
        SearchConfig {
            coarse,
            refine,
            exact: true,
            tolerance: 0.0,
            model: ModelSide::Off,
            prune_margin: 0.0,
        }
    }

    /// This config with the given model side (builder-style, for the CLI
    /// escape hatch and the equivalence tests).
    pub fn with_model(mut self, model: ModelSide) -> Self {
        self.model = model;
        self
    }
}

/// The makespan cap shared by every search simulation: a candidate whose
/// makespan exceeds ~50x the job (waste ≥ 0.98) cannot win any search;
/// abandoning it early keeps the brute force tractable in the heavy-tailed
/// regimes.
fn hopeless_cap(sc: &Scenario) -> f64 {
    50.0 * sc.job_size + 100.0 * sc.platform.mu
}

/// Mean simulated waste of `kind` at period `tr` over `seeds`.
pub fn mean_waste(sc: &Scenario, kind: PolicyKind, tr: f64, tp: f64, seeds: &[u64]) -> f64 {
    let pol = Policy { kind, tr, tp };
    let sum: f64 = seeds
        .iter()
        .map(|&s| simulate(sc, &pol, s).waste())
        .sum();
    sum / seeds.len() as f64
}

/// [`mean_waste`] over memoized traces with the hopeless-candidate cutoff:
/// identical results for viable candidates, but trace generation is paid
/// once per seed instead of once per (seed, candidate).
pub fn mean_waste_cached(
    sc: &Scenario,
    kind: PolicyKind,
    tr: f64,
    tp: f64,
    seeds: &[u64],
    caches: &mut [TraceCache],
) -> f64 {
    let pol = Policy { kind, tr, tp };
    let cap = hopeless_cap(sc);
    let sum: f64 = seeds
        .iter()
        .zip(caches.iter_mut())
        .map(|(&s, cache)| {
            simulate_from_capped(sc, &pol, 1.0, s, cache.replay(), cap)
                .waste()
        })
        .sum();
    sum / seeds.len() as f64
}

/// The coarse candidate set: geometric grid over `[1.05 C, hi]` plus the
/// analytic reference period — included exactly once (it is deduplicated
/// against the grid, e.g. when clamping lands it on `hi`).
/// Returns (candidates, grid ratio, lo, hi).
fn candidate_grid(sc: &Scenario, coarse: usize) -> (Vec<f64>, f64, f64, f64) {
    let c = sc.platform.c;
    let lo = 1.05 * c;
    // Upper bound: well past any sensible period, but capped by the job
    // itself (a period larger than the job == "never checkpoint").
    let t_ref = crate::model::optimal::rfo_period(&sc.platform);
    let hi = (40.0 * t_ref).min(sc.job_size).max(2.0 * lo);
    let ratio = (hi / lo).powf(1.0 / (coarse.max(2) - 1) as f64);
    let mut cands: Vec<f64> =
        (0..coarse).map(|k| lo * ratio.powi(k as i32)).collect();
    let t_ref = t_ref.min(hi).max(lo);
    if !cands.iter().any(|&g| (g - t_ref).abs() <= 1e-9 * t_ref) {
        cands.push(t_ref);
    }
    (cands, ratio, lo, hi)
}

/// The refinement candidates around a coarse winner `btr`: the winner
/// itself plus `refine` linearly spaced points within one grid ratio.
fn refine_grid(btr: f64, ratio: f64, lo: f64, hi: f64, refine: usize) -> Vec<f64> {
    let span = btr * (ratio - 1.0);
    let lo2 = (btr - span).max(lo);
    let hi2 = (btr + span).min(hi);
    let mut cands = vec![btr];
    for k in 0..refine {
        cands.push(lo2 + (hi2 - lo2) * (k as f64 + 0.5) / refine as f64);
    }
    cands
}

/// The elimination trace of one adaptive search: one entry per race
/// stage, holding the candidate periods still alive *after* that stage's
/// elimination, in race order.  The batched-vs-scalar equivalence tests
/// pin this trace, not just the winner — bit-identical model seeding must
/// produce bit-identical races.
pub type RaceLog = Vec<Vec<f64>>;

/// Model-seed a candidate batch: evaluate every candidate's closed-form
/// waste (batched or scalar — bit-identical), reorder applicable
/// candidates by ascending model waste (ties by original position), drop
/// the applicable ones worse than the model minimum by more than
/// `margin`, and append the inapplicable ones (unpruned, original order).
/// Returns the candidates untouched when the model side is [`ModelSide::Off`],
/// the policy has no closed form ([`PolicyKind::grid_strategy`] is
/// `None`), or no candidate is applicable.
fn model_seed(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    cands: Vec<f64>,
    side: ModelSide,
    margin: f64,
) -> Vec<f64> {
    let strat = match (side, kind.grid_strategy()) {
        (ModelSide::Off, _) | (_, None) => return cands,
        (_, Some(s)) => s,
    };
    let model: Vec<Applicability> = match side {
        ModelSide::Batched => {
            let mut ev = BatchEvaluator::new();
            let mut row = Vec::new();
            ev.eval_row(sc, strat, tp, &cands, &mut row);
            row
        }
        ModelSide::Scalar => cands
            .iter()
            .map(|&tr| waste_checked(sc, strat, tr, tp))
            .collect(),
        ModelSide::Off => unreachable!(),
    };
    let mut ranked: Vec<(f64, usize)> = Vec::with_capacity(cands.len());
    let mut unranked: Vec<usize> = Vec::new();
    for (i, a) in model.iter().enumerate() {
        match a.value() {
            Some(w) => ranked.push((w, i)),
            None => unranked.push(i),
        }
    }
    if ranked.is_empty() {
        return cands;
    }
    // Applicable values are finite by construction: total_cmp is a plain
    // f64 order here, the index tie-break keeps the sort schedule-free.
    ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let floor = ranked[0].0;
    let mut out: Vec<f64> = ranked
        .iter()
        .filter(|(w, _)| *w <= floor + margin)
        .map(|&(_, i)| cands[i])
        .collect();
    out.extend(unranked.iter().map(|&i| cands[i]));
    out
}

/// Race `cands` over `seeds`: evaluate on a doubling seed prefix,
/// eliminating statistically dominated candidates between stages, stopping
/// early once every survivor is within `tol` of the leader.  Returns
/// (winner index, winner mean waste over the seeds it consumed, evals).
/// When `log` is given, the surviving periods are appended after every
/// stage (the [`RaceLog`] entry).
#[allow(clippy::too_many_arguments)]
fn race(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    cands: &[f64],
    seeds: &[u64],
    caches: &mut [TraceCache],
    cap: f64,
    tol: f64,
    mut log: Option<&mut RaceLog>,
) -> (usize, f64, u64) {
    let n = seeds.len();
    let mut wastes: Vec<Vec<f64>> = vec![Vec::with_capacity(n); cands.len()];
    let mut alive: Vec<usize> = (0..cands.len()).collect();
    let mut evals = 0u64;
    let mut s = 0usize;
    loop {
        let s_next = if s == 0 { n.min(2) } else { (s * 2).min(n) };
        for &ci in &alive {
            let pol = Policy { kind, tr: cands[ci], tp };
            for k in s..s_next {
                let w = simulate_from_capped(
                    sc,
                    &pol,
                    1.0,
                    seeds[k],
                    caches[k].replay(),
                    cap,
                )
                .waste();
                wastes[ci].push(w);
            }
            evals += (s_next - s) as u64;
        }
        s = s_next;
        let mean_of = |ci: usize| wastes[ci].iter().sum::<f64>() / s as f64;
        // First minimum wins ties, like the exhaustive sweep's `w < best`.
        let mut leader = alive[0];
        for &ci in &alive[1..] {
            if mean_of(ci) < mean_of(leader) {
                leader = ci;
            }
        }
        if s == n {
            if let Some(l) = log.as_deref_mut() {
                l.push(alive.iter().map(|&ci| cands[ci]).collect());
            }
            return (leader, mean_of(leader), evals);
        }
        // Paired statistics of candidate ci against the leader over the
        // seeds seen so far: (mean difference, its standard error).
        let leader_w = wastes[leader].clone();
        let paired = |ci: usize| -> (f64, f64) {
            let mut mean_d = 0.0;
            for (w, l) in wastes[ci].iter().zip(&leader_w) {
                mean_d += w - l;
            }
            mean_d /= s as f64;
            let mut var = 0.0;
            for (w, l) in wastes[ci].iter().zip(&leader_w) {
                let d = (w - l) - mean_d;
                var += d * d;
            }
            let var = if s >= 2 { var / (s - 1) as f64 } else { 0.0 };
            (mean_d, (var / s as f64).sqrt())
        };
        // Elimination: dominated by more than 3 paired standard errors
        // (plus a small absolute slack so near-ties at tiny s survive the
        // unreliable variance estimate in neither direction).
        alive.retain(|&ci| {
            if ci == leader {
                return true;
            }
            let (mean_d, se) = paired(ci);
            mean_d <= 3.0 * se + 0.1 * tol
        });
        if let Some(l) = log.as_deref_mut() {
            l.push(alive.iter().map(|&ci| cands[ci]).collect());
        }
        // Equivalence stop: no survivor can still beat the leader by more
        // than tol/2 (2 standard errors below its observed deficit), so
        // spending the remaining seed budget cannot change the answer by
        // more than the tolerance.  Needs ≥ 4 seeds for a usable se.
        if s >= 4
            && alive.iter().all(|&ci| {
                if ci == leader {
                    return true;
                }
                let (mean_d, se) = paired(ci);
                2.0 * se - mean_d <= 0.5 * tol
            })
        {
            return (leader, mean_of(leader), evals);
        }
    }
}

/// Brute-force search for the best `T_R` (the proactive period `tp` is kept
/// fixed at its analytic optimum, as in the paper), with the default
/// adaptive racing configuration.  See [`search_with`].
pub fn search(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    seeds: &[u64],
    coarse: usize,
    refine: usize,
) -> BestPeriod {
    let mut caches: Vec<TraceCache> =
        seeds.iter().map(|&s| TraceCache::new(sc, s)).collect();
    search_with(sc, kind, tp, seeds, &SearchConfig::adaptive(coarse, refine), &mut caches)
}

/// [`search`] in exhaustive mode: every candidate scored on every seed
/// (deterministic eval counts; the adaptive race's quality reference).
pub fn search_exhaustive(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    seeds: &[u64],
    coarse: usize,
    refine: usize,
) -> BestPeriod {
    let mut caches: Vec<TraceCache> =
        seeds.iter().map(|&s| TraceCache::new(sc, s)).collect();
    search_with(sc, kind, tp, seeds, &SearchConfig::exhaustive(coarse, refine), &mut caches)
}

/// The search core, over caller-supplied trace memos (`caches[k]` holds
/// seed `seeds[k]`'s trace).  Passing the same caches to several searches —
/// as the harness does for the four BestPeriod twins of one scenario —
/// amortizes trace generation across all of them.
pub fn search_with(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    seeds: &[u64],
    cfg: &SearchConfig,
    caches: &mut [TraceCache],
) -> BestPeriod {
    search_core(sc, kind, tp, seeds, cfg, caches, None)
}

/// [`search_with`] that also returns the [`RaceLog`] — the per-stage
/// survivor sets of both races.  The batched-vs-scalar equivalence tests
/// compare these traces bitwise; exhaustive mode has no race, so its log
/// is empty.
pub fn search_logged(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    seeds: &[u64],
    cfg: &SearchConfig,
    caches: &mut [TraceCache],
) -> (BestPeriod, RaceLog) {
    let mut log = RaceLog::new();
    let bp = search_core(sc, kind, tp, seeds, cfg, caches, Some(&mut log));
    (bp, log)
}

fn search_core(
    sc: &Scenario,
    kind: PolicyKind,
    tp: f64,
    seeds: &[u64],
    cfg: &SearchConfig,
    caches: &mut [TraceCache],
    mut log: Option<&mut RaceLog>,
) -> BestPeriod {
    assert!(!seeds.is_empty());
    assert_eq!(seeds.len(), caches.len(), "one trace memo per seed");
    let (cands, ratio, lo, hi) = candidate_grid(sc, cfg.coarse);

    if cfg.exact {
        let mut evals = 0u64;
        let mut best = (f64::INFINITY, lo);
        for &tr in &cands {
            let w = mean_waste_cached(sc, kind, tr, tp, seeds, caches);
            evals += seeds.len() as u64;
            if w < best.0 {
                best = (w, tr);
            }
        }
        let (mut bw, mut btr) = best;
        for &tr in refine_grid(btr, ratio, lo, hi, cfg.refine).iter().skip(1) {
            let w = mean_waste_cached(sc, kind, tr, tp, seeds, caches);
            evals += seeds.len() as u64;
            if w < bw {
                bw = w;
                btr = tr;
            }
        }
        return BestPeriod { tr: btr, waste: bw, evals };
    }

    let cap = hopeless_cap(sc);
    // Model seeding: rank and prune the candidate batch through the
    // closed forms before any simulation (no-op at ModelSide::Off).
    let cands = model_seed(sc, kind, tp, cands, cfg.model, cfg.prune_margin);
    let (wi, _, e1) = race(
        sc,
        kind,
        tp,
        &cands,
        seeds,
        caches,
        cap,
        cfg.tolerance,
        log.as_deref_mut(),
    );
    // Refine around the coarse winner; the winner itself stays in the race
    // so refinement can only improve on it.
    let rcands = refine_grid(cands[wi], ratio, lo, hi, cfg.refine);
    let rcands = model_seed(sc, kind, tp, rcands, cfg.model, cfg.prune_margin);
    let (ri, rw, e2) = race(
        sc,
        kind,
        tp,
        &rcands,
        seeds,
        caches,
        cap,
        cfg.tolerance,
        log,
    );
    BestPeriod { tr: rcands[ri], waste: rw, evals: e1 + e2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;
    use crate::strategy::registry;

    fn sc() -> Scenario {
        Scenario {
            platform: Platform { mu: 30_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 2e6,
        }
    }

    #[test]
    fn best_period_no_worse_than_formula() {
        let s = sc();
        let seeds: Vec<u64> = (0..8).collect();
        for name in ["RFO", "Instant", "NoCkptI"] {
            let strat = registry::get(name).unwrap();
            let pol = strat.policy(&s);
            let w_formula =
                mean_waste(&s, pol.kind, pol.tr, pol.tp, &seeds);
            let bp = search_exhaustive(&s, pol.kind, pol.tp, &seeds, 24, 8);
            assert!(
                bp.waste <= w_formula + 1e-9,
                "{name}: search {} vs formula {}",
                bp.waste,
                w_formula
            );
        }
    }

    #[test]
    fn adaptive_search_within_tolerance_of_exhaustive() {
        // The paired guarantee of the racing sweep: its winner, scored on
        // the FULL seed set, is within the configured tolerance of the
        // exhaustive winner (scored on the same seeds, same traces).
        let s = sc();
        let seeds: Vec<u64> = (0..8).collect();
        let tol = SearchConfig::adaptive(16, 6).tolerance;
        for kind in [PolicyKind::IgnorePredictions, PolicyKind::NoCkpt] {
            let exact = search_exhaustive(&s, kind, 700.0, &seeds, 16, 6);
            let fast = search(&s, kind, 700.0, &seeds, 16, 6);
            let w_fast = mean_waste(&s, kind, fast.tr, 700.0, &seeds);
            assert!(
                w_fast <= exact.waste + 2.0 * tol,
                "{kind:?}: adaptive {} (tr {}) vs exhaustive {} (tr {})",
                w_fast,
                fast.tr,
                exact.waste,
                exact.tr
            );
        }
    }

    #[test]
    fn adaptive_degenerates_to_full_sweep_on_two_seeds() {
        // With n = 2 the race's first stage already covers every seed, so
        // adaptive and exhaustive agree exactly on the winner.
        let s = sc();
        let seeds: Vec<u64> = (0..2).collect();
        let a = search(&s, PolicyKind::NoCkpt, 700.0, &seeds, 12, 4);
        let b = search_exhaustive(&s, PolicyKind::NoCkpt, 700.0, &seeds, 12, 4);
        assert_eq!(a.tr, b.tr);
        assert!((a.waste - b.waste).abs() < 1e-12);
    }

    #[test]
    fn search_counts_evals() {
        let s = sc();
        let seeds: Vec<u64> = (0..2).collect();
        let bp = search_exhaustive(&s, PolicyKind::IgnorePredictions, 700.0, &seeds, 10, 4);
        assert_eq!(bp.evals, ((10 + 1 + 4) * 2) as u64);
        assert!(bp.tr > s.platform.c);
    }

    #[test]
    fn model_seed_is_identity_when_off_or_no_closed_form() {
        let s = sc();
        let cands = vec![5000.0, 700.0, 20_000.0];
        assert_eq!(
            model_seed(&s, PolicyKind::NoCkpt, 700.0, cands.clone(), ModelSide::Off, 0.25),
            cands
        );
        // QTrust has no grid column: the model cannot rank it.
        assert_eq!(
            model_seed(
                &s,
                PolicyKind::QTrust { q: 0.5 },
                700.0,
                cands.clone(),
                ModelSide::Batched,
                0.25
            ),
            cands
        );
    }

    #[test]
    fn model_seed_ranks_prunes_and_keeps_inapplicable() {
        let s = sc();
        // 500 is below C (inapplicable), the rest applicable with Q0 waste
        // increasing away from the optimum; a tight margin prunes the
        // far-off 40000 candidate (applicable, ~0.69 waste vs ~0.21 at the
        // best) but must keep the inapplicable 500.
        let cands = vec![40_000.0, 5000.0, 500.0, 8000.0];
        let out = model_seed(
            &s,
            PolicyKind::IgnorePredictions,
            700.0,
            cands,
            ModelSide::Batched,
            0.05,
        );
        assert_eq!(out, vec![5000.0, 8000.0, 500.0]);
        // Batched and scalar sides agree exactly (bit-identical model).
        let again = model_seed(
            &s,
            PolicyKind::IgnorePredictions,
            700.0,
            vec![40_000.0, 5000.0, 500.0, 8000.0],
            ModelSide::Scalar,
            0.05,
        );
        assert_eq!(out, again);
    }

    #[test]
    fn search_dedups_reference_candidate() {
        // With a job smaller than the RFO period, the reference candidate
        // clamps onto `hi` — the last grid point — and must be swept only
        // once: exactly (coarse + refine) × seeds evals, not +1.
        let mut s = sc();
        s.job_size = 5000.0;
        let seeds: Vec<u64> = (0..2).collect();
        let bp = search_exhaustive(&s, PolicyKind::IgnorePredictions, 700.0, &seeds, 10, 4);
        assert_eq!(bp.evals, ((10 + 4) * 2) as u64);
        assert!(bp.tr > s.platform.c);
    }
}
