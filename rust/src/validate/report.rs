//! Conformance reporting: per-strategy summaries, a paper-style terminal
//! table, failing-cell detail lines, and the machine-readable
//! `CONFORMANCE.json` artifact CI uploads.

use std::collections::BTreeMap;
use std::path::Path;

use crate::jsonio::Value;
use crate::validate::{CellReport, Verdict};

/// Aggregated conformance of one strategy across a sweep.
#[derive(Clone, Debug, Default)]
pub struct StrategySummary {
    pub strategy: String,
    pub cells: usize,
    pub pass: usize,
    pub fail: usize,
    pub inapplicable: usize,
    /// Max / mean |sim − model| over the compared (pass + fail) cells.
    pub max_deviation: f64,
    pub mean_deviation: f64,
    /// Max relative deviation |sim − model| / model over compared cells.
    pub max_rel_deviation: f64,
    /// Inapplicability reasons seen, with counts (label → count).
    pub reasons: BTreeMap<&'static str, usize>,
}

impl StrategySummary {
    /// Pass rate over the compared (applicable) cells; NaN when none.
    pub fn pass_rate(&self) -> f64 {
        self.pass as f64 / (self.pass + self.fail) as f64
    }
}

/// Summarize per strategy, in first-seen order (= registry order for grid
/// sweeps, since the strategy axis is innermost-but-one).
pub fn summarize(reports: &[CellReport]) -> Vec<StrategySummary> {
    let mut order: Vec<String> = Vec::new();
    let mut by_name: BTreeMap<String, StrategySummary> = BTreeMap::new();
    for r in reports {
        let s = by_name.entry(r.strategy.clone()).or_insert_with(|| {
            order.push(r.strategy.clone());
            StrategySummary { strategy: r.strategy.clone(), ..Default::default() }
        });
        s.cells += 1;
        match r.verdict {
            Verdict::Pass | Verdict::Fail => {
                if matches!(r.verdict, Verdict::Pass) {
                    s.pass += 1;
                } else {
                    s.fail += 1;
                }
                // Streaming mean over compared cells.
                let n = (s.pass + s.fail) as f64;
                s.mean_deviation += (r.deviation - s.mean_deviation) / n;
                s.max_deviation = s.max_deviation.max(r.deviation);
                s.max_rel_deviation = s.max_rel_deviation.max(r.rel_deviation());
            }
            Verdict::Inapplicable(reason) => {
                s.inapplicable += 1;
                *s.reasons.entry(reason.label()).or_insert(0) += 1;
            }
        }
    }
    order.into_iter().map(|n| by_name.remove(&n).expect("present")).collect()
}

/// Paper-style per-strategy conformance table.
pub fn render_table(summaries: &[StrategySummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:>6} {:>6} {:>6} {:>7} {:>10} {:>10} {:>9}\n",
        "strategy", "cells", "pass", "fail", "inappl", "max|dev|", "mean|dev|", "pass rate"
    ));
    for s in summaries {
        let compared = s.pass + s.fail;
        out.push_str(&format!(
            "{:<22} {:>6} {:>6} {:>6} {:>7} {:>10} {:>10} {:>9}\n",
            s.strategy,
            s.cells,
            s.pass,
            s.fail,
            s.inapplicable,
            if compared > 0 { format!("{:.4}", s.max_deviation) } else { "-".into() },
            if compared > 0 { format!("{:.4}", s.mean_deviation) } else { "-".into() },
            if compared > 0 {
                format!("{:.0}%", 100.0 * s.pass_rate())
            } else {
                "-".into()
            },
        ));
    }
    out
}

/// Detail lines for every failing cell (empty string when none fail).
pub fn render_failures(reports: &[CellReport]) -> String {
    let mut out = String::new();
    for r in reports {
        if matches!(r.verdict, Verdict::Fail) {
            out.push_str(&format!(
                "FAIL {}: sim {:.4} ±{:.4} vs model {:.4} — |dev| {:.4} > tol {:.4}\n",
                r.key, r.sim_mean, r.sim_ci95, r.model, r.deviation, r.tolerance
            ));
        }
    }
    out
}

fn num_or_null(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

/// Build the `CONFORMANCE.json` document.
pub fn conformance_json(reports: &[CellReport], summaries: &[StrategySummary]) -> Value {
    let (mut pass, mut fail, mut inapplicable) = (0usize, 0usize, 0usize);
    for r in reports {
        match r.verdict {
            Verdict::Pass => pass += 1,
            Verdict::Fail => fail += 1,
            Verdict::Inapplicable(_) => inapplicable += 1,
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("schema".into(), Value::Str("ckptwin-conformance/1".into()));
    let mut summary = BTreeMap::new();
    summary.insert("cells".into(), Value::Num(reports.len() as f64));
    summary.insert("pass".into(), Value::Num(pass as f64));
    summary.insert("fail".into(), Value::Num(fail as f64));
    summary.insert("inapplicable".into(), Value::Num(inapplicable as f64));
    doc.insert("summary".into(), Value::Obj(summary));
    doc.insert(
        "strategies".into(),
        Value::Arr(
            summaries
                .iter()
                .map(|s| {
                    let mut o = BTreeMap::new();
                    o.insert("name".into(), Value::Str(s.strategy.clone()));
                    o.insert("cells".into(), Value::Num(s.cells as f64));
                    o.insert("pass".into(), Value::Num(s.pass as f64));
                    o.insert("fail".into(), Value::Num(s.fail as f64));
                    o.insert(
                        "inapplicable".into(),
                        Value::Num(s.inapplicable as f64),
                    );
                    o.insert("max_deviation".into(), num_or_null(s.max_deviation));
                    o.insert("mean_deviation".into(), num_or_null(s.mean_deviation));
                    o.insert(
                        "max_rel_deviation".into(),
                        num_or_null(s.max_rel_deviation),
                    );
                    o.insert("pass_rate".into(), num_or_null(s.pass_rate()));
                    let reasons = s
                        .reasons
                        .iter()
                        .map(|(k, v)| ((*k).to_string(), Value::Num(*v as f64)))
                        .collect();
                    o.insert("reasons".into(), Value::Obj(reasons));
                    Value::Obj(o)
                })
                .collect(),
        ),
    );
    doc.insert(
        "cells".into(),
        Value::Arr(
            reports
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("key".into(), Value::Str(r.key.clone()));
                    o.insert("hash".into(), Value::Str(format!("{:016x}", r.hash)));
                    o.insert("strategy".into(), Value::Str(r.strategy.clone()));
                    o.insert("law".into(), Value::Str(r.law.clone()));
                    o.insert("multiplier".into(), Value::Num(r.multiplier));
                    o.insert("tr".into(), num_or_null(r.tr));
                    o.insert("instances".into(), Value::Num(r.instances as f64));
                    o.insert("sim_mean".into(), num_or_null(r.sim_mean));
                    o.insert("sim_ci95".into(), num_or_null(r.sim_ci95));
                    o.insert("model".into(), num_or_null(r.model));
                    o.insert("deviation".into(), num_or_null(r.deviation));
                    o.insert("tolerance".into(), num_or_null(r.tolerance));
                    o.insert("verdict".into(), Value::Str(r.verdict.label().into()));
                    if let Verdict::Inapplicable(reason) = r.verdict {
                        o.insert("reason".into(), Value::Str(reason.label().into()));
                    }
                    Value::Obj(o)
                })
                .collect(),
        ),
    );
    Value::Obj(doc)
}

/// Write `CONFORMANCE.json` (creating parent directories); returns the
/// serialized length in bytes.
pub fn write_json(
    path: &Path,
    reports: &[CellReport],
    summaries: &[StrategySummary],
) -> std::io::Result<usize> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let text = crate::jsonio::to_string(&conformance_json(reports, summaries));
    std::fs::write(path, &text)?;
    Ok(text.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::validate::Inapplicable;

    fn rep(strategy: &str, verdict: Verdict, dev: f64) -> CellReport {
        CellReport {
            hash: 42,
            key: format!("k-{strategy}-{dev}"),
            strategy: strategy.into(),
            law: "exponential".into(),
            multiplier: 1.0,
            tr: 8000.0,
            instances: if matches!(verdict, Verdict::Inapplicable(_)) { 0 } else { 10 },
            sim_mean: 0.15,
            sim_ci95: 0.004,
            model: 0.148,
            deviation: dev,
            tolerance: 0.05,
            verdict,
        }
    }

    #[test]
    fn summarize_groups_and_aggregates() {
        let reports = vec![
            rep("RFO", Verdict::Pass, 0.010),
            rep("RFO", Verdict::Pass, 0.030),
            rep("RFO", Verdict::Fail, 0.080),
            rep("QTrust(q=0.5)", Verdict::Inapplicable(Inapplicable::NoClosedForm), f64::NAN),
        ];
        let sums = summarize(&reports);
        assert_eq!(sums.len(), 2);
        assert_eq!(sums[0].strategy, "RFO");
        assert_eq!((sums[0].pass, sums[0].fail, sums[0].inapplicable), (2, 1, 0));
        assert!((sums[0].max_deviation - 0.08).abs() < 1e-12);
        assert!((sums[0].mean_deviation - 0.04).abs() < 1e-12);
        assert!((sums[0].pass_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(sums[1].strategy, "QTrust(q=0.5)");
        assert_eq!(sums[1].inapplicable, 1);
        assert_eq!(sums[1].reasons.get("no_closed_form"), Some(&1));
        assert!(sums[1].pass_rate().is_nan());
    }

    #[test]
    fn renders_are_well_formed() {
        let reports = vec![
            rep("RFO", Verdict::Pass, 0.01),
            rep("NoCkptI", Verdict::Fail, 0.09),
        ];
        let table = render_table(&summarize(&reports));
        assert!(table.contains("RFO") && table.contains("NoCkptI"));
        assert!(table.contains("100%"));
        let fails = render_failures(&reports);
        assert!(fails.starts_with("FAIL k-NoCkptI"));
        assert_eq!(render_failures(&reports[..1]), "");
    }

    #[test]
    fn json_document_is_valid_and_complete() {
        let reports = vec![
            rep("RFO", Verdict::Pass, 0.01),
            rep("ExactPred", Verdict::Inapplicable(Inapplicable::NoClosedForm), f64::NAN),
        ];
        let doc = conformance_json(&reports, &summarize(&reports));
        let text = crate::jsonio::to_string(&doc);
        let back = crate::jsonio::parse(&text).expect("valid JSON despite NaN fields");
        assert_eq!(
            back.get("summary").unwrap().get("pass").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            back.get("summary").unwrap().get("inapplicable").unwrap().as_usize(),
            Some(1)
        );
        let cells = match back.get("cells").unwrap() {
            Value::Arr(v) => v,
            _ => panic!("cells must be an array"),
        };
        assert_eq!(cells.len(), 2);
        assert_eq!(
            cells[1].get("reason").and_then(Value::as_str),
            Some("no_closed_form")
        );
        assert_eq!(cells[1].get("model"), Some(&Value::Null));
    }

    #[test]
    fn write_json_creates_dirs() {
        let dir = std::env::temp_dir()
            .join(format!("ckptwin-conf-json-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/CONFORMANCE.json");
        let reports = vec![rep("RFO", Verdict::Pass, 0.01)];
        let n = write_json(&path, &reports, &summarize(&reports)).unwrap();
        assert!(n > 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::jsonio::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
