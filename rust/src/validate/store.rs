//! Resumable JSONL conformance store: one line per verdicted cell, keyed
//! by the stable [`crate::validate::ValCell`] hash.
//!
//! Same crash-consistency contract as the campaign result store
//! (`campaign::store`): append + flush per cell, torn final line detected
//! and repaired on reopen, re-appended hashes are last-wins.  A conformance
//! sweep interrupted mid-run resumes from its store and re-verdicts only
//! the missing cells.
//!
//! Non-finite numbers (an inapplicable cell has no model value, no
//! deviation) are serialized as JSON `null` — `NaN` is not JSON — and come
//! back as `f64::NAN`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::jsonio::{self, JsonlAppender, RecordCheck, Value};
use crate::resilience::failpoint::{self, Site};
use crate::resilience::retry::Backoff;

/// One persisted conformance verdict (one JSONL line).
#[derive(Clone, Debug, PartialEq)]
pub struct ConformanceRecord {
    /// Stable conformance-cell hash ([`crate::validate::ValCell::hash`]).
    pub hash: u64,
    /// Canonical cell key (provenance; greppable).
    pub key: String,
    /// Strategy display name (`StrategyId` canonical form).
    pub strategy: String,
    /// Fault-law label.
    pub law: String,
    /// Off-optimal period multiplier (1.0 = at the analytic optimum).
    pub multiplier: f64,
    /// Regular period probed (NaN when never instantiated).
    pub tr: f64,
    /// Simulated instances (0 for inapplicable cells).
    pub instances: u64,
    pub sim_mean: f64,
    pub sim_ci95: f64,
    /// Closed-form waste at the probed period (NaN when inapplicable).
    pub model: f64,
    /// |sim − model| (NaN when inapplicable).
    pub deviation: f64,
    /// The declared tolerance for this cell (NaN when inapplicable).
    pub tolerance: f64,
    /// `"pass"`, `"fail"`, or `"inapplicable"`.
    pub verdict: String,
    /// Inapplicability label (empty for pass/fail).
    pub reason: String,
}

fn num_or_null(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

impl ConformanceRecord {
    fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("hash".into(), Value::Str(format!("{:016x}", self.hash)));
        obj.insert("key".into(), Value::Str(self.key.clone()));
        obj.insert("strategy".into(), Value::Str(self.strategy.clone()));
        obj.insert("law".into(), Value::Str(self.law.clone()));
        obj.insert("multiplier".into(), Value::Num(self.multiplier));
        obj.insert("tr".into(), num_or_null(self.tr));
        obj.insert("instances".into(), Value::Num(self.instances as f64));
        obj.insert("sim_mean".into(), num_or_null(self.sim_mean));
        obj.insert("sim_ci95".into(), num_or_null(self.sim_ci95));
        obj.insert("model".into(), num_or_null(self.model));
        obj.insert("deviation".into(), num_or_null(self.deviation));
        obj.insert("tolerance".into(), num_or_null(self.tolerance));
        obj.insert("verdict".into(), Value::Str(self.verdict.clone()));
        obj.insert("reason".into(), Value::Str(self.reason.clone()));
        // CRC-sealed like the campaign store: interior corruption is
        // quarantined on reload instead of silently trusted.
        jsonio::seal_record(obj)
    }

    fn from_json(line: &str) -> Option<ConformanceRecord> {
        ConformanceRecord::from_value(&jsonio::parse(line).ok()?)
    }

    fn from_value(v: &Value) -> Option<ConformanceRecord> {
        let opt_num =
            |k: &str| v.get(k).and_then(Value::as_f64).unwrap_or(f64::NAN);
        let text = |k: &str| Some(v.get(k)?.as_str()?.to_string());
        Some(ConformanceRecord {
            hash: u64::from_str_radix(v.get("hash")?.as_str()?, 16).ok()?,
            key: text("key")?,
            strategy: text("strategy")?,
            law: text("law")?,
            multiplier: v.get("multiplier")?.as_f64()?,
            tr: opt_num("tr"),
            instances: v.get("instances")?.as_f64()? as u64,
            sim_mean: opt_num("sim_mean"),
            sim_ci95: opt_num("sim_ci95"),
            model: opt_num("model"),
            deviation: opt_num("deviation"),
            tolerance: opt_num("tolerance"),
            verdict: text("verdict")?,
            reason: text("reason")?,
        })
    }
}

/// Append-only JSONL store with an in-memory index by cell hash.
pub struct ConformanceStore {
    path: PathBuf,
    file: JsonlAppender,
    records: BTreeMap<u64, ConformanceRecord>,
    /// Unparseable lines skipped on open (a torn tail from an interrupt).
    pub skipped_lines: usize,
    /// Lines that parsed but failed their CRC seal (interior corruption);
    /// the damaged cells are absent from the index and get re-verdicted.
    pub quarantined_lines: usize,
}

impl ConformanceStore {
    /// Open for resuming: parse existing records (creating the file if
    /// missing) and append new ones after them.
    pub fn open(path: impl AsRef<Path>) -> Result<ConformanceStore> {
        ConformanceStore::open_inner(path.as_ref(), false)
    }

    /// Open for a fresh sweep: truncate any existing store.
    pub fn create(path: impl AsRef<Path>) -> Result<ConformanceStore> {
        ConformanceStore::open_inner(path.as_ref(), true)
    }

    fn open_inner(path: &Path, truncate: bool) -> Result<ConformanceStore> {
        // Replay existing lines last-wins; the appender repairs a torn
        // tail and counts unparseable lines (see `jsonio::JsonlAppender`).
        // CRC-seal failures are quarantined, not treated as torn.
        let mut records = BTreeMap::new();
        let mut quarantined_lines = 0usize;
        let file = JsonlAppender::open(path, truncate, |line| {
            let Ok(v) = jsonio::parse(line) else { return false };
            if jsonio::check_record(&v) == RecordCheck::Corrupt {
                quarantined_lines += 1;
                return true;
            }
            match ConformanceRecord::from_value(&v) {
                Some(rec) => {
                    records.insert(rec.hash, rec);
                    true
                }
                None => false,
            }
        })?;
        let skipped_lines = file.skipped_lines;
        Ok(ConformanceStore {
            path: path.to_path_buf(),
            file,
            records,
            skipped_lines,
            quarantined_lines,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.records.contains_key(&hash)
    }

    pub fn get(&self, hash: u64) -> Option<&ConformanceRecord> {
        self.records.get(&hash)
    }

    /// All records, ordered by hash.
    pub fn records(&self) -> impl Iterator<Item = &ConformanceRecord> {
        self.records.values()
    }

    /// Append one verdicted cell and flush it to disk immediately.  A
    /// record whose hash is already present supersedes the earlier line
    /// (last-wins, both in memory and on reload).
    pub fn append(&mut self, rec: &ConformanceRecord) -> Result<()> {
        let line = rec.to_json();
        let file = &mut self.file;
        // Same transient-fault retry policy as the campaign store.
        Backoff::default().run(|_attempt| {
            if let Some(inj) = failpoint::check(Site::StoreAppend) {
                inj.trigger()?;
            }
            file.append_line(&line)
        })?;
        self.records.insert(rec.hash, rec.clone());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "ckptwin-conformance-{tag}-{}.jsonl",
            std::process::id()
        ))
    }

    fn rec(hash: u64, verdict: &str) -> ConformanceRecord {
        ConformanceRecord {
            hash,
            key: format!("cell-{hash}"),
            strategy: "NoCkptI".into(),
            law: "exponential".into(),
            multiplier: 1.0,
            tr: 8210.0,
            instances: 40,
            sim_mean: 0.1312,
            sim_ci95: 0.0041,
            model: 0.1278,
            deviation: 0.0034,
            tolerance: 0.041,
            verdict: verdict.into(),
            reason: String::new(),
        }
    }

    #[test]
    fn roundtrip_and_resume() {
        let path = tmp("rt");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ConformanceStore::create(&path).unwrap();
            s.append(&rec(3, "pass")).unwrap();
            s.append(&rec(u64::MAX - 1, "fail")).unwrap();
        }
        let s = ConformanceStore::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(3).unwrap(), &rec(3, "pass"));
        assert_eq!(s.get(u64::MAX - 1).unwrap().verdict, "fail");
        assert_eq!(s.skipped_lines, 0);
    }

    #[test]
    fn non_finite_fields_serialize_as_null_and_read_back_as_nan() {
        let path = tmp("nan");
        let _ = std::fs::remove_file(&path);
        let mut inap = rec(9, "inapplicable");
        inap.instances = 0;
        inap.tr = f64::NAN;
        inap.sim_mean = f64::NAN;
        inap.sim_ci95 = f64::NAN;
        inap.model = f64::NAN;
        inap.deviation = f64::NAN;
        inap.tolerance = f64::NAN;
        inap.reason = "no_closed_form".into();
        {
            let mut s = ConformanceStore::create(&path).unwrap();
            s.append(&inap).unwrap();
        }
        // The line must be valid JSON (no bare NaN tokens).
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(jsonio::parse(text.trim()).is_ok(), "{text}");
        assert!(text.contains("\"model\":null"), "{text}");
        let s = ConformanceStore::open(&path).unwrap();
        let back = s.get(9).unwrap();
        assert!(back.model.is_nan() && back.deviation.is_nan());
        assert_eq!(back.reason, "no_closed_form");
        assert_eq!(back.instances, 0);
    }

    #[test]
    fn torn_tail_is_skipped_and_repaired() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ConformanceStore::create(&path).unwrap();
            s.append(&rec(21, "pass")).unwrap();
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"hash\":\"00");
        std::fs::write(&path, text).unwrap();
        let mut s = ConformanceStore::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.skipped_lines, 1);
        s.append(&rec(22, "pass")).unwrap();
        drop(s);
        let s = ConformanceStore::open(&path).unwrap();
        assert!(s.contains(21) && s.contains(22));
    }

    #[test]
    fn reappend_supersedes_last_wins() {
        let path = tmp("supersede");
        let _ = std::fs::remove_file(&path);
        let mut s = ConformanceStore::create(&path).unwrap();
        s.append(&rec(5, "fail")).unwrap();
        let mut upgraded = rec(5, "pass");
        upgraded.instances = 100;
        s.append(&upgraded).unwrap();
        assert_eq!(s.len(), 1);
        drop(s);
        let s = ConformanceStore::open(&path).unwrap();
        assert_eq!(s.get(5).unwrap().verdict, "pass");
        assert_eq!(s.get(5).unwrap().instances, 100);
    }
}
