//! Conformance subsystem: model-vs-simulation validation sweeps with
//! statistical oracles.
//!
//! The paper's headline claim is that the analytic waste model is
//! "nicely corroborated by a comprehensive set of simulations" (§5).  This
//! module turns that corroboration into an executable, CI-gated artifact:
//! every registered strategy × fault-law × predictor cell of a campaign
//! grid becomes a *checked* scenario, not just a simulated one.
//!
//! Dataflow (see DESIGN.md §Validation):
//!
//! ```text
//!  Grid × period multipliers ──expand_cells──▶ [ValCell]
//!    │ per cell (work-stealing scheduler, one TracePool per worker):
//!    ├─ classify (validate::domain): closed form + validity domain
//!    │     Inapplicable ⇒ verdict now, no simulation
//!    ├─ simulate `instances` paired seeds (memoized trace replay)
//!    │     → Welford waste mean/CI
//!    └─ verdict: |sim − model| vs the declared tolerance
//!  [CellReport] ──append──▶ ConformanceStore (resumable JSONL)
//!            └──summarize──▶ per-strategy table + CONFORMANCE.json
//! ```
//!
//! Cells are classified against each formula's validity domain *before*
//! comparison, so out-of-domain cells (no closed form, `p = 0`, saturated
//! first-order values, overlap-dominated windows, …) report as
//! [`Verdict::Inapplicable`] with a named reason rather than as failures —
//! the acceptance bar is **zero unexplained failures**, not zero
//! classifications.
//!
//! The sweep runs each cell's instances on the same paired seed streams as
//! the campaign engine ([`Cell::instance_seed`]) and replays memoized
//! traces through a per-worker [`TracePool`], so strategy variants and
//! period multipliers of one scenario share trace generation.
//!
//! `ckptwin validate` drives this from the CLI; `tests/conformance.rs`
//! gates a small deterministic grid in tier-1.

pub mod domain;
pub mod report;
pub mod store;

pub use domain::{Inapplicable, TolerancePolicy};
pub use report::{
    render_failures, render_table, summarize, write_json, StrategySummary,
};
pub use store::{ConformanceRecord, ConformanceStore};

use std::sync::Mutex;

use anyhow::Result;

use crate::campaign::grid::fnv1a64;
use crate::campaign::{scheduler, Cell, Grid, TracePool};
use crate::config::{FaultModel, Scenario};
use crate::obs::SpanTimer;
use crate::sim::distribution::Law;
use crate::sim::engine::simulate_from;
use crate::stats::Welford;
use crate::strategy::registry;

/// Sweep throughput telemetry — the same shape as a campaign's (cells,
/// instances, events, wall-clock, trace-pool efficacy).
pub type SweepMetrics = crate::campaign::CampaignMetrics;

/// One conformance cell: a campaign [`Cell`] probed at `multiplier ×` the
/// strategy's analytic period, under an explicit fault-trace model.
#[derive(Clone, Debug)]
pub struct ValCell {
    pub cell: Cell,
    /// Off-optimal period multiplier (1.0 = at the analytic optimum).
    pub multiplier: f64,
    /// Fault-trace model the sweep simulates under.  Conformance defaults
    /// to [`FaultModel::PlatformRenewal`]: the steady-state regime the
    /// closed forms assume (the per-processor fresh-start transient is a
    /// known divergence, classified by `domain::classify`).
    pub fault_model: FaultModel,
    /// Stable identity hash (keys the conformance store).
    pub hash: u64,
    /// Trace-memo key: the scenario + fault model, minus strategy and
    /// multiplier — everything that shapes the event trace.
    pub pool_hash: u64,
}

fn fault_model_label(fm: FaultModel) -> String {
    match fm {
        FaultModel::PlatformRenewal => "platform".to_string(),
        FaultModel::PerProcessor { n } => format!("perproc{n}"),
        FaultModel::PerProcessorStationary { n } => format!("stationary{n}"),
    }
}

impl ValCell {
    pub fn new(cell: Cell, multiplier: f64, fault_model: FaultModel) -> ValCell {
        assert!(multiplier.is_finite() && multiplier > 0.0, "multiplier {multiplier}");
        let mut vc = ValCell { cell, multiplier, fault_model, hash: 0, pool_hash: 0 };
        vc.hash = fnv1a64(vc.key().as_bytes());
        vc.pool_hash = fnv1a64(
            format!("{};fm={}", vc.cell.scenario_key(), fault_model_label(fault_model))
                .as_bytes(),
        );
        vc
    }

    /// Canonical, human-greppable identity: the campaign cell key plus the
    /// conformance axes (fault model, period multiplier).
    pub fn key(&self) -> String {
        format!(
            "{};fm={};m={}",
            self.cell.key(),
            fault_model_label(self.fault_model),
            self.multiplier,
        )
    }

    /// The concrete scenario this cell simulates.
    pub fn scenario(&self) -> Scenario {
        let mut sc = self.cell.scenario();
        sc.fault_model = self.fault_model;
        sc
    }
}

/// Expand a campaign grid × period multipliers into conformance cells
/// (deterministic order: grid expansion order, multipliers innermost),
/// under the steady-state platform-renewal fault model.
pub fn expand_cells(grid: &Grid, multipliers: &[f64]) -> Vec<ValCell> {
    let mut out = Vec::with_capacity(grid.len() * multipliers.len());
    for cell in grid.expand() {
        for &m in multipliers {
            out.push(ValCell::new(cell.clone(), m, FaultModel::PlatformRenewal));
        }
    }
    out
}

/// The default conformance grid: both predictors, the paper's three fault
/// laws, two platform sizes and C_p ratios, three window sizes, every
/// registered strategy except the BestPeriod twins (their period rule is
/// itself simulation-derived; pass them explicitly to check Eq. (3)/(10)…
/// at a *searched* period).  `scale = 0.25` keeps ≈ 20 faults per
/// instance — enough steady state for the asymptotic model, cheap enough
/// for a full sweep in seconds.
pub fn default_grid() -> Grid {
    Grid {
        procs: vec![1 << 16, 1 << 17],
        cp_ratios: vec![1.0, 0.1],
        fault_laws: vec![
            Law::Exponential,
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.5 },
        ],
        uniform_false_preds: false,
        predictors: crate::predictor::registry::paper_pair(),
        windows: vec![300.0, 600.0, 1200.0],
        strategies: registry::all_defaults()
            .into_iter()
            .filter(|s| !s.name().starts_with("BestPeriod"))
            .collect(),
        scale: 0.25,
        platform_shards: vec![1],
    }
}

/// Default off-optimal period multipliers for [`default_grid`].
pub const DEFAULT_MULTIPLIERS: [f64; 3] = [0.75, 1.0, 1.5];

/// A cheap deterministic grid for CI smoke runs and the tier-1 gate.
pub fn smoke_grid() -> Grid {
    Grid {
        procs: vec![1 << 16],
        cp_ratios: vec![1.0, 0.1],
        fault_laws: vec![Law::Exponential, Law::Weibull { shape: 0.7 }],
        uniform_false_preds: false,
        predictors: vec![crate::predictor::registry::get("a")
            .expect("registered")],
        windows: vec![600.0, 1200.0],
        strategies: registry::all_defaults()
            .into_iter()
            .filter(|s| !s.name().starts_with("BestPeriod"))
            .collect(),
        scale: 0.2,
        platform_shards: vec![1],
    }
}

/// Execution knobs for a conformance sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    /// Random instances per applicable cell (paired seeds, like the
    /// campaign engine).
    pub instances: usize,
    /// Worker threads; 0 = all available cores.
    pub threads: usize,
    /// The tolerance policy (see [`domain::TolerancePolicy`]).
    pub tolerance: TolerancePolicy,
    /// Fetch the model side through [`crate::model::batch`]: cells sharing
    /// a scenario × strategy (the period multipliers) are classified as
    /// one batched grid, with the policy instantiated once per group
    /// instead of once per cell.  Byte-identical verdicts either way
    /// (`classify_batch` ≡ `classify` element-wise — the census pins hold
    /// on both paths); `false` is the scalar escape hatch.
    pub batch_model: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            instances: 100,
            threads: 0,
            tolerance: TolerancePolicy::default(),
            batch_model: true,
        }
    }
}

/// One cell's precomputed model side (the batched pre-pass): the probed
/// period, the proactive period, and the classification — exactly what
/// the scalar path would have derived inside [`evaluate_cell`].
#[derive(Clone, Copy, Debug)]
struct ModelPre {
    tr: f64,
    tp: f64,
    model: Result<f64, Inapplicable>,
}

/// The batched model pre-pass: group the pending cells by campaign cell ×
/// fault model (the axes that fix scenario and strategy — multipliers of
/// one cell differ only in period), instantiate each group's policy once,
/// and classify the whole period batch through [`domain::classify_batch`].
/// Sharded over the scheduler: BestPeriod-twin groups pay their search
/// once per *group* here instead of once per multiplier in the workers.
/// Cells without a closed form get no entry (the scalar early-return in
/// [`evaluate_cell`] handles them without instantiating a policy).
fn precompute_models(
    cells: &[ValCell],
    pending: &[usize],
    opt: &SweepOptions,
) -> Vec<Option<ModelPre>> {
    use crate::model::batch::BatchEvaluator;
    let mut groups: std::collections::BTreeMap<(u64, String), Vec<usize>> =
        std::collections::BTreeMap::new();
    for (pi, &ci) in pending.iter().enumerate() {
        let vc = &cells[ci];
        if vc.cell.strategy.kind().grid_strategy().is_none() {
            continue;
        }
        groups
            .entry((vc.cell.hash, fault_model_label(vc.fault_model)))
            .or_default()
            .push(pi);
    }
    let members: Vec<&Vec<usize>> = groups.values().collect();
    let computed = scheduler::run_units(members.len(), opt.threads, |g| {
        let group = members[g];
        let vc0 = &cells[pending[group[0]]];
        let sc = vc0.scenario();
        let kind = vc0.cell.strategy.kind();
        let pol = vc0.cell.strategy.policy(&sc);
        let trs: Vec<f64> = group
            .iter()
            .map(|&pi| pol.tr * cells[pending[pi]].multiplier)
            .collect();
        let mut ev = BatchEvaluator::new();
        let models =
            domain::classify_batch(&sc, kind, &trs, pol.tp, &opt.tolerance, &mut ev);
        group
            .iter()
            .zip(trs)
            .zip(models)
            .map(|((&pi, tr), model)| (pi, ModelPre { tr, tp: pol.tp, model }))
            .collect::<Vec<_>>()
    });
    let mut out: Vec<Option<ModelPre>> = vec![None; pending.len()];
    for unit in computed {
        for (pi, mp) in unit {
            out[pi] = Some(mp);
        }
    }
    out
}

/// The structured verdict of one conformance cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Verdict {
    /// |sim − model| within the declared tolerance.
    Pass,
    /// Exceeded the tolerance: a genuine model/simulation disagreement.
    Fail,
    /// No meaningful comparison at this cell (named reason).
    Inapplicable(Inapplicable),
}

impl Verdict {
    pub fn label(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Fail => "fail",
            Verdict::Inapplicable(_) => "inapplicable",
        }
    }
}

/// One verdicted conformance cell.
#[derive(Clone, Debug)]
pub struct CellReport {
    pub hash: u64,
    pub key: String,
    /// Strategy display name.
    pub strategy: String,
    /// Fault-law label.
    pub law: String,
    pub multiplier: f64,
    /// Regular period probed (NaN when never instantiated).
    pub tr: f64,
    /// Simulated instances (0 for inapplicable cells).
    pub instances: u64,
    pub sim_mean: f64,
    pub sim_ci95: f64,
    /// Closed-form waste at the probed period (NaN when inapplicable).
    pub model: f64,
    /// |sim − model| (NaN when inapplicable).
    pub deviation: f64,
    /// Declared tolerance (NaN when inapplicable).
    pub tolerance: f64,
    pub verdict: Verdict,
}

impl CellReport {
    /// Relative deviation |sim − model| / model (NaN when inapplicable).
    pub fn rel_deviation(&self) -> f64 {
        self.deviation / self.model
    }

    /// The persisted form of this report.
    pub fn record(&self) -> ConformanceRecord {
        ConformanceRecord {
            hash: self.hash,
            key: self.key.clone(),
            strategy: self.strategy.clone(),
            law: self.law.clone(),
            multiplier: self.multiplier,
            tr: self.tr,
            instances: self.instances,
            sim_mean: self.sim_mean,
            sim_ci95: self.sim_ci95,
            model: self.model,
            deviation: self.deviation,
            tolerance: self.tolerance,
            verdict: self.verdict.label().to_string(),
            reason: match self.verdict {
                Verdict::Inapplicable(r) => r.label().to_string(),
                _ => String::new(),
            },
        }
    }

    /// Rebuild a report from a stored record (resume path).  `None` when
    /// the record's verdict/reason vocabulary is unknown (a newer build).
    pub fn from_record(rec: &ConformanceRecord) -> Option<CellReport> {
        let verdict = match rec.verdict.as_str() {
            "pass" => Verdict::Pass,
            "fail" => Verdict::Fail,
            "inapplicable" => Verdict::Inapplicable(Inapplicable::parse(&rec.reason)?),
            _ => return None,
        };
        Some(CellReport {
            hash: rec.hash,
            key: rec.key.clone(),
            strategy: rec.strategy.clone(),
            law: rec.law.clone(),
            multiplier: rec.multiplier,
            tr: rec.tr,
            instances: rec.instances,
            sim_mean: rec.sim_mean,
            sim_ci95: rec.sim_ci95,
            model: rec.model,
            deviation: rec.deviation,
            tolerance: rec.tolerance,
            verdict,
        })
    }
}

/// Verdict one cell: classify, then (when applicable) simulate the paired
/// instances through the worker's trace pool and compare.  Also returns
/// (instances simulated, trace events consumed) for the sweep telemetry.
/// `pre` carries the batched pre-pass's model side when the sweep runs
/// with [`SweepOptions::batch_model`]; `None` falls back to the scalar
/// per-cell derivation (bit-identical results either way).
fn evaluate_cell(
    vc: &ValCell,
    opt: &SweepOptions,
    pool: &mut TracePool,
    pre: Option<&ModelPre>,
) -> (CellReport, u64, u64) {
    let sc = vc.scenario();
    let kind = vc.cell.strategy.kind();
    let base = CellReport {
        hash: vc.hash,
        key: vc.key(),
        strategy: vc.cell.strategy.to_string(),
        law: vc.cell.fault_law.label(),
        multiplier: vc.multiplier,
        tr: f64::NAN,
        instances: 0,
        sim_mean: f64::NAN,
        sim_ci95: f64::NAN,
        model: f64::NAN,
        deviation: f64::NAN,
        tolerance: f64::NAN,
        verdict: Verdict::Inapplicable(Inapplicable::NoClosedForm),
    };
    // No closed form ⇒ no comparison; skip policy instantiation entirely.
    // (ExactPred/WindowEndCkpt/QTrust land here.  The BestPeriod twins do
    // NOT: their *mode* maps to a paper formula, so they instantiate —
    // a brute-force search, paid per (cell, multiplier) — and are compared
    // to that formula at the searched period.)
    if kind.grid_strategy().is_none() {
        return (base, 0, 0);
    }
    let (tr, tp, model) = match pre {
        Some(p) => (p.tr, p.tp, p.model),
        None => {
            let pol = vc.cell.strategy.policy(&sc);
            let tr = pol.tr * vc.multiplier;
            (tr, pol.tp, domain::classify(&sc, kind, tr, pol.tp, &opt.tolerance))
        }
    };
    let model = match model {
        Err(reason) => {
            return (
                CellReport { tr, verdict: Verdict::Inapplicable(reason), ..base },
                0,
                0,
            )
        }
        Ok(m) => m,
    };
    let pol = crate::strategy::Policy { kind, tr, tp };
    let mut waste = Welford::new();
    let mut events: u64 = 0;
    for i in 0..opt.instances.max(1) {
        let seed = vc.cell.instance_seed(i as u64);
        let out =
            simulate_from(&sc, &pol, 1.0, seed, pool.replay(vc.pool_hash, &sc, seed));
        waste.push(out.waste());
        events += out.events;
    }
    let deviation = (waste.mean() - model).abs();
    let tolerance = domain::tolerance(&opt.tolerance, &sc, kind, tr, waste.ci95());
    let sims = waste.len() as u64;
    let rep = CellReport {
        tr,
        instances: sims,
        sim_mean: waste.mean(),
        sim_ci95: waste.ci95(),
        model,
        deviation,
        tolerance,
        verdict: if deviation <= tolerance { Verdict::Pass } else { Verdict::Fail },
        ..base
    };
    (rep, sims, events)
}

/// Is `vc` already satisfactorily verdicted in `store`?  Inapplicable
/// verdicts never need recomputation; pass/fail records are reusable when
/// they hold at least the requested instance count.
pub fn cell_complete(store: &ConformanceStore, vc: &ValCell, instances: usize) -> bool {
    store.get(vc.hash).is_some_and(|rec| {
        rec.verdict == "inapplicable" || rec.instances >= instances.max(1) as u64
    })
}

/// Execute a conformance sweep on the work-stealing scheduler.
///
/// Cells already verdicted in `store` (see [`cell_complete`]) and
/// duplicate-hash cells are skipped.  Each fresh verdict is appended (and
/// flushed) to the store the moment it lands, so an interrupted sweep
/// resumes.  Returns the freshly computed reports in (deduplicated) cell
/// order plus the number of skipped cells.
pub fn run_sweep(
    cells: &[ValCell],
    opt: &SweepOptions,
    store: Option<&mut ConformanceStore>,
) -> Result<(Vec<CellReport>, usize)> {
    let (reports, skipped, _) = run_sweep_metered(cells, opt, store)?;
    Ok((reports, skipped))
}

/// [`run_sweep`] plus throughput telemetry.  Harvested through the
/// scheduler's per-unit return values — each unit carries its instance /
/// event counts and trace-pool deltas back to the join, so the workers
/// share nothing and the hot path is untouched.
pub fn run_sweep_metered(
    cells: &[ValCell],
    opt: &SweepOptions,
    store: Option<&mut ConformanceStore>,
) -> Result<(Vec<CellReport>, usize, SweepMetrics)> {
    let mut seen = std::collections::BTreeSet::new();
    let pending: Vec<usize> = (0..cells.len())
        .filter(|&i| {
            seen.insert(cells[i].hash)
                && store
                    .as_ref()
                    .map_or(true, |s| !cell_complete(s, &cells[i], opt.instances))
        })
        .collect();
    let skipped = cells.len() - pending.len();
    if pending.is_empty() {
        return Ok((Vec::new(), skipped, SweepMetrics::default()));
    }
    // The batched model pre-pass (policy + classification per scenario ×
    // strategy group); `None` entries take the scalar in-worker path.
    let pre: Vec<Option<ModelPre>> = if opt.batch_model {
        precompute_models(cells, &pending, opt)
    } else {
        vec![None; pending.len()]
    };
    let store_mx = store.map(Mutex::new);
    let append_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    /// Worker scratch: the trace pool plus the pool-stat watermarks
    /// already reported through earlier units' return values.
    struct Worker {
        tp: TracePool,
        seen: (u64, u64, u64),
    }
    let timer = SpanTimer::start();
    let out = scheduler::run_units_stateful(
        pending.len(),
        opt.threads,
        || Worker { tp: TracePool::new(), seen: (0, 0, 0) },
        |w: &mut Worker, u| {
            let (rep, sims, events) =
                evaluate_cell(&cells[pending[u]], opt, &mut w.tp, pre[u].as_ref());
            if let Some(mx) = &store_mx {
                let mut s = mx.lock().expect("conformance store poisoned");
                if let Err(e) = s.append(&rep.record()) {
                    let mut slot = append_err.lock().expect("append_err poisoned");
                    if slot.is_none() {
                        *slot = Some(
                            e.context(format!("persisting cell {:016x}", rep.hash)),
                        );
                    }
                }
            }
            let now = (w.tp.hits(), w.tp.misses(), w.tp.evictions());
            let delta =
                (now.0 - w.seen.0, now.1 - w.seen.1, now.2 - w.seen.2);
            w.seen = now;
            (rep, sims, events, delta)
        },
    );
    if let Some(e) = append_err.into_inner().expect("append_err poisoned") {
        return Err(e);
    }
    let mut metrics = SweepMetrics {
        cells: pending.len(),
        elapsed_secs: timer.elapsed_secs(),
        ..SweepMetrics::default()
    };
    let mut reports = Vec::with_capacity(out.len());
    for (rep, sims, events, (h, m, e)) in out {
        metrics.instances += sims;
        metrics.sim_events += events;
        metrics.pool_hits += h;
        metrics.pool_misses += m;
        metrics.pool_evictions += e;
        reports.push(rep);
    }
    Ok((reports, skipped, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cells() -> Vec<ValCell> {
        let mut g = smoke_grid();
        g.procs = vec![1 << 16];
        g.cp_ratios = vec![1.0];
        g.fault_laws = vec![Law::Exponential];
        g.windows = vec![600.0];
        g.strategies = vec![
            registry::get("RFO").unwrap(),
            registry::get("NoCkptI").unwrap(),
            registry::get("ExactPred").unwrap(),
        ];
        expand_cells(&g, &[1.0])
    }

    #[test]
    fn val_cell_identity_is_stable_and_multiplier_aware() {
        let g = smoke_grid();
        let cells = expand_cells(&g, &[0.75, 1.0]);
        assert_eq!(cells.len(), 2 * g.len());
        // Multipliers separate hashes but share the trace-pool key.
        let (a, b) = (&cells[0], &cells[1]);
        assert_eq!(a.cell.hash, b.cell.hash);
        assert_ne!(a.hash, b.hash);
        assert_eq!(a.pool_hash, b.pool_hash);
        assert!(a.key().ends_with(";fm=platform;m=0.75"), "{}", a.key());
        // Same cell re-expanded hashes identically.
        let again = expand_cells(&g, &[0.75, 1.0]);
        assert_eq!(again[0].hash, cells[0].hash);
        assert_eq!(again[0].key(), cells[0].key());
        // The simulated scenario really runs the platform-renewal model.
        assert_eq!(a.scenario().fault_model, FaultModel::PlatformRenewal);
    }

    #[test]
    fn sweep_verdicts_every_cell_with_zero_unexplained_failures() {
        let cells = tiny_cells();
        let opt = SweepOptions { instances: 24, threads: 2, ..Default::default() };
        let (reports, skipped) = run_sweep(&cells, &opt, None).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(reports.len(), cells.len());
        let mut passes = 0;
        for r in &reports {
            match r.verdict {
                Verdict::Pass => {
                    passes += 1;
                    assert!(r.deviation <= r.tolerance);
                    assert!(r.sim_mean > 0.0 && r.sim_mean < 1.0);
                    assert!(r.model > 0.0 && r.model < 1.0);
                    assert_eq!(r.instances, 24);
                }
                Verdict::Fail => panic!(
                    "{}: |sim − model| = {} > tolerance {}",
                    r.key, r.deviation, r.tolerance
                ),
                Verdict::Inapplicable(reason) => {
                    assert_eq!(r.strategy, "ExactPred", "{}: {reason}", r.key);
                    assert_eq!(reason, Inapplicable::NoClosedForm);
                    assert_eq!(r.instances, 0);
                    assert!(r.model.is_nan());
                }
            }
        }
        assert_eq!(passes, 2, "RFO and NoCkptI must both verdict Pass");
    }

    #[test]
    fn metered_sweep_reports_throughput() {
        let cells = tiny_cells();
        let opt = SweepOptions { instances: 8, threads: 2, ..Default::default() };
        let (reports, skipped, m) = run_sweep_metered(&cells, &opt, None).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(reports.len(), cells.len());
        assert_eq!(m.cells, cells.len());
        // ExactPred has no closed form → only RFO and NoCkptI simulate.
        assert_eq!(m.instances, 16);
        assert!(m.sim_events >= m.instances);
        // One pool lookup per simulated instance.
        assert_eq!(m.pool_hits + m.pool_misses, m.instances);
        assert!(m.elapsed_secs >= 0.0);
    }

    #[test]
    fn sweep_is_thread_count_deterministic() {
        let cells = tiny_cells();
        let opt1 = SweepOptions { instances: 10, threads: 1, ..Default::default() };
        let opt8 = SweepOptions { instances: 10, threads: 8, ..Default::default() };
        let (a, _) = run_sweep(&cells, &opt1, None).unwrap();
        let (b, _) = run_sweep(&cells, &opt8, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.hash, y.hash);
            assert_eq!(x.sim_mean.to_bits(), y.sim_mean.to_bits(), "{}", x.key);
            assert_eq!(x.verdict, y.verdict);
        }
    }

    #[test]
    fn batched_and_scalar_model_paths_agree_bitwise() {
        // The tentpole contract at the sweep level: flipping batch_model
        // changes nothing — period, model value, deviation, verdict and
        // simulated mean are bit-identical (multipliers exercise whole
        // per-group batches, ExactPred the no-closed-form path).
        let mut g = smoke_grid();
        g.procs = vec![1 << 16];
        g.cp_ratios = vec![1.0];
        g.fault_laws = vec![Law::Exponential, Law::Weibull { shape: 0.7 }];
        g.windows = vec![600.0];
        g.strategies = vec![
            registry::get("RFO").unwrap(),
            registry::get("NoCkptI").unwrap(),
            registry::get("WithCkptI").unwrap(),
            registry::get("ExactPred").unwrap(),
        ];
        let cells = expand_cells(&g, &DEFAULT_MULTIPLIERS);
        let batched = SweepOptions { instances: 8, threads: 2, ..Default::default() };
        let scalar = SweepOptions { batch_model: false, ..batched };
        assert!(batched.batch_model);
        let (a, _) = run_sweep(&cells, &batched, None).unwrap();
        let (b, _) = run_sweep(&cells, &scalar, None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.hash, y.hash);
            assert_eq!(x.tr.to_bits(), y.tr.to_bits(), "{}", x.key);
            assert_eq!(x.model.to_bits(), y.model.to_bits(), "{}", x.key);
            assert_eq!(x.sim_mean.to_bits(), y.sim_mean.to_bits(), "{}", x.key);
            assert_eq!(x.deviation.to_bits(), y.deviation.to_bits(), "{}", x.key);
            assert_eq!(x.verdict, y.verdict, "{}", x.key);
        }
    }

    #[test]
    fn sweep_resumes_from_store() {
        let path = std::env::temp_dir().join(format!(
            "ckptwin-validate-resume-{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let cells = tiny_cells();
        let opt = SweepOptions { instances: 8, threads: 2, ..Default::default() };
        {
            let mut store = ConformanceStore::create(&path).unwrap();
            let (fresh, skipped) = run_sweep(&cells, &opt, Some(&mut store)).unwrap();
            assert_eq!(fresh.len(), cells.len());
            assert_eq!(skipped, 0);
            assert_eq!(store.len(), cells.len());
        }
        // Reopen: everything is already verdicted (including the
        // inapplicable ExactPred cell, which stores 0 instances).
        let mut store = ConformanceStore::open(&path).unwrap();
        let (fresh, skipped) = run_sweep(&cells, &opt, Some(&mut store)).unwrap();
        assert!(fresh.is_empty());
        assert_eq!(skipped, cells.len());
        // Stored records round-trip into reports (bitwise on the floats —
        // NaN fields must survive the null serialization too).
        for rec in store.records() {
            let rep = CellReport::from_record(rec).expect("known vocabulary");
            let back = rep.record();
            assert_eq!(back.key, rec.key);
            assert_eq!(back.verdict, rec.verdict);
            assert_eq!(back.reason, rec.reason);
            assert_eq!(back.instances, rec.instances);
            assert_eq!(back.sim_mean.to_bits(), rec.sim_mean.to_bits());
            assert_eq!(back.model.to_bits(), rec.model.to_bits());
            assert_eq!(back.tolerance.to_bits(), rec.tolerance.to_bits());
        }
        // A higher instance count re-verdicts the applicable cells only.
        let more = SweepOptions { instances: 16, ..opt };
        let (fresh, skipped) = run_sweep(&cells, &more, Some(&mut store)).unwrap();
        assert_eq!(fresh.len(), 2);
        assert_eq!(skipped, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn off_optimal_multipliers_also_conform() {
        let mut g = smoke_grid();
        g.procs = vec![1 << 16];
        g.cp_ratios = vec![1.0];
        g.fault_laws = vec![Law::Exponential];
        g.windows = vec![600.0];
        g.strategies = vec![registry::get("RFO").unwrap()];
        let cells = expand_cells(&g, &[0.6, 1.0, 1.8]);
        let opt = SweepOptions { instances: 24, threads: 0, ..Default::default() };
        let (reports, _) = run_sweep(&cells, &opt, None).unwrap();
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert_eq!(
                r.verdict,
                Verdict::Pass,
                "{}: dev {} vs tol {}",
                r.key,
                r.deviation,
                r.tolerance
            );
        }
        // The probed periods really differ.
        assert!(reports[0].tr < reports[1].tr && reports[1].tr < reports[2].tr);
    }
}
