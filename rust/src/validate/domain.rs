//! Validity-domain classification and the tolerance policy — the
//! "statistical oracle" half of the conformance subsystem.
//!
//! A model-vs-simulation comparison is only meaningful inside the closed
//! forms' validity domain.  [`classify`] encodes that domain as code: the
//! structural guards of the formulas themselves
//! ([`crate::model::waste::waste_checked`] — `p = 0`, `T_R ≤ C`,
//! `μ ≤ D+R`, `T_P` vs the window, saturated values) plus the *regime*
//! guards of the first-order derivation that only the comparison layer can
//! know (period vs MTBF ratio, job horizon, prediction-window overlap,
//! fault-model transients).  Out-of-domain cells classify as
//! [`Inapplicable`] — reported, never failed.
//!
//! [`tolerance`] prices the residual, *explainable* disagreement between an
//! in-domain formula and a finite simulation:
//!
//! ```text
//!   tol = abs_floor + tail_floor·min(CV²−1, 2)      discretization floor
//!       + curvature·(T_R/μ)²                        first-order truncation
//!       + renewal_excess(laws, T_R, job)            finite-horizon renewal
//!       + ci_mult·CI95(sim mean)                    sampling noise
//! ```
//!
//! Each term is a known, bounded error source (see DESIGN.md §Validation);
//! a deviation beyond their sum is a genuine conformance failure.

use crate::config::{FaultModel, Scenario};
use crate::model::waste::{self, Applicability, Inapplicability};
use crate::sim::distribution::Law;
use crate::strategy::PolicyKind;

/// Why a conformance cell has no meaningful model-vs-sim comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inapplicable {
    /// A structural guard of the formula itself (see
    /// [`crate::model::waste::Inapplicability`]).
    Model(Inapplicability),
    /// The paper derives no closed form for this strategy's execution
    /// mode (ExactPred, WindowEndCkpt, QTrust).  The BestPeriod twins do
    /// *not* land here: their modes map to the paper formulas, which the
    /// sweep then checks at the twin's searched period.
    NoClosedForm,
    /// `T_R/μ` too large: the first-order expansion's truncated
    /// O((T_R/μ)²) terms dominate — no tolerance is honest there.
    BeyondFirstOrder,
    /// Fewer than [`MIN_PERIODS`] regular periods fit the job: the
    /// asymptotic waste model has no steady state to predict.
    JobTooShort,
    /// `(I + C_p)` is a large fraction of the predicted-event
    /// inter-arrival μ_P: overlapping windows, which the analysis assumes
    /// away (§2.3), dominate the execution.
    WindowsOverlap,
    /// Per-processor *fresh* fault traces under a non-exponential law: the
    /// superposed infant-mortality transient puts the effective fault rate
    /// far above the 1/μ the formulas assume (the paper's own
    /// Daly-vs-BestPeriod gap; see DESIGN.md §Fault-model).
    TransientFaultModel,
    /// The finite-horizon renewal excess alone exceeds the cap: the job is
    /// too short for this heavy-tailed law to reach its renewal rate.
    HorizonTooShort,
    /// The predictor's window sizes vary per announcement
    /// ([`crate::config::PredModel::MixedWindow`]): the fixed-I terms of
    /// Eqs. (4)/(10)/(14) — window exposure `(1−p)I`, the `T_P` fit — have
    /// no single I to use.  (Eq. (3) never sees the window: q = 0 cells
    /// stay applicable.)
    NonUniformWindow,
    /// The predictor's window placement is noisy
    /// ([`crate::config::PredModel::Jitter`]): faults can fall outside
    /// their announced window, so the *effective* recall sits below the
    /// nominal r the formulas are evaluated at.
    NoisyWindowPlacement,
    /// The predictor attaches per-announcement confidence weights
    /// ([`crate::config::PredModel::Classed`]): the engine's trust
    /// probability varies per announcement, while the q = 1 formulas
    /// assume every prediction is acted on.
    ConfidenceClasses,
    /// The *measured* superposed platform fault rate disagrees with the
    /// `1/μ_p` approximation the closed forms are evaluated at (found by
    /// the N = 10^4..10^6 scale-conformance guard,
    /// [`platform_rate_check`]).  Distinct from [`TransientFaultModel`],
    /// which is the a-priori structural guard: this one is the a-posteriori
    /// measurement — it fires when the trace itself proves the
    /// approximation broken at the cell's platform scale.
    PlatformRateNonconforming,
}

impl Inapplicable {
    /// Stable snake_case label (conformance stores / `CONFORMANCE.json`).
    pub fn label(&self) -> &'static str {
        match self {
            Inapplicable::Model(m) => m.label(),
            Inapplicable::NoClosedForm => "no_closed_form",
            Inapplicable::BeyondFirstOrder => "beyond_first_order",
            Inapplicable::JobTooShort => "job_too_short",
            Inapplicable::WindowsOverlap => "windows_overlap",
            Inapplicable::TransientFaultModel => "transient_fault_model",
            Inapplicable::HorizonTooShort => "horizon_too_short",
            Inapplicable::NonUniformWindow => "non_uniform_window",
            Inapplicable::NoisyWindowPlacement => "noisy_window_placement",
            Inapplicable::ConfidenceClasses => "confidence_classes",
            Inapplicable::PlatformRateNonconforming => "platform_rate_nonconforming",
        }
    }

    /// Parse a stored label back (resume path).  Unknown labels — a store
    /// written by a newer build — map to `None`.
    pub fn parse(label: &str) -> Option<Inapplicable> {
        use Inapplicability::*;
        Some(match label {
            "period_within_checkpoint" => Inapplicable::Model(PeriodWithinCheckpoint),
            "mtbf_within_recovery" => Inapplicable::Model(MtbfWithinRecovery),
            "zero_precision" => Inapplicable::Model(ZeroPrecision),
            "proactive_period_outside_window" => {
                Inapplicable::Model(ProactivePeriodOutsideWindow)
            }
            "waste_out_of_range" => Inapplicable::Model(WasteOutOfRange),
            "no_closed_form" => Inapplicable::NoClosedForm,
            "beyond_first_order" => Inapplicable::BeyondFirstOrder,
            "job_too_short" => Inapplicable::JobTooShort,
            "windows_overlap" => Inapplicable::WindowsOverlap,
            "transient_fault_model" => Inapplicable::TransientFaultModel,
            "horizon_too_short" => Inapplicable::HorizonTooShort,
            "non_uniform_window" => Inapplicable::NonUniformWindow,
            "noisy_window_placement" => Inapplicable::NoisyWindowPlacement,
            "confidence_classes" => Inapplicable::ConfidenceClasses,
            "platform_rate_nonconforming" => Inapplicable::PlatformRateNonconforming,
            _ => return None,
        })
    }
}

impl std::fmt::Display for Inapplicable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// `T_R/μ` beyond this is outside the first-order expansion's regime.
pub const FIRST_ORDER_MAX: f64 = 0.5;
/// Minimum regular periods the job must hold for the asymptotic model.
pub const MIN_PERIODS: f64 = 10.0;
/// Maximum `(I + C_p)/μ_P` before window overlaps dominate.
pub const OVERLAP_MAX: f64 = 0.25;

/// Tolerance policy: the coefficients pricing each explainable error
/// source (module docs give the formula; DESIGN.md §Validation derives it).
#[derive(Clone, Copy, Debug)]
pub struct TolerancePolicy {
    /// Law-independent floor: final-period truncation, strike-position
    /// discretization, residual second-order terms at tiny `T_R/μ`.
    pub abs_floor: f64,
    /// Extra floor per unit of excess CV² (heavy-tailed laws mix slower),
    /// applied as `tail_floor · min(CV² − 1, 2)`.
    pub tail_floor: f64,
    /// Coefficient of the `(T_R/μ)²` first-order truncation term.
    pub curvature: f64,
    /// CI multiplier on the simulated mean's 95% half-width.
    pub ci_mult: f64,
    /// Cells whose renewal-excess term alone exceeds this classify as
    /// [`Inapplicable::HorizonTooShort`] instead of hiding behind it.
    pub max_renewal_excess: f64,
}

impl Default for TolerancePolicy {
    fn default() -> Self {
        TolerancePolicy {
            abs_floor: 0.02,
            tail_floor: 0.01,
            curvature: 0.5,
            ci_mult: 3.0,
            max_renewal_excess: 0.05,
        }
    }
}

/// Finite-horizon renewal excess, in waste units: a renewal process with
/// squared CV `c²` delivers ≈ `(c² − 1)/2` events *more* than `T/mean`
/// over a finite horizon (the asymptotic renewal-function constant; 0 for
/// Exponential).  Each excess fault costs ≈ `T_R/2 + D + R`, each excess
/// false prediction ≈ `C_p` (when the strategy listens), spread over the
/// job.
pub fn renewal_excess_waste(sc: &Scenario, kind: PolicyKind, tr: f64) -> f64 {
    let excess = |cv2: f64| (cv2 - 1.0).max(0.0) / 2.0;
    let pf = &sc.platform;
    let mut w = excess(sc.fault_law.cv2()) * (tr / 2.0 + pf.d + pf.r) / sc.job_size;
    if !matches!(kind, PolicyKind::IgnorePredictions) {
        w += excess(sc.false_pred_law.cv2()) * pf.cp / sc.job_size;
    }
    w
}

/// Classify a conformance cell: the model waste at `(tr, tp)` when the
/// formula applies there, or the [`Inapplicable`] reason.
pub fn classify(
    sc: &Scenario,
    kind: PolicyKind,
    tr: f64,
    tp: f64,
    policy: &TolerancePolicy,
) -> Result<f64, Inapplicable> {
    let gs = kind.grid_strategy().ok_or(Inapplicable::NoClosedForm)?;
    // Predictor-model assumptions of the prediction-aware formulas.  The
    // `biased` model stays in-domain: the derivation only consumes the
    // fault's expected in-window position E_I^f, which `Scenario::e_if`
    // now exposes per model.  Eq. (3) ignores predictions, so q = 0 cells
    // are compared under every model.
    if gs != waste::GridStrategy::Q0 {
        use crate::config::PredModel;
        match sc.predictor.model {
            PredModel::Paper | PredModel::Biased { .. } => {}
            PredModel::MixedWindow { .. } => {
                return Err(Inapplicable::NonUniformWindow)
            }
            PredModel::Jitter { .. } => {
                return Err(Inapplicable::NoisyWindowPlacement)
            }
            PredModel::Classed { .. } => {
                return Err(Inapplicable::ConfidenceClasses)
            }
        }
    }
    // Structural formula guards first (they also catch p = 0 before any
    // division below).
    let model = match waste::waste_checked(sc, gs, tr, tp) {
        Applicability::Applicable(w) => w,
        Applicability::Inapplicable(r) => return Err(Inapplicable::Model(r)),
    };
    // Regime guards of the first-order derivation.
    if tr / sc.platform.mu > FIRST_ORDER_MAX {
        return Err(Inapplicable::BeyondFirstOrder);
    }
    if sc.job_size < MIN_PERIODS * tr {
        return Err(Inapplicable::JobTooShort);
    }
    if gs != waste::GridStrategy::Q0 {
        let mu_p = sc.predictor.mu_p(sc.platform.mu);
        if (sc.predictor.max_window() + sc.platform.cp) / mu_p > OVERLAP_MAX {
            return Err(Inapplicable::WindowsOverlap);
        }
    }
    // Only Weibull has a per-processor superposition implemented; other
    // laws run as platform-level renewals under every fault model (see
    // DESIGN.md §Fault-model), so only fresh per-proc Weibull traces carry
    // the infant-mortality transient.
    if matches!(sc.fault_model, FaultModel::PerProcessor { .. })
        && matches!(sc.fault_law, Law::Weibull { .. })
    {
        return Err(Inapplicable::TransientFaultModel);
    }
    if renewal_excess_waste(sc, kind, tr) > policy.max_renewal_excess {
        return Err(Inapplicable::HorizonTooShort);
    }
    Ok(model)
}

/// Batched [`classify`]: one scenario × one strategy × a whole period
/// grid, element-wise identical (value and reason) to calling `classify`
/// per period — pinned by `classify_batch_matches_scalar_elementwise` and
/// `tests/batch_model.rs`.
///
/// The period-independent guards (no closed form, the predictor-model
/// guards, window overlap, the transient fault model, and — inside
/// [`crate::model::batch::BatchEvaluator::eval_row`] — `μ ≤ D+R`, `p = 0`
/// and the `T_P` window fit) are decided once per call; only the genuinely
/// per-period guards (`T_R ≤ C`, the formula range, `T_R/μ`, job length,
/// renewal excess) run per cell.  The caller supplies the evaluator so a
/// sweep worker reuses one scratch buffer across groups.
pub fn classify_batch(
    sc: &Scenario,
    kind: PolicyKind,
    trs: &[f64],
    tp: f64,
    policy: &TolerancePolicy,
    ev: &mut crate::model::batch::BatchEvaluator,
) -> Vec<Result<f64, Inapplicable>> {
    let gs = match kind.grid_strategy() {
        None => return vec![Err(Inapplicable::NoClosedForm); trs.len()],
        Some(gs) => gs,
    };
    if gs != waste::GridStrategy::Q0 {
        use crate::config::PredModel;
        let guard = match sc.predictor.model {
            PredModel::Paper | PredModel::Biased { .. } => None,
            PredModel::MixedWindow { .. } => Some(Inapplicable::NonUniformWindow),
            PredModel::Jitter { .. } => Some(Inapplicable::NoisyWindowPlacement),
            PredModel::Classed { .. } => Some(Inapplicable::ConfidenceClasses),
        };
        if let Some(g) = guard {
            return vec![Err(g); trs.len()];
        }
    }
    let mut row = Vec::new();
    ev.eval_row(sc, gs, tp, trs, &mut row);
    // Regime guards that do not depend on the period, hoisted.
    let overlap = gs != waste::GridStrategy::Q0 && {
        let mu_p = sc.predictor.mu_p(sc.platform.mu);
        (sc.predictor.max_window() + sc.platform.cp) / mu_p > OVERLAP_MAX
    };
    let transient = matches!(sc.fault_model, FaultModel::PerProcessor { .. })
        && matches!(sc.fault_law, Law::Weibull { .. });
    trs.iter()
        .zip(row)
        .map(|(&tr, a)| {
            let model = match a {
                Applicability::Applicable(w) => w,
                Applicability::Inapplicable(r) => {
                    return Err(Inapplicable::Model(r))
                }
            };
            if tr / sc.platform.mu > FIRST_ORDER_MAX {
                return Err(Inapplicable::BeyondFirstOrder);
            }
            if sc.job_size < MIN_PERIODS * tr {
                return Err(Inapplicable::JobTooShort);
            }
            if overlap {
                return Err(Inapplicable::WindowsOverlap);
            }
            if transient {
                return Err(Inapplicable::TransientFaultModel);
            }
            if renewal_excess_waste(sc, kind, tr) > policy.max_renewal_excess {
                return Err(Inapplicable::HorizonTooShort);
            }
            Ok(model)
        })
        .collect()
}

/// The declared tolerance for a classified-applicable cell, given the
/// simulated mean's CI half-width (see module docs for the terms).
pub fn tolerance(
    policy: &TolerancePolicy,
    sc: &Scenario,
    kind: PolicyKind,
    tr: f64,
    ci95: f64,
) -> f64 {
    let x = tr / sc.platform.mu;
    policy.abs_floor
        + policy.tail_floor * (sc.fault_law.cv2() - 1.0).clamp(0.0, 2.0)
        + policy.curvature * x * x
        + renewal_excess_waste(sc, kind, tr)
        + policy.ci_mult * ci95
}

/// Default relative tolerance of the scale-conformance guard: the
/// superposed platform rate may deviate from `1/μ_p` by this much before
/// the closed forms' approximation counts as broken (generously above the
/// sampling noise of the measurement horizons used).
pub const PLATFORM_RATE_TOL: f64 = 0.10;

/// One measurement of the scale-conformance guard (see
/// [`platform_rate_check`]).
#[derive(Clone, Copy, Debug)]
pub struct PlatformRateCheck {
    /// Mean measured platform fault rate (faults/s) across the seeds.
    pub measured_rate: f64,
    /// The `1/μ_p` rate the closed forms assume (`config.rs` sets
    /// `μ_p = μ_ind/N`).
    pub nominal_rate: f64,
    /// `|measured/nominal − 1|`.
    pub rel_err: f64,
    /// `Some(`[`Inapplicable::PlatformRateNonconforming`]`)` when the
    /// deviation exceeds the tolerance — the named regime for cells whose
    /// platform-scale trace breaks the approximation.
    pub verdict: Option<Inapplicable>,
}

/// Scale-conformance guard: measure the scenario's *true* superposed
/// platform fault rate over `horizon_mtbfs · μ` per seed and compare it
/// against the `1/μ_p` approximation every closed form is evaluated at.
///
/// At any N the stationary superposition must conform (its rate is exactly
/// `1/μ` by construction — a deviation is a generator bug); fresh Weibull
/// k < 1 traces must *not* (the infant-mortality transient — this guard
/// measuring the same break that [`Inapplicable::TransientFaultModel`]
/// predicts structurally).  `ckptwin validate --scale` sweeps this at
/// N = 10^4..10^6.
pub fn platform_rate_check(
    sc: &Scenario,
    seeds: u64,
    horizon_mtbfs: f64,
    tol: f64,
) -> PlatformRateCheck {
    let horizon = horizon_mtbfs * sc.platform.mu;
    let mut acc = 0.0;
    for seed in 0..seeds.max(1) {
        acc += crate::sim::trace::measured_fault_rate(sc, seed, horizon);
    }
    let measured_rate = acc / seeds.max(1) as f64;
    let nominal_rate = 1.0 / sc.platform.mu;
    let rel_err = (measured_rate / nominal_rate - 1.0).abs();
    PlatformRateCheck {
        measured_rate,
        nominal_rate,
        rel_err,
        verdict: (rel_err > tol).then_some(Inapplicable::PlatformRateNonconforming),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, PredictorSpec};
    use crate::sim::distribution::Law;

    fn sc(law: Law, fm: FaultModel) -> Scenario {
        Scenario {
            platform: Platform { mu: 60_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: law,
            false_pred_law: law,
            fault_model: fm,
            job_size: 1e6,
        }
    }

    #[test]
    fn classify_applies_in_the_paper_regime() {
        let s = sc(Law::Exponential, FaultModel::PlatformRenewal);
        let pol = TolerancePolicy::default();
        let w = classify(&s, PolicyKind::IgnorePredictions, 8000.0, 700.0, &pol)
            .expect("in-domain");
        assert!((w - crate::model::waste::q0(&s, 8000.0)).abs() < 1e-12);
        let w = classify(&s, PolicyKind::NoCkpt, 8000.0, 700.0, &pol).unwrap();
        assert!((w - crate::model::waste::nockpt(&s, 8000.0)).abs() < 1e-12);
    }

    #[test]
    fn classify_names_each_regime_guard() {
        let pol = TolerancePolicy::default();
        let s = sc(Law::Exponential, FaultModel::PlatformRenewal);
        assert_eq!(
            classify(&s, PolicyKind::ExactPred, 8000.0, 700.0, &pol),
            Err(Inapplicable::NoClosedForm)
        );
        assert_eq!(
            classify(&s, PolicyKind::QTrust { q: 0.5 }, 8000.0, 700.0, &pol),
            Err(Inapplicable::NoClosedForm)
        );
        // T_R/μ > 0.5.
        assert_eq!(
            classify(&s, PolicyKind::IgnorePredictions, 40_000.0, 700.0, &pol),
            Err(Inapplicable::BeyondFirstOrder)
        );
        // Fewer than MIN_PERIODS periods in the job.
        let mut short = s;
        short.job_size = 50_000.0;
        assert_eq!(
            classify(&short, PolicyKind::IgnorePredictions, 8000.0, 700.0, &pol),
            Err(Inapplicable::JobTooShort)
        );
        // Overlapping windows: huge I vs μ_P.
        let mut wide = s;
        wide.predictor.window = 30_000.0;
        assert_eq!(
            classify(&wide, PolicyKind::NoCkpt, 8000.0, 700.0, &pol),
            Err(Inapplicable::WindowsOverlap)
        );
        // …but the q = 0 model never sees the window.
        assert!(classify(&wide, PolicyKind::IgnorePredictions, 8000.0, 700.0, &pol)
            .is_ok());
        // Fresh per-processor Weibull traces: transient fault model.
        let weib = sc(
            Law::Weibull { shape: 0.7 },
            FaultModel::PerProcessor { n: 1 << 16 },
        );
        assert_eq!(
            classify(&weib, PolicyKind::NoCkpt, 8000.0, 700.0, &pol),
            Err(Inapplicable::TransientFaultModel)
        );
        // The same law under the steady-state renewal is in-domain…
        let weib_pr = sc(Law::Weibull { shape: 0.7 }, FaultModel::PlatformRenewal);
        assert!(classify(&weib_pr, PolicyKind::NoCkpt, 8000.0, 700.0, &pol).is_ok());
        // …and exponential per-processor traces are too (exactly Poisson).
        let exp_pp =
            sc(Law::Exponential, FaultModel::PerProcessor { n: 1 << 16 });
        assert!(classify(&exp_pp, PolicyKind::NoCkpt, 8000.0, 700.0, &pol).is_ok());
        // Heavy tail on a tiny job: the renewal excess alone blows the cap.
        let mut heavy = sc(Law::Weibull { shape: 0.5 }, FaultModel::PlatformRenewal);
        heavy.job_size = 150_000.0;
        assert_eq!(
            classify(&heavy, PolicyKind::IgnorePredictions, 8000.0, 700.0, &pol),
            Err(Inapplicable::HorizonTooShort)
        );
        // Structural model guards pass through with their own reason.
        let mut p0 = s;
        p0.predictor.precision = 0.0;
        assert_eq!(
            classify(&p0, PolicyKind::Instant, 8000.0, 700.0, &pol),
            Err(Inapplicable::Model(
                crate::model::waste::Inapplicability::ZeroPrecision
            ))
        );
    }

    #[test]
    fn classify_names_each_predictor_model_guard() {
        use crate::config::PredModel;
        let pol = TolerancePolicy::default();
        let mut s = sc(Law::Exponential, FaultModel::PlatformRenewal);

        // Biased placement: in-domain, compared at the per-model E_I^f.
        s.predictor.model = PredModel::Biased { beta: 2.0 };
        let w = classify(&s, PolicyKind::NoCkpt, 8000.0, 700.0, &pol)
            .expect("biased stays in-domain");
        assert!(
            (w - crate::model::waste::nockpt(&s, 8000.0)).abs() < 1e-12,
            "biased must be priced with its own e_if"
        );
        // And the value genuinely differs from the uniform-placement one.
        let mut uni = s;
        uni.predictor.model = PredModel::Paper;
        let w_uni =
            classify(&uni, PolicyKind::NoCkpt, 8000.0, 700.0, &pol).unwrap();
        assert!((w - w_uni).abs() > 1e-9, "e_if shift must move the model");

        // Mixed windows / jitter / classes: named classifications for the
        // prediction-aware formulas…
        s.predictor.model =
            PredModel::MixedWindow { i1: 300.0, i2: 1200.0, w: 0.5 };
        assert_eq!(
            classify(&s, PolicyKind::NoCkpt, 8000.0, 700.0, &pol),
            Err(Inapplicable::NonUniformWindow)
        );
        s.predictor.model = PredModel::Jitter { sigma: 120.0 };
        assert_eq!(
            classify(&s, PolicyKind::Instant, 8000.0, 700.0, &pol),
            Err(Inapplicable::NoisyWindowPlacement)
        );
        s.predictor.model =
            PredModel::Classed { p_hi: 0.95, p_lo: 0.6, frac: 0.5 };
        assert_eq!(
            classify(&s, PolicyKind::WithCkpt, 8000.0, 700.0, &pol),
            Err(Inapplicable::ConfidenceClasses)
        );
        // …while Eq. (3) never sees the predictor: q = 0 stays applicable
        // under every model.
        for model in [
            PredModel::MixedWindow { i1: 300.0, i2: 1200.0, w: 0.5 },
            PredModel::Jitter { sigma: 120.0 },
            PredModel::Classed { p_hi: 0.95, p_lo: 0.6, frac: 0.5 },
        ] {
            s.predictor.model = model;
            assert!(
                classify(&s, PolicyKind::IgnorePredictions, 8000.0, 700.0, &pol)
                    .is_ok(),
                "{model:?}"
            );
        }

        // The new labels are stable store identities and round-trip.
        for (v, label) in [
            (Inapplicable::NonUniformWindow, "non_uniform_window"),
            (Inapplicable::NoisyWindowPlacement, "noisy_window_placement"),
            (Inapplicable::ConfidenceClasses, "confidence_classes"),
            (
                Inapplicable::PlatformRateNonconforming,
                "platform_rate_nonconforming",
            ),
        ] {
            assert_eq!(v.label(), label);
            assert_eq!(Inapplicable::parse(label), Some(v));
        }
    }

    #[test]
    fn classify_batch_matches_scalar_elementwise() {
        let pol = TolerancePolicy::default();
        // Periods crossing every per-cell guard: below C, in-domain,
        // job-short, beyond first order, plus a duplicate.
        let trs =
            vec![100.0, 600.0, 8000.0, 8000.0, 150_000.0, 40_000.0, 2000.0];
        let scenarios = [
            sc(Law::Exponential, FaultModel::PlatformRenewal),
            sc(Law::Weibull { shape: 0.7 }, FaultModel::PlatformRenewal),
            sc(
                Law::Weibull { shape: 0.7 },
                FaultModel::PerProcessor { n: 1 << 16 },
            ),
            {
                let mut p0 = sc(Law::Exponential, FaultModel::PlatformRenewal);
                p0.predictor.precision = 0.0;
                p0
            },
            {
                let mut j = sc(Law::Exponential, FaultModel::PlatformRenewal);
                j.predictor.model = crate::config::PredModel::Jitter { sigma: 120.0 };
                j
            },
        ];
        let kinds = [
            PolicyKind::IgnorePredictions,
            PolicyKind::Instant,
            PolicyKind::NoCkpt,
            PolicyKind::WithCkpt,
            PolicyKind::ExactPred,
            PolicyKind::QTrust { q: 0.5 },
        ];
        let mut ev = crate::model::batch::BatchEvaluator::new();
        for s in &scenarios {
            for kind in kinds {
                let batch = classify_batch(s, kind, &trs, 700.0, &pol, &mut ev);
                assert_eq!(batch.len(), trs.len());
                for (j, &tr) in trs.iter().enumerate() {
                    let scalar = classify(s, kind, tr, 700.0, &pol);
                    match (&batch[j], &scalar) {
                        (Ok(b), Ok(w)) => assert_eq!(
                            b.to_bits(),
                            w.to_bits(),
                            "{kind:?} tr={tr}"
                        ),
                        _ => assert_eq!(batch[j], scalar, "{kind:?} tr={tr}"),
                    }
                }
            }
        }
    }

    #[test]
    fn platform_rate_check_flags_fresh_weibull_transient() {
        // Stationary superposition: the measured rate is 1/μ at any N, so
        // the guard must conform.
        let n = 1u64 << 14;
        let mut stat = sc(
            Law::Weibull { shape: 0.7 },
            FaultModel::PerProcessorStationary { n },
        );
        // Pin μ_ind = μ·N explicitly so nominal 1/μ is the honest target.
        // 6 seeds × 200 MTBFs ≈ 1200 faults: sampling σ ≈ 2.9%, so the
        // 10% tolerance sits beyond 3σ of the conforming rate.
        stat.platform.mu = 60_000.0;
        let chk = platform_rate_check(&stat, 6, 200.0, PLATFORM_RATE_TOL);
        assert!(
            chk.verdict.is_none(),
            "stationary rate must conform: rel_err {}",
            chk.rel_err
        );
        assert!((chk.nominal_rate - 1.0 / 60_000.0).abs() < 1e-18);

        // Fresh Weibull k < 1: every processor starts in its
        // infant-mortality phase, so the early platform rate runs far hot
        // of 1/μ — the named nonconforming regime.
        let fresh = sc(
            Law::Weibull { shape: 0.7 },
            FaultModel::PerProcessor { n },
        );
        let chk = platform_rate_check(&fresh, 6, 200.0, PLATFORM_RATE_TOL);
        assert_eq!(
            chk.verdict,
            Some(Inapplicable::PlatformRateNonconforming),
            "fresh k<1 must break the μ_p approximation: rel_err {}",
            chk.rel_err
        );
        assert!(chk.measured_rate > chk.nominal_rate);
    }

    #[test]
    fn tolerance_terms_behave() {
        let pol = TolerancePolicy::default();
        let exp = sc(Law::Exponential, FaultModel::PlatformRenewal);
        let weib = sc(Law::Weibull { shape: 0.7 }, FaultModel::PlatformRenewal);
        let kind = PolicyKind::IgnorePredictions;
        // Zero CI, small period: tolerance is essentially the floor.
        let base = tolerance(&pol, &exp, kind, 2000.0, 0.0);
        assert!(base >= pol.abs_floor && base < pol.abs_floor + 0.01, "{base}");
        // Heavier law ⇒ larger tolerance (tail floor + renewal excess).
        assert!(tolerance(&pol, &weib, kind, 2000.0, 0.0) > base);
        // Longer period ⇒ larger curvature term; CI enters ci_mult×.
        assert!(tolerance(&pol, &exp, kind, 20_000.0, 0.0) > base);
        let with_ci = tolerance(&pol, &exp, kind, 2000.0, 0.01);
        assert!((with_ci - base - pol.ci_mult * 0.01).abs() < 1e-12);
        // Exponential renewal excess is exactly zero.
        assert_eq!(renewal_excess_waste(&exp, kind, 8000.0), 0.0);
        assert!(renewal_excess_waste(&weib, kind, 8000.0) > 0.0);
        // Prediction-aware strategies also pay the false-prediction term.
        assert!(
            renewal_excess_waste(&weib, PolicyKind::NoCkpt, 8000.0)
                > renewal_excess_waste(&weib, kind, 8000.0)
        );
    }
}
