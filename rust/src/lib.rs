//! # ckptwin — Checkpointing strategies with prediction windows
//!
//! Full reproduction of Aupy, Robert, Vivien & Zaidouni, *"Checkpointing
//! strategies with prediction windows"* (2013): the analytic waste model
//! (Eqs. 3/4/10/14 and the optimal periods), a discrete-event simulator of
//! the two-mode (regular/proactive) scheduling algorithm (Algorithm 1 and
//! the Instant / NoCkptI / WithCkptI variants), the brute-force BestPeriod
//! baselines, the Daly / Young / RFO prediction-ignoring policies, and the
//! complete experiment harness regenerating every figure (2–21) and table
//! (4–5) of the paper's evaluation.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L3 (this crate)** — coordination: the simulator, the analytic model,
//!   the experiment harness, the [`campaign`] engine (declarative scenario
//!   grids with work-stealing execution, streaming aggregation and a
//!   resumable result store), the [`validate`] conformance engine
//!   (CI-gated model-vs-simulation sweeps with statistical oracles), and a
//!   *real* checkpointing coordinator that
//!   trains a transformer LM (AOT-compiled to an HLO artifact) under fault
//!   injection with proactive checkpointing.
//! * **L2/L1 (build-time Python)** — JAX model + Pallas kernels, lowered
//!   once to `artifacts/*.hlo.txt`; the [`runtime`] module loads and runs
//!   them through the PJRT CPU client (`xla` crate). Python never runs on
//!   the request path.

pub mod bench_support;
pub mod campaign;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod jsonio;
pub mod model;
pub mod obs;
pub mod predictor;
pub mod resilience;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod stats;
pub mod strategy;
pub mod util;
pub mod validate;

pub use config::{Platform, PredModel, PredictorSpec, Scenario};
pub use predictor::PredictorId;
pub use sim::engine::{simulate, SimOutcome};
pub use strategy::{Policy, PolicyKind, StrategyId};
