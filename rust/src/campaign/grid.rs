//! Declarative scenario grids: axes → deterministic cell list.
//!
//! A [`Grid`] names the axes of a campaign (platform sizes, C_p/C ratios,
//! fault laws, predictors, window sizes, strategy set); [`Grid::expand`]
//! cartesian-expands them into a flat, deterministically ordered list of
//! [`Cell`]s.  Each cell carries a stable 64-bit **scenario hash** (FNV-1a
//! over a canonical key string — independent of process, platform and
//! expansion order) that keys the resumable result store, and derives its
//! own per-instance RNG streams from that hash, so results are identical
//! whether a cell is computed in a fresh run, a resume, or a differently
//! sized grid containing it.

use crate::config::{PredModel, PredictorSpec, Scenario};
use crate::predictor::registry::PredictorId;
use crate::sim::distribution::Law;
use crate::strategy::{registry, StrategyId};

/// FNV-1a 64-bit hash (stable across platforms and runs).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// SplitMix64 finalizer — decorrelates nearby seeds.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One campaign cell: a fully specified paper scenario plus the strategy to
/// run on it.  The finest unit of scheduling and aggregation.
///
/// The strategy axis is a registry [`StrategyId`] (stable name + parameter
/// map — see [`crate::strategy::registry`]), so any registered strategy,
/// including parameterized ones like `QTrust(q=0.25)`, is a grid value with
/// no campaign-layer edits.
#[derive(Clone, Debug)]
pub struct Cell {
    pub procs: u64,
    pub cp_ratio: f64,
    pub fault_law: Law,
    pub false_pred_law: Law,
    pub predictor: PredictorSpec,
    pub strategy: StrategyId,
    /// Job-size multiplier (1.0 = the paper's `Time_base = 10000 y / N`;
    /// small values make cheap smoke grids for tests and benches).
    pub scale: f64,
    /// Stable cell hash (scenario + strategy), derived from [`Cell::key`]
    /// at construction; keys the result store.
    pub hash: u64,
    /// Stable hash of the full scenario minus the strategy
    /// ([`Cell::scenario_key`]).  The strategy is the only cell axis that
    /// does not shape the event trace, so this hash keys the per-worker
    /// [`crate::campaign::TracePool`]: every strategy variant of one
    /// scenario replays the same memoized traces.
    pub scenario_hash: u64,
    /// Stable hash of the fault *environment* alone ([`Cell::trace_key`]:
    /// platform, laws, scale — no strategy, no predictor).  Seeds derive
    /// from this, so every strategy, predictor and window at one
    /// environment simulates the *same* fault traces (the paper's
    /// paired-comparison methodology).
    pub trace_hash: u64,
    /// Platform shard count: the per-processor pool is split into this
    /// many wheel sub-sources with derived seeds
    /// ([`crate::sim::trace::TraceCache::sharded`]).  1 = the unsharded
    /// source (and the pre-shards key string, byte-identical).  Shards ≠ 1
    /// are their *own* trace definition — the axis lands in
    /// [`Cell::trace_key`], so hashes and instance seeds separate.
    pub shards: u32,
}

impl Cell {
    pub fn new(
        procs: u64,
        cp_ratio: f64,
        fault_law: Law,
        false_pred_law: Law,
        predictor: PredictorSpec,
        strategy: StrategyId,
        scale: f64,
    ) -> Cell {
        let mut cell = Cell {
            procs,
            cp_ratio,
            fault_law,
            false_pred_law,
            predictor,
            strategy,
            scale,
            hash: 0,
            scenario_hash: 0,
            trace_hash: 0,
            shards: 1,
        };
        cell.rehash();
        cell
    }

    /// The same cell with its platform split into `shards` sub-sources
    /// (clamped to ≥ 1); identity hashes are recomputed, since shards ≠ 1
    /// changes the fault trace.
    pub fn with_shards(mut self, shards: u32) -> Cell {
        self.shards = shards.max(1);
        self.rehash();
        self
    }

    fn rehash(&mut self) {
        self.trace_hash = fnv1a64(self.trace_key().as_bytes());
        self.scenario_hash = fnv1a64(self.scenario_key().as_bytes());
        self.hash = fnv1a64(self.key().as_bytes());
    }

    /// Canonical identity of the fault environment: everything that shapes
    /// the fault arrival process (platform size, C_p ratio, laws, job
    /// scale) and nothing that doesn't (strategy, predictor p/r/I — the
    /// fault substream of the trace is predictor-independent).  Cells that
    /// share this string share [`Cell::instance_seed`] streams, so e.g. a
    /// Daly baseline and a predictor-B row of Tables 4/5 are scored on
    /// identical fault traces.
    pub fn trace_key(&self) -> String {
        let mut key = format!(
            "procs={};cp={};law={};fp={};scale={}",
            self.procs,
            self.cp_ratio,
            self.fault_law.label(),
            self.false_pred_law.label(),
            self.scale,
        );
        // Like the `pm=` component of the scenario key: shards = 1 (the
        // only pre-axis value) appends nothing, so existing stores stay
        // resumable (`tests/campaign.rs` pins the literal strings).
        if self.shards != 1 {
            key.push_str(&format!(";shards={}", self.shards));
        }
        key
    }

    /// Canonical identity of the simulated scenario: the fault environment
    /// plus the predictor — everything that shapes the event trace, and
    /// nothing that doesn't (the strategy only consumes it).  Non-paper
    /// window-placement models append a `pm=<model>` component; paper
    /// predictors keep the pre-registry key byte-identical, so existing
    /// campaign and conformance stores stay resumable
    /// (`tests/campaign.rs` pins the literal strings).
    pub fn scenario_key(&self) -> String {
        let mut key = format!(
            "{};p={};r={};I={}",
            self.trace_key(),
            self.predictor.precision,
            self.predictor.recall,
            self.predictor.window,
        );
        if self.predictor.model != PredModel::Paper {
            key.push_str(&format!(";pm={}", self.predictor.model.label()));
        }
        key
    }

    /// Canonical, human-greppable identity string of the full cell.  The
    /// store hash is FNV-1a of exactly this, so any parameter change
    /// changes the hash and any re-expansion reproduces it.  The strategy
    /// component is the [`StrategyId`]'s canonical display form, which for
    /// the paper's six named heuristics is byte-identical to the
    /// pre-registry enum labels — existing stores stay resumable
    /// (`tests/campaign.rs` pins the literal keys).
    pub fn key(&self) -> String {
        format!("{};strat={}", self.scenario_key(), self.strategy)
    }

    /// The concrete scenario this cell simulates.
    pub fn scenario(&self) -> Scenario {
        let mut sc = Scenario::paper(
            self.procs,
            self.cp_ratio,
            self.predictor,
            self.fault_law,
            self.false_pred_law,
        );
        sc.job_size *= self.scale;
        sc
    }

    /// Per-instance RNG seed: an independent, reproducible stream per
    /// (fault environment, instance) pair.  Derived from
    /// [`Cell::trace_hash`] — NOT the full cell hash — so all strategies,
    /// predictors and windows over one environment see identical fault
    /// traces (paired comparisons, as in the paper), and a cell's
    /// instances never depend on where it sits in a grid.
    pub fn instance_seed(&self, instance: u64) -> u64 {
        mix64(self.trace_hash ^ instance.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Declarative axes of a campaign.  `expand()` iterates, outermost first:
/// fault law → window → procs → C_p ratio → predictor → strategy (matching
/// the row order of the paper's figure CSVs).
#[derive(Clone, Debug)]
pub struct Grid {
    pub procs: Vec<u64>,
    pub cp_ratios: Vec<f64>,
    pub fault_laws: Vec<Law>,
    /// False predictions ~ Uniform (Figures 8–13) instead of the fault law.
    pub uniform_false_preds: bool,
    /// The predictor axis: registry identifiers
    /// ([`crate::predictor::registry`]) — the paper's `a`/`b` pair, the
    /// parameterized `paper(r;p)`, or any registered window-placement
    /// model (`biased(beta=2)`, `mixedwin(…)`, `jitter(…)`, `classed(…)`).
    pub predictors: Vec<PredictorId>,
    pub windows: Vec<f64>,
    pub strategies: Vec<StrategyId>,
    pub scale: f64,
    /// Platform-shards axis (see [`Cell::shards`]): how many per-worker
    /// sub-sources each platform is split into.  `[1]` — the default for
    /// every preset — reproduces the pre-axis grids exactly.
    pub platform_shards: Vec<u32>,
}

impl Grid {
    /// The paper's full simulation campaign: 4 platform sizes × 2 C_p
    /// ratios × 3 fault laws × 2 predictors × 5 window sizes, with the
    /// 5-strategy set — 240 scenario points, 1200 cells.
    pub fn paper() -> Grid {
        Grid {
            procs: crate::harness::PAPER_PROCS.to_vec(),
            cp_ratios: vec![1.0, 0.1],
            fault_laws: vec![
                Law::Exponential,
                Law::Weibull { shape: 0.7 },
                Law::Weibull { shape: 0.5 },
            ],
            uniform_false_preds: false,
            predictors: crate::predictor::registry::paper_pair(),
            windows: crate::harness::PAPER_WINDOWS.to_vec(),
            strategies: registry::paper_set(),
            scale: 1.0,
            platform_shards: vec![1],
        }
    }

    /// A cheap smoke grid (single scenario axis values, scaled-down job).
    pub fn smoke() -> Grid {
        Grid {
            procs: vec![1 << 16, 1 << 18],
            cp_ratios: vec![1.0],
            fault_laws: vec![Law::Exponential, Law::Weibull { shape: 0.7 }],
            uniform_false_preds: false,
            predictors: vec![crate::predictor::registry::get("a")
                .expect("registered")],
            windows: vec![600.0, 1200.0],
            strategies: vec![
                registry::get("RFO").expect("registered"),
                registry::get("NoCkptI").expect("registered"),
            ],
            scale: 0.05,
            platform_shards: vec![1],
        }
    }

    /// Number of cells `expand()` will produce.
    pub fn len(&self) -> usize {
        self.procs.len()
            * self.cp_ratios.len()
            * self.fault_laws.len()
            * self.predictors.len()
            * self.windows.len()
            * self.strategies.len()
            * self.platform_shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cartesian-expand the axes into the deterministic cell list.
    pub fn expand(&self) -> Vec<Cell> {
        let mut cells = Vec::with_capacity(self.len());
        for &law in &self.fault_laws {
            let fp_law = if self.uniform_false_preds { Law::Uniform } else { law };
            for &window in &self.windows {
                for &procs in &self.procs {
                    for &shards in &self.platform_shards {
                        for &cp_ratio in &self.cp_ratios {
                            for pred in &self.predictors {
                                for strategy in &self.strategies {
                                    cells.push(
                                        Cell::new(
                                            procs,
                                            cp_ratio,
                                            law,
                                            fp_law,
                                            pred.spec(window),
                                            strategy.clone(),
                                            self.scale,
                                        )
                                        .with_shards(shards),
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn paper_grid_shape() {
        let g = Grid::paper();
        assert_eq!(g.len(), 4 * 2 * 3 * 2 * 5 * 5);
        assert_eq!(g.expand().len(), g.len());
    }

    #[test]
    fn expansion_is_deterministic() {
        let g = Grid::smoke();
        let a = g.expand();
        let b = g.expand();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.key(), y.key());
            assert_eq!(x.hash, y.hash);
        }
    }

    #[test]
    fn hashes_unique_within_grid() {
        let cells = Grid::paper().expand();
        let mut hashes: Vec<u64> = cells.iter().map(|c| c.hash).collect();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), cells.len());
    }

    #[test]
    fn hash_position_independent() {
        // The same cell in two different grids hashes identically.
        let mut small = Grid::smoke();
        small.procs = vec![1 << 16];
        small.fault_laws = vec![Law::Exponential];
        small.windows = vec![600.0];
        small.strategies = vec![registry::get("RFO").unwrap()];
        let lone = &small.expand()[0];
        let full = Grid::smoke().expand();
        let twin = full.iter().find(|c| c.key() == lone.key()).unwrap();
        assert_eq!(twin.hash, lone.hash);
    }

    #[test]
    fn instance_seeds_distinct() {
        let cell = &Grid::smoke().expand()[0];
        let s0 = cell.instance_seed(0);
        let s1 = cell.instance_seed(1);
        assert_ne!(s0, s1);
        assert_eq!(s0, cell.instance_seed(0));
    }

    #[test]
    fn strategies_at_one_point_share_traces_but_not_hashes() {
        // smoke() has two strategies as the innermost axis: cells 0 and 1
        // are the same scenario under Rfo and NoCkptI.
        let cells = Grid::smoke().expand();
        let (a, b) = (&cells[0], &cells[1]);
        assert_ne!(a.strategy, b.strategy);
        assert_eq!(a.trace_key(), b.trace_key());
        assert_eq!(a.trace_hash, b.trace_hash);
        // Same scenario too: they replay one TracePool entry.
        assert_eq!(a.scenario_key(), b.scenario_key());
        assert_eq!(a.scenario_hash, b.scenario_hash);
        // Paired comparison: identical instance seeds → identical traces.
        assert_eq!(a.instance_seed(7), b.instance_seed(7));
        // But distinct store identities.
        assert_ne!(a.hash, b.hash);
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn predictors_and_windows_share_traces_too() {
        // The fault substream is predictor-independent, so Tables 4/5 can
        // pair a Daly baseline (predictor A) against predictor-B rows.
        let a = Cell::new(
            1 << 16,
            1.0,
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.7 },
            crate::predictor::registry::get("a").unwrap().spec(300.0),
            registry::get("Daly").unwrap(),
            1.0,
        );
        let b = Cell::new(
            1 << 16,
            1.0,
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.7 },
            crate::predictor::registry::get("b").unwrap().spec(1200.0),
            registry::get("NoCkptI").unwrap(),
            1.0,
        );
        assert_eq!(a.trace_hash, b.trace_hash);
        assert_eq!(a.instance_seed(3), b.instance_seed(3));
        assert_ne!(a.hash, b.hash);
        // A different predictor is a different event trace: the scenario
        // hash (the TracePool key) must separate them even though the
        // fault substream is shared.
        assert_ne!(a.scenario_hash, b.scenario_hash);
    }

    #[test]
    fn scenario_scales_job() {
        let cells = Grid::smoke().expand();
        let sc = cells[0].scenario();
        let full = Scenario::paper(
            cells[0].procs,
            cells[0].cp_ratio,
            cells[0].predictor,
            cells[0].fault_law,
            cells[0].false_pred_law,
        );
        assert!((sc.job_size - full.job_size * 0.05).abs() < 1e-6);
    }

    #[test]
    fn strategy_and_predictor_parsing() {
        assert_eq!(
            "withckpt".parse::<StrategyId>().unwrap(),
            registry::get("WithCkptI").unwrap()
        );
        assert!("nope".parse::<StrategyId>().is_err());
        assert_eq!(
            "A".parse::<PredictorId>().unwrap(),
            crate::predictor::registry::get("a").unwrap()
        );
        assert!("x".parse::<PredictorId>().is_err());
    }

    #[test]
    fn non_paper_predictor_models_separate_keys_but_share_fault_traces() {
        let mk = |spec: PredictorSpec| {
            Cell::new(
                1 << 16,
                1.0,
                Law::Exponential,
                Law::Exponential,
                spec,
                registry::get("NoCkptI").unwrap(),
                1.0,
            )
        };
        let paper = mk(PredictorSpec::paper_a(600.0));
        let biased = mk(PredictorId::parse("biased(beta=2)").unwrap().spec(600.0));
        // The fault environment is predictor-independent: paired traces.
        assert_eq!(paper.trace_hash, biased.trace_hash);
        assert_eq!(paper.instance_seed(4), biased.instance_seed(4));
        // But the event trace (and the store identity) differ: the model
        // label lands in the scenario key.
        assert_ne!(paper.scenario_hash, biased.scenario_hash);
        assert_ne!(paper.hash, biased.hash);
        assert!(
            biased.scenario_key().ends_with(";pm=biased(beta=2)"),
            "{}",
            biased.scenario_key()
        );
        // Paper cells carry NO pm component: pre-registry keys unchanged.
        assert!(!paper.key().contains("pm="), "{}", paper.key());
    }

    #[test]
    fn shard_axis_separates_hashes_but_default_keys_unchanged() {
        let base = Cell::new(
            1 << 20,
            1.0,
            Law::Weibull { shape: 0.7 },
            Law::Weibull { shape: 0.7 },
            PredictorSpec::paper_a(600.0),
            registry::get("RFO").unwrap(),
            1.0,
        );
        // shards = 1 is the identity: no key component, same hashes.
        let one = base.clone().with_shards(1);
        assert_eq!(one.key(), base.key());
        assert_eq!(one.hash, base.hash);
        assert!(!base.trace_key().contains("shards="), "{}", base.trace_key());
        // shards ≠ 1 is a distinct fault environment.
        let four = base.clone().with_shards(4);
        assert!(four.trace_key().ends_with(";shards=4"), "{}", four.trace_key());
        assert_ne!(four.trace_hash, base.trace_hash);
        assert_ne!(four.hash, base.hash);
        assert_ne!(four.instance_seed(0), base.instance_seed(0));
        // The axis multiplies the grid and expansion honors it.
        let mut g = Grid::smoke();
        let plain = g.len();
        g.platform_shards = vec![1, 8];
        assert_eq!(g.len(), plain * 2);
        let cells = g.expand();
        assert_eq!(cells.len(), g.len());
        assert!(cells.iter().any(|c| c.shards == 8));
        assert!(cells.iter().any(|c| c.shards == 1));
    }

    #[test]
    fn parameterized_strategies_are_distinct_cells() {
        // Two QTrust settings at one scenario point: same traces (paired
        // comparison over q), distinct store identities.
        let mk = |q: f64| {
            Cell::new(
                1 << 16,
                1.0,
                Law::Exponential,
                Law::Exponential,
                PredictorSpec::paper_a(600.0),
                StrategyId::parse(&format!("qtrust(q={q})")).unwrap(),
                1.0,
            )
        };
        let (a, b) = (mk(0.25), mk(0.75));
        assert_eq!(a.scenario_hash, b.scenario_hash);
        assert_eq!(a.instance_seed(5), b.instance_seed(5));
        assert_ne!(a.hash, b.hash);
        assert!(a.key().ends_with("strat=QTrust(q=0.25)"), "{}", a.key());
    }
}
