//! Work-stealing execution over scoped std threads.
//!
//! Replaces the static per-thread chunking the harness used to do: workers
//! claim units one at a time from a shared atomic queue (`fetch_add`
//! self-scheduling), so a skewed unit (a large-window cell, a heavy-tailed
//! Weibull instance) delays only the thread running it instead of
//! serializing a whole pre-assigned chunk at the tail of the run.
//!
//! Results are returned **in unit order**, independent of which worker
//! computed what — callers get determinism for free and can merge
//! per-unit partial aggregates in a fixed order (see
//! [`crate::stats::Welford::merge`]).

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::resilience::failpoint::{self, Mode, Site};

/// Worker count for `n_units` of work: all available cores, but never more
/// threads than units.
pub fn default_threads(n_units: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(n_units.max(1))
}

/// Execute `n` independent units on `threads` workers pulling from a shared
/// atomic work queue; `f(i)` computes unit `i`.  Returns the results in
/// unit order.  `threads == 0` selects [`default_threads`].  With one
/// thread (or one unit) the units run inline on the caller, bit-identically
/// to the parallel path.
pub fn run_units<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_units_stateful(n, threads, || (), |_: &mut (), i| f(i))
}

/// [`run_units`] with per-worker scratch state: each worker initializes one
/// `S` with `init()` and threads it through every unit it claims.  This is
/// how worker-lifetime caches (the campaign's [`crate::campaign::TracePool`],
/// a [`crate::sim::trace::TraceArena`]) live across units without locking:
/// the state is worker-local by construction.
///
/// Results must not depend on the state for determinism to survive work
/// stealing — a cache is fine (hit or miss, same value), an accumulator is
/// not.
pub fn run_units_stateful<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let run = run_units_contained(n, threads, 0, init, f);
    if let Some(fail) = run.failures.first() {
        // The old behaviour was an opaque `join().expect(..)`; name the
        // unit so a panicking cell is identifiable from the message.
        panic!(
            "unit {} panicked after {} attempt(s): {}",
            fail.unit, fail.attempts, fail.message
        );
    }
    run.results.into_iter().map(|o| o.unwrap()).collect()
}

/// One unit that exhausted its attempts (see [`run_units_contained`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnitFailure {
    /// Unit index that panicked.
    pub unit: usize,
    /// Attempts made (1 + retries granted).
    pub attempts: u32,
    /// Panic payload (stringified).
    pub message: String,
}

/// Outcome of a contained run: per-unit results (`None` where the unit
/// ultimately failed) plus the failure manifest, sorted by unit.
#[derive(Debug)]
pub struct ContainedRun<T> {
    pub results: Vec<Option<T>>,
    pub failures: Vec<UnitFailure>,
}

/// [`run_units_stateful`] with panic containment: each unit runs under
/// `catch_unwind`, a panicking unit is requeued up to `retries` times
/// (the worker's scratch state is rebuilt first — the panic may have left
/// it inconsistent), and units that exhaust their attempts are reported
/// in [`ContainedRun::failures`] instead of poisoning the whole run.
///
/// Fail point `sched.worker` fires inside the contained region, so
/// injected worker panics exercise exactly this requeue path.
pub fn run_units_contained<T, S, I, F>(
    n: usize,
    threads: usize,
    retries: u32,
    init: I,
    f: F,
) -> ContainedRun<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return ContainedRun { results: Vec::new(), failures: Vec::new() };
    }
    let threads = match threads {
        0 => default_threads(n),
        t => t.min(n),
    };
    let attempt = |state: &mut S, i: usize| -> Result<T, String> {
        std::panic::catch_unwind(AssertUnwindSafe(|| {
            if let Some(inj) = failpoint::check(Site::SchedWorker) {
                if inj.mode == Mode::Kill {
                    failpoint::kill_now(&inj);
                }
                panic!("injected panic at sched.worker (hit {})", inj.hit);
            }
            f(state, i)
        }))
        .map_err(panic_message)
    };
    if threads <= 1 {
        // Inline on the caller, as before — same containment semantics.
        let mut state = init();
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut failures = Vec::new();
        let mut queue: Vec<(usize, u32)> = (0..n).rev().map(|i| (i, 0u32)).collect();
        while let Some((i, tried)) = queue.pop() {
            match attempt(&mut state, i) {
                Ok(v) => results[i] = Some(v),
                Err(message) => {
                    state = init();
                    if tried < retries {
                        queue.push((i, tried + 1));
                    } else {
                        failures.push(UnitFailure {
                            unit: i,
                            attempts: tried + 1,
                            message,
                        });
                    }
                }
            }
        }
        failures.sort_by_key(|f| f.unit);
        return ContainedRun { results, failures };
    }
    // LIFO retry queue seeded in unit order (0 pops first); `resolved`
    // counts units with a final outcome so idle workers know when to exit
    // even while a failed unit is in flight on another worker.
    let queue: Mutex<Vec<(usize, u32)>> =
        Mutex::new((0..n).rev().map(|i| (i, 0u32)).collect());
    let resolved = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    let failures: Mutex<Vec<UnitFailure>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let job = lock_queue(&queue).pop();
                    let Some((i, tried)) = job else {
                        if resolved.load(Ordering::SeqCst) >= n {
                            break;
                        }
                        std::thread::yield_now();
                        continue;
                    };
                    match attempt(&mut state, i) {
                        Ok(v) => {
                            results.lock().unwrap_or_else(|e| e.into_inner())[i] =
                                Some(v);
                            resolved.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(message) => {
                            state = init();
                            if tried < retries {
                                lock_queue(&queue).push((i, tried + 1));
                            } else {
                                failures
                                    .lock()
                                    .unwrap_or_else(|e| e.into_inner())
                                    .push(UnitFailure {
                                        unit: i,
                                        attempts: tried + 1,
                                        message,
                                    });
                                resolved.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    }
                }
            });
        }
    });
    let results = results.into_inner().unwrap_or_else(|e| e.into_inner());
    let mut failures = failures.into_inner().unwrap_or_else(|e| e.into_inner());
    failures.sort_by_key(|f| f.unit);
    ContainedRun { results, failures }
}

/// Poison-recovering queue lock: injected panics can poison the mutex,
/// but every update is a whole-value push/pop, so the inner Vec is sound.
fn lock_queue(
    m: &Mutex<Vec<(usize, u32)>>,
) -> std::sync::MutexGuard<'_, Vec<(usize, u32)>> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_unit_order() {
        let out = run_units(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_matches_parallel() {
        let serial = run_units(37, 1, |i| (i as f64).sqrt());
        let parallel = run_units(37, 6, |i| (i as f64).sqrt());
        assert_eq!(serial, parallel);
    }

    #[test]
    fn every_unit_runs_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = run_units(250, 4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(out.len(), 250);
        assert_eq!(counter.load(Ordering::Relaxed), 250);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(run_units(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(run_units(1, 8, |i| i + 1), vec![1]);
        assert_eq!(run_units(3, 0, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn stateful_state_is_reused_within_a_worker() {
        // Each worker's state is a scratch Vec; results reflect the input
        // only (cache semantics), so any thread count agrees.
        let compute = |buf: &mut Vec<u64>, i: usize| {
            buf.clear();
            buf.extend((0..=i as u64).map(|k| k * k));
            buf.iter().sum::<u64>()
        };
        let serial = run_units_stateful(50, 1, Vec::new, compute);
        let parallel = run_units_stateful(50, 6, Vec::new, compute);
        assert_eq!(serial, parallel);
        // 0² + 1² + 2² + 3²
        assert_eq!(serial[3], 14);
    }

    #[test]
    fn contained_run_reports_failed_unit_and_keeps_the_rest() {
        let run = run_units_contained(
            20,
            4,
            1,
            || (),
            |_: &mut (), i| {
                if i == 13 {
                    panic!("boom on unit {i}");
                }
                i * 2
            },
        );
        assert_eq!(run.failures.len(), 1);
        assert_eq!(run.failures[0].unit, 13);
        assert_eq!(run.failures[0].attempts, 2); // 1 try + 1 retry
        assert!(run.failures[0].message.contains("boom on unit 13"));
        for (i, r) in run.results.iter().enumerate() {
            if i == 13 {
                assert!(r.is_none());
            } else {
                assert_eq!(*r, Some(i * 2));
            }
        }
    }

    #[test]
    fn contained_retry_recovers_flaky_unit() {
        use std::sync::atomic::AtomicBool;
        let first = AtomicBool::new(true);
        let run = run_units_contained(
            5,
            1,
            2,
            || (),
            |_: &mut (), i| {
                if i == 2 && first.swap(false, Ordering::SeqCst) {
                    panic!("flaky once");
                }
                i + 100
            },
        );
        assert!(run.failures.is_empty(), "{:?}", run.failures);
        let vals: Vec<usize> = run.results.into_iter().map(|o| o.unwrap()).collect();
        assert_eq!(vals, vec![100, 101, 102, 103, 104]);
    }

    #[test]
    fn stateful_panic_names_the_unit() {
        let caught = std::panic::catch_unwind(|| {
            run_units_stateful(8, 3, || (), |_: &mut (), i| {
                if i == 5 {
                    panic!("bad cell");
                }
                i
            });
        })
        .unwrap_err();
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains("unit 5") && msg.contains("bad cell"),
            "panic message should name the unit: {msg}"
        );
    }

    #[test]
    fn skewed_units_complete() {
        // One unit is 100x heavier; the queue must still drain fully.
        let out = run_units(40, 4, |i| {
            let spins = if i == 0 { 200_000 } else { 2_000 };
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_mul(31).wrapping_add(k);
            }
            (i, acc)
        });
        assert_eq!(out.len(), 40);
        for (i, (idx, _)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
        }
    }
}
