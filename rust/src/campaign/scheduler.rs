//! Work-stealing execution over scoped std threads.
//!
//! Replaces the static per-thread chunking the harness used to do: workers
//! claim units one at a time from a shared atomic queue (`fetch_add`
//! self-scheduling), so a skewed unit (a large-window cell, a heavy-tailed
//! Weibull instance) delays only the thread running it instead of
//! serializing a whole pre-assigned chunk at the tail of the run.
//!
//! Results are returned **in unit order**, independent of which worker
//! computed what — callers get determinism for free and can merge
//! per-unit partial aggregates in a fixed order (see
//! [`crate::stats::Welford::merge`]).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count for `n_units` of work: all available cores, but never more
/// threads than units.
pub fn default_threads(n_units: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(n_units.max(1))
}

/// Execute `n` independent units on `threads` workers pulling from a shared
/// atomic work queue; `f(i)` computes unit `i`.  Returns the results in
/// unit order.  `threads == 0` selects [`default_threads`].  With one
/// thread (or one unit) the units run inline on the caller, bit-identically
/// to the parallel path.
pub fn run_units<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_units_stateful(n, threads, || (), |_: &mut (), i| f(i))
}

/// [`run_units`] with per-worker scratch state: each worker initializes one
/// `S` with `init()` and threads it through every unit it claims.  This is
/// how worker-lifetime caches (the campaign's [`crate::campaign::TracePool`],
/// a [`crate::sim::trace::TraceArena`]) live across units without locking:
/// the state is worker-local by construction.
///
/// Results must not depend on the state for determinism to survive work
/// stealing — a cache is fine (hit or miss, same value), an accumulator is
/// not.
pub fn run_units_stateful<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = match threads {
        0 => default_threads(n),
        t => t.min(n),
    };
    if threads <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let init = &init;
                let f = &f;
                scope.spawn(move || {
                    let mut state = init();
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&mut state, i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("campaign worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_unit_order() {
        let out = run_units(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_matches_parallel() {
        let serial = run_units(37, 1, |i| (i as f64).sqrt());
        let parallel = run_units(37, 6, |i| (i as f64).sqrt());
        assert_eq!(serial, parallel);
    }

    #[test]
    fn every_unit_runs_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = run_units(250, 4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(out.len(), 250);
        assert_eq!(counter.load(Ordering::Relaxed), 250);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(run_units(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(run_units(1, 8, |i| i + 1), vec![1]);
        assert_eq!(run_units(3, 0, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn stateful_state_is_reused_within_a_worker() {
        // Each worker's state is a scratch Vec; results reflect the input
        // only (cache semantics), so any thread count agrees.
        let compute = |buf: &mut Vec<u64>, i: usize| {
            buf.clear();
            buf.extend((0..=i as u64).map(|k| k * k));
            buf.iter().sum::<u64>()
        };
        let serial = run_units_stateful(50, 1, Vec::new, compute);
        let parallel = run_units_stateful(50, 6, Vec::new, compute);
        assert_eq!(serial, parallel);
        // 0² + 1² + 2² + 3²
        assert_eq!(serial[3], 14);
    }

    #[test]
    fn skewed_units_complete() {
        // One unit is 100x heavier; the queue must still drain fully.
        let out = run_units(40, 4, |i| {
            let spins = if i == 0 { 200_000 } else { 2_000 };
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_mul(31).wrapping_add(k);
            }
            (i, acc)
        });
        assert_eq!(out.len(), 40);
        for (i, (idx, _)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
        }
    }
}
