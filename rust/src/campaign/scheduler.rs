//! Work-stealing execution over scoped std threads.
//!
//! Replaces the static per-thread chunking the harness used to do: workers
//! claim units one at a time from a shared atomic queue (`fetch_add`
//! self-scheduling), so a skewed unit (a large-window cell, a heavy-tailed
//! Weibull instance) delays only the thread running it instead of
//! serializing a whole pre-assigned chunk at the tail of the run.
//!
//! Results are returned **in unit order**, independent of which worker
//! computed what — callers get determinism for free and can merge
//! per-unit partial aggregates in a fixed order (see
//! [`crate::stats::Welford::merge`]).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count for `n_units` of work: all available cores, but never more
/// threads than units.
pub fn default_threads(n_units: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(n_units.max(1))
}

/// Execute `n` independent units on `threads` workers pulling from a shared
/// atomic work queue; `f(i)` computes unit `i`.  Returns the results in
/// unit order.  `threads == 0` selects [`default_threads`].  With one
/// thread (or one unit) the units run inline on the caller, bit-identically
/// to the parallel path.
pub fn run_units<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = match threads {
        0 => default_threads(n),
        t => t.min(n),
    };
    if threads <= 1 {
        return (0..n).map(|i| f(i)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("campaign worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_unit_order() {
        let out = run_units(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_matches_parallel() {
        let serial = run_units(37, 1, |i| (i as f64).sqrt());
        let parallel = run_units(37, 6, |i| (i as f64).sqrt());
        assert_eq!(serial, parallel);
    }

    #[test]
    fn every_unit_runs_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = run_units(250, 4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(out.len(), 250);
        assert_eq!(counter.load(Ordering::Relaxed), 250);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(run_units(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(run_units(1, 8, |i| i + 1), vec![1]);
        assert_eq!(run_units(3, 0, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn skewed_units_complete() {
        // One unit is 100x heavier; the queue must still drain fully.
        let out = run_units(40, 4, |i| {
            let spins = if i == 0 { 200_000 } else { 2_000 };
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_mul(31).wrapping_add(k);
            }
            (i, acc)
        });
        assert_eq!(out.len(), 40);
        for (i, (idx, _)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
        }
    }
}
