//! Campaign-wide trace memoization: one [`TraceCache`] per
//! (scenario, instance seed), shared by every simulation that replays it.
//!
//! The strategy is the only cell axis that does not shape the event trace
//! (seeds already derive from the fault-environment hash, and the
//! predictor is part of the scenario), so the 4–5 strategy variants of a
//! scenario point — and every BestPeriod candidate evaluated on it —
//! simulate *identical* traces.  A `TracePool` keyed by
//! [`crate::campaign::Cell::scenario_hash`] pays trace generation once per
//! (scenario, seed) and replays it for every consumer.
//!
//! Pools are **worker-local** (held as per-worker state in
//! [`crate::campaign::scheduler::run_units_stateful`]), so they need no
//! locking; whether a lookup hits only changes speed, never values, so
//! work stealing keeps its bit-determinism.  Memory is bounded by a total
//! cached-event budget: crossing it clears the pool (traces are cheap to
//! regenerate relative to juggling an eviction order).

use std::collections::HashMap;

use crate::config::Scenario;
use crate::sim::trace::{Replay, TraceCache};

/// Per-worker memo of generated traces, keyed by (scenario hash, seed).
pub struct TracePool {
    entries: HashMap<(u64, u64), TraceCache>,
    max_events: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl Default for TracePool {
    fn default() -> Self {
        TracePool::with_budget(TracePool::DEFAULT_MAX_EVENTS)
    }
}

impl TracePool {
    /// Default per-pool budget: ~256k cached events (a few MB per worker;
    /// hundreds of paper-scale traces).
    pub const DEFAULT_MAX_EVENTS: usize = 1 << 18;

    pub fn new() -> Self {
        Self::default()
    }

    /// A pool that clears itself once it caches more than `max_events`
    /// events in total.
    pub fn with_budget(max_events: usize) -> Self {
        TracePool {
            entries: HashMap::new(),
            max_events,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// A replay cursor over the memoized trace of (`scenario_hash`, `seed`),
    /// generating it (from `sc`) on first use.  `scenario_hash` must
    /// identify everything trace-relevant in `sc` — use
    /// [`crate::campaign::Cell::scenario_hash`] for campaign cells.
    ///
    /// The budget is enforced on misses only: hits — the hot path — do no
    /// bookkeeping beyond the lookup.  (Caches grow lazily during replay,
    /// so a running counter could not stay exact anyway; an O(entries)
    /// scan once per generated trace is noise next to the generation.)
    pub fn replay(&mut self, scenario_hash: u64, sc: &Scenario, seed: u64) -> Replay<'_> {
        self.replay_sharded(scenario_hash, sc, seed, 1)
    }

    /// [`TracePool::replay`] over a platform split into `shards` per-shard
    /// sub-sources ([`TraceCache::sharded`]); `shards <= 1` is exactly
    /// `replay`.  The caller's `scenario_hash` must already encode the
    /// shard count (campaign cells do: shards ≠ 1 lands in
    /// [`crate::campaign::Cell::trace_key`]), since it is the memo key.
    // contains_key + insert instead of the entry API: the budget scan must
    // run between the lookup and the insert, which entry()'s borrow of the
    // map cannot interleave.
    #[allow(clippy::map_entry)]
    pub fn replay_sharded(
        &mut self,
        scenario_hash: u64,
        sc: &Scenario,
        seed: u64,
        shards: u32,
    ) -> Replay<'_> {
        let key = (scenario_hash, seed);
        if self.entries.contains_key(&key) {
            self.hits += 1;
        } else {
            // Fail point `pool.insert`: fires on the miss path, before the
            // fresh trace lands in the memo.  The pool has no Result
            // channel, so every error-ish mode degrades to a panic — the
            // scheduler's containment catches it and rebuilds the worker's
            // pool, which is exactly the state-reinit path under test.
            {
                use crate::resilience::failpoint::{self, Mode, Site};
                if let Some(inj) = failpoint::check(Site::PoolInsert) {
                    if inj.mode == Mode::Kill {
                        failpoint::kill_now(&inj);
                    }
                    panic!("injected panic at pool.insert (hit {})", inj.hit);
                }
            }
            if self.cached_events() > self.max_events {
                self.entries.clear();
                self.evictions += 1;
            }
            self.misses += 1;
            self.entries.insert(key, TraceCache::sharded(sc, seed, shards));
        }
        self.entries.get_mut(&key).expect("present").replay()
    }

    /// Aggregate wheel/shard counters over every cached trace: summed
    /// [`crate::sim::trace::WheelStats`] plus total shard merges (`None`
    /// when no cached trace runs a wheel — platform-renewal scenarios).
    pub fn wheel_stats(&self) -> Option<(crate::sim::trace::WheelStats, u64)> {
        let mut agg: Option<(crate::sim::trace::WheelStats, u64)> = None;
        for cache in self.entries.values() {
            if let Some((s, m)) = cache.wheel_stats() {
                let (a, merges) = agg.get_or_insert_with(Default::default);
                a.pops += s.pops;
                a.bucket_scans += s.bucket_scans;
                a.overflow_promotions += s.overflow_promotions;
                a.occupancy += s.occupancy;
                *merges += m;
            }
        }
        agg
    }

    /// Total events currently memoized across all entries.
    pub fn cached_events(&self) -> usize {
        self.entries.values().map(TraceCache::len).sum()
    }

    /// Number of memoized (scenario, seed) traces.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups served from the memo.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that generated a fresh trace.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Budget-exceeded clears performed.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultModel, Platform, PredictorSpec};
    use crate::sim::distribution::Law;
    use crate::sim::engine::{simulate, simulate_from};
    use crate::strategy::{Policy, PolicyKind};

    fn sc() -> Scenario {
        Scenario {
            platform: Platform { mu: 40_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 8e5,
        }
    }

    #[test]
    fn pooled_replay_matches_fresh_simulation() {
        let sc = sc();
        let mut pool = TracePool::new();
        let pols = [
            Policy { kind: PolicyKind::IgnorePredictions, tr: 6000.0, tp: 700.0 },
            Policy { kind: PolicyKind::Instant, tr: 6000.0, tp: 700.0 },
            Policy { kind: PolicyKind::WithCkpt, tr: 6000.0, tp: 700.0 },
        ];
        for seed in [3u64, 4] {
            for pol in &pols {
                let direct = simulate(&sc, pol, seed);
                let pooled =
                    simulate_from(&sc, pol, 1.0, seed, pool.replay(7, &sc, seed));
                assert_eq!(direct, pooled);
            }
        }
        // 2 seeds × 3 policies: one miss per seed, the rest hits.
        assert_eq!(pool.misses(), 2);
        assert_eq!(pool.hits(), 4);
        assert_eq!(pool.len(), 2);
        assert!(pool.cached_events() > 0);
    }

    #[test]
    fn over_budget_pool_clears_and_stays_correct() {
        let sc = sc();
        let mut pool = TracePool::with_budget(1); // absurdly tight
        let pol = Policy { kind: PolicyKind::NoCkpt, tr: 6000.0, tp: 700.0 };
        // Alternating seeds: every lookup is a miss (the clear evicts the
        // other seed's trace), so each one runs the budget check.
        for &seed in &[9u64, 10, 9, 10] {
            let direct = simulate(&sc, &pol, seed);
            let pooled =
                simulate_from(&sc, &pol, 1.0, seed, pool.replay(1, &sc, seed));
            assert_eq!(direct, pooled);
        }
        assert!(pool.evictions() >= 1, "budget never enforced");
        assert_eq!(pool.misses(), 4);
        assert_eq!(pool.hits(), 0);
    }
}
