//! Campaign engine: declarative scenario grids, work-stealing execution,
//! streaming aggregation, and resumable on-disk checkpoints.
//!
//! The paper validates its analytic model with a large cross-product of
//! simulated scenarios (Figures 2–21: platform sizes × C_p ratios × fault
//! laws × predictors × window sizes × strategies).  This module turns that
//! cross-product into a first-class object:
//!
//! ```text
//!   Grid ──expand──▶ [Cell; N] ──(cell × instance-block units)──▶
//!     scheduler::run_units_stateful (shared atomic work queue, scoped
//!         threads, one TracePool per worker)
//!       each unit: replay the (scenario, seed) trace from the worker's
//!         pool — generated once, shared by every strategy variant — and
//!         simulate a block of instances → Welford partials
//!     last unit of a cell: merge partials IN BLOCK ORDER (deterministic)
//!       ──▶ CellOutcome ──append──▶ Store (JSONL keyed by scenario hash)
//! ```
//!
//! * **Determinism** — cell hashes and per-instance seeds derive from the
//!   cell parameters alone; partial aggregates merge in block order, so any
//!   thread count (including 1) produces bit-identical per-cell results.
//! * **Streaming** — memory is O(cells), never O(cells × instances):
//!   instances fold into constant-size [`Welford`] accumulators as they
//!   finish.
//! * **Resumability** — completed cells land in the [`Store`] immediately;
//!   [`run_cells`] skips cells whose hash the store already holds, so an
//!   interrupted campaign recomputes only what is missing.
//!
//! The harness figure/table runners drive their grids through this engine
//! (`harness::figures`, `harness::tables`), and the `campaign` CLI
//! subcommand (run / resume / report) exposes it directly.

pub mod grid;
pub mod pool;
pub mod scheduler;
pub mod store;

pub use crate::predictor::registry::PredictorId;
pub use grid::{Cell, Grid};
pub use pool::TracePool;
pub use store::{CellRecord, Store};

use std::sync::Mutex;

use anyhow::Result;

use crate::sim::engine::simulate_from_capped;
use crate::stats::Welford;
use crate::strategy::Policy;

/// Execution knobs for a campaign.
#[derive(Clone, Copy, Debug)]
pub struct CampaignOptions {
    /// Random instances per cell (the paper uses 100).
    pub instances: usize,
    /// Instances per work unit; 0 = auto (instances/4, clamped to [1, 32]).
    /// Smaller blocks steal better; larger blocks amortize scenario setup.
    pub block: usize,
    /// Worker threads; 0 = all available cores.
    pub threads: usize,
}

impl Default for CampaignOptions {
    fn default() -> Self {
        CampaignOptions { instances: 100, block: 0, threads: 0 }
    }
}

impl CampaignOptions {
    fn block_size(&self) -> usize {
        if self.block > 0 {
            self.block.min(self.instances.max(1))
        } else {
            (self.instances / 4).clamp(1, 32)
        }
    }
}

/// Aggregated outcome of one executed cell.
#[derive(Clone, Debug)]
pub struct CellOutcome {
    pub cell: Cell,
    pub waste: Welford,
    pub makespan: Welford,
    /// Regular period the strategy used (s).
    pub tr: f64,
}

impl CellOutcome {
    /// The persisted form of this outcome.
    pub fn record(&self) -> CellRecord {
        CellRecord {
            hash: self.cell.hash,
            key: self.cell.key(),
            instances: self.waste.len() as u64,
            waste_mean: self.waste.mean(),
            waste_var: self.waste.var(),
            waste_ci95: self.waste.ci95(),
            waste_min: self.waste.min(),
            waste_max: self.waste.max(),
            makespan_mean: self.makespan.mean(),
            tr: self.tr,
        }
    }
}

/// Per-cell in-flight state: one slot per instance block, merged in slot
/// order by whichever worker completes the last block.
struct CellState {
    slots: Vec<Option<(Welford, Welford)>>,
    remaining: usize,
    done: Option<CellOutcome>,
    /// The instantiated policy, memoized by whichever worker claims the
    /// cell's first block.  Analytic strategies are cheap to re-derive,
    /// but registry strategies may instantiate by *search* (the
    /// BestPeriod twins); memoizing keeps that cost per-cell, not
    /// per-block, and every block provably uses the same periods.
    policy: Option<Policy>,
}

/// Is `cell` already satisfactorily computed in `store`?  True when a
/// record exists with at least the requested instance count — resuming
/// with a larger `--instances` recomputes (and supersedes) cells stored at
/// lower precision instead of silently keeping them.
pub fn cell_complete(store: &Store, cell: &Cell, instances: usize) -> bool {
    store
        .get(cell.hash)
        .is_some_and(|rec| rec.instances >= instances.max(1) as u64)
}

/// Execute `cells` through the work-stealing pool.
///
/// Cells already computed in `store` with enough instances are skipped
/// (resume; see [`cell_complete`]), and duplicate-hash cells (e.g. a
/// repeated CLI axis value expanding the same scenario twice) are executed
/// once — later duplicates count as skipped.  Each newly completed cell is
/// appended to `store` (and flushed) the moment its last instance block
/// lands; an append failure (disk full, permissions) aborts with that
/// error after the in-flight units drain.  Returns the newly computed
/// outcomes in (deduplicated) cell order plus the number of skipped cells.
pub fn run_cells(
    cells: &[Cell],
    opt: &CampaignOptions,
    store: Option<&mut Store>,
) -> Result<(Vec<CellOutcome>, usize)> {
    let instances = opt.instances.max(1);
    let block = opt.block_size();
    let blocks_per_cell = instances.div_ceil(block);

    let mut seen = std::collections::BTreeSet::new();
    let pending: Vec<usize> = (0..cells.len())
        .filter(|&i| {
            seen.insert(cells[i].hash)
                && store
                    .as_ref()
                    .map_or(true, |s| !cell_complete(s, &cells[i], instances))
        })
        .collect();
    let skipped = cells.len() - pending.len();
    if pending.is_empty() {
        return Ok((Vec::new(), skipped));
    }

    let states: Vec<Mutex<CellState>> = pending
        .iter()
        .map(|_| {
            Mutex::new(CellState {
                slots: vec![None; blocks_per_cell],
                remaining: blocks_per_cell,
                done: None,
                policy: None,
            })
        })
        .collect();
    let store_mx = store.map(Mutex::new);
    let append_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

    let n_units = pending.len() * blocks_per_cell;
    // Each worker owns a TracePool: the strategy variants of a scenario
    // (and any other unit sharing scenario_hash + seed that lands on this
    // worker) replay one memoized trace instead of regenerating it.  Hits
    // only change speed, never values, so determinism is preserved.
    scheduler::run_units_stateful(n_units, opt.threads, TracePool::new, |tp: &mut TracePool, u| {
        let (ci, bi) = (u / blocks_per_cell, u % blocks_per_cell);
        let cell = &cells[pending[ci]];
        let sc = cell.scenario();
        let pol = {
            let mut st = states[ci].lock().expect("cell state poisoned");
            match st.policy {
                Some(p) => p,
                None => {
                    // Instantiation may search (BestPeriod twins); sibling
                    // blocks of this cell wait on the lock — they need the
                    // policy anyway — while other cells' units proceed.
                    let p = cell.strategy.policy(&sc);
                    st.policy = Some(p);
                    p
                }
            }
        };
        let mut waste = Welford::new();
        let mut makespan = Welford::new();
        for i in (bi * block)..((bi + 1) * block).min(instances) {
            let seed = cell.instance_seed(i as u64);
            let out = simulate_from_capped(
                &sc,
                &pol,
                1.0,
                seed,
                tp.replay(cell.scenario_hash, &sc, seed),
                f64::INFINITY,
            );
            waste.push(out.waste());
            makespan.push(out.makespan);
        }
        let mut st = states[ci].lock().expect("cell state poisoned");
        st.slots[bi] = Some((waste, makespan));
        st.remaining -= 1;
        if st.remaining == 0 {
            // Merge partials in block order — deterministic for any thread
            // count and any completion order.
            let mut waste = Welford::new();
            let mut makespan = Welford::new();
            for slot in st.slots.drain(..) {
                let (w, m) = slot.expect("all blocks complete");
                waste.merge(&w);
                makespan.merge(&m);
            }
            let outcome = CellOutcome { cell: cell.clone(), waste, makespan, tr: pol.tr };
            if let Some(mx) = &store_mx {
                let mut s = mx.lock().expect("store poisoned");
                if let Err(e) = s.append(&outcome.record()) {
                    let mut slot = append_err.lock().expect("append_err poisoned");
                    if slot.is_none() {
                        *slot = Some(e.context(format!(
                            "persisting cell {:016x}",
                            outcome.cell.hash
                        )));
                    }
                }
            }
            st.done = Some(outcome);
        }
    });

    if let Some(e) = append_err.into_inner().expect("append_err poisoned") {
        return Err(e);
    }
    let outcomes = states
        .into_iter()
        .map(|st| {
            st.into_inner()
                .expect("cell state poisoned")
                .done
                .expect("cell completed")
        })
        .collect();
    Ok((outcomes, skipped))
}

/// Expand and execute a grid without a store (in-memory sweep); outcomes in
/// grid expansion order.
pub fn evaluate_grid(g: &Grid, opt: &CampaignOptions) -> Vec<CellOutcome> {
    run_cells(&g.expand(), opt, None)
        .expect("in-memory campaign has no store to fail")
        .0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::registry;

    fn tiny_grid() -> Grid {
        let mut g = Grid::smoke();
        g.procs = vec![1 << 16];
        g.windows = vec![600.0];
        g.scale = 0.02;
        g.strategies = vec![
            registry::get("RFO").unwrap(),
            registry::get("NoCkptI").unwrap(),
        ];
        g
    }

    #[test]
    fn outcomes_follow_expansion_order() {
        let g = tiny_grid();
        let opt = CampaignOptions { instances: 3, block: 2, threads: 2 };
        let outcomes = evaluate_grid(&g, &opt);
        let cells = g.expand();
        assert_eq!(outcomes.len(), cells.len());
        for (o, c) in outcomes.iter().zip(&cells) {
            assert_eq!(o.cell.hash, c.hash);
            assert_eq!(o.waste.len(), 3);
            assert!(o.waste.mean() > 0.0 && o.waste.mean() < 1.0);
            assert!(o.makespan.mean() > 0.0);
            assert!(o.tr > 0.0);
        }
    }

    #[test]
    fn thread_count_does_not_change_aggregates() {
        let g = tiny_grid();
        for block in [1, 2, 5] {
            let serial = evaluate_grid(
                &g,
                &CampaignOptions { instances: 5, block, threads: 1 },
            );
            let parallel = evaluate_grid(
                &g,
                &CampaignOptions { instances: 5, block, threads: 8 },
            );
            for (a, b) in serial.iter().zip(&parallel) {
                assert_eq!(a.waste, b.waste, "cell {}", a.cell.key());
                assert_eq!(a.makespan, b.makespan);
            }
        }
    }

    #[test]
    fn duplicate_cells_run_once() {
        let g = tiny_grid();
        let cells = g.expand();
        // Expand the same grid twice into one list: every cell duplicated.
        let mut doubled = cells.clone();
        doubled.extend(cells.iter().cloned());
        let opt = CampaignOptions { instances: 2, block: 1, threads: 2 };
        let (outcomes, skipped) = run_cells(&doubled, &opt, None).unwrap();
        assert_eq!(outcomes.len(), cells.len());
        assert_eq!(skipped, cells.len());
    }

    #[test]
    fn pooled_execution_matches_direct_simulation() {
        // The TracePool replay path must be bit-identical to running each
        // instance through a fresh stream, including the block-ordered
        // Welford merge.
        let g = tiny_grid();
        let (instances, block) = (3usize, 2usize);
        let opt = CampaignOptions { instances, block, threads: 4 };
        let outcomes = evaluate_grid(&g, &opt);
        for o in &outcomes {
            let sc = o.cell.scenario();
            let pol = o.cell.strategy.policy(&sc);
            let mut waste = Welford::new();
            for b in 0..instances.div_ceil(block) {
                let mut part = Welford::new();
                for i in (b * block)..((b + 1) * block).min(instances) {
                    let out = crate::sim::engine::simulate(
                        &sc,
                        &pol,
                        o.cell.instance_seed(i as u64),
                    );
                    part.push(out.waste());
                }
                waste.merge(&part);
            }
            assert_eq!(o.waste, waste, "cell {}", o.cell.key());
        }
    }

    #[test]
    fn block_partition_covers_all_instances() {
        let g = tiny_grid();
        // 7 instances in blocks of 3: 3 + 3 + 1.
        let opt = CampaignOptions { instances: 7, block: 3, threads: 4 };
        for o in evaluate_grid(&g, &opt) {
            assert_eq!(o.waste.len(), 7);
            assert_eq!(o.makespan.len(), 7);
        }
    }
}
