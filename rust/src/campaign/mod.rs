//! Campaign engine: declarative scenario grids, work-stealing execution,
//! streaming aggregation, and resumable on-disk checkpoints.
//!
//! The paper validates its analytic model with a large cross-product of
//! simulated scenarios (Figures 2–21: platform sizes × C_p ratios × fault
//! laws × predictors × window sizes × strategies).  This module turns that
//! cross-product into a first-class object:
//!
//! ```text
//!   Grid ──expand──▶ [Cell; N] ──(cell × instance-block units)──▶
//!     scheduler::run_units_stateful (shared atomic work queue, scoped
//!         threads, one TracePool per worker)
//!       each unit: replay the (scenario, seed) trace from the worker's
//!         pool — generated once, shared by every strategy variant — and
//!         simulate a block of instances → Welford partials
//!     last unit of a cell: merge partials IN BLOCK ORDER (deterministic)
//!       ──▶ CellOutcome ──append──▶ Store (JSONL keyed by scenario hash)
//! ```
//!
//! * **Determinism** — cell hashes and per-instance seeds derive from the
//!   cell parameters alone; partial aggregates merge in block order, so any
//!   thread count (including 1) produces bit-identical per-cell results.
//! * **Streaming** — memory is O(cells), never O(cells × instances):
//!   instances fold into constant-size [`Welford`] accumulators as they
//!   finish.
//! * **Resumability** — completed cells land in the [`Store`] immediately;
//!   [`run_cells`] skips cells whose hash the store already holds, so an
//!   interrupted campaign recomputes only what is missing.
//!
//! The harness figure/table runners drive their grids through this engine
//! (`harness::figures`, `harness::tables`), and the `campaign` CLI
//! subcommand (run / resume / report) exposes it directly.

pub mod grid;
pub mod overrides;
pub mod pool;
pub mod scheduler;
pub mod store;

pub use crate::predictor::registry::PredictorId;
pub use grid::{Cell, Grid};
pub use pool::TracePool;
pub use store::{CellRecord, Store};

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::obs::SpanTimer;
use crate::sim::engine::simulate_from_capped;
use crate::stats::Welford;
use crate::strategy::Policy;

/// Execution knobs for a campaign.
#[derive(Clone, Copy, Debug)]
pub struct CampaignOptions {
    /// Random instances per cell (the paper uses 100).
    pub instances: usize,
    /// Instances per work unit; 0 = auto (instances/4, clamped to [1, 32]).
    /// Smaller blocks steal better; larger blocks amortize scenario setup.
    pub block: usize,
    /// Worker threads; 0 = all available cores.
    pub threads: usize,
}

impl Default for CampaignOptions {
    fn default() -> Self {
        CampaignOptions { instances: 100, block: 0, threads: 0 }
    }
}

impl CampaignOptions {
    fn block_size(&self) -> usize {
        if self.block > 0 {
            self.block.min(self.instances.max(1))
        } else {
            (self.instances / 4).clamp(1, 32)
        }
    }
}

/// Aggregated outcome of one executed cell.
#[derive(Clone, Debug)]
pub struct CellOutcome {
    pub cell: Cell,
    pub waste: Welford,
    pub makespan: Welford,
    /// Regular period the strategy used (s).
    pub tr: f64,
}

impl CellOutcome {
    /// The persisted form of this outcome.
    pub fn record(&self) -> CellRecord {
        CellRecord {
            hash: self.cell.hash,
            key: self.cell.key(),
            instances: self.waste.len() as u64,
            waste_mean: self.waste.mean(),
            waste_var: self.waste.var(),
            waste_ci95: self.waste.ci95(),
            waste_min: self.waste.min(),
            waste_max: self.waste.max(),
            makespan_mean: self.makespan.mean(),
            tr: self.tr,
        }
    }
}

/// Throughput telemetry of one campaign execution ([`run_cells_metered`]).
///
/// Gathered lock-free: workers bump relaxed atomics once per *unit* (an
/// instance block), never per event, and per-worker [`TracePool`] stats
/// are folded in as deltas at unit boundaries — the simulation hot path
/// is untouched.
#[derive(Clone, Copy, Debug, Default)]
pub struct CampaignMetrics {
    /// Cells newly computed (skipped/resumed cells excluded).
    pub cells: usize,
    /// Simulation instances executed.
    pub instances: u64,
    /// Trace events consumed across all simulations.
    pub sim_events: u64,
    /// Wall-clock seconds of the execution phase.
    pub elapsed_secs: f64,
    pub pool_hits: u64,
    pub pool_misses: u64,
    pub pool_evictions: u64,
    /// Failure times popped off per-processor timer wheels (0 when no cell
    /// runs a per-proc Weibull superposition).
    pub wheel_pops: u64,
    /// Empty wheel buckets scanned while seeking the next failure — the
    /// amortized-cost driver (healthy: a few per pop).
    pub wheel_bucket_scans: u64,
    /// Wheel items promoted down a level or redistributed on a rebase.
    pub wheel_overflow_promotions: u64,
    /// Head merges performed by sharded platform sources (0 without a
    /// shards ≠ 1 cell).
    pub shard_merges: u64,
}

impl CampaignMetrics {
    pub fn cells_per_sec(&self) -> f64 {
        if self.elapsed_secs > 0.0 {
            self.cells as f64 / self.elapsed_secs
        } else {
            0.0
        }
    }

    pub fn events_per_sec(&self) -> f64 {
        if self.elapsed_secs > 0.0 {
            self.sim_events as f64 / self.elapsed_secs
        } else {
            0.0
        }
    }

    /// Trace-pool hit rate in [0, 1] (0 when the pool was never asked).
    pub fn pool_hit_rate(&self) -> f64 {
        let asked = self.pool_hits + self.pool_misses;
        if asked > 0 {
            self.pool_hits as f64 / asked as f64
        } else {
            0.0
        }
    }
}

/// Lock-free progress/throughput accumulators shared by the workers.
#[derive(Default)]
struct Meter {
    units_done: AtomicUsize,
    cells_done: AtomicUsize,
    instances: AtomicU64,
    sim_events: AtomicU64,
    pool_hits: AtomicU64,
    pool_misses: AtomicU64,
    pool_evictions: AtomicU64,
    wheel_pops: AtomicU64,
    wheel_bucket_scans: AtomicU64,
    wheel_overflow_promotions: AtomicU64,
    shard_merges: AtomicU64,
}

/// Per-worker scratch: the trace pool plus the pool-stat watermarks
/// already folded into the [`Meter`] (stats are cumulative; workers
/// report deltas at unit boundaries).
struct WorkerState {
    tp: TracePool,
    seen_hits: u64,
    seen_misses: u64,
    seen_evictions: u64,
    /// Watermarks of the pool's wheel counters already reported:
    /// (pops, bucket scans, overflow promotions, shard merges).
    seen_wheel: (u64, u64, u64, u64),
}

impl WorkerState {
    fn new() -> WorkerState {
        WorkerState {
            tp: TracePool::new(),
            seen_hits: 0,
            seen_misses: 0,
            seen_evictions: 0,
            seen_wheel: (0, 0, 0, 0),
        }
    }

    fn flush_pool_stats(&mut self, meter: &Meter) {
        let (h, m, e) = (self.tp.hits(), self.tp.misses(), self.tp.evictions());
        meter.pool_hits.fetch_add(h - self.seen_hits, Ordering::Relaxed);
        meter.pool_misses.fetch_add(m - self.seen_misses, Ordering::Relaxed);
        meter
            .pool_evictions
            .fetch_add(e - self.seen_evictions, Ordering::Relaxed);
        (self.seen_hits, self.seen_misses, self.seen_evictions) = (h, m, e);
        // Wheel counters live in the cached traces, which budget clears
        // evict wholesale — the cumulative view can shrink.  Clamp the
        // delta and re-anchor the watermark (evicted-but-unreported work
        // is dropped rather than double-counted).
        let w = self
            .tp
            .wheel_stats()
            .map(|(s, merges)| (s.pops, s.bucket_scans, s.overflow_promotions, merges))
            .unwrap_or_default();
        meter
            .wheel_pops
            .fetch_add(w.0.saturating_sub(self.seen_wheel.0), Ordering::Relaxed);
        meter
            .wheel_bucket_scans
            .fetch_add(w.1.saturating_sub(self.seen_wheel.1), Ordering::Relaxed);
        meter
            .wheel_overflow_promotions
            .fetch_add(w.2.saturating_sub(self.seen_wheel.2), Ordering::Relaxed);
        meter
            .shard_merges
            .fetch_add(w.3.saturating_sub(self.seen_wheel.3), Ordering::Relaxed);
        self.seen_wheel = w;
    }
}

/// Per-cell in-flight state: one slot per instance block, merged in slot
/// order by whichever worker completes the last block.
struct CellState {
    slots: Vec<Option<(Welford, Welford)>>,
    remaining: usize,
    done: Option<CellOutcome>,
    /// The instantiated policy, memoized by whichever worker claims the
    /// cell's first block.  Analytic strategies are cheap to re-derive,
    /// but registry strategies may instantiate by *search* (the
    /// BestPeriod twins); memoizing keeps that cost per-cell, not
    /// per-block, and every block provably uses the same periods.
    policy: Option<Policy>,
}

/// Is `cell` already satisfactorily computed in `store`?  True when a
/// record exists with at least the requested instance count — resuming
/// with a larger `--instances` recomputes (and supersedes) cells stored at
/// lower precision instead of silently keeping them.
pub fn cell_complete(store: &Store, cell: &Cell, instances: usize) -> bool {
    store
        .get(cell.hash)
        .is_some_and(|rec| rec.instances >= instances.max(1) as u64)
}

/// Execute `cells` through the work-stealing pool.
///
/// Cells already computed in `store` with enough instances are skipped
/// (resume; see [`cell_complete`]), and duplicate-hash cells (e.g. a
/// repeated CLI axis value expanding the same scenario twice) are executed
/// once — later duplicates count as skipped.  Each newly completed cell is
/// appended to `store` (and flushed) the moment its last instance block
/// lands; an append failure (disk full, permissions) aborts with that
/// error after the in-flight units drain.  Returns the newly computed
/// outcomes in (deduplicated) cell order plus the number of skipped cells.
pub fn run_cells(
    cells: &[Cell],
    opt: &CampaignOptions,
    store: Option<&mut Store>,
) -> Result<(Vec<CellOutcome>, usize)> {
    let (outcomes, skipped, _) = run_cells_metered(cells, opt, store, false)?;
    Ok((outcomes, skipped))
}

/// [`run_cells`] plus throughput telemetry, and (optionally) a stderr
/// heartbeat: a monitor thread that prints progress, rates and an ETA
/// every couple of seconds while the workers grind.  The heartbeat is
/// meant for interactive CLI runs — library callers pass `false`.
///
/// Worker panics are contained and retried (up to 2 requeues per unit);
/// a cell that still cannot complete surfaces as an error naming the
/// degraded cells — callers that want the partial results instead use
/// [`run_cells_contained`].
pub fn run_cells_metered(
    cells: &[Cell],
    opt: &CampaignOptions,
    store: Option<&mut Store>,
    heartbeat: bool,
) -> Result<(Vec<CellOutcome>, usize, CampaignMetrics)> {
    let run = run_cells_contained(cells, opt, store, heartbeat, 2)?;
    if !run.degraded.is_empty() {
        let keys: Vec<&str> =
            run.degraded.iter().map(|d| d.key.as_str()).collect();
        bail!(
            "{} cell(s) degraded after contained worker panics: {}",
            run.degraded.len(),
            keys.join(", ")
        );
    }
    Ok((run.outcomes, run.skipped, run.metrics))
}

/// A cell that lost at least one work unit to a contained worker panic
/// (after the per-unit retry budget); absent from the outcome list and
/// from the store.
#[derive(Clone, Debug)]
pub struct DegradedCell {
    pub hash: u64,
    pub key: String,
    /// The exhausted unit failures mapped to this cell.
    pub failures: Vec<scheduler::UnitFailure>,
}

/// Outcome of a contained campaign execution ([`run_cells_contained`]).
#[derive(Debug)]
pub struct CampaignRun {
    /// Completed cells, in (deduplicated) cell order.
    pub outcomes: Vec<CellOutcome>,
    /// Cells skipped (already satisfactorily in the store, or duplicates).
    pub skipped: usize,
    pub metrics: CampaignMetrics,
    /// Cells that could not complete — the degraded manifest.
    pub degraded: Vec<DegradedCell>,
}

/// The containment-aware core of the campaign engine: like
/// [`run_cells_metered`], but a worker panic (including injected
/// `sched.worker` / `pool.insert` faults) only costs the unit in flight —
/// the unit is requeued up to `unit_retries` times, and cells that still
/// cannot complete are returned in the degraded manifest instead of
/// poisoning the run.
pub fn run_cells_contained(
    cells: &[Cell],
    opt: &CampaignOptions,
    store: Option<&mut Store>,
    heartbeat: bool,
    unit_retries: u32,
) -> Result<CampaignRun> {
    let instances = opt.instances.max(1);
    let block = opt.block_size();
    let blocks_per_cell = instances.div_ceil(block);

    let mut seen = std::collections::BTreeSet::new();
    let pending: Vec<usize> = (0..cells.len())
        .filter(|&i| {
            seen.insert(cells[i].hash)
                && store
                    .as_ref()
                    .map_or(true, |s| !cell_complete(s, &cells[i], instances))
        })
        .collect();
    let skipped = cells.len() - pending.len();
    if pending.is_empty() {
        return Ok(CampaignRun {
            outcomes: Vec::new(),
            skipped,
            metrics: CampaignMetrics::default(),
            degraded: Vec::new(),
        });
    }

    let states: Vec<Mutex<CellState>> = pending
        .iter()
        .map(|_| {
            Mutex::new(CellState {
                slots: vec![None; blocks_per_cell],
                remaining: blocks_per_cell,
                done: None,
                policy: None,
            })
        })
        .collect();
    let store_mx = store.map(Mutex::new);
    let append_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

    let n_units = pending.len() * blocks_per_cell;
    let meter = Meter::default();
    let finished = AtomicBool::new(false);
    let timer = SpanTimer::start();
    // Each worker owns a TracePool: the strategy variants of a scenario
    // (and any other unit sharing scenario_hash + seed that lands on this
    // worker) replay one memoized trace instead of regenerating it.  Hits
    // only change speed, never values, so determinism is preserved.
    let unit = |ws: &mut WorkerState, u: usize| {
        let (ci, bi) = (u / blocks_per_cell, u % blocks_per_cell);
        let cell = &cells[pending[ci]];
        let sc = cell.scenario();
        let pol = {
            // Contained panics can poison cell-state mutexes; every update
            // under them is transactional (slot writes, counter moves), so
            // recovering the inner value is sound.
            let mut st = states[ci].lock().unwrap_or_else(|e| e.into_inner());
            match st.policy {
                Some(p) => p,
                None => {
                    // Instantiation may search (BestPeriod twins); sibling
                    // blocks of this cell wait on the lock — they need the
                    // policy anyway — while other cells' units proceed.
                    let p = cell.strategy.policy(&sc);
                    st.policy = Some(p);
                    p
                }
            }
        };
        let mut waste = Welford::new();
        let mut makespan = Welford::new();
        let mut events: u64 = 0;
        let mut sims: u64 = 0;
        for i in (bi * block)..((bi + 1) * block).min(instances) {
            let seed = cell.instance_seed(i as u64);
            let out = simulate_from_capped(
                &sc,
                &pol,
                1.0,
                seed,
                // The cell's shard count shapes the trace (shards ≠ 1 is
                // part of scenario_hash, so the memo key separates too).
                ws.tp.replay_sharded(cell.scenario_hash, &sc, seed, cell.shards),
                f64::INFINITY,
            );
            waste.push(out.waste());
            makespan.push(out.makespan);
            events += out.events;
            sims += 1;
        }
        // One batch of relaxed bumps per unit, after the simulation work.
        meter.sim_events.fetch_add(events, Ordering::Relaxed);
        meter.instances.fetch_add(sims, Ordering::Relaxed);
        meter.units_done.fetch_add(1, Ordering::Relaxed);
        ws.flush_pool_stats(&meter);
        let mut st = states[ci].lock().unwrap_or_else(|e| e.into_inner());
        st.slots[bi] = Some((waste, makespan));
        st.remaining -= 1;
        if st.remaining == 0 {
            // Merge partials in block order — deterministic for any thread
            // count and any completion order.
            let mut waste = Welford::new();
            let mut makespan = Welford::new();
            for slot in st.slots.drain(..) {
                let (w, m) = slot.expect("all blocks complete");
                waste.merge(&w);
                makespan.merge(&m);
            }
            let outcome = CellOutcome { cell: cell.clone(), waste, makespan, tr: pol.tr };
            if let Some(mx) = &store_mx {
                let mut s = mx.lock().unwrap_or_else(|e| e.into_inner());
                if let Err(e) = s.append(&outcome.record()) {
                    let mut slot =
                        append_err.lock().unwrap_or_else(|e| e.into_inner());
                    if slot.is_none() {
                        *slot = Some(e.context(format!(
                            "persisting cell {:016x}",
                            outcome.cell.hash
                        )));
                    }
                }
            }
            st.done = Some(outcome);
            meter.cells_done.fetch_add(1, Ordering::Relaxed);
        }
    };
    let contained = std::thread::scope(|s| {
        if heartbeat {
            s.spawn(|| heartbeat_loop(&meter, &finished, n_units, pending.len(), &timer));
        }
        let run = scheduler::run_units_contained(
            n_units,
            opt.threads,
            unit_retries,
            WorkerState::new,
            unit,
        );
        finished.store(true, Ordering::Relaxed);
        run
    });
    let metrics = CampaignMetrics {
        cells: pending.len(),
        instances: meter.instances.load(Ordering::Relaxed),
        sim_events: meter.sim_events.load(Ordering::Relaxed),
        elapsed_secs: timer.elapsed_secs(),
        pool_hits: meter.pool_hits.load(Ordering::Relaxed),
        pool_misses: meter.pool_misses.load(Ordering::Relaxed),
        pool_evictions: meter.pool_evictions.load(Ordering::Relaxed),
        wheel_pops: meter.wheel_pops.load(Ordering::Relaxed),
        wheel_bucket_scans: meter.wheel_bucket_scans.load(Ordering::Relaxed),
        wheel_overflow_promotions: meter
            .wheel_overflow_promotions
            .load(Ordering::Relaxed),
        shard_merges: meter.shard_merges.load(Ordering::Relaxed),
    };

    if let Some(e) = append_err.into_inner().unwrap_or_else(|e| e.into_inner()) {
        return Err(e);
    }
    // Map exhausted unit failures back to their cells: any cell missing
    // its outcome must own at least one failed unit.
    let mut failures_by_cell: std::collections::BTreeMap<
        usize,
        Vec<scheduler::UnitFailure>,
    > = std::collections::BTreeMap::new();
    for f in contained.failures {
        failures_by_cell.entry(f.unit / blocks_per_cell).or_default().push(f);
    }
    let mut outcomes = Vec::new();
    let mut degraded = Vec::new();
    for (ci, st) in states.into_iter().enumerate() {
        let st = st.into_inner().unwrap_or_else(|e| e.into_inner());
        match st.done {
            Some(o) => outcomes.push(o),
            None => {
                let cell = &cells[pending[ci]];
                degraded.push(DegradedCell {
                    hash: cell.hash,
                    key: cell.key(),
                    failures: failures_by_cell.remove(&ci).unwrap_or_default(),
                });
            }
        }
    }
    Ok(CampaignRun { outcomes, skipped, metrics, degraded })
}

/// The heartbeat monitor: wake every ~2 s, print progress + ETA to stderr,
/// exit within one period of the workers draining the queue.
fn heartbeat_loop(
    meter: &Meter,
    finished: &AtomicBool,
    n_units: usize,
    n_cells: usize,
    timer: &SpanTimer,
) {
    loop {
        std::thread::sleep(Duration::from_millis(2000));
        if finished.load(Ordering::Relaxed) {
            return;
        }
        let done = meter.units_done.load(Ordering::Relaxed);
        let elapsed = timer.elapsed_secs();
        let eta = if done > 0 {
            elapsed / done as f64 * (n_units - done) as f64
        } else {
            f64::NAN
        };
        let events = meter.sim_events.load(Ordering::Relaxed);
        eprintln!(
            "[campaign] {done}/{n_units} units, {}/{} cells, {:.0} events/s, ETA {:.0}s",
            meter.cells_done.load(Ordering::Relaxed),
            n_cells,
            events as f64 / elapsed.max(1e-9),
            eta,
        );
    }
}

/// Expand and execute a grid without a store (in-memory sweep); outcomes in
/// grid expansion order.
pub fn evaluate_grid(g: &Grid, opt: &CampaignOptions) -> Vec<CellOutcome> {
    run_cells(&g.expand(), opt, None)
        .expect("in-memory campaign has no store to fail")
        .0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::registry;

    fn tiny_grid() -> Grid {
        let mut g = Grid::smoke();
        g.procs = vec![1 << 16];
        g.windows = vec![600.0];
        g.scale = 0.02;
        g.strategies = vec![
            registry::get("RFO").unwrap(),
            registry::get("NoCkptI").unwrap(),
        ];
        g
    }

    #[test]
    fn outcomes_follow_expansion_order() {
        let g = tiny_grid();
        let opt = CampaignOptions { instances: 3, block: 2, threads: 2 };
        let outcomes = evaluate_grid(&g, &opt);
        let cells = g.expand();
        assert_eq!(outcomes.len(), cells.len());
        for (o, c) in outcomes.iter().zip(&cells) {
            assert_eq!(o.cell.hash, c.hash);
            assert_eq!(o.waste.len(), 3);
            assert!(o.waste.mean() > 0.0 && o.waste.mean() < 1.0);
            assert!(o.makespan.mean() > 0.0);
            assert!(o.tr > 0.0);
        }
    }

    #[test]
    fn thread_count_does_not_change_aggregates() {
        let g = tiny_grid();
        for block in [1, 2, 5] {
            let serial = evaluate_grid(
                &g,
                &CampaignOptions { instances: 5, block, threads: 1 },
            );
            let parallel = evaluate_grid(
                &g,
                &CampaignOptions { instances: 5, block, threads: 8 },
            );
            for (a, b) in serial.iter().zip(&parallel) {
                assert_eq!(a.waste, b.waste, "cell {}", a.cell.key());
                assert_eq!(a.makespan, b.makespan);
            }
        }
    }

    #[test]
    fn duplicate_cells_run_once() {
        let g = tiny_grid();
        let cells = g.expand();
        // Expand the same grid twice into one list: every cell duplicated.
        let mut doubled = cells.clone();
        doubled.extend(cells.iter().cloned());
        let opt = CampaignOptions { instances: 2, block: 1, threads: 2 };
        let (outcomes, skipped) = run_cells(&doubled, &opt, None).unwrap();
        assert_eq!(outcomes.len(), cells.len());
        assert_eq!(skipped, cells.len());
    }

    #[test]
    fn pooled_execution_matches_direct_simulation() {
        // The TracePool replay path must be bit-identical to running each
        // instance through a fresh stream, including the block-ordered
        // Welford merge.
        let g = tiny_grid();
        let (instances, block) = (3usize, 2usize);
        let opt = CampaignOptions { instances, block, threads: 4 };
        let outcomes = evaluate_grid(&g, &opt);
        for o in &outcomes {
            let sc = o.cell.scenario();
            let pol = o.cell.strategy.policy(&sc);
            let mut waste = Welford::new();
            for b in 0..instances.div_ceil(block) {
                let mut part = Welford::new();
                for i in (b * block)..((b + 1) * block).min(instances) {
                    let out = crate::sim::engine::simulate(
                        &sc,
                        &pol,
                        o.cell.instance_seed(i as u64),
                    );
                    part.push(out.waste());
                }
                waste.merge(&part);
            }
            assert_eq!(o.waste, waste, "cell {}", o.cell.key());
        }
    }

    #[test]
    fn metered_run_matches_plain_run_and_counts_everything() {
        let g = tiny_grid();
        let cells = g.expand();
        let opt = CampaignOptions { instances: 4, block: 2, threads: 3 };
        let (plain, _) = run_cells(&cells, &opt, None).unwrap();
        let (metered, skipped, m) =
            run_cells_metered(&cells, &opt, None, false).unwrap();
        assert_eq!(skipped, 0);
        // Telemetry is passive: aggregates are bit-identical.
        for (a, b) in plain.iter().zip(&metered) {
            assert_eq!(a.waste, b.waste, "cell {}", a.cell.key());
            assert_eq!(a.makespan, b.makespan);
        }
        assert_eq!(m.cells, cells.len());
        assert_eq!(m.instances, (cells.len() * 4) as u64);
        // Every simulation consumes at least one trace event, and the pool
        // was consulted once per instance.
        assert!(m.sim_events >= m.instances);
        assert_eq!(m.pool_hits + m.pool_misses, m.instances);
        assert!((0.0..=1.0).contains(&m.pool_hit_rate()));
        assert!(m.elapsed_secs >= 0.0);
        // Nothing ran => empty metrics.
        let (_, _, m2) = run_cells_metered(&[], &opt, None, false).unwrap();
        assert_eq!(m2.instances, 0);
        assert_eq!(m2.events_per_sec(), 0.0);
        assert_eq!(m2.pool_hit_rate(), 0.0);
    }

    #[test]
    fn block_partition_covers_all_instances() {
        let g = tiny_grid();
        // 7 instances in blocks of 3: 3 + 3 + 1.
        let opt = CampaignOptions { instances: 7, block: 3, threads: 4 };
        for o in evaluate_grid(&g, &opt) {
            assert_eq!(o.waste.len(), 7);
            assert_eq!(o.makespan.len(), 7);
        }
    }
}
